"""Layer-1 Pallas kernels for UnIT + baselines, with pure-jnp oracles."""

from .fatrelu import fatrelu
from .ref import (
    fatrelu_ref,
    maxpool2x2_ref,
    unit_conv2d_kept_ref,
    unit_conv2d_ref,
    unit_linear_kept_ref,
    unit_linear_ref,
)
from .unit_conv import unit_conv2d
from .unit_linear import unit_linear

__all__ = [
    "fatrelu",
    "fatrelu_ref",
    "maxpool2x2_ref",
    "unit_conv2d",
    "unit_conv2d_kept_ref",
    "unit_conv2d_ref",
    "unit_linear",
    "unit_linear_kept_ref",
    "unit_linear_ref",
]
