"""Layer-1 Pallas kernel: FATReLU baseline (Kurtz et al. 2020).

FATReLU ("forced-activation-threshold" ReLU, a.k.a. truncated rectifier) is
the inference-time pruning baseline the paper compares against: raising the
ReLU cut-off induces extra activation sparsity at runtime, zeroing small
positive activations so downstream MACs on them can be skipped.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, t_ref, y_ref):
    x = x_ref[...]
    t = t_ref[0]
    y_ref[...] = jnp.where(x > t, x, 0.0)


@jax.jit
def fatrelu(x, t):
    """Elementwise ``x if x > t else 0`` for any-rank float32 ``x``."""
    shape = x.shape
    flat = x.reshape(-1)
    t_arr = jnp.asarray(t, jnp.float32).reshape(1)
    y = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat, t_arr)
    return y.reshape(shape)
