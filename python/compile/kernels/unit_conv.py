"""Layer-1 Pallas kernel: UnIT-pruned valid 2-D convolution (paper Eq. 3).

In convolution the *weights* are the reused operand: each kernel tap
``W[o, c, u, v]`` multiplies every spatial position of the input. The
paper therefore inverts the comparison of Eq. 2 and computes
``w_bar[o, c, u, v] = T / |W[o, c, u, v]|`` once per tap, reusing it across
all ``OH × OW`` positions — one division amortized over the whole feature
map.

TPU mapping: the grid is ``(B, O)`` — one program materializes one output
channel of one sample. The ``C × KH × KW`` tap thresholds are a tiny
VMEM-resident table (for Table-1 models ≤ 96·64·9 taps); the inner body is
``KH·KW`` shifted dense multiply-accumulates over ``(C, OH, OW)`` tiles,
which XLA maps onto the vector unit. The pruning mask costs one compare per
contribution — exactly the paper's compare-instead-of-multiply trade,
expressed as a vectorized select.

``interpret=True``: see unit_linear.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS


def _kernel(x_ref, w_ref, b_ref, t_ref, y_ref, *, kh: int, kw: int, oh: int, ow: int):
    """One (sample, output-channel) grid step."""
    x = x_ref[0]  # (C, H, W)
    w = w_ref[0]  # (C, KH, KW) taps of this output channel
    t = t_ref[0, 0]

    absw = jnp.abs(w)
    # Reuse-aware threshold: one reciprocal per tap, reused across OH*OW
    # spatial positions (Eq. 3).
    w_bar = jnp.where(absw > EPS, t / jnp.maximum(absw, EPS), jnp.inf)

    acc = jnp.zeros((oh, ow), jnp.float32)
    # KH*KW is tiny (9..36 for Table-1 models): unroll at trace time. Each
    # iteration is a dense (C, OH, OW) masked multiply-accumulate.
    for u in range(kh):
        for v in range(kw):
            patch = jax.lax.dynamic_slice(
                x, (0, u, v), (x.shape[0], oh, ow)
            )  # (C, OH, OW)
            keep = jnp.abs(patch) > w_bar[:, u, v][:, None, None]
            tap = w[:, u, v][:, None, None]
            acc = acc + jnp.sum(patch * tap * keep, axis=0)

    y_ref[0, 0] = acc + b_ref[0]


@jax.jit
def unit_conv2d(x, w, b, t):
    """UnIT-pruned valid conv2d.

    Args:
      x: ``(B, C, H, W)`` activations.
      w: ``(O, C, KH, KW)`` kernel.
      b: ``(O,)`` bias.
      t: scalar threshold ``T`` (0 ⇒ dense numerics).

    Returns:
      ``(B, O, OH, OW)`` float32 with ``OH = H - KH + 1``, ``OW = W - KW + 1``.
    """
    bsz, c, h, wd = x.shape
    o, c2, kh, kw = w.shape
    assert c == c2, f"channel mismatch: {c} vs {c2}"
    oh, ow = h - kh + 1, wd - kw + 1
    t_arr = jnp.asarray(t, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, oh=oh, ow=ow),
        grid=(bsz, o),
        in_specs=[
            pl.BlockSpec((1, c, h, wd), lambda i, j: (i, 0, 0, 0)),  # sample
            pl.BlockSpec((1, c, kh, kw), lambda i, j: (j, 0, 0, 0)),  # channel taps
            pl.BlockSpec((1,), lambda i, j: (j,)),  # bias
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # threshold
        ],
        out_specs=pl.BlockSpec((1, 1, oh, ow), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, o, oh, ow), jnp.float32),
        interpret=True,
    )(x, w, b, t_arr)
