"""Layer-1 Pallas kernel: UnIT-pruned fully connected layer (paper Eq. 2).

The paper's insight for linear layers is that each input activation
``x[b, k]`` is *reused* across every output neuron ``j``, so the pruning
threshold ``t_bar[b, k] = T / |x[b, k]|`` is computed ONCE per activation
and amortized across the whole weight row ``W[k, :]``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): on a scalar MCU the win
is replacing a 77-cycle multiply with a 2-4 cycle compare; on a TPU the
same rank-1 separability means the mask over an ``(bn, M)`` weight tile
costs only ``bn`` reciprocals (one per activation row) living in VMEM,
reused across the entire tile — O(N) divisions for an O(N·M) mask. The
kernel tiles the contraction dimension N with BlockSpec so each weight tile
is streamed from HBM once and accumulated into the output block.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowering produces plain HLO that the Rust
runtime loads via the xla crate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (>= 1).

    Pallas blocks must tile the array exactly for the accumulation scheme
    below; model dims here are small enough that a divisor search is free.
    """
    best = 1
    for d in range(1, min(n, target) + 1):
        if n % d == 0:
            best = d
    return best


def _kernel(x_ref, w_ref, b_ref, t_ref, y_ref, *, nsteps: int):
    """One (sample, N-tile) grid step.

    Grid is ``(B, nsteps)``; the output block ``y_ref`` is revisited by all
    ``nsteps`` contraction steps of a given sample and accumulated in place
    (VMEM-resident between steps on real hardware).
    """
    step = pl.program_id(1)
    x = x_ref[0, :]  # (bn,) activation tile
    w = w_ref[...]  # (bn, M) weight tile
    t = t_ref[0, 0]

    absx = jnp.abs(x)
    # Reuse-aware threshold: one reciprocal per activation, reused across
    # the full weight row (M comparisons per division).
    t_bar = jnp.where(absx > EPS, t / jnp.maximum(absx, EPS), jnp.inf)
    keep = jnp.abs(w) > t_bar[:, None]  # (bn, M)
    partial = jnp.sum(x[:, None] * w * keep, axis=0)  # (M,)

    @pl.when(step == 0)
    def _init():
        y_ref[0, :] = partial + b_ref[...]

    @pl.when(step != 0)
    def _acc():
        y_ref[0, :] = y_ref[0, :] + partial


@functools.partial(jax.jit, static_argnames=("block_n",))
def unit_linear(x, w, b, t, block_n: int = 512):
    """UnIT-pruned linear layer: ``y[b] = (W ⊙ keep(x[b], T))ᵀ x[b] + bias``.

    Args:
      x: ``(B, N)`` activations.
      w: ``(N, M)`` weights.
      b: ``(M,)`` bias.
      t: scalar threshold ``T`` (0 ⇒ dense numerics).
      block_n: target contraction tile; rounded down to a divisor of N.

    Returns:
      ``(B, M)`` float32.
    """
    bsz, n = x.shape
    n2, m = w.shape
    assert n == n2, f"x/w contraction mismatch: {n} vs {n2}"
    bn = _pick_block(n, block_n)
    nsteps = n // bn
    t_arr = jnp.asarray(t, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        functools.partial(_kernel, nsteps=nsteps),
        grid=(bsz, nsteps),
        in_specs=[
            pl.BlockSpec((1, bn), lambda i, s: (i, s)),  # x tile
            pl.BlockSpec((bn, m), lambda i, s: (s, 0)),  # w tile
            pl.BlockSpec((m,), lambda i, s: (0,)),  # bias
            pl.BlockSpec((1, 1), lambda i, s: (0, 0)),  # threshold
        ],
        out_specs=pl.BlockSpec((1, m), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, m), jnp.float32),
        interpret=True,
    )(x, w, b, t_arr)
