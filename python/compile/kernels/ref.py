"""Pure-jnp reference oracles for the UnIT kernels.

These are the CORRECTNESS SIGNAL for the Pallas kernels (Layer 1): every
kernel in this package must match its oracle to float tolerance under the
pytest + hypothesis sweeps in ``python/tests/``.

The oracles implement the paper's equations directly and naively:

* Eq. 2 (linear layers): prune weight ``W[k, j]`` for sample ``b`` iff
  ``|W[k, j]| <= T / |x[b, k]|`` — the activation is the reused control
  term.
* Eq. 3 (conv layers): prune activation ``x[c, p+u, q+v]`` for output
  channel ``o`` iff ``|x| <= T / |W[o, c, u, v]|`` — the weight is the
  reused control term.
* FATReLU (baseline, Kurtz et al. 2020): ``y = x if x > t else 0``.

Skipping a MAC is numerically identical to zeroing its contribution, so the
oracles compute dense products with a mask.
"""

import jax.numpy as jnp

# A control term of exactly zero would divide by zero; the paper's MCU code
# never divides by zero because a zero activation/weight contributes nothing
# and is always skipped. We reproduce that: |c| < EPS ==> contribution
# pruned unconditionally (T / |c| -> +inf).
EPS = 1e-30


def unit_linear_ref(x, w, b, t):
    """UnIT-pruned fully connected layer (Eq. 2).

    Args:
      x: activations ``(B, N)``.
      w: weights ``(N, M)``.
      b: bias ``(M,)``.
      t: scalar layer threshold ``T`` (``T = 0`` keeps every connection
         whose weight and activation are non-zero — i.e. dense numerics).

    Returns:
      ``(B, M)`` output where each scalar MAC ``x[b,k] * w[k,j]`` is
      included iff ``|w[k,j]| > T / |x[b,k]|``.
    """
    absx = jnp.abs(x)  # (B, N)
    # Threshold relative to the reused activation: t_bar[b, k] = T / |x[b,k]|
    t_bar = jnp.where(absx > EPS, t / jnp.maximum(absx, EPS), jnp.inf)
    keep = jnp.abs(w)[None, :, :] > t_bar[:, :, None]  # (B, N, M)
    contrib = x[:, :, None] * w[None, :, :] * keep
    return jnp.sum(contrib, axis=1) + b[None, :]


def unit_linear_kept_ref(x, w, t):
    """Number of MACs *kept* (executed) by Eq. 2 per sample. (B,) int32."""
    absx = jnp.abs(x)
    t_bar = jnp.where(absx > EPS, t / jnp.maximum(absx, EPS), jnp.inf)
    keep = jnp.abs(w)[None, :, :] > t_bar[:, :, None]
    return jnp.sum(keep, axis=(1, 2)).astype(jnp.int32)


def _patches(x, kh, kw):
    """im2col for a single sample.

    Args:
      x: ``(C, H, W)``.
    Returns:
      ``(OH, OW, C, KH, KW)`` valid-convolution patches.
    """
    c, h, w = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    rows = []
    for u in range(kh):
        cols = []
        for v in range(kw):
            cols.append(x[:, u : u + oh, v : v + ow])  # (C, OH, OW)
        rows.append(jnp.stack(cols, axis=-1))  # (C, OH, OW, KW)
    pat = jnp.stack(rows, axis=-2)  # (C, OH, OW, KH, KW)
    return jnp.transpose(pat, (1, 2, 0, 3, 4))  # (OH, OW, C, KH, KW)


def unit_conv2d_ref(x, w, b, t):
    """UnIT-pruned valid 2-D convolution (Eq. 3), batched.

    Args:
      x: activations ``(B, C, H, W)``.
      w: kernel ``(O, C, KH, KW)``.
      b: bias ``(O,)``.
      t: scalar layer threshold ``T``.

    Returns:
      ``(B, O, OH, OW)`` where the contribution of activation ``a`` against
      weight ``w`` is included iff ``|a| > T / |w|``.
    """
    o, c, kh, kw = w.shape
    absw = jnp.abs(w)
    # Threshold relative to the reused weight: w_bar[o,c,u,v] = T / |w|.
    w_bar = jnp.where(absw > EPS, t / jnp.maximum(absw, EPS), jnp.inf)

    def one(xi):
        pat = _patches(xi, kh, kw)  # (OH, OW, C, KH, KW)
        keep = jnp.abs(pat)[:, :, None] > w_bar[None, None]  # (OH,OW,O,C,KH,KW)
        contrib = pat[:, :, None] * w[None, None] * keep
        y = jnp.sum(contrib, axis=(3, 4, 5))  # (OH, OW, O)
        return jnp.transpose(y, (2, 0, 1)) + b[:, None, None]

    return jnp.stack([one(x[i]) for i in range(x.shape[0])], axis=0)


def unit_conv2d_kept_ref(x, w, t):
    """Number of MACs kept by Eq. 3 per sample. (B,) int32."""
    o, c, kh, kw = w.shape
    absw = jnp.abs(w)
    w_bar = jnp.where(absw > EPS, t / jnp.maximum(absw, EPS), jnp.inf)

    def one(xi):
        pat = _patches(xi, kh, kw)
        keep = jnp.abs(pat)[:, :, None] > w_bar[None, None]
        return jnp.sum(keep).astype(jnp.int32)

    return jnp.stack([one(x[i]) for i in range(x.shape[0])])


def fatrelu_ref(x, t):
    """FATReLU / truncated rectifier: zero everything <= t (t >= 0)."""
    return jnp.where(x > t, x, 0.0)


def maxpool2x2_ref(x):
    """2x2 max pooling with stride 2 and floor semantics. x: (B,C,H,W)."""
    b, c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, :, : h2 * 2, : w2 * 2]
    x = x.reshape(b, c, h2, 2, w2, 2)
    return jnp.max(x, axis=(3, 5))
