"""AOT export: lower the Layer-2 graphs to HLO **text** artifacts.

Interchange format is HLO text, NOT serialized ``HloModuleProto``: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per dataset this writes into ``--out`` (default ``../artifacts``):

  <ds>_fwd_b1.hlo.txt     inference, batch 1   (MCU-serving shape)
  <ds>_fwd_b8.hlo.txt     inference, batch 8   (PJRT-serving shape)
  <ds>_train_b32.hlo.txt  one SGD+momentum step, batch 32
  <ds>_manifest.txt       flat param ABI + shapes + dense MAC counts

The manifest is a deliberately trivial line format (no JSON dependency on
the Rust side):

  model <name>
  input <C> <H> <W>
  classes <K>
  prunable <n>
  param <name> <d0> <d1> ...
  macs <layer-idx> <dense-mac-count>

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ARCHS, dense_macs, fwd, param_specs, train_step

FWD_BATCHES = (1, 8)
TRAIN_BATCH = 32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def export_fwd(arch, batch: int) -> str:
    specs = [_spec(s) for _, s in param_specs(arch)]
    x_spec = _spec((batch,) + arch.input_shape)
    n_prunable = len(arch.layers)
    t_spec = _spec((n_prunable,))
    fat_spec = _spec(())

    def fn(*args):
        n = len(specs)
        params, x, t_vec, fat_t = list(args[:n]), args[n], args[n + 1], args[n + 2]
        return (fwd(arch, params, x, t_vec, fat_t),)

    lowered = jax.jit(fn).lower(*specs, x_spec, t_spec, fat_spec)
    return to_hlo_text(lowered)


def export_train(arch, batch: int) -> str:
    specs = [_spec(s) for _, s in param_specs(arch)]
    x_spec = _spec((batch,) + arch.input_shape)
    y_spec = _spec((batch, arch.classes))
    lr_spec = _spec(())

    def fn(*args):
        n = len(specs)
        params = list(args[:n])
        mom = list(args[n : 2 * n])
        x, y, lr = args[2 * n], args[2 * n + 1], args[2 * n + 2]
        new_p, new_m, loss = train_step(arch, params, mom, x, y, lr)
        return tuple(new_p) + tuple(new_m) + (loss,)

    lowered = jax.jit(fn).lower(*specs, *specs, x_spec, y_spec, lr_spec)
    return to_hlo_text(lowered)


def write_manifest(arch, path: str) -> None:
    lines = [
        f"model {arch.name}",
        "input " + " ".join(str(d) for d in arch.input_shape),
        f"classes {arch.classes}",
        f"prunable {len(arch.layers)}",
    ]
    for name, shape in param_specs(arch):
        lines.append(f"param {name} " + " ".join(str(d) for d in shape))
    for li, m in enumerate(dense_macs(arch)):
        lines.append(f"macs {li} {m}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--models",
        default="mnist,cifar,kws,widar",
        help="comma-separated subset of models to export",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for name in args.models.split(","):
        arch = ARCHS[name]
        for batch in FWD_BATCHES:
            path = os.path.join(args.out, f"{name}_fwd_b{batch}.hlo.txt")
            text = export_fwd(arch, batch)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")
        path = os.path.join(args.out, f"{name}_train_b{TRAIN_BATCH}.hlo.txt")
        text = export_train(arch, TRAIN_BATCH)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
        write_manifest(arch, os.path.join(args.out, f"{name}_manifest.txt"))

    # Build stamp so `make artifacts` can skip when inputs are unchanged.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")
    print("aot export complete")


if __name__ == "__main__":
    main()
