"""Layer-2 JAX models: the four Table-1 architectures.

Two computation graphs are exported per dataset (see ``aot.py``):

* ``fwd`` — the *inference* graph, built from the Layer-1 Pallas kernels
  (``unit_conv2d`` / ``unit_linear`` / ``fatrelu``). Per-layer UnIT
  thresholds ``t_vec`` and the FATReLU cut-off ``fat_t`` are runtime
  inputs, so a single AOT artifact serves unpruned (``t_vec = 0``),
  UnIT-pruned, FATReLU-pruned, and combined configurations.
* ``train_step`` — one SGD-with-momentum step over the *dense* graph
  (``lax.conv`` + matmul; pruning is inference-time only, exactly as in
  the paper, which never retrains).

Architectures (paper Table 1) and the input shapes that make the linear
dimensions come out exactly (valid convs, floor 2x2 max-pool):

  mnist  1x28x28  : C6x1x5x5  P2 C16x6x5x5 P2 L256x10    (16*4*4   = 256)
  cifar  3x32x32  : C6x3x5x5  P2 C16x6x5x5 P2 L400x10    (16*5*5   = 400)
  kws    1x124x80 : C6x1x5x5  P2 C16x6x5x5 P2 L7616x12   (16*28*17 = 7616)
  widar  22x13x13 : C32x22x6x6 C64x32x3x3 C96x64x3x3 L1536x128 L128x6
                                                          (96*4*4  = 1536)
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import fatrelu, unit_conv2d, unit_linear


@dataclasses.dataclass(frozen=True)
class Conv:
    out_ch: int
    in_ch: int
    kh: int
    kw: int
    pool: bool  # 2x2 max pool after activation
    relu: bool = True


@dataclasses.dataclass(frozen=True)
class Linear:
    n_in: int
    n_out: int
    relu: bool = False


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    input_shape: Tuple[int, int, int]  # (C, H, W)
    classes: int
    layers: tuple  # of Conv | Linear


ARCHS = {
    "mnist": Arch(
        "mnist",
        (1, 28, 28),
        10,
        (
            Conv(6, 1, 5, 5, pool=True),
            Conv(16, 6, 5, 5, pool=True),
            Linear(256, 10),
        ),
    ),
    "cifar": Arch(
        "cifar",
        (3, 32, 32),
        10,
        (
            Conv(6, 3, 5, 5, pool=True),
            Conv(16, 6, 5, 5, pool=True),
            Linear(400, 10),
        ),
    ),
    "kws": Arch(
        "kws",
        (1, 124, 80),
        12,
        (
            Conv(6, 1, 5, 5, pool=True),
            Conv(16, 6, 5, 5, pool=True),
            Linear(7616, 12),
        ),
    ),
    "widar": Arch(
        "widar",
        (22, 13, 13),
        6,
        (
            Conv(32, 22, 6, 6, pool=False),
            Conv(64, 32, 3, 3, pool=False),
            Conv(96, 64, 3, 3, pool=False),
            Linear(1536, 128, relu=True),
            Linear(128, 6),
        ),
    ),
}


def param_specs(arch: Arch) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered ``(name, shape)`` list — the flat param ABI shared with Rust."""
    specs = []
    for li, layer in enumerate(arch.layers):
        if isinstance(layer, Conv):
            specs.append((f"l{li}.w", (layer.out_ch, layer.in_ch, layer.kh, layer.kw)))
            specs.append((f"l{li}.b", (layer.out_ch,)))
        else:
            specs.append((f"l{li}.w", (layer.n_in, layer.n_out)))
            specs.append((f"l{li}.b", (layer.n_out,)))
    return specs


def init_params(arch: Arch, seed: int = 0) -> List[jnp.ndarray]:
    """He-normal weights, zero biases, in ``param_specs`` order."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(arch):
        if name.endswith(".b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            key, sub = jax.random.split(key)
            fan_in = 1
            for d in shape[1:] if len(shape) == 4 else shape[:1]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def dense_macs(arch: Arch) -> List[int]:
    """Dense MAC count per prunable layer — the Fig. 5 denominators."""
    macs = []
    c, h, w = arch.input_shape
    for layer in arch.layers:
        if isinstance(layer, Conv):
            oh, ow = h - layer.kh + 1, w - layer.kw + 1
            macs.append(layer.out_ch * layer.in_ch * layer.kh * layer.kw * oh * ow)
            c, h, w = layer.out_ch, oh, ow
            if layer.pool:
                h, w = h // 2, w // 2
        else:
            macs.append(layer.n_in * layer.n_out)
    return macs


def _maxpool2x2(x):
    b, c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, :, : h2 * 2, : w2 * 2].reshape(b, c, h2, 2, w2, 2)
    return jnp.max(x, axis=(3, 5))


def fwd(arch: Arch, params: List[jnp.ndarray], x, t_vec, fat_t):
    """Inference with UnIT pruning — built from the Layer-1 Pallas kernels.

    Args:
      params: flat list per ``param_specs``.
      x: ``(B, C, H, W)`` input batch.
      t_vec: ``(n_prunable,)`` per-layer UnIT thresholds (0 ⇒ dense).
      fat_t: scalar FATReLU cut-off applied at every activation (0 ⇒ ReLU).

    Returns:
      ``(B, classes)`` logits.
    """
    pi = 0
    li = 0
    for layer in arch.layers:
        w, b = params[pi], params[pi + 1]
        pi += 2
        if isinstance(layer, Conv):
            x = unit_conv2d(x, w, b, t_vec[li])
            if layer.relu:
                x = fatrelu(x, fat_t)
            if layer.pool:
                x = _maxpool2x2(x)
        else:
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            x = unit_linear(x, w, b, t_vec[li])
            if layer.relu:
                x = fatrelu(x, fat_t)
        li += 1
    return x


def fwd_dense(arch: Arch, params: List[jnp.ndarray], x):
    """Dense float forward (lax.conv path) — training graph + cross-check."""
    pi = 0
    for layer in arch.layers:
        w, b = params[pi], params[pi + 1]
        pi += 2
        if isinstance(layer, Conv):
            x = jax.lax.conv_general_dilated(
                x, w, (1, 1), "VALID", dimension_numbers=("NCHW", "OIHW", "NCHW")
            ) + b[None, :, None, None]
            if layer.relu:
                x = jax.nn.relu(x)
            if layer.pool:
                x = _maxpool2x2(x)
        else:
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            x = x @ w + b[None, :]
            if layer.relu:
                x = jax.nn.relu(x)
    return x


def loss_fn(arch: Arch, params, x, y_onehot):
    logits = fwd_dense(arch, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def train_step(arch: Arch, params, mom, x, y_onehot, lr):
    """One SGD+momentum(0.9) step. Returns (params', mom', loss)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(arch, p, x, y_onehot))(params)
    new_mom = [0.9 * m + g for m, g in zip(mom, grads)]
    new_params = [p - lr * m for p, m in zip(params, new_mom)]
    return new_params, new_mom, loss
