"""AOT path: HLO text export round-trips through the XLA client.

Compiles the exported text back with the in-process CPU client and runs it,
verifying the artifact the Rust runtime will consume is executable and
numerically equal to the jit path.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import export_fwd, to_hlo_text, write_manifest
from compile.model import ARCHS, fwd, init_params, param_specs


def _compile_hlo_text(text: str):
    backend = jax.devices("cpu")[0].client
    return backend.compile(xc._xla.mlir.xla_computation_to_mlir_module(
        xc.XlaComputation(_parse(text).as_serialized_hlo_module_proto())
    ))


def _parse(text: str):
    return xc._xla.hlo_module_from_text(text)


def test_fwd_hlo_text_parses():
    text = export_fwd(ARCHS["mnist"], batch=1)
    mod = _parse(text)
    assert mod is not None
    assert "ENTRY" in text


def test_fwd_hlo_executes_and_matches_jit():
    arch = ARCHS["mnist"]
    text = export_fwd(arch, batch=1)
    try:
        exe = _compile_hlo_text(text)
    except Exception as e:  # pragma: no cover - environment-specific
        pytest.skip(f"in-process HLO recompile unsupported here: {e}")
    params = init_params(arch, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1,) + arch.input_shape, jnp.float32)
    t = jnp.array([0.2, 0.2, 0.2], jnp.float32)
    fat = jnp.float32(0.0)
    args = [np.asarray(p) for p in params] + [np.asarray(x), np.asarray(t), np.asarray(fat)]
    out = exe.execute_sharded(args)  # may differ per jaxlib; guarded by skip
    got = np.asarray(out.disassemble_into_single_device_arrays()[0][0])
    want = np.asarray(fwd(arch, params, x, t, fat))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_manifest_format(tmp_path):
    arch = ARCHS["widar"]
    path = tmp_path / "m.txt"
    write_manifest(arch, str(path))
    lines = path.read_text().strip().split("\n")
    assert lines[0] == "model widar"
    assert lines[1] == "input 22 13 13"
    assert lines[2] == "classes 6"
    kinds = {l.split()[0] for l in lines}
    assert {"model", "input", "classes", "prunable", "param", "macs"} <= kinds
    n_params = sum(1 for l in lines if l.startswith("param "))
    assert n_params == len(param_specs(arch))


def test_to_hlo_text_simple_roundtrip():
    lowered = jax.jit(lambda a, b: (a * b + 1.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32), jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[4]" in text
    assert _parse(text) is not None
