"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and thresholds; assert_allclose against ref.py is
the core correctness signal for the whole stack (the Rust fixed-point
engine is in turn validated against these same semantics).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    fatrelu,
    fatrelu_ref,
    unit_conv2d,
    unit_conv2d_ref,
    unit_linear,
    unit_linear_ref,
)

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- linear


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    n=st.integers(1, 48),
    m=st.integers(1, 16),
    t=st.floats(0.0, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_unit_linear_matches_ref(b, n, m, t, seed):
    x = _rand(seed, (b, n))
    w = _rand(seed + 1, (n, m))
    bias = _rand(seed + 2, (m,))
    got = unit_linear(x, w, bias, t)
    want = unit_linear_ref(x, w, bias, t)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_unit_linear_t0_is_dense():
    x = _rand(0, (3, 20))
    w = _rand(1, (20, 7))
    bias = _rand(2, (7,))
    got = unit_linear(x, w, bias, 0.0)
    np.testing.assert_allclose(got, x @ w + bias[None, :], rtol=1e-5, atol=1e-5)


def test_unit_linear_huge_t_prunes_everything():
    x = _rand(0, (2, 10))
    w = _rand(1, (10, 5))
    bias = _rand(2, (5,))
    got = unit_linear(x, w, bias, 1e9)
    np.testing.assert_allclose(got, jnp.broadcast_to(bias, (2, 5)), atol=1e-6)


def test_unit_linear_zero_activation_contributes_nothing():
    # A zero activation must be pruned (T/0 -> inf), never divide-by-zero.
    x = jnp.zeros((1, 6), jnp.float32)
    w = _rand(1, (6, 4))
    bias = _rand(2, (4,))
    got = unit_linear(x, w, bias, 0.5)
    np.testing.assert_allclose(got, bias[None, :], atol=1e-6)
    assert np.all(np.isfinite(np.asarray(got)))


def test_unit_linear_monotone_in_threshold():
    # Raising T can only remove contributions, never add them: the kept-MAC
    # set shrinks monotonically. Verify via the ref mask count.
    from compile.kernels import unit_linear_kept_ref

    x = _rand(0, (4, 32))
    w = _rand(1, (32, 8))
    kept = [int(unit_linear_kept_ref(x, w, t).sum()) for t in (0.0, 0.1, 0.5, 1.0, 3.0)]
    assert kept == sorted(kept, reverse=True)


@pytest.mark.parametrize("block_n", [1, 4, 512])
def test_unit_linear_tiling_invariance(block_n):
    # Result must not depend on the contraction tile size.
    x = _rand(3, (2, 24))
    w = _rand(4, (24, 6))
    bias = _rand(5, (6,))
    got = unit_linear(x, w, bias, 0.4, block_n=block_n)
    want = unit_linear_ref(x, w, bias, 0.4)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- conv


@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    c=st.integers(1, 3),
    o=st.integers(1, 4),
    h=st.integers(5, 12),
    w=st.integers(5, 12),
    k=st.integers(1, 4),
    t=st.floats(0.0, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_unit_conv2d_matches_ref(b, c, o, h, w, k, t, seed):
    if k > h or k > w:
        k = min(h, w)
    x = _rand(seed, (b, c, h, w))
    wk = _rand(seed + 1, (o, c, k, k))
    bias = _rand(seed + 2, (o,))
    got = unit_conv2d(x, wk, bias, t)
    want = unit_conv2d_ref(x, wk, bias, t)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_unit_conv2d_t0_matches_lax_conv():
    x = _rand(0, (2, 3, 9, 8))
    wk = _rand(1, (4, 3, 3, 3))
    bias = _rand(2, (4,))
    got = unit_conv2d(x, wk, bias, 0.0)
    want = (
        jax.lax.conv_general_dilated(
            x, wk, (1, 1), "VALID", dimension_numbers=("NCHW", "OIHW", "NCHW")
        )
        + bias[None, :, None, None]
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_unit_conv2d_huge_t_prunes_everything():
    x = _rand(0, (1, 2, 7, 7))
    wk = _rand(1, (3, 2, 3, 3))
    bias = _rand(2, (3,))
    got = unit_conv2d(x, wk, bias, 1e9)
    want = jnp.broadcast_to(bias[None, :, None, None], (1, 3, 5, 5))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_unit_conv2d_rect_kernel():
    x = _rand(0, (1, 2, 10, 8))
    wk = _rand(1, (3, 2, 5, 3))
    bias = _rand(2, (3,))
    got = unit_conv2d(x, wk, bias, 0.7)
    want = unit_conv2d_ref(x, wk, bias, 0.7)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- fatrelu


@settings(**SETTINGS)
@given(
    n=st.integers(1, 200),
    t=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_fatrelu_matches_ref(n, t, seed):
    x = _rand(seed, (n,))
    np.testing.assert_allclose(fatrelu(x, t), fatrelu_ref(x, t))


def test_fatrelu_t0_is_relu():
    x = _rand(0, (3, 4, 5))
    np.testing.assert_allclose(fatrelu(x, 0.0), jax.nn.relu(x))


def test_fatrelu_kills_subthreshold_positives():
    x = jnp.array([0.1, 0.3, 0.6, -1.0], jnp.float32)
    got = np.asarray(fatrelu(x, 0.5))
    np.testing.assert_allclose(got, [0.0, 0.0, 0.6, 0.0])
