"""Layer-2 correctness: architectures, shapes, training step, pruned fwd."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ARCHS,
    dense_macs,
    fwd,
    fwd_dense,
    init_params,
    param_specs,
    train_step,
)

TABLE1_LINEAR_IN = {"mnist": 256, "cifar": 400, "kws": 7616, "widar": 1536}


@pytest.mark.parametrize("name", list(ARCHS))
def test_param_shapes_match_table1(name):
    arch = ARCHS[name]
    specs = dict(param_specs(arch))
    # First linear layer input dim must equal the Table-1 value exactly.
    first_lin = next(
        s for n, s in sorted(specs.items()) if len(s) == 2 and s[0] == TABLE1_LINEAR_IN[name]
    )
    assert first_lin[0] == TABLE1_LINEAR_IN[name]


@pytest.mark.parametrize("name", ["mnist", "cifar", "widar"])
def test_fwd_logits_shape(name):
    arch = ARCHS[name]
    params = init_params(arch)
    x = jnp.zeros((2,) + arch.input_shape, jnp.float32)
    t = jnp.zeros((len(arch.layers),), jnp.float32)
    logits = fwd(arch, params, x, t, jnp.float32(0.0))
    assert logits.shape == (2, arch.classes)


@pytest.mark.parametrize("name", ["mnist", "widar"])
def test_fwd_t0_matches_dense(name):
    # The pruned fwd with T=0 / fat_t=0 must equal the dense training graph.
    arch = ARCHS[name]
    params = init_params(arch, seed=3)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (2,) + arch.input_shape, jnp.float32)
    t = jnp.zeros((len(arch.layers),), jnp.float32)
    got = fwd(arch, params, x, t, jnp.float32(0.0))
    want = fwd_dense(arch, params, x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_fwd_pruning_reduces_magnitude():
    arch = ARCHS["mnist"]
    params = init_params(arch, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(0), (1,) + arch.input_shape)
    t_hi = 0.5 * jnp.ones((len(arch.layers),), jnp.float32)
    dense = fwd(arch, params, x, jnp.zeros_like(t_hi), jnp.float32(0.0))
    pruned = fwd(arch, params, x, t_hi, jnp.float32(0.0))
    # Pruned logits differ from dense (some MACs dropped) but stay finite.
    assert np.all(np.isfinite(np.asarray(pruned)))
    assert not np.allclose(dense, pruned)


def test_dense_macs_table1_totals():
    # Cross-check a few hand-computed dense MAC counts.
    m = dense_macs(ARCHS["mnist"])
    assert m[0] == 6 * 1 * 5 * 5 * 24 * 24  # conv1: 86_400
    assert m[1] == 16 * 6 * 5 * 5 * 8 * 8  # conv2: 153_600
    assert m[2] == 256 * 10
    w = dense_macs(ARCHS["widar"])
    assert w[3] == 1536 * 128 and w[4] == 128 * 6


def test_train_step_reduces_loss():
    arch = ARCHS["mnist"]
    params = init_params(arch, seed=0)
    mom = [jnp.zeros_like(p) for p in params]
    key = jax.random.PRNGKey(42)
    x = jax.random.normal(key, (16,) + arch.input_shape, jnp.float32)
    y = jax.nn.one_hot(jnp.arange(16) % arch.classes, arch.classes)
    losses = []
    step = jax.jit(lambda p, m, x, y: train_step(arch, p, m, x, y, jnp.float32(0.05)))
    for _ in range(30):
        params, mom, loss = step(params, mom, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses[:3] + losses[-3:]


def test_init_params_deterministic():
    a = init_params(ARCHS["cifar"], seed=5)
    b = init_params(ARCHS["cifar"], seed=5)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
