//! Per-tenant SLO engine: declared objectives, multi-window burn
//! rates, and the admission policy they feed.
//!
//! Each served model (tenant) may declare three objectives: a p99
//! total-latency bound, a keep-ratio floor (the MAC-budget quality the
//! fleet scheduler is supposed to be buying), and an error-rate
//! ceiling. The engine turns the *existing* cumulative per-tenant
//! histograms in [`crate::coordinator::metrics`] into Google-SRE-style
//! **burn rates** over a fast and a slow window — no new sample paths
//! on the hot path; the ticker takes monotone counter cuts and
//! subtracts them.
//!
//! A burn rate of 1 means the tenant is consuming its violation
//! budget exactly as fast as the objective allows (1 % of requests for
//! the latency/keep objectives, the declared ceiling for errors); a
//! burn of 100 means every request violates a 1 % objective. The
//! engine **trips** a tenant when both windows burn hot (fast window
//! for responsiveness, slow window so a blip cannot trip alone) and
//! clears when the fast window cools. Tripping tightens that tenant's
//! [`AdmissionPolicy`] — a token-bucket admit rate plus an inflight
//! quota — so an overloaded tenant is degraded *first and alone*: its
//! excess traffic is answered with the wire's `Throttled` status
//! (retryable) while other tenants' traffic is untouched. A trip
//! transition is also reported to an optional callback, which serving
//! wires to the fleet scheduler so the MAC-budget solver can stop
//! spending quality budget on a tenant that is shedding load.
//!
//! Everything is deterministic and clock-driven: `tick()` is public
//! and takes "now" from the caller's monotonic clock, so tests drive
//! the engine tick by tick without threads; production runs the same
//! function on a background ticker thread.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::{Metrics, TenantCut};
use crate::obs::hist::RATIO_SCALE;

/// Fraction of requests allowed to violate the latency / keep-floor
/// objectives (the "p99" in the declared objective: 1 %).
const VIOLATION_BUDGET: f64 = 0.01;

/// Upper bound on retained window cuts per tenant (memory backstop;
/// at the default 1 s tick the slow hour window needs 3600).
const MAX_CUTS: usize = 4096;

/// A tenant's declared service-level objectives. A component `<= 0`
/// disables that objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// p99 total-latency objective in milliseconds.
    pub p99_ms: f64,
    /// Keep-ratio floor in `[0, 1]`: the quality level the tenant is
    /// owed (requests served below it count against the budget).
    pub keep_floor: f64,
    /// Error-rate ceiling in `[0, 1]` (`Error`/`Failed` outcomes per
    /// completed request).
    pub err_ceiling: f64,
}

impl SloSpec {
    /// Parse one `name=lat_ms:kr:err` objective spec (the `--slo`
    /// flag / wire `SetSlo` shape), e.g. `kws=50:0.3:0.01`.
    pub fn parse(s: &str) -> Result<(String, SloSpec), String> {
        let (name, rest) = s
            .split_once('=')
            .ok_or_else(|| format!("bad --slo entry `{s}`: expected name=lat_ms:kr:err"))?;
        let parts: Vec<&str> = rest.split(':').collect();
        if parts.len() != 3 {
            return Err(format!("bad --slo entry `{s}`: expected three `:`-separated objectives"));
        }
        let num = |p: &str, what: &str| -> Result<f64, String> {
            p.parse::<f64>().map_err(|_| format!("bad --slo {what} `{p}` in `{s}`"))
        };
        let spec = SloSpec {
            p99_ms: num(parts[0], "latency objective")?,
            keep_floor: num(parts[1], "keep floor")?,
            err_ceiling: num(parts[2], "error ceiling")?,
        };
        if spec.keep_floor > 1.0 || spec.err_ceiling > 1.0 {
            return Err(format!("bad --slo entry `{s}`: keep floor and error ceiling are ratios"));
        }
        Ok((name.to_string(), spec))
    }

    /// Parse a comma-separated list of [`parse`](SloSpec::parse)
    /// entries (the full `--slo` flag value).
    pub fn parse_list(s: &str) -> Result<Vec<(String, SloSpec)>, String> {
        s.split(',').filter(|e| !e.trim().is_empty()).map(|e| SloSpec::parse(e.trim())).collect()
    }

    /// Latency objective in µs for violation counting (`u64::MAX`
    /// when disabled).
    pub fn lat_obj_us(&self) -> u64 {
        if self.p99_ms > 0.0 {
            (self.p99_ms * 1000.0).round() as u64
        } else {
            u64::MAX
        }
    }

    /// Keep floor in [`RATIO_SCALE`] fixed point (0 when disabled).
    pub fn keep_floor_scaled(&self) -> u64 {
        if self.keep_floor > 0.0 {
            (self.keep_floor * RATIO_SCALE as f64).round() as u64
        } else {
            0
        }
    }
}

/// Burn-rate window geometry and trip thresholds. Defaults follow the
/// SRE-workbook multi-window pattern: a fast window that reacts within
/// a minute and a slow window that keeps a blip from tripping alone.
#[derive(Debug, Clone, Copy)]
pub struct SloWindows {
    /// Fast burn window (default 1 min).
    pub fast: Duration,
    /// Slow burn window (default 1 h).
    pub slow: Duration,
    /// Ticker period (default 1 s).
    pub tick: Duration,
    /// Trip when the fast-window burn reaches this (default 14.4:
    /// budget for the day gone in 100 minutes).
    pub trip_fast: f64,
    /// ... and the slow-window burn also reaches this (default 6).
    pub trip_slow: f64,
    /// Clear the trip when the fast-window burn falls below this
    /// (default 1: back inside budget).
    pub clear: f64,
}

impl Default for SloWindows {
    fn default() -> Self {
        SloWindows {
            fast: Duration::from_secs(60),
            slow: Duration::from_secs(3600),
            tick: Duration::from_secs(1),
            trip_fast: 14.4,
            trip_slow: 6.0,
            clear: 1.0,
        }
    }
}

/// Admission limits applied to a tenant **while its burn rate is
/// tripped** (untripped tenants are not rate-limited by the engine).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    /// Token-bucket refill rate while tripped (admitted requests per
    /// second; the trickle that lets the engine observe recovery).
    pub throttle_rps: f64,
    /// Token-bucket capacity (burst) while tripped.
    pub throttle_burst: f64,
    /// Inflight quota while tripped: admission is refused while the
    /// tenant's inflight gauge is at or above this.
    pub throttle_inflight: i64,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy { throttle_rps: 8.0, throttle_burst: 8.0, throttle_inflight: 2 }
    }
}

/// Token bucket state for one tripped tenant.
#[derive(Debug)]
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

/// Per-tenant engine state.
struct TenantState {
    name: String,
    spec: Mutex<Option<SloSpec>>,
    /// Timestamped monotone cuts of the tenant's violation counters,
    /// newest at the back; covers the slow window.
    cuts: Mutex<VecDeque<(Instant, TenantCut)>>,
    tripped: AtomicBool,
    /// Burn gauges (f64 bits) for exposition.
    burn_fast: AtomicU64,
    burn_slow: AtomicU64,
    /// Trip transitions since start.
    trips: AtomicU64,
    bucket: Mutex<TokenBucket>,
}

/// Point-in-time SLO state for one tenant, for `[stats]`, the `Stats`
/// frame, and Prometheus exposition.
#[derive(Debug, Clone)]
pub struct SloStatus {
    /// Model id (fleet index).
    pub model: u32,
    /// Model name.
    pub name: String,
    /// Declared objectives (`None` until configured).
    pub spec: Option<SloSpec>,
    /// Fast-window burn rate.
    pub burn_fast: f64,
    /// Slow-window burn rate.
    pub burn_slow: f64,
    /// Whether admission is currently throttling this tenant.
    pub tripped: bool,
    /// Trip transitions since start.
    pub trips: u64,
}

/// The per-tenant SLO engine. One per server; sessions consult
/// [`try_admit`](SloEngine::try_admit) per request, a background
/// ticker (or a test) drives [`tick`](SloEngine::tick).
pub struct SloEngine {
    tenants: Vec<TenantState>,
    metrics: Arc<Metrics>,
    windows: SloWindows,
    policy: AdmissionPolicy,
    /// Called with `(model, tripped)` on every trip transition —
    /// serving wires this to the fleet scheduler's re-solve.
    on_trip: Mutex<Option<Box<dyn Fn(u32, bool) + Send + Sync>>>,
}

impl std::fmt::Debug for SloEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SloEngine").field("tenants", &self.tenants.len()).finish()
    }
}

impl SloEngine {
    /// An engine for the given tenants (index = model id), reading
    /// burn inputs from `metrics`.
    pub fn new(
        names: Vec<String>,
        metrics: Arc<Metrics>,
        windows: SloWindows,
        policy: AdmissionPolicy,
    ) -> Arc<SloEngine> {
        let now = Instant::now();
        Arc::new(SloEngine {
            tenants: names
                .into_iter()
                .map(|name| TenantState {
                    name,
                    spec: Mutex::new(None),
                    cuts: Mutex::new(VecDeque::new()),
                    tripped: AtomicBool::new(false),
                    burn_fast: AtomicU64::new(0),
                    burn_slow: AtomicU64::new(0),
                    trips: AtomicU64::new(0),
                    bucket: Mutex::new(TokenBucket { tokens: policy.throttle_burst, last: now }),
                })
                .collect(),
            metrics,
            windows,
            policy,
            on_trip: Mutex::new(None),
        })
    }

    /// Register the trip-transition callback (replaces any previous).
    pub fn set_on_trip(&self, cb: impl Fn(u32, bool) + Send + Sync + 'static) {
        *self.on_trip.lock().unwrap() = Some(Box::new(cb));
    }

    /// Number of tenants the engine tracks.
    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    /// Resolve a tenant name to its model id.
    pub fn model_id_of(&self, name: &str) -> Option<u32> {
        self.tenants.iter().position(|t| t.name == name).map(|i| i as u32)
    }

    /// Declare (or replace) a tenant's objectives. Resets the
    /// tenant's burn windows — historical violation counts were taken
    /// against the old objectives and cannot be reinterpreted.
    /// Returns false for an unknown model id.
    pub fn set_slo(&self, model: u32, spec: SloSpec) -> bool {
        let Some(t) = self.tenants.get(model as usize) else {
            return false;
        };
        *t.spec.lock().unwrap() = Some(spec);
        t.cuts.lock().unwrap().clear();
        t.burn_fast.store(0, Ordering::Relaxed);
        t.burn_slow.store(0, Ordering::Relaxed);
        self.transition(model, t, false);
        true
    }

    /// A tenant's declared objectives, if any.
    pub fn spec(&self, model: u32) -> Option<SloSpec> {
        self.tenants.get(model as usize).and_then(|t| *t.spec.lock().unwrap())
    }

    /// Per-request admission check. Free (`true`) unless the tenant's
    /// burn rate is tripped; while tripped, admission drains the
    /// throttle token bucket and respects the inflight quota. The
    /// caller answers a refusal with the wire's `Throttled` status.
    pub fn try_admit(&self, model: u32) -> bool {
        let Some(t) = self.tenants.get(model as usize) else {
            return true;
        };
        if !t.tripped.load(Ordering::Acquire) {
            return true;
        }
        if self.metrics.tenant_inflight(model as usize) >= self.policy.throttle_inflight {
            return false;
        }
        let mut b = t.bucket.lock().unwrap();
        let now = Instant::now();
        let dt = now.duration_since(b.last).as_secs_f64();
        b.last = now;
        b.tokens = (b.tokens + dt * self.policy.throttle_rps).min(self.policy.throttle_burst);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// One burn-rate evaluation pass at time `now`: cut every
    /// configured tenant's violation counters, recompute both window
    /// burns, and apply the trip/clear hysteresis. Deterministic given
    /// the metrics state and `now` — tests call this directly.
    pub fn tick(&self, now: Instant) {
        for (model, t) in self.tenants.iter().enumerate() {
            let Some(spec) = *t.spec.lock().unwrap() else {
                continue;
            };
            let cut = self
                .metrics
                .tenant_cut(model, spec.lat_obj_us(), spec.keep_floor_scaled())
                .unwrap_or_default();
            let mut cuts = t.cuts.lock().unwrap();
            cuts.push_back((now, cut));
            let horizon = self.windows.slow + self.windows.tick * 2;
            while cuts.len() > MAX_CUTS
                || cuts.front().is_some_and(|&(at, _)| now.duration_since(at) > horizon)
            {
                cuts.pop_front();
            }
            let fast = burn_over(&cuts, now, self.windows.fast, &spec);
            let slow = burn_over(&cuts, now, self.windows.slow, &spec);
            drop(cuts);
            t.burn_fast.store(fast.to_bits(), Ordering::Relaxed);
            t.burn_slow.store(slow.to_bits(), Ordering::Relaxed);
            let was = t.tripped.load(Ordering::Acquire);
            if !was && fast >= self.windows.trip_fast && slow >= self.windows.trip_slow {
                // Arm the throttle bucket full so the trickle starts
                // immediately rather than after a refill delay.
                let mut b = t.bucket.lock().unwrap();
                b.tokens = self.policy.throttle_burst;
                b.last = Instant::now();
                drop(b);
                t.trips.fetch_add(1, Ordering::Relaxed);
                self.transition(model as u32, t, true);
            } else if was && fast < self.windows.clear {
                self.transition(model as u32, t, false);
            }
        }
    }

    /// Store a trip state and fire the callback on actual change.
    fn transition(&self, model: u32, t: &TenantState, tripped: bool) {
        if t.tripped.swap(tripped, Ordering::AcqRel) != tripped {
            if let Some(cb) = self.on_trip.lock().unwrap().as_ref() {
                cb(model, tripped);
            }
        }
    }

    /// Point-in-time status of every tenant (index = model id).
    pub fn status(&self) -> Vec<SloStatus> {
        self.tenants
            .iter()
            .enumerate()
            .map(|(i, t)| SloStatus {
                model: i as u32,
                name: t.name.clone(),
                spec: *t.spec.lock().unwrap(),
                burn_fast: f64::from_bits(t.burn_fast.load(Ordering::Relaxed)),
                burn_slow: f64::from_bits(t.burn_slow.load(Ordering::Relaxed)),
                tripped: t.tripped.load(Ordering::Acquire),
                trips: t.trips.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Whether a tenant is currently tripped.
    pub fn tripped(&self, model: u32) -> bool {
        self.tenants.get(model as usize).is_some_and(|t| t.tripped.load(Ordering::Acquire))
    }

    /// Spawn the background ticker driving [`tick`](SloEngine::tick)
    /// every `windows.tick`. The thread holds only a weak reference,
    /// so it exits on its own once the server drops the engine — no
    /// explicit shutdown required.
    pub fn start_ticker(self: &Arc<Self>) {
        let weak: Weak<SloEngine> = Arc::downgrade(self);
        let period = self.windows.tick;
        thread::Builder::new()
            .name("slo-ticker".into())
            .spawn(move || loop {
                thread::sleep(period);
                match weak.upgrade() {
                    Some(engine) => engine.tick(Instant::now()),
                    None => break,
                }
            })
            .expect("spawn slo ticker");
    }
}

/// Burn rate over the trailing `window` ending at `now`: delta of the
/// newest cut against the oldest cut inside the window, violation
/// fraction divided by the objective's budget, maxed across the
/// enabled objectives. 0 when the window holds no completed requests.
fn burn_over(
    cuts: &VecDeque<(Instant, TenantCut)>,
    now: Instant,
    window: Duration,
    spec: &SloSpec,
) -> f64 {
    let Some(&(_, newest)) = cuts.back() else {
        return 0.0;
    };
    // Baseline: the oldest cut not older than the window (the counts
    // *before* the window started; absent one, zero — server younger
    // than the window).
    let base = cuts
        .iter()
        .rev()
        .find(|&&(at, _)| now.duration_since(at) >= window)
        .map(|&(_, c)| c)
        .unwrap_or_default();
    let served = newest.served.saturating_sub(base.served);
    let errors = newest.errors.saturating_sub(base.errors);
    let attempts = served + errors;
    if attempts == 0 {
        return 0.0;
    }
    let mut burn = 0.0f64;
    if spec.p99_ms > 0.0 {
        let viol = newest.lat_violations.saturating_sub(base.lat_violations);
        burn = burn.max(viol as f64 / attempts as f64 / VIOLATION_BUDGET);
    }
    if spec.keep_floor > 0.0 {
        let viol = newest.keep_violations.saturating_sub(base.keep_violations);
        burn = burn.max(viol as f64 / attempts as f64 / VIOLATION_BUDGET);
    }
    if spec.err_ceiling > 0.0 {
        burn = burn.max(errors as f64 / attempts as f64 / spec.err_ceiling);
    }
    burn
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_windows() -> SloWindows {
        SloWindows {
            fast: Duration::from_millis(200),
            slow: Duration::from_millis(800),
            tick: Duration::from_millis(50),
            trip_fast: 10.0,
            trip_slow: 5.0,
            clear: 1.0,
        }
    }

    fn engine_for(names: &[&str]) -> (Arc<SloEngine>, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let engine = SloEngine::new(
            names.iter().map(|s| s.to_string()).collect(),
            Arc::clone(&metrics),
            fast_windows(),
            AdmissionPolicy { throttle_rps: 0.0, throttle_burst: 0.0, throttle_inflight: 0 },
        );
        (engine, metrics)
    }

    #[test]
    fn spec_parsing_roundtrips_and_rejects_garbage() {
        let (name, s) = SloSpec::parse("kws=50:0.3:0.01").unwrap();
        assert_eq!(name, "kws");
        assert_eq!(s.p99_ms, 50.0);
        assert_eq!(s.lat_obj_us(), 50_000);
        assert_eq!(s.keep_floor_scaled(), 3000);
        let list = SloSpec::parse_list("a=1:0:0, b=0:0.5:0.02").unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[1].0, "b");
        assert_eq!(list[1].1.lat_obj_us(), u64::MAX, "0 disables latency objective");
        assert!(SloSpec::parse("no-equals").is_err());
        assert!(SloSpec::parse("a=1:2").is_err());
        assert!(SloSpec::parse("a=x:0:0").is_err());
        assert!(SloSpec::parse("a=1:2.0:0").is_err(), "keep floor is a ratio");
    }

    #[test]
    fn burn_trips_on_sustained_violation_and_clears_on_recovery() {
        let (engine, metrics) = engine_for(&["hot", "cold"]);
        // hot: 1µs objective every request violates; cold: huge bound.
        engine.set_slo(0, SloSpec { p99_ms: 0.001, keep_floor: 0.0, err_ceiling: 0.0 });
        engine.set_slo(1, SloSpec { p99_ms: 10_000.0, keep_floor: 0.0, err_ceiling: 0.0 });
        let t0 = Instant::now();
        for i in 0..50 {
            metrics.record_request(0, 100, 400, 0.0, 0.0, 0.0, 0);
            metrics.record_request(1, 100, 400, 0.0, 0.0, 0.0, 0);
            engine.tick(t0 + Duration::from_millis(50 * i));
        }
        let st = engine.status();
        assert!(st[0].tripped, "every request violated 1µs: {:?}", st[0]);
        assert!(st[0].burn_fast > 10.0);
        assert_eq!(st[0].trips, 1);
        assert!(!st[1].tripped, "healthy tenant must not trip: {:?}", st[1]);
        assert_eq!(st[1].burn_fast, 0.0);
        // Recovery: no new traffic → windows drain → burn 0 → clear.
        let later = t0 + Duration::from_millis(50 * 50);
        for i in 0..40 {
            engine.tick(later + Duration::from_millis(50 * i));
        }
        let st = engine.status();
        assert!(!st[0].tripped, "idle windows must clear the trip: {:?}", st[0]);
        assert!(engine.try_admit(0), "cleared tenant admits freely");
    }

    #[test]
    fn tripped_tenant_is_throttled_and_others_are_not() {
        let (engine, metrics) = engine_for(&["hot", "cold"]);
        engine.set_slo(0, SloSpec { p99_ms: 0.001, keep_floor: 0.0, err_ceiling: 0.0 });
        let t0 = Instant::now();
        for i in 0..30 {
            metrics.record_request(0, 50, 50, 0.0, 0.0, 0.0, 0);
            engine.tick(t0 + Duration::from_millis(50 * i));
        }
        assert!(engine.tripped(0));
        // Zero-rate policy: a tripped tenant admits nothing at all.
        assert!(!engine.try_admit(0));
        assert!(engine.try_admit(1), "untripped tenant unaffected");
        assert!(engine.try_admit(9999), "unknown model is not the engine's call");
    }

    #[test]
    fn keep_floor_and_error_ceiling_also_burn() {
        let (engine, metrics) = engine_for(&["kr", "err"]);
        engine.set_slo(0, SloSpec { p99_ms: 0.0, keep_floor: 0.9, err_ceiling: 0.0 });
        engine.set_slo(1, SloSpec { p99_ms: 0.0, keep_floor: 0.0, err_ceiling: 0.01 });
        let t0 = Instant::now();
        for i in 0..30 {
            // kr tenant: keep ratio 0.5 < floor 0.9 every request.
            metrics.record_request(0, 10, 10, 0.5, 0.0, 0.0, 0);
            // err tenant: every other request errors (50× the 1% cap).
            metrics.record_request(1, 10, 10, 0.0, 0.0, 0.0, 0);
            metrics.record_tenant_error(1);
            engine.tick(t0 + Duration::from_millis(50 * i));
        }
        let st = engine.status();
        assert!(st[0].tripped, "keep-floor violations must burn: {:?}", st[0]);
        assert!(st[1].tripped, "error rate over ceiling must burn: {:?}", st[1]);
    }

    #[test]
    fn set_slo_resets_windows_and_unknown_model_is_rejected() {
        let (engine, metrics) = engine_for(&["a"]);
        engine.set_slo(0, SloSpec { p99_ms: 0.001, keep_floor: 0.0, err_ceiling: 0.0 });
        let t0 = Instant::now();
        for i in 0..30 {
            metrics.record_request(0, 50, 50, 0.0, 0.0, 0.0, 0);
            engine.tick(t0 + Duration::from_millis(50 * i));
        }
        assert!(engine.tripped(0));
        // Relaxing the objective over the wire resets state and clears.
        assert!(engine.set_slo(0, SloSpec { p99_ms: 10_000.0, keep_floor: 0.0, err_ceiling: 0.0 }));
        assert!(!engine.tripped(0));
        assert_eq!(engine.status()[0].burn_fast, 0.0);
        assert!(!engine.set_slo(7, SloSpec { p99_ms: 1.0, keep_floor: 0.0, err_ceiling: 0.0 }));
        assert_eq!(engine.spec(0).unwrap().p99_ms, 10_000.0);
        assert_eq!(engine.model_id_of("a"), Some(0));
        assert_eq!(engine.model_id_of("zz"), None);
    }

    #[test]
    fn trip_callback_fires_on_transitions_only() {
        let (engine, metrics) = engine_for(&["a"]);
        let log = Arc::new(Mutex::new(Vec::new()));
        let log2 = Arc::clone(&log);
        engine.set_on_trip(move |model, tripped| log2.lock().unwrap().push((model, tripped)));
        engine.set_slo(0, SloSpec { p99_ms: 0.001, keep_floor: 0.0, err_ceiling: 0.0 });
        let t0 = Instant::now();
        for i in 0..30 {
            metrics.record_request(0, 50, 50, 0.0, 0.0, 0.0, 0);
            engine.tick(t0 + Duration::from_millis(50 * i));
        }
        let later = t0 + Duration::from_millis(50 * 30);
        for i in 0..40 {
            engine.tick(later + Duration::from_millis(50 * i));
        }
        let got = log.lock().unwrap().clone();
        assert_eq!(got, vec![(0, true), (0, false)], "one trip, one clear, no repeats");
    }

    #[test]
    fn token_bucket_trickles_admissions_while_tripped() {
        let metrics = Arc::new(Metrics::new());
        let engine = SloEngine::new(
            vec!["a".into()],
            Arc::clone(&metrics),
            fast_windows(),
            AdmissionPolicy { throttle_rps: 1000.0, throttle_burst: 2.0, throttle_inflight: 100 },
        );
        engine.set_slo(0, SloSpec { p99_ms: 0.001, keep_floor: 0.0, err_ceiling: 0.0 });
        let t0 = Instant::now();
        for i in 0..30 {
            metrics.record_request(0, 50, 50, 0.0, 0.0, 0.0, 0);
            engine.tick(t0 + Duration::from_millis(50 * i));
        }
        assert!(engine.tripped(0));
        // Burst drains, then refills at the throttle rate.
        let mut admitted = 0;
        for _ in 0..4 {
            if engine.try_admit(0) {
                admitted += 1;
            }
        }
        assert!(admitted >= 2, "burst of 2 must admit at least 2, got {admitted}");
        // Inflight quota bites regardless of tokens.
        metrics.tenant_inflight_delta(0, 100);
        assert!(!engine.try_admit(0), "inflight at quota must refuse");
        metrics.tenant_inflight_delta(0, -100);
    }
}
