//! Flight recorder: lock-free ring buffers of typed, timestamped
//! events, exportable as Chrome trace-event JSON.
//!
//! Each [`TraceRing`] is a fixed-capacity ring of seqlock-protected
//! slots. Writers never block and never allocate: a monotone cursor
//! (`fetch_add`) assigns each event a global sequence number, the slot
//! at `seq % capacity` is stamped odd → fields → even, and old events
//! are silently overwritten — so memory is bounded and the **exact**
//! number of overwritten (dropped) events is `cursor - capacity`.
//! Readers ([`TraceRing::snapshot`]) validate each slot's sequence
//! before and after copying the fields and skip any slot a writer was
//! mid-flight in, so snapshots never stop workers and never observe a
//! torn event.
//!
//! The [`FlightRecorder`] is the registry of named rings (one per
//! worker, plus `intake` / `session` / `control` / `fleet` / `faults`)
//! and renders them all as a single Chrome `traceEvents` JSON document
//! (load it at `chrome://tracing` or <https://ui.perfetto.dev>): each
//! ring becomes one "thread" row, durational events (`Service`,
//! `Layer`) become `ph:"X"` spans, everything else instants.

use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default per-ring capacity (events), used by [`FlightRecorder::ring`].
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Typed flight-recorder events covering the request lifecycle and the
/// control plane. The `id`/`a`/`b`/`c` payload words are
/// per-kind (documented on each variant); unused words are 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u64)]
pub enum EventKind {
    /// Request accepted into the coordinator queue. `id` = request id,
    /// `a` = model index.
    Enqueue = 0,
    /// Request parked by session admission (queue full). `id` = wire id.
    Park = 1,
    /// Request admitted into a session's in-flight window. `id` = wire id.
    Admit = 2,
    /// Worker pulled the request off its deque. `id` = request id,
    /// `a` = worker index.
    Dequeue = 3,
    /// Whole-request service span (dur = service time). `id` = request
    /// id, `a` = worker index, `b` = model index.
    Service = 4,
    /// Per-layer kernel span (dur = layer time). `id` = request id,
    /// `a` = layer index, `b` = executed MACs, `c` = skipped MACs.
    Layer = 5,
    /// A plan `Arc` was swapped into a `PlanSlot`. `id` = model index,
    /// `a` = grid step.
    PlanSwap = 6,
    /// Background plan compile finished. `a` = grid step.
    BgCompile = 7,
    /// Drift tracker tripped (observed keep ratio diverged from the
    /// calibrated profile). `id` = model index.
    DriftTrip = 8,
    /// Live recalibration completed and was republished. `id` = model
    /// index.
    Recalibrate = 9,
    /// Fleet scheduler re-solved the global budget allocation.
    FleetResolve = 10,
    /// A chaos fault actually fired. `a` = fault site
    /// (see [`crate::util::fault`] site constants).
    Fault = 11,
    /// A worker panicked mid-request. `a` = worker index.
    WorkerPanic = 12,
    /// The supervisor respawned a panicked worker. `a` = worker index.
    WorkerRespawn = 13,
    /// A tenant's SLO burn rate crossed its trip threshold (or
    /// cleared). `id` = model index, `a` = 1 tripped / 0 cleared.
    SloTrip = 14,
}

impl EventKind {
    /// Decode a slot's raw kind word (`None` for garbage, which a
    /// snapshot then drops).
    pub fn from_u64(v: u64) -> Option<EventKind> {
        Some(match v {
            0 => EventKind::Enqueue,
            1 => EventKind::Park,
            2 => EventKind::Admit,
            3 => EventKind::Dequeue,
            4 => EventKind::Service,
            5 => EventKind::Layer,
            6 => EventKind::PlanSwap,
            7 => EventKind::BgCompile,
            8 => EventKind::DriftTrip,
            9 => EventKind::Recalibrate,
            10 => EventKind::FleetResolve,
            11 => EventKind::Fault,
            12 => EventKind::WorkerPanic,
            13 => EventKind::WorkerRespawn,
            14 => EventKind::SloTrip,
            _ => return None,
        })
    }

    /// Stable display name (Chrome trace `name` field).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Enqueue => "Enqueue",
            EventKind::Park => "Park",
            EventKind::Admit => "Admit",
            EventKind::Dequeue => "Dequeue",
            EventKind::Service => "Service",
            EventKind::Layer => "Layer",
            EventKind::PlanSwap => "PlanSwap",
            EventKind::BgCompile => "BgCompile",
            EventKind::DriftTrip => "DriftTrip",
            EventKind::Recalibrate => "Recalibrate",
            EventKind::FleetResolve => "FleetResolve",
            EventKind::Fault => "Fault",
            EventKind::WorkerPanic => "WorkerPanic",
            EventKind::WorkerRespawn => "WorkerRespawn",
            EventKind::SloTrip => "SloTrip",
        }
    }

    /// Whether the event is a span (has a meaningful duration) rather
    /// than an instant.
    pub fn is_span(self) -> bool {
        matches!(self, EventKind::Service | EventKind::Layer)
    }
}

/// One decoded flight-recorder event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// What happened.
    pub kind: EventKind,
    /// Start time, microseconds since the recorder's origin.
    pub t_us: u64,
    /// Span duration in microseconds (0 for instants).
    pub dur_us: u64,
    /// Request / model id (kind-specific; see [`EventKind`]).
    pub id: u64,
    /// First payload word (kind-specific).
    pub a: u64,
    /// Second payload word (kind-specific).
    pub b: u64,
    /// Third payload word (kind-specific).
    pub c: u64,
}

/// One seqlock slot: sequence word plus the seven event words
/// (kind, t_us, dur_us, id, a, b, c).
struct Slot {
    seq: AtomicU64,
    fields: [AtomicU64; 7],
}

impl Slot {
    fn new() -> Slot {
        Slot { seq: AtomicU64::new(0), fields: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

/// A named, fixed-capacity, lock-free event ring. Writers are
/// wait-free (one `fetch_add` + eight relaxed/ordered stores); readers
/// snapshot concurrently and skip in-flight slots. Multiple writers
/// are memory-safe; rings are *conventionally* single-writer (one per
/// worker) so Chrome traces get one row per thread, except the shared
/// `intake` / `session` / `faults` rings where cross-thread order is
/// already meaningless.
pub struct TraceRing {
    name: String,
    origin: Instant,
    cap: u64,
    cursor: AtomicU64,
    slots: Vec<Slot>,
}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRing")
            .field("name", &self.name)
            .field("cap", &self.cap)
            .field("events", &self.events_total())
            .finish()
    }
}

impl TraceRing {
    /// A fresh ring. `origin` is the recorder-wide epoch all
    /// timestamps are relative to; `capacity` is clamped to >= 2.
    pub fn new(name: &str, origin: Instant, capacity: usize) -> TraceRing {
        let cap = capacity.max(2);
        TraceRing {
            name: name.to_string(),
            origin,
            cap: cap as u64,
            cursor: AtomicU64::new(0),
            slots: (0..cap).map(|_| Slot::new()).collect(),
        }
    }

    /// Ring name (Chrome trace row label).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Capacity in events.
    pub fn capacity(&self) -> usize {
        self.cap as usize
    }

    /// Microseconds since the recorder origin (the event clock).
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    /// Record an instant event stamped `now`.
    pub fn emit(&self, kind: EventKind, id: u64, a: u64, b: u64, c: u64) {
        self.record(kind, self.now_us(), 0, id, a, b, c);
    }

    /// Record a span with an explicit start time and duration.
    #[allow(clippy::too_many_arguments)]
    pub fn span(&self, kind: EventKind, id: u64, t_us: u64, dur_us: u64, a: u64, b: u64, c: u64) {
        self.record(kind, t_us, dur_us, id, a, b, c);
    }

    #[allow(clippy::too_many_arguments)]
    fn record(&self, kind: EventKind, t_us: u64, dur_us: u64, id: u64, a: u64, b: u64, c: u64) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(i % self.cap) as usize];
        // Seqlock write: odd (in-flight) -> fields -> even (published).
        // The release fence keeps the field stores from becoming
        // visible before the odd mark; the final release store
        // publishes them no later than the even mark.
        slot.seq.store(2 * i + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        let raw = [kind as u64, t_us, dur_us, id, a, b, c];
        for (f, v) in slot.fields.iter().zip(raw) {
            f.store(v, Ordering::Relaxed);
        }
        slot.seq.store(2 * i + 2, Ordering::Release);
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn events_total(&self) -> u64 {
        self.cursor.load(Ordering::Acquire)
    }

    /// Exact number of events lost to ring wrap-around.
    pub fn dropped(&self) -> u64 {
        self.events_total().saturating_sub(self.cap)
    }

    /// Copy out every published event still resident, oldest first,
    /// without stopping writers. Slots a writer is mid-flight in (or
    /// overwrites during the copy) are skipped, never torn.
    pub fn snapshot(&self) -> Vec<Event> {
        let cur = self.cursor.load(Ordering::Acquire);
        let start = cur.saturating_sub(self.cap);
        let mut out = Vec::with_capacity((cur - start) as usize);
        for i in start..cur {
            let slot = &self.slots[(i % self.cap) as usize];
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 != 2 * i + 2 {
                continue; // unpublished, in-flight, or already lapped
            }
            let raw: [u64; 7] = std::array::from_fn(|k| slot.fields[k].load(Ordering::Relaxed));
            fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != s1 {
                continue; // a writer lapped us mid-copy
            }
            if let Some(kind) = EventKind::from_u64(raw[0]) {
                out.push(Event {
                    kind,
                    t_us: raw[1],
                    dur_us: raw[2],
                    id: raw[3],
                    a: raw[4],
                    b: raw[5],
                    c: raw[6],
                });
            }
        }
        out
    }
}

/// Registry of named [`TraceRing`]s sharing one time origin, plus the
/// Chrome trace-event JSON exporter. Cheap to share (`Arc`); ring
/// lookup takes a short registry lock, so callers cache the
/// `Arc<TraceRing>` they write to.
pub struct FlightRecorder {
    origin: Instant,
    rings: Mutex<Vec<Arc<TraceRing>>>,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.rings.lock().map(|r| r.len()).unwrap_or(0);
        f.debug_struct("FlightRecorder").field("rings", &n).finish()
    }
}

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::new()
    }
}

impl FlightRecorder {
    /// A recorder whose origin is "now".
    pub fn new() -> FlightRecorder {
        FlightRecorder { origin: Instant::now(), rings: Mutex::new(Vec::new()) }
    }

    /// Find-or-create the ring named `name` at the default capacity.
    pub fn ring(&self, name: &str) -> Arc<TraceRing> {
        self.ring_with_capacity(name, DEFAULT_RING_CAPACITY)
    }

    /// Find-or-create the ring named `name`. If the ring already
    /// exists it is returned as-is (its original capacity wins).
    pub fn ring_with_capacity(&self, name: &str, capacity: usize) -> Arc<TraceRing> {
        let mut rings = self.rings.lock().unwrap();
        if let Some(r) = rings.iter().find(|r| r.name() == name) {
            return Arc::clone(r);
        }
        let r = Arc::new(TraceRing::new(name, self.origin, capacity));
        rings.push(Arc::clone(&r));
        r
    }

    /// All registered rings, in registration order.
    pub fn rings(&self) -> Vec<Arc<TraceRing>> {
        self.rings.lock().unwrap().clone()
    }

    /// Render every ring as one Chrome trace-event JSON document
    /// (`{"traceEvents":[...]}`). Spans become `ph:"X"` with `ts`/`dur`
    /// in microseconds; instants become `ph:"i"`; each ring is a
    /// synthetic thread (`tid` = registration index) named via a
    /// `thread_name` metadata event.
    ///
    /// Events are merged across rings and emitted in global timestamp
    /// order (metadata first): the trace-viewer spec wants sorted
    /// input, and downstream tools that stream the document (rather
    /// than sorting it themselves) misrender interleaved rings
    /// otherwise. The sort is stable, so same-microsecond events keep
    /// ring registration order.
    pub fn chrome_trace(&self) -> String {
        let rings = self.rings();
        let mut out = String::with_capacity(4096);
        out.push_str("{\"traceEvents\":[");
        let mut first = true;
        for (tid, ring) in rings.iter().enumerate() {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
                 \"args\":{{\"name\":\"{}\"}}}}",
                ring.name()
            ));
        }
        let mut events: Vec<(usize, Event)> = Vec::new();
        for (tid, ring) in rings.iter().enumerate() {
            for e in ring.snapshot() {
                events.push((tid, e));
            }
        }
        events.sort_by_key(|(_, e)| e.t_us);
        for (tid, e) in events {
            let args = format!(
                "{{\"id\":{},\"a\":{},\"b\":{},\"c\":{}}}",
                e.id, e.a, e.b, e.c
            );
            if e.kind.is_span() {
                out.push_str(&format!(
                    ",{{\"name\":\"{}\",\"cat\":\"unit\",\"ph\":\"X\",\"ts\":{},\
                     \"dur\":{},\"pid\":1,\"tid\":{tid},\"args\":{args}}}",
                    e.kind.name(),
                    e.t_us,
                    e.dur_us
                ));
            } else {
                out.push_str(&format!(
                    ",{{\"name\":\"{}\",\"cat\":\"unit\",\"ph\":\"i\",\"s\":\"t\",\
                     \"ts\":{},\"pid\":1,\"tid\":{tid},\"args\":{args}}}",
                    e.kind.name(),
                    e.t_us
                ));
            }
        }
        out.push_str("],\"displayTimeUnit\":\"ms\"}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread;

    #[test]
    fn ring_keeps_most_recent_and_counts_drops_exactly() {
        let ring = TraceRing::new("t", Instant::now(), 8);
        for i in 0..20u64 {
            ring.span(EventKind::Enqueue, i, i, 0, 0, 0, 0);
        }
        assert_eq!(ring.events_total(), 20);
        assert_eq!(ring.dropped(), 12);
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 8);
        // Oldest-first, exactly the last `cap` events.
        let ids: Vec<u64> = snap.iter().map(|e| e.id).collect();
        assert_eq!(ids, (12..20).collect::<Vec<_>>());
    }

    #[test]
    fn no_drops_below_capacity() {
        let ring = TraceRing::new("t", Instant::now(), 64);
        for i in 0..64u64 {
            ring.emit(EventKind::Fault, i, 0, 0, 0);
        }
        assert_eq!(ring.dropped(), 0);
        assert_eq!(ring.snapshot().len(), 64);
    }

    #[test]
    fn multithreaded_writers_never_tear_events() {
        // 4 writers x 10k events into a 1024-slot ring, with a reader
        // snapshotting concurrently. Every snapshotted event must be
        // internally consistent: (writer, seq) stamped into (a, b)
        // with c = a ^ b as a checksum; the drop counter must be
        // exact once writers are done.
        const WRITERS: u64 = 4;
        const PER: u64 = 10_000;
        const CAP: usize = 1024;
        let ring = Arc::new(TraceRing::new("mt", Instant::now(), CAP));
        let check = |events: &[Event]| {
            for e in events {
                assert_eq!(e.kind, EventKind::Enqueue);
                assert!(e.a < WRITERS, "writer id out of range");
                assert!(e.b < PER, "writer seq out of range");
                assert_eq!(e.c, e.a ^ e.b, "torn event: {e:?}");
            }
        };
        let mut handles = Vec::new();
        for w in 0..WRITERS {
            let ring = Arc::clone(&ring);
            handles.push(thread::spawn(move || {
                for s in 0..PER {
                    ring.emit(EventKind::Enqueue, w * PER + s, w, s, w ^ s);
                }
            }));
        }
        // Concurrent reader: snapshots while writers run.
        let reader = {
            let ring = Arc::clone(&ring);
            thread::spawn(move || {
                for _ in 0..50 {
                    let snap = ring.snapshot();
                    assert!(snap.len() <= CAP);
                    snap
                }
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        check(&reader.join().unwrap());
        assert_eq!(ring.events_total(), WRITERS * PER);
        assert_eq!(ring.dropped(), WRITERS * PER - CAP as u64);
        let final_snap = ring.snapshot();
        check(&final_snap);
        assert_eq!(final_snap.len(), CAP, "quiescent snapshot must be full");
        // No duplicate (writer, seq) pairs in one snapshot.
        let uniq: HashSet<(u64, u64)> = final_snap.iter().map(|e| (e.a, e.b)).collect();
        assert_eq!(uniq.len(), final_snap.len());
    }

    #[test]
    fn recorder_interns_rings_by_name() {
        let rec = FlightRecorder::new();
        let a = rec.ring("worker0");
        let b = rec.ring("worker0");
        assert!(Arc::ptr_eq(&a, &b));
        let c = rec.ring_with_capacity("worker0", 9999);
        assert!(Arc::ptr_eq(&a, &c), "existing ring wins over new capacity");
        assert_eq!(rec.rings().len(), 1);
        rec.ring("worker1");
        assert_eq!(rec.rings().len(), 2);
    }

    #[test]
    fn chrome_trace_is_valid_shape() {
        let rec = FlightRecorder::new();
        let ring = rec.ring("worker0");
        ring.emit(EventKind::Dequeue, 7, 0, 0, 0);
        ring.span(EventKind::Service, 7, 100, 250, 0, 1, 0);
        ring.span(EventKind::Layer, 7, 120, 30, 0, 500, 123);
        let json = rec.chrome_trace();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with('}'));
        assert!(json.contains("\"name\":\"Service\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"dur\":250"));
        assert!(json.contains("\"name\":\"Dequeue\""));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains("\"name\":\"worker0\""));
        // Balanced braces/brackets — cheap well-formedness check.
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes);
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn chrome_trace_merges_rings_in_timestamp_order() {
        let rec = FlightRecorder::new();
        let a = rec.ring("worker0");
        let b = rec.ring("worker1");
        // Interleaved across rings: per-ring emission order would
        // render 100, 300, 50, 200 — out of global timestamp order.
        a.span(EventKind::Service, 1, 100, 10, 0, 0, 0);
        a.span(EventKind::Service, 2, 300, 10, 0, 0, 0);
        b.span(EventKind::Service, 3, 50, 10, 0, 0, 0);
        b.span(EventKind::Service, 4, 200, 10, 0, 0, 0);
        let json = rec.chrome_trace();
        let ts: Vec<u64> = json
            .match_indices("\"ts\":")
            .map(|(i, _)| {
                json[i + 5..]
                    .chars()
                    .take_while(|c| c.is_ascii_digit())
                    .collect::<String>()
                    .parse()
                    .unwrap()
            })
            .collect();
        assert_eq!(ts, vec![50, 100, 200, 300]);
        // Both thread_name metadata rows still precede every sample.
        let last_meta = json.rfind("thread_name").unwrap();
        let first_span = json.find("\"ph\":\"X\"").unwrap();
        assert!(last_meta < first_span);
    }
}
