//! Head-based deterministic trace sampling.
//!
//! PR 8's flight recorder emits a `Layer` span per layer per request —
//! fine on a workbench, unsustainable at full load (the measurement
//! machinery must be budgeted like the kernels it watches). The fix is
//! the classic head-based sampling decision: hash the *request id*
//! once at the head of the request and either record **every** span of
//! that request or **none** of them, so sampled traces are always
//! complete (a partial trace is worse than no trace) and the sampled
//! population is an unbiased 1-in-N slice of traffic.
//!
//! The decision is a pure function of the request id — no RNG state,
//! no atomics, no clock — so it is reproducible across runs, identical
//! on every thread that touches the request, and free to re-evaluate
//! wherever the id is in hand (intake ring, worker ring) without
//! coordination. The hash is splitmix64, the same finalizer the fault
//! plan uses: cheap (3 multiplies) and well-distributed even on
//! sequential ids.
//!
//! At rate 0 the sampler returns `false` for every id and the serving
//! path collapses to the exact unobserved code path (property-tested
//! bit-identical in `coordinator/server.rs`); at rate 1 it returns
//! `true` for every id, which is what [`ObsConfig::enabled`]
//! (crate::obs::ObsConfig::enabled) defaults to so existing
//! full-capture behaviour is unchanged.

/// splitmix64 finalizer: a bijective avalanche mix of a `u64`. Output
/// bits are uniform over sequential inputs, which is exactly the
/// property head sampling needs (request ids are sequential).
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic head sampler: `sampled(id)` is true for a `rate`
/// fraction of the id space, decided by `splitmix64(id) < threshold`.
///
/// `Copy` and two words big, so it is threaded by value into every
/// worker; the per-request cost is one hash and one compare.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceSampler {
    /// Ids whose hash falls below this are sampled. `u64::MAX` is
    /// special-cased to mean "always" (a threshold of exactly
    /// `u64::MAX` would still miss the id hashing to `u64::MAX`).
    threshold: u64,
}

impl Default for TraceSampler {
    /// Defaults to sampling everything, matching pre-sampling
    /// behaviour when observability is enabled without a rate.
    fn default() -> Self {
        TraceSampler::always()
    }
}

impl TraceSampler {
    /// Sample every request (rate 1).
    pub fn always() -> TraceSampler {
        TraceSampler { threshold: u64::MAX }
    }

    /// Sample no requests (rate 0).
    pub fn never() -> TraceSampler {
        TraceSampler { threshold: 0 }
    }

    /// Sampler for a rate in `[0, 1]` (clamped; NaN reads as 0).
    /// `rate >= 1` samples everything, `rate <= 0` nothing; in between
    /// the sampled fraction of a large id population converges to
    /// `rate`.
    pub fn from_rate(rate: f64) -> TraceSampler {
        if !(rate > 0.0) {
            return TraceSampler::never();
        }
        if rate >= 1.0 {
            return TraceSampler::always();
        }
        // rate in (0, 1): scale into the u64 space. f64 has 53
        // mantissa bits so the threshold is exact to ~2^-53, far finer
        // than any plausible sampling rate.
        TraceSampler { threshold: (rate * u64::MAX as f64) as u64 }
    }

    /// Head decision for a request id: record all of this request's
    /// spans, or none.
    pub fn sampled(&self, id: u64) -> bool {
        self.threshold == u64::MAX || splitmix64(id) < self.threshold
    }

    /// True when this sampler records every request.
    pub fn is_full(&self) -> bool {
        self.threshold == u64::MAX
    }

    /// The effective rate this sampler was built with (approximate
    /// round-trip of `from_rate`, for display).
    pub fn rate(&self) -> f64 {
        if self.threshold == u64::MAX {
            1.0
        } else {
            self.threshold as f64 / u64::MAX as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_zero_samples_nothing_and_rate_one_everything() {
        let none = TraceSampler::from_rate(0.0);
        let all = TraceSampler::from_rate(1.0);
        for id in 0..10_000u64 {
            assert!(!none.sampled(id));
            assert!(all.sampled(id));
        }
        assert!(!TraceSampler::from_rate(-3.0).sampled(7));
        assert!(TraceSampler::from_rate(2.5).sampled(7));
        assert!(!TraceSampler::from_rate(f64::NAN).sampled(7));
    }

    #[test]
    fn decision_is_deterministic_per_id() {
        crate::util::prop::check(0x5A3D, 300, |g| {
            let rate = g.usize_in(0, 1000) as f64 / 1000.0;
            let s1 = TraceSampler::from_rate(rate);
            let s2 = TraceSampler::from_rate(rate);
            let id = g.usize_in(0, usize::MAX >> 1) as u64;
            assert_eq!(s1.sampled(id), s2.sampled(id));
            assert_eq!(s1.sampled(id), s1.sampled(id));
        });
    }

    #[test]
    fn sampled_fraction_converges_to_rate_on_sequential_ids() {
        // Request ids are sequential in production; the sampler must
        // not alias against that pattern.
        for &rate in &[0.1f64, 0.25, 0.5, 0.9] {
            let s = TraceSampler::from_rate(rate);
            let n = 100_000u64;
            let hits = (0..n).filter(|&id| s.sampled(id)).count() as f64;
            let got = hits / n as f64;
            assert!(
                (got - rate).abs() < 0.01,
                "rate {rate}: sampled fraction {got}"
            );
        }
    }

    #[test]
    fn splitmix_matches_reference_vectors() {
        // First outputs of the reference splitmix64 stream seeded 0
        // and 1 (the widely published test vectors), pinning the mix
        // constants against typos.
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(1), 0x910A_2DEC_8902_5CC1);
    }
}
