//! Metrics exposition: Prometheus text-format rendering and the HTTP
//! side listener.
//!
//! [`MetricsHub`] bundles every stats source the serving stack has —
//! coordinator [`Metrics`], the optional [`Governor`], the optional
//! [`FleetScheduler`], the optional [`FlightRecorder`] — behind one
//! handle, and [`render_prometheus`] turns it into Prometheus text
//! format (version 0.0.4: `# HELP` / `# TYPE` heads, counter and gauge
//! families, percentiles as gauges with a `quantile` label).
//!
//! The same renderings are served two ways:
//!
//! * over the wire protocol, as the v5 `Scrape` / `TraceDump` admin
//!   frames (any connected client can ask);
//! * over plain HTTP by [`spawn_http`] (`unit serve --metrics-addr`):
//!   `GET /metrics` → Prometheus text, `GET /trace` → Chrome
//!   trace-event JSON. HTTP/1.0-style one-shot responses
//!   (`Connection: close`), which is all a scraper needs.
//!
//! Every metric family name appears as a string literal in this file —
//! `scripts/check_metrics.py` greps them and fails CI if any is
//! missing from `docs/observability.md`.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use crate::control::{FleetScheduler, Governor};
use crate::coordinator::Metrics;
use crate::obs::hist::{Histogram, RATIO_SCALE};
use crate::obs::slo::SloEngine;
use crate::obs::trace::FlightRecorder;

/// Every stats source the exposition layer renders, bundled behind one
/// cloneable handle. Built by the serve entry points after the server
/// is up; `None` members simply omit their metric sections.
pub struct MetricsHub {
    /// Coordinator serving metrics (always present).
    pub metrics: Arc<Metrics>,
    /// Single-model adaptive governor, if installed.
    pub governor: Option<Arc<Governor>>,
    /// Multi-model fleet scheduler, if installed.
    pub scheduler: Option<Arc<FleetScheduler>>,
    /// Flight recorder, if observability is on.
    pub recorder: Option<Arc<FlightRecorder>>,
    /// Per-tenant SLO engine, if installed (burn-rate gauges, trip
    /// state, and declared objectives per tenant).
    pub slo: Option<Arc<SloEngine>>,
    /// Hosted model names, index-aligned with the coordinator's model
    /// table (labels for per-model/per-layer families).
    pub model_names: Vec<String>,
    /// Resolved kernel backend label (`"scalar"` / `"lanes"` /
    /// `"simd"`), captured at server start via
    /// [`crate::engine::KernelBackend::active_label`]. Rendered as the
    /// `unit_kernel_backend` info gauge so dashboards can tell which
    /// inner-loop implementation a host is running.
    pub kernel_backend: &'static str,
}

/// `# HELP` + `# TYPE` head for one family.
fn head(out: &mut String, name: &str, ty: &str, help: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(ty);
    out.push('\n');
}

/// One unlabeled sample line.
fn plain<V: std::fmt::Display>(out: &mut String, name: &str, v: V) {
    out.push_str(name);
    out.push(' ');
    out.push_str(&v.to_string());
    out.push('\n');
}

/// Prometheus label-value escaping (backslash, quote, newline).
fn esc_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// One labeled sample line.
fn labeled<V: std::fmt::Display>(out: &mut String, name: &str, labels: &[(&str, &str)], v: V) {
    out.push_str(name);
    out.push('{');
    for (i, (k, val)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(&esc_label(val));
        out.push('"');
    }
    out.push_str("} ");
    out.push_str(&v.to_string());
    out.push('\n');
}

/// One native Prometheus histogram family: cumulative `_bucket` series
/// with `le` labels over the non-empty buckets (exact at bucket upper
/// bounds — see [`Histogram::cumulative_buckets`]), the `+Inf` bucket,
/// then `_count` and `_sum`. `scale` divides bucket bounds and the sum
/// so fixed-point series (keep ratio at [`RATIO_SCALE`]) render as
/// fractions. The `_bucket`/`_count`/`_sum` series names are passed as
/// literals by the caller so `scripts/check_metrics.py` can grep them.
#[allow(clippy::too_many_arguments)]
fn native_hist(
    out: &mut String,
    name: &str,
    bucket: &str,
    count: &str,
    sum: &str,
    help: &str,
    h: &Histogram,
    scale: f64,
) {
    head(out, name, "histogram", help);
    for (le, cum) in h.cumulative_buckets() {
        labeled(out, bucket, &[("le", &(le as f64 / scale).to_string())], cum);
    }
    labeled(out, bucket, &[("le", "+Inf")], h.count());
    plain(out, count, h.count());
    plain(out, sum, h.sum() as f64 / scale);
}

/// Render the full metric set as Prometheus text format. Pure: reads
/// the hub's sources, writes a `String`, touches no I/O — which is
/// what the golden test pins.
pub fn render_prometheus(hub: &MetricsHub) -> String {
    let s = hub.metrics.snapshot();
    let mut out = String::with_capacity(8192);

    // -- coordinator counters -------------------------------------------------
    head(&mut out, "unit_requests_served_total", "counter", "Samples completed Ok");
    plain(&mut out, "unit_requests_served_total", s.served);
    head(&mut out, "unit_batches_total", "counter", "Worker batches executed");
    plain(&mut out, "unit_batches_total", s.batches);
    head(&mut out, "unit_requests_failed_total", "counter", "Requests failed by worker panic");
    plain(&mut out, "unit_requests_failed_total", s.failed);
    head(&mut out, "unit_rejected_total", "counter", "Requests rejected by backpressure");
    plain(&mut out, "unit_rejected_total", s.rejected);
    head(&mut out, "unit_expired_total", "counter", "Requests expired at their deadline");
    plain(&mut out, "unit_expired_total", s.expired);
    head(&mut out, "unit_cancelled_total", "counter", "Requests cancelled by the client");
    plain(&mut out, "unit_cancelled_total", s.cancelled);
    head(&mut out, "unit_dropped_total", "counter", "Dead samples dropped at dequeue");
    plain(&mut out, "unit_dropped_total", s.dropped);
    head(&mut out, "unit_parked_total", "counter", "Requests admitted via the park queue");
    plain(&mut out, "unit_parked_total", s.parked);
    head(&mut out, "unit_sessions_opened_total", "counter", "Sessions accepted");
    plain(&mut out, "unit_sessions_opened_total", s.sessions_opened);
    head(&mut out, "unit_sessions_closed_total", "counter", "Sessions closed");
    plain(&mut out, "unit_sessions_closed_total", s.sessions_closed);
    head(&mut out, "unit_worker_panics_total", "counter", "Worker panics caught");
    plain(&mut out, "unit_worker_panics_total", s.worker_panics);
    head(&mut out, "unit_worker_respawns_total", "counter", "Workers respawned after panic");
    plain(&mut out, "unit_worker_respawns_total", s.respawns);

    // -- coordinator gauges ---------------------------------------------------
    head(&mut out, "unit_inflight", "gauge", "Admitted-but-unfinished requests");
    plain(&mut out, "unit_inflight", s.inflight);
    head(&mut out, "unit_mean_batch", "gauge", "Mean executed batch size");
    plain(&mut out, "unit_mean_batch", s.mean_batch);
    head(&mut out, "unit_mac_skipped_ratio", "gauge", "Mean fraction of MACs skipped");
    plain(&mut out, "unit_mac_skipped_ratio", s.mean_mac_skipped);
    head(&mut out, "unit_energy_mj_mean", "gauge", "Mean modeled energy per sample (mJ)");
    plain(&mut out, "unit_energy_mj_mean", s.mean_energy_mj);
    head(&mut out, "unit_mcu_secs_mean", "gauge", "Mean modeled MCU seconds per sample");
    plain(&mut out, "unit_mcu_secs_mean", s.mean_mcu_secs);

    // -- engine build info ----------------------------------------------------
    head(&mut out, "unit_kernel_backend", "gauge", "Active kernel backend (info gauge, always 1)");
    labeled(&mut out, "unit_kernel_backend", &[("backend", hub.kernel_backend)], 1);

    // -- latency / work histogram percentiles ---------------------------------
    head(&mut out, "unit_latency_us", "gauge", "Total latency percentiles (us)");
    labeled(&mut out, "unit_latency_us", &[("quantile", "0.5")], s.p50_us);
    labeled(&mut out, "unit_latency_us", &[("quantile", "0.95")], s.p95_us);
    labeled(&mut out, "unit_latency_us", &[("quantile", "0.99")], s.p99_us);
    head(&mut out, "unit_queue_latency_us", "gauge", "Queue-wait percentiles (us)");
    labeled(&mut out, "unit_queue_latency_us", &[("quantile", "0.5")], s.queue_p50_us);
    labeled(&mut out, "unit_queue_latency_us", &[("quantile", "0.95")], s.queue_p95_us);
    labeled(&mut out, "unit_queue_latency_us", &[("quantile", "0.99")], s.queue_p99_us);
    head(&mut out, "unit_service_latency_us", "gauge", "Service-time percentiles (us)");
    labeled(&mut out, "unit_service_latency_us", &[("quantile", "0.5")], s.service_p50_us);
    labeled(&mut out, "unit_service_latency_us", &[("quantile", "0.95")], s.service_p95_us);
    labeled(&mut out, "unit_service_latency_us", &[("quantile", "0.99")], s.service_p99_us);
    head(&mut out, "unit_keep_ratio", "gauge", "Keep-ratio percentiles (fraction executed)");
    labeled(&mut out, "unit_keep_ratio", &[("quantile", "0.5")], s.keep_p50);
    labeled(&mut out, "unit_keep_ratio", &[("quantile", "0.95")], s.keep_p95);
    head(&mut out, "unit_request_macs", "gauge", "Executed MACs per request percentiles");
    labeled(&mut out, "unit_request_macs", &[("quantile", "0.5")], s.mac_p50);
    labeled(&mut out, "unit_request_macs", &[("quantile", "0.99")], s.mac_p99);

    // -- native histograms (cumulative le buckets) ----------------------------
    native_hist(
        &mut out,
        "unit_request_latency_us",
        "unit_request_latency_us_bucket",
        "unit_request_latency_us_count",
        "unit_request_latency_us_sum",
        "Total request latency histogram (us)",
        &hub.metrics.latency_hist(),
        1.0,
    );
    native_hist(
        &mut out,
        "unit_request_keep_ratio",
        "unit_request_keep_ratio_bucket",
        "unit_request_keep_ratio_count",
        "unit_request_keep_ratio_sum",
        "Keep-ratio histogram (fraction executed)",
        &hub.metrics.keep_hist(),
        RATIO_SCALE as f64,
    );

    // -- shard / background-compile health ------------------------------------
    head(&mut out, "unit_shard_queued_cost", "gauge", "Estimated queued MACs per shard");
    for (i, c) in s.shard_costs.iter().enumerate() {
        labeled(&mut out, "unit_shard_queued_cost", &[("shard", &i.to_string())], c);
    }
    head(&mut out, "unit_bg_compiles_pending", "gauge", "Background compiles in flight");
    plain(&mut out, "unit_bg_compiles_pending", s.bg_pending);
    head(&mut out, "unit_bg_compiles_total", "counter", "Background compiles completed");
    plain(&mut out, "unit_bg_compiles_total", s.bg_compiled);
    head(&mut out, "unit_bg_upgrades_total", "counter", "Background compiles that upgraded the slot");
    plain(&mut out, "unit_bg_upgrades_total", s.bg_upgrades);

    // -- per-layer MAC families (populated when observability is on) ----------
    let model_label = |mi: usize| -> String {
        hub.model_names.get(mi).cloned().unwrap_or_else(|| mi.to_string())
    };
    head(
        &mut out,
        "unit_layer_macs_total",
        "counter",
        "Cumulative per-layer MACs by kind (executed|skipped)",
    );
    let layers = hub.metrics.layer_totals();
    for (mi, rows) in layers.iter().enumerate() {
        let model = model_label(mi);
        for (li, &(exec, skip)) in rows.iter().enumerate() {
            let layer = li.to_string();
            labeled(
                &mut out,
                "unit_layer_macs_total",
                &[("model", &model), ("layer", &layer), ("kind", "executed")],
                exec,
            );
            labeled(
                &mut out,
                "unit_layer_macs_total",
                &[("model", &model), ("layer", &layer), ("kind", "skipped")],
                skip,
            );
        }
    }
    head(&mut out, "unit_layer_keep_ratio", "gauge", "Cumulative per-layer keep ratio");
    for (mi, rows) in layers.iter().enumerate() {
        let model = model_label(mi);
        for (li, &(exec, skip)) in rows.iter().enumerate() {
            let total = exec + skip;
            if total > 0 {
                labeled(
                    &mut out,
                    "unit_layer_keep_ratio",
                    &[("model", &model), ("layer", &li.to_string())],
                    exec as f64 / total as f64,
                );
            }
        }
    }

    // -- adaptive governor (single-model control plane) -----------------------
    if let Some(gov) = &hub.governor {
        let g = gov.status();
        head(&mut out, "unit_governor_step", "gauge", "Active scale-grid step");
        plain(&mut out, "unit_governor_step", g.step);
        head(&mut out, "unit_governor_steps_total", "gauge", "Scale-grid size");
        plain(&mut out, "unit_governor_steps_total", g.steps_total);
        head(&mut out, "unit_governor_scale_q8", "gauge", "Active threshold scale (Q8.8)");
        plain(&mut out, "unit_governor_scale_q8", g.scale_q8);
        head(&mut out, "unit_governor_budget_mj", "gauge", "Energy budget (mJ/inference)");
        plain(&mut out, "unit_governor_budget_mj", g.budget_mj);
        head(&mut out, "unit_governor_ewma_mj", "gauge", "EWMA of observed energy (mJ)");
        plain(&mut out, "unit_governor_ewma_mj", g.ewma_mj);
        head(&mut out, "unit_governor_keep_ratio", "gauge", "Calibrated keep ratio at step");
        plain(&mut out, "unit_governor_keep_ratio", g.keep_ratio);
        head(&mut out, "unit_governor_swaps_total", "counter", "Plan swaps since install");
        plain(&mut out, "unit_governor_swaps_total", g.swaps);
        head(&mut out, "unit_governor_drift_trips_total", "counter", "Drift-tracker trips");
        plain(&mut out, "unit_governor_drift_trips_total", g.drift_trips);
        head(&mut out, "unit_governor_recalibrations_total", "counter", "Live recalibrations");
        plain(&mut out, "unit_governor_recalibrations_total", g.recalibrations);
        head(&mut out, "unit_plan_cache_hits_total", "counter", "Plan-cache hits");
        plain(&mut out, "unit_plan_cache_hits_total", g.cache_hits);
        head(&mut out, "unit_plan_cache_misses_total", "counter", "Plan-cache misses");
        plain(&mut out, "unit_plan_cache_misses_total", g.cache_misses);
    }

    // -- fleet scheduler (multi-model control plane) --------------------------
    if let Some(fleet) = &hub.scheduler {
        let f = fleet.fleet_status();
        head(&mut out, "unit_fleet_budget_mj", "gauge", "Fleet-wide energy budget (mJ)");
        plain(&mut out, "unit_fleet_budget_mj", f.fleet_budget_mj);
        head(&mut out, "unit_fleet_models", "gauge", "Hosted model count");
        plain(&mut out, "unit_fleet_models", f.models);
        head(&mut out, "unit_fleet_resolves_total", "counter", "Fleet allocation solves");
        plain(&mut out, "unit_fleet_resolves_total", f.resolves);
        let mut heads_done = false;
        for mi in 0..f.models {
            let Some(t) = fleet.status(mi as u32) else { continue };
            let model = if t.name.is_empty() { model_label(mi) } else { t.name.clone() };
            let l: &[(&str, &str)] = &[("model", &model)];
            if !heads_done {
                heads_done = true;
                head(&mut out, "unit_tenant_step", "gauge", "Published grid step per tenant");
                head(&mut out, "unit_tenant_keep_ratio", "gauge", "Calibrated keep ratio per tenant");
                head(&mut out, "unit_tenant_ewma_mj", "gauge", "Observed energy EWMA per tenant");
                head(&mut out, "unit_tenant_cap_mj", "gauge", "Energy cap per tenant (if set)");
                head(&mut out, "unit_tenant_drift_trips_total", "counter", "Drift trips per tenant");
                head(
                    &mut out,
                    "unit_tenant_recalibrations_total",
                    "counter",
                    "Recalibrations per tenant",
                );
                head(&mut out, "unit_tenant_swaps_total", "counter", "Plan swaps per tenant");
            }
            labeled(&mut out, "unit_tenant_step", l, t.step);
            labeled(&mut out, "unit_tenant_keep_ratio", l, t.keep_ratio);
            labeled(&mut out, "unit_tenant_ewma_mj", l, t.ewma_mj);
            if let Some(cap) = t.cap_mj {
                labeled(&mut out, "unit_tenant_cap_mj", l, cap);
            }
            labeled(&mut out, "unit_tenant_drift_trips_total", l, t.drift_trips);
            labeled(&mut out, "unit_tenant_recalibrations_total", l, t.recalibrations);
            labeled(&mut out, "unit_tenant_swaps_total", l, t.swaps);
        }
    }

    // -- per-tenant serving outcomes ------------------------------------------
    let tenants = hub.metrics.tenant_snapshot();
    if !tenants.is_empty() {
        head(&mut out, "unit_tenant_requests_total", "counter", "Requests completed Ok per tenant");
        for (mi, t) in tenants.iter().enumerate() {
            labeled(&mut out, "unit_tenant_requests_total", &[("model", &model_label(mi))], t.served);
        }
        head(
            &mut out,
            "unit_tenant_errors_total",
            "counter",
            "Requests ended Error or Failed per tenant",
        );
        for (mi, t) in tenants.iter().enumerate() {
            labeled(&mut out, "unit_tenant_errors_total", &[("model", &model_label(mi))], t.errors);
        }
        head(
            &mut out,
            "unit_tenant_throttled_total",
            "counter",
            "Requests refused Throttled by SLO admission per tenant",
        );
        for (mi, t) in tenants.iter().enumerate() {
            labeled(
                &mut out,
                "unit_tenant_throttled_total",
                &[("model", &model_label(mi))],
                t.throttled,
            );
        }
        head(&mut out, "unit_tenant_inflight", "gauge", "Admitted-but-unfinished requests per tenant");
        for (mi, t) in tenants.iter().enumerate() {
            labeled(&mut out, "unit_tenant_inflight", &[("model", &model_label(mi))], t.inflight);
        }
    }

    // -- per-tenant SLO engine (burn rates, trip state, objectives) -----------
    if let Some(slo) = &hub.slo {
        let rows = slo.status();
        if !rows.is_empty() {
            let name_of = |r: &crate::obs::slo::SloStatus| -> String {
                if r.name.is_empty() {
                    r.model.to_string()
                } else {
                    r.name.clone()
                }
            };
            head(&mut out, "unit_slo_burn_fast", "gauge", "Fast-window SLO burn rate per tenant");
            for r in &rows {
                labeled(&mut out, "unit_slo_burn_fast", &[("model", &name_of(r))], r.burn_fast);
            }
            head(&mut out, "unit_slo_burn_slow", "gauge", "Slow-window SLO burn rate per tenant");
            for r in &rows {
                labeled(&mut out, "unit_slo_burn_slow", &[("model", &name_of(r))], r.burn_slow);
            }
            head(
                &mut out,
                "unit_slo_tripped",
                "gauge",
                "1 while the tenant's burn trip is latched (admission throttled)",
            );
            for r in &rows {
                labeled(&mut out, "unit_slo_tripped", &[("model", &name_of(r))], r.tripped as u8);
            }
            head(&mut out, "unit_slo_trips_total", "counter", "Burn-trip transitions per tenant");
            for r in &rows {
                labeled(&mut out, "unit_slo_trips_total", &[("model", &name_of(r))], r.trips);
            }
            head(
                &mut out,
                "unit_slo_objective_p99_ms",
                "gauge",
                "Declared p99 latency objective (ms; series absent when undeclared)",
            );
            for r in &rows {
                if let Some(spec) = &r.spec {
                    if spec.p99_ms > 0.0 {
                        labeled(
                            &mut out,
                            "unit_slo_objective_p99_ms",
                            &[("model", &name_of(r))],
                            spec.p99_ms,
                        );
                    }
                }
            }
            head(
                &mut out,
                "unit_slo_objective_keep_floor",
                "gauge",
                "Declared keep-ratio floor (fraction; series absent when undeclared)",
            );
            for r in &rows {
                if let Some(spec) = &r.spec {
                    if spec.keep_floor > 0.0 {
                        labeled(
                            &mut out,
                            "unit_slo_objective_keep_floor",
                            &[("model", &name_of(r))],
                            spec.keep_floor,
                        );
                    }
                }
            }
            head(
                &mut out,
                "unit_slo_objective_err_ceiling",
                "gauge",
                "Declared error-rate ceiling (fraction; series absent when undeclared)",
            );
            for r in &rows {
                if let Some(spec) = &r.spec {
                    if spec.err_ceiling > 0.0 {
                        labeled(
                            &mut out,
                            "unit_slo_objective_err_ceiling",
                            &[("model", &name_of(r))],
                            spec.err_ceiling,
                        );
                    }
                }
            }
        }
    }

    // -- flight-recorder health -----------------------------------------------
    if let Some(rec) = &hub.recorder {
        head(&mut out, "unit_trace_events_total", "counter", "Events recorded per ring");
        head(&mut out, "unit_trace_dropped_total", "counter", "Events overwritten per ring");
        for ring in rec.rings() {
            let l: &[(&str, &str)] = &[("ring", ring.name())];
            labeled(&mut out, "unit_trace_events_total", l, ring.events_total());
            labeled(&mut out, "unit_trace_dropped_total", l, ring.dropped());
        }
    }

    out
}

/// Render the flight-recorder Chrome trace (an empty but valid trace
/// document when observability is off).
pub fn render_trace(hub: &MetricsHub) -> String {
    match &hub.recorder {
        Some(rec) => rec.chrome_trace(),
        None => "{\"traceEvents\":[],\"displayTimeUnit\":\"ms\"}".to_string(),
    }
}

/// Bind `addr` and serve `GET /metrics` (Prometheus text) and
/// `GET /trace` (Chrome trace JSON) on a detached thread, one-shot
/// HTTP/1.0-style responses. Returns the bound address (so
/// `--metrics-addr 127.0.0.1:0` reports its ephemeral port).
pub fn spawn_http(addr: &str, hub: Arc<MetricsHub>) -> std::io::Result<SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    std::thread::Builder::new().name("unit-metrics".into()).spawn(move || {
        for conn in listener.incoming() {
            let Ok(mut stream) = conn else { continue };
            let _ = serve_one(&mut stream, &hub);
        }
    })?;
    Ok(local)
}

/// Handle one HTTP exchange on `stream`.
fn serve_one(stream: &mut TcpStream, hub: &MetricsHub) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    // Read the request head (we only need the request line; bound the
    // read so a misbehaving client cannot hold the thread).
    let mut buf = [0u8; 4096];
    let mut len = 0usize;
    loop {
        let n = stream.read(&mut buf[len..])?;
        if n == 0 {
            break;
        }
        len += n;
        if buf[..len].windows(4).any(|w| w == b"\r\n\r\n") || len == buf.len() {
            break;
        }
    }
    let req = String::from_utf8_lossy(&buf[..len]);
    let path = req.split_whitespace().nth(1).unwrap_or("/");
    let (status, ctype, body) = match path {
        "/metrics" => ("200 OK", "text/plain; version=0.0.4", render_prometheus(hub)),
        "/trace" => ("200 OK", "application/json", render_trace(hub)),
        _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
    };
    let resp = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    fn minimal_hub() -> MetricsHub {
        MetricsHub {
            metrics: Arc::new(Metrics::new()),
            governor: None,
            scheduler: None,
            recorder: None,
            slo: None,
            model_names: vec!["default".to_string()],
            // Fixed label: the golden exposition must not depend on
            // the CPU features of the machine running the tests.
            kernel_backend: "scalar",
        }
    }

    #[test]
    fn golden_prometheus_exposition() {
        // Pin the full exposition format for a minimal hub with one
        // request recorded. Any change to family names, types, label
        // shapes, or ordering must update this golden (and
        // docs/observability.md with it).
        let hub = minimal_hub();
        // keep = (1 - 0.1808) * 10000 = 8192, which is bucket-exact.
        hub.metrics.record_request(0, 10, 30, 0.1808, 2.0, 0.5, 1024);
        hub.metrics.record_batch(1);
        let got = render_prometheus(&hub);
        let want = "\
# HELP unit_requests_served_total Samples completed Ok
# TYPE unit_requests_served_total counter
unit_requests_served_total 1
# HELP unit_batches_total Worker batches executed
# TYPE unit_batches_total counter
unit_batches_total 1
# HELP unit_requests_failed_total Requests failed by worker panic
# TYPE unit_requests_failed_total counter
unit_requests_failed_total 0
# HELP unit_rejected_total Requests rejected by backpressure
# TYPE unit_rejected_total counter
unit_rejected_total 0
# HELP unit_expired_total Requests expired at their deadline
# TYPE unit_expired_total counter
unit_expired_total 0
# HELP unit_cancelled_total Requests cancelled by the client
# TYPE unit_cancelled_total counter
unit_cancelled_total 0
# HELP unit_dropped_total Dead samples dropped at dequeue
# TYPE unit_dropped_total counter
unit_dropped_total 0
# HELP unit_parked_total Requests admitted via the park queue
# TYPE unit_parked_total counter
unit_parked_total 0
# HELP unit_sessions_opened_total Sessions accepted
# TYPE unit_sessions_opened_total counter
unit_sessions_opened_total 0
# HELP unit_sessions_closed_total Sessions closed
# TYPE unit_sessions_closed_total counter
unit_sessions_closed_total 0
# HELP unit_worker_panics_total Worker panics caught
# TYPE unit_worker_panics_total counter
unit_worker_panics_total 0
# HELP unit_worker_respawns_total Workers respawned after panic
# TYPE unit_worker_respawns_total counter
unit_worker_respawns_total 0
# HELP unit_inflight Admitted-but-unfinished requests
# TYPE unit_inflight gauge
unit_inflight 0
# HELP unit_mean_batch Mean executed batch size
# TYPE unit_mean_batch gauge
unit_mean_batch 1
# HELP unit_mac_skipped_ratio Mean fraction of MACs skipped
# TYPE unit_mac_skipped_ratio gauge
unit_mac_skipped_ratio 0.1808
# HELP unit_energy_mj_mean Mean modeled energy per sample (mJ)
# TYPE unit_energy_mj_mean gauge
unit_energy_mj_mean 2
# HELP unit_mcu_secs_mean Mean modeled MCU seconds per sample
# TYPE unit_mcu_secs_mean gauge
unit_mcu_secs_mean 0.5
# HELP unit_kernel_backend Active kernel backend (info gauge, always 1)
# TYPE unit_kernel_backend gauge
unit_kernel_backend{backend=\"scalar\"} 1
# HELP unit_latency_us Total latency percentiles (us)
# TYPE unit_latency_us gauge
unit_latency_us{quantile=\"0.5\"} 40
unit_latency_us{quantile=\"0.95\"} 40
unit_latency_us{quantile=\"0.99\"} 40
# HELP unit_queue_latency_us Queue-wait percentiles (us)
# TYPE unit_queue_latency_us gauge
unit_queue_latency_us{quantile=\"0.5\"} 10
unit_queue_latency_us{quantile=\"0.95\"} 10
unit_queue_latency_us{quantile=\"0.99\"} 10
# HELP unit_service_latency_us Service-time percentiles (us)
# TYPE unit_service_latency_us gauge
unit_service_latency_us{quantile=\"0.5\"} 30
unit_service_latency_us{quantile=\"0.95\"} 30
unit_service_latency_us{quantile=\"0.99\"} 30
# HELP unit_keep_ratio Keep-ratio percentiles (fraction executed)
# TYPE unit_keep_ratio gauge
unit_keep_ratio{quantile=\"0.5\"} 0.8192
unit_keep_ratio{quantile=\"0.95\"} 0.8192
# HELP unit_request_macs Executed MACs per request percentiles
# TYPE unit_request_macs gauge
unit_request_macs{quantile=\"0.5\"} 1024
unit_request_macs{quantile=\"0.99\"} 1024
# HELP unit_request_latency_us Total request latency histogram (us)
# TYPE unit_request_latency_us histogram
unit_request_latency_us_bucket{le=\"41\"} 1
unit_request_latency_us_bucket{le=\"+Inf\"} 1
unit_request_latency_us_count 1
unit_request_latency_us_sum 40
# HELP unit_request_keep_ratio Keep-ratio histogram (fraction executed)
# TYPE unit_request_keep_ratio histogram
unit_request_keep_ratio_bucket{le=\"0.8703\"} 1
unit_request_keep_ratio_bucket{le=\"+Inf\"} 1
unit_request_keep_ratio_count 1
unit_request_keep_ratio_sum 0.8192
# HELP unit_shard_queued_cost Estimated queued MACs per shard
# TYPE unit_shard_queued_cost gauge
# HELP unit_bg_compiles_pending Background compiles in flight
# TYPE unit_bg_compiles_pending gauge
unit_bg_compiles_pending 0
# HELP unit_bg_compiles_total Background compiles completed
# TYPE unit_bg_compiles_total counter
unit_bg_compiles_total 0
# HELP unit_bg_upgrades_total Background compiles that upgraded the slot
# TYPE unit_bg_upgrades_total counter
unit_bg_upgrades_total 0
# HELP unit_layer_macs_total Cumulative per-layer MACs by kind (executed|skipped)
# TYPE unit_layer_macs_total counter
# HELP unit_layer_keep_ratio Cumulative per-layer keep ratio
# TYPE unit_layer_keep_ratio gauge
# HELP unit_tenant_requests_total Requests completed Ok per tenant
# TYPE unit_tenant_requests_total counter
unit_tenant_requests_total{model=\"default\"} 1
# HELP unit_tenant_errors_total Requests ended Error or Failed per tenant
# TYPE unit_tenant_errors_total counter
unit_tenant_errors_total{model=\"default\"} 0
# HELP unit_tenant_throttled_total Requests refused Throttled by SLO admission per tenant
# TYPE unit_tenant_throttled_total counter
unit_tenant_throttled_total{model=\"default\"} 0
# HELP unit_tenant_inflight Admitted-but-unfinished requests per tenant
# TYPE unit_tenant_inflight gauge
unit_tenant_inflight{model=\"default\"} 0
";
        assert_eq!(got, want, "exposition format drifted from the golden");
    }

    #[test]
    fn native_histogram_buckets_are_cumulative_and_consistent() {
        let hub = minimal_hub();
        for (q, s, skip, macs) in
            [(10u64, 30u64, 0.1808, 1024u64), (5, 100, 0.5, 64), (1, 2, 0.0, 7)]
        {
            hub.metrics.record_request(0, q, s, skip, 1.0, 0.1, macs);
        }
        let text = render_prometheus(&hub);
        for fam in ["unit_request_latency_us", "unit_request_keep_ratio"] {
            let bucket_prefix = format!("{fam}_bucket");
            let mut last = 0u64;
            let mut inf = None;
            for line in text.lines().filter(|l| l.starts_with(&bucket_prefix)) {
                let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= last, "non-monotone bucket in {fam}: {line}");
                last = v;
                if line.contains("le=\"+Inf\"") {
                    inf = Some(v);
                }
            }
            let count_prefix = format!("{fam}_count ");
            let count_line = text.lines().find(|l| l.starts_with(&count_prefix)).unwrap();
            let count: u64 = count_line.rsplit(' ').next().unwrap().parse().unwrap();
            assert_eq!(inf, Some(count), "{fam}: +Inf bucket must equal _count");
            assert_eq!(count, 3);
        }
        // _sum for latency is the exact µs total: 40 + 105 + 3.
        assert!(text.contains("unit_request_latency_us_sum 148"));
    }

    #[test]
    fn slo_families_render_burn_and_objectives() {
        use crate::obs::slo::{AdmissionPolicy, SloEngine, SloSpec, SloWindows};
        let mut hub = minimal_hub();
        let slo = SloEngine::new(
            vec!["default".to_string()],
            Arc::clone(&hub.metrics),
            SloWindows::default(),
            AdmissionPolicy::default(),
        );
        slo.set_slo(0, SloSpec { p99_ms: 5.0, keep_floor: 0.5, err_ceiling: 0.0 });
        hub.slo = Some(slo);
        let text = render_prometheus(&hub);
        assert!(text.contains("unit_slo_burn_fast{model=\"default\"} 0"));
        assert!(text.contains("unit_slo_burn_slow{model=\"default\"} 0"));
        assert!(text.contains("unit_slo_tripped{model=\"default\"} 0"));
        assert!(text.contains("unit_slo_trips_total{model=\"default\"} 0"));
        assert!(text.contains("unit_slo_objective_p99_ms{model=\"default\"} 5"));
        assert!(text.contains("unit_slo_objective_keep_floor{model=\"default\"} 0.5"));
        // A disabled component (0) keeps its head but emits no series.
        assert!(text.contains("# TYPE unit_slo_objective_err_ceiling gauge"));
        assert!(!text.contains("unit_slo_objective_err_ceiling{"));
    }

    #[test]
    fn per_layer_families_render_labels() {
        let hub = minimal_hub();
        hub.metrics.record_layers(0, &[300, 100], &[100, 0]);
        let text = render_prometheus(&hub);
        assert!(text
            .contains("unit_layer_macs_total{model=\"default\",layer=\"0\",kind=\"executed\"} 300"));
        assert!(text
            .contains("unit_layer_macs_total{model=\"default\",layer=\"0\",kind=\"skipped\"} 100"));
        assert!(text.contains("unit_layer_keep_ratio{model=\"default\",layer=\"0\"} 0.75"));
        assert!(text.contains("unit_layer_keep_ratio{model=\"default\",layer=\"1\"} 1"));
    }

    #[test]
    fn trace_families_render_ring_health() {
        let mut hub = minimal_hub();
        let rec = Arc::new(FlightRecorder::new());
        let ring = rec.ring_with_capacity("worker0", 2);
        for i in 0..5 {
            ring.emit(crate::obs::trace::EventKind::Dequeue, i, 0, 0, 0);
        }
        hub.recorder = Some(rec);
        let text = render_prometheus(&hub);
        assert!(text.contains("unit_trace_events_total{ring=\"worker0\"} 5"));
        assert!(text.contains("unit_trace_dropped_total{ring=\"worker0\"} 3"));
        assert!(render_trace(&hub).contains("\"name\":\"Dequeue\""));
    }

    #[test]
    fn trace_render_without_recorder_is_valid_empty() {
        let hub = minimal_hub();
        assert_eq!(render_trace(&hub), "{\"traceEvents\":[],\"displayTimeUnit\":\"ms\"}");
    }

    #[test]
    fn label_escaping() {
        let mut out = String::new();
        labeled(&mut out, "m", &[("k", "a\"b\\c\nd")], 1);
        assert_eq!(out, "m{k=\"a\\\"b\\\\c\\nd\"} 1\n");
    }

    #[test]
    fn http_listener_serves_metrics_and_trace() {
        let hub = Arc::new(minimal_hub());
        let addr = spawn_http("127.0.0.1:0", Arc::clone(&hub)).expect("bind");
        let get = |path: &str| -> String {
            let mut s = TcpStream::connect(addr).expect("connect");
            s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes()).unwrap();
            let mut resp = String::new();
            s.read_to_string(&mut resp).unwrap();
            resp
        };
        let metrics = get("/metrics");
        assert!(metrics.starts_with("HTTP/1.0 200 OK"));
        assert!(metrics.contains("unit_requests_served_total 0"));
        let trace = get("/trace");
        assert!(trace.starts_with("HTTP/1.0 200 OK"));
        assert!(trace.contains("traceEvents"));
        assert!(get("/nope").starts_with("HTTP/1.0 404"));
    }
}
