//! Observability: flight-recorder tracing, mergeable histograms, and
//! metrics exposition for the serving stack.
//!
//! UnIT's value claim is quantitative — per-layer MAC skipping driven
//! by input-dependent thresholds — yet through PR 7 the serving stack
//! could only report aggregate counters and a periodic `[stats]` line.
//! This module makes the whole pipeline observable on live traffic
//! without perturbing it:
//!
//! * [`trace`] — the flight recorder: per-worker lock-free event rings
//!   (enqueue → park/admit → dequeue → service → per-layer kernel
//!   spans with executed/skipped MACs, plus plan swaps, bg compiles,
//!   drift trips, recalibrations, fleet re-solves, injected faults,
//!   worker panics/respawns), bounded memory, exact drop counters,
//!   exportable as Chrome trace-event JSON (`unit trace`).
//! * [`hist`] — fixed-size log-bucketed mergeable histograms (HDR
//!   style) backing the latency/keep-ratio percentiles in
//!   [`crate::coordinator::Metrics`]: constant memory, shard-local
//!   recording, bucket-exact merge at snapshot.
//! * [`export`] — Prometheus text-format rendering of the full metric
//!   set (coordinator, governor, fleet scheduler, per-model and
//!   per-layer gauges, native `le`-bucket histograms, SLO burn rates,
//!   trace-ring health), served over the wire v5 `Scrape`/`TraceDump`
//!   admin frames and the `unit serve --metrics-addr` HTTP side
//!   listener; `unit top` polls it for a live terminal view.
//! * [`sample`] — head-based deterministic trace sampling: one
//!   splitmix64 hash of the request id decides whether a request
//!   carries *all* of its spans or none, so per-layer tracing stays
//!   affordable at full load (`--trace-sample-rate`).
//! * [`slo`] — the per-tenant SLO engine: declared objectives
//!   (`--slo`, wire `SetSlo`), multi-window burn rates computed from
//!   the existing histograms, and the tripped-tenant admission policy
//!   behind the wire's `Throttled` status.
//!
//! **Cost discipline:** everything here is opt-in through
//! [`ObsConfig`]. With the default [`ObsConfig::off`], no ring exists,
//! no per-layer timestamps are taken, and the inference hot path is
//! bit-identical to the pre-observability plans (pinned by the
//! cross-layer property tests); the same holds with observability on
//! at `--trace-sample-rate 0` for every request.

pub mod export;
pub mod hist;
pub mod sample;
pub mod slo;
pub mod trace;

pub use export::{render_prometheus, render_trace, spawn_http, MetricsHub};
pub use hist::{Histogram, ShardedHistogram, RATIO_SCALE};
pub use sample::TraceSampler;
pub use slo::{AdmissionPolicy, SloEngine, SloSpec, SloStatus, SloWindows};
pub use trace::{Event, EventKind, FlightRecorder, TraceRing};

use std::sync::Arc;

/// Observability switch threaded through
/// [`ServeConfig`](crate::coordinator::ServeConfig): `off` (the
/// default) disables all tracing at near-zero cost; `enabled` attaches
/// a shared [`FlightRecorder`] that every subsystem registers its
/// event rings with.
#[derive(Clone, Default)]
pub struct ObsConfig {
    /// The shared flight recorder, if observability is on.
    pub recorder: Option<Arc<FlightRecorder>>,
    /// Head-based per-request trace sampling decision (defaults to
    /// sampling everything; irrelevant when no recorder is attached).
    pub sampler: TraceSampler,
}

impl std::fmt::Debug for ObsConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsConfig")
            .field("on", &self.is_on())
            .field("sample_rate", &self.sampler.rate())
            .finish()
    }
}

impl ObsConfig {
    /// Observability disabled (the default): no recorder, no spans,
    /// bit-identical hot path.
    pub fn off() -> ObsConfig {
        ObsConfig { recorder: None, sampler: TraceSampler::always() }
    }

    /// Observability enabled with a fresh [`FlightRecorder`], sampling
    /// every request (pre-sampling behaviour).
    pub fn enabled() -> ObsConfig {
        ObsConfig { recorder: Some(Arc::new(FlightRecorder::new())), sampler: TraceSampler::always() }
    }

    /// Observability enabled with head-based request sampling at
    /// `rate` in `[0, 1]`: a sampled request records all of its
    /// lifecycle/`Layer` spans, an unsampled one records none and runs
    /// the exact unobserved inference path.
    pub fn enabled_sampled(rate: f64) -> ObsConfig {
        ObsConfig {
            recorder: Some(Arc::new(FlightRecorder::new())),
            sampler: TraceSampler::from_rate(rate),
        }
    }

    /// Whether a recorder is attached.
    pub fn is_on(&self) -> bool {
        self.recorder.is_some()
    }
}

/// Receiver for per-layer execution spans from the planned engines
/// ([`PlannedModel::infer_observed`](crate::engine::PlannedModel) and
/// the float plan's observed forward). Implemented by the worker's
/// ring adapter; `None` sinks skip even the timestamp reads, keeping
/// the unobserved path identical to the pre-observability engine.
pub trait LayerSink {
    /// One layer finished: `index` within the plan, wall time in
    /// nanoseconds, and the layer's executed (`kept`) / skipped MAC
    /// counts.
    fn layer(&self, index: usize, elapsed_ns: u64, kept: u64, skipped: u64);
}
