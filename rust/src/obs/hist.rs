//! Mergeable fixed-size log-bucketed histograms (HDR-style).
//!
//! The serving metrics used to keep raw-sample windows (`TIMING_WINDOW`
//! boxed `u64`s per series) and sort them at every snapshot. That is
//! O(window) memory per series, O(n log n) per snapshot, and two
//! windows cannot be combined after the fact. This module replaces them
//! with a constant-size bucketed histogram:
//!
//! * **Exact below 32**: values `0..32` get one bucket each, so the
//!   small exact values the unit tests pin (and microsecond timings of
//!   trivially fast paths) survive bucketing unchanged.
//! * **Log-spaced above**: each power-of-two octave is split into 16
//!   sub-buckets, so any recorded value is reproduced by its bucket's
//!   lower bound with relative error `< 1/16` (6.25 %).
//! * **Mergeable**: two histograms over disjoint sample sets merge by
//!   element-wise bucket addition, *bucket-exactly* equal to the
//!   histogram of the concatenated samples — which is what makes
//!   shard-local recording + merge-at-snapshot correct.
//!
//! Percentiles use the same nearest-rank rule the raw-sample windows
//! used (`rank = round(p/100 * (n-1))`), walked over the bucket CDF.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Values below this are binned exactly (one bucket per value).
const LINEAR: usize = 32;
/// Sub-buckets per power-of-two octave above the linear region.
const SUB: usize = 16;
/// log2(SUB): how many top mantissa bits select the sub-bucket.
const SUB_BITS: usize = 4;
/// First octave handled logarithmically (values `32..64` live in
/// octave 5, since `2^5 = 32`).
const FIRST_OCTAVE: usize = 5;
/// Total bucket count: 32 exact + 16 per octave for octaves 5..=63.
const BUCKETS: usize = LINEAR + (64 - FIRST_OCTAVE) * SUB;

/// Fixed-point scale used when recording ratios (keep ratio, skip
/// fraction) into a [`Histogram`]: `ratio * RATIO_SCALE` as `u64`.
pub const RATIO_SCALE: u64 = 10_000;

/// Bucket index for a value. Total order preserving: `a <= b` implies
/// `bucket_index(a) <= bucket_index(b)`.
fn bucket_index(v: u64) -> usize {
    if v < LINEAR as u64 {
        v as usize
    } else {
        let o = 63 - v.leading_zeros() as usize; // >= FIRST_OCTAVE
        let sub = ((v >> (o - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        LINEAR + (o - FIRST_OCTAVE) * SUB + sub
    }
}

/// Lower bound of a bucket — the representative value reported for any
/// sample binned there. Using the *lower* bound keeps every value that
/// is exactly representable (all values `< 32`, and any value of the
/// form `(16 + m) * 2^k` for `m < 16`) reported exactly.
fn bucket_lower(idx: usize) -> u64 {
    if idx < LINEAR {
        idx as u64
    } else {
        let o = FIRST_OCTAVE + (idx - LINEAR) / SUB;
        let sub = ((idx - LINEAR) % SUB) as u64;
        (1u64 << o) + (sub << (o - SUB_BITS))
    }
}

/// Largest value binned into a bucket (inclusive). Because
/// `bucket_index` is total-order preserving, this is one less than the
/// next bucket's lower bound, and every sample in bucket `idx` is
/// `<= bucket_upper(idx)` *exactly* — which is what makes cumulative
/// `le`-bucket rendering exact at these bounds.
fn bucket_upper(idx: usize) -> u64 {
    if idx + 1 < BUCKETS {
        bucket_lower(idx + 1) - 1
    } else {
        u64::MAX
    }
}

/// A fixed-size log-bucketed histogram of `u64` samples.
///
/// Constant memory (976 buckets), O(1) record, O(buckets) percentile
/// and merge. See the module docs for the bucket layout and error
/// bound.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram").field("total", &self.total).finish()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram { counts: vec![0; BUCKETS], total: 0, sum: 0 }
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.total += 1;
        self.sum = self.sum.wrapping_add(v);
    }

    /// Record `n` samples of the same value.
    pub fn record_n(&mut self, v: u64, n: u64) {
        self.counts[bucket_index(v)] += n;
        self.total += n;
        self.sum = self.sum.wrapping_add(v.wrapping_mul(n));
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact sum of all recorded sample values (wrapping on overflow,
    /// which for microsecond timings is ~584k years of accumulated
    /// latency). Tracked alongside the buckets so the Prometheus
    /// `_sum` series is exact, not bucket-approximated, and stays
    /// consistent under [`merge`](Histogram::merge).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Fold another histogram in by element-wise bucket addition.
    /// Bucket-exactly equivalent to having recorded both sample sets
    /// into one histogram.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum = self.sum.wrapping_add(other.sum);
    }

    /// Samples recorded with value `<= v`, at bucket granularity: the
    /// count is taken over whole buckets up to and including `v`'s
    /// bucket, so it is exact whenever `v` is a bucket upper bound
    /// (all values `< 32`, and values of the form `(16+m)·2^k − 1`)
    /// and otherwise may overcount by at most the one straddling
    /// bucket. This is the primitive SLO burn-rate tracking uses to
    /// count objective violations without touching the hot path.
    pub fn count_le(&self, v: u64) -> u64 {
        self.counts[..=bucket_index(v)].iter().sum()
    }

    /// Per-bucket difference `self − earlier` (saturating), for
    /// cut-point deltas between two snapshots of a monotonically
    /// growing histogram. If `earlier` really is an earlier snapshot
    /// of the same series the subtraction is exact and the result is
    /// the histogram of the samples recorded in between.
    pub fn diff(&self, earlier: &Histogram) -> Histogram {
        let counts: Vec<u64> = self
            .counts
            .iter()
            .zip(&earlier.counts)
            .map(|(a, b)| a.saturating_sub(*b))
            .collect();
        let total = counts.iter().sum();
        Histogram { counts, total, sum: self.sum.wrapping_sub(earlier.sum) }
    }

    /// Cumulative bucket view for native Prometheus exposition: yields
    /// `(le, cumulative_count)` for every *non-empty* bucket, where
    /// `le` is the bucket's inclusive upper bound and the count covers
    /// all samples `<= le` (exact — see [`bucket_upper`]). The
    /// renderer appends the `+Inf` bucket itself from
    /// [`count`](Histogram::count).
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((bucket_upper(idx), cum));
            }
        }
        out
    }

    /// Nearest-rank percentile (`p` in 0..=100), reported as the
    /// containing bucket's lower bound. Matches the raw-sample rule
    /// `sorted[round(p/100 * (n-1))]` up to bucketing (relative error
    /// `< 1/16`). Returns 0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * (self.total - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return bucket_lower(idx);
            }
        }
        bucket_lower(BUCKETS - 1)
    }

    /// Largest recorded value, as its bucket's lower bound (0 if empty).
    pub fn max(&self) -> u64 {
        match self.counts.iter().rposition(|&c| c > 0) {
            Some(idx) => bucket_lower(idx),
            None => 0,
        }
    }
}

/// A histogram sharded across several mutexes so concurrent recorders
/// (worker threads) do not serialize on one lock; snapshots merge the
/// shards into a single [`Histogram`].
///
/// Shard choice is a round-robin atomic counter — cheap, allocation
/// free, and statistically spreads recorders without any thread-local
/// state.
pub struct ShardedHistogram {
    shards: Vec<Mutex<Histogram>>,
    next: AtomicUsize,
}

impl std::fmt::Debug for ShardedHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedHistogram").field("shards", &self.shards.len()).finish()
    }
}

impl ShardedHistogram {
    /// A sharded histogram with `shards` independent locks (min 1).
    pub fn new(shards: usize) -> ShardedHistogram {
        let n = shards.max(1);
        ShardedHistogram {
            shards: (0..n).map(|_| Mutex::new(Histogram::new())).collect(),
            next: AtomicUsize::new(0),
        }
    }

    /// Record one sample into a round-robin-chosen shard.
    pub fn record(&self, v: u64) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        self.shards[i].lock().unwrap().record(v);
    }

    /// Merge every shard into one histogram (the snapshot view).
    pub fn merged(&self) -> Histogram {
        let mut out = Histogram::new();
        for s in &self.shards {
            out.merge(&s.lock().unwrap());
        }
        out
    }

    /// Total samples across shards.
    pub fn count(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The raw-sample percentile rule the histograms replace.
    fn exact_percentile(sorted: &[u64], p: f64) -> u64 {
        let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = Histogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        for v in 0..32u64 {
            assert_eq!(bucket_lower(bucket_index(v)), v);
        }
        assert_eq!(h.percentile(0.0), 0);
        assert_eq!(h.percentile(100.0), 31);
    }

    #[test]
    fn representative_is_lower_bound_and_within_error() {
        // Every u64's representative is <= the value and within 1/16
        // relative error of it.
        crate::util::prop::check(0x0B5E, 400, |g| {
            let shift = g.usize_in(0, 31);
            let v = (g.usize_in(0, u32::MAX as usize) as u64) << shift;
            let r = bucket_lower(bucket_index(v));
            assert!(r <= v, "rep {r} > value {v}");
            // err < width(bucket) <= v / 16 in the log region; exact below.
            assert!(v - r <= v / 16, "rep {r} too far below {v}");
        });
    }

    #[test]
    fn bucket_index_is_monotone() {
        crate::util::prop::check(0x0B5F, 400, |g| {
            let a = g.usize_in(0, 1 << 40) as u64;
            let b = g.usize_in(0, 1 << 40) as u64;
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(bucket_index(lo) <= bucket_index(hi));
        });
    }

    #[test]
    fn merged_shards_equal_concatenated_samples_bucket_exactly() {
        // The property the shard-local recording design rests on:
        // recording a sample set split arbitrarily across K histograms
        // and merging equals recording it all into one.
        crate::util::prop::check(0xC0CA, 200, |g| {
            let n = g.usize_in(0, 300);
            let k = g.usize_in(1, 6);
            let mut whole = Histogram::new();
            let mut parts = vec![Histogram::new(); k];
            for _ in 0..n {
                let v = (g.usize_in(0, u32::MAX as usize) as u64)
                    << g.usize_in(0, 20);
                whole.record(v);
                parts[g.usize_in(0, k - 1)].record(v);
            }
            let mut merged = Histogram::new();
            let start = g.usize_in(0, k - 1);
            for i in 0..k {
                merged.merge(&parts[(start + i) % k]);
            }
            assert_eq!(merged.counts, whole.counts, "n={n} k={k}");
            assert_eq!(merged.total, whole.total);
            for &p in &[0.0, 50.0, 95.0, 99.0, 100.0] {
                assert_eq!(merged.percentile(p), whole.percentile(p));
            }
        });
    }

    #[test]
    fn percentiles_within_one_bucket_of_exact() {
        // p50/p95/p99 of the histogram stay within one bucket's
        // relative error (1/16) of the exact raw-sample percentiles.
        crate::util::prop::check(0x9E7C, 120, |g| {
            let n = g.usize_in(1, 400);
            let mut samples = Vec::with_capacity(n);
            let mut h = Histogram::new();
            for _ in 0..n {
                let v = (g.usize_in(0, 1 << 30) as u64) << g.usize_in(0, 8);
                samples.push(v);
                h.record(v);
            }
            samples.sort_unstable();
            for &p in &[50.0, 95.0, 99.0] {
                let exact = exact_percentile(&samples, p);
                let got = h.percentile(p);
                assert!(got <= exact, "p{p}: got {got} > exact {exact}");
                assert!(
                    exact - got <= exact / 16,
                    "p{p}: got {got}, exact {exact} (err > 1/16)"
                );
            }
        });
    }

    #[test]
    fn sharded_recording_matches_unsharded() {
        let sh = ShardedHistogram::new(4);
        let mut plain = Histogram::new();
        for v in [0u64, 1, 17, 40, 1000, 65_536, 12_345_678] {
            sh.record(v);
            plain.record(v);
        }
        let merged = sh.merged();
        assert_eq!(merged.count(), plain.count());
        assert_eq!(sh.count(), plain.count());
        for &p in &[0.0, 50.0, 99.0, 100.0] {
            assert_eq!(merged.percentile(p), plain.percentile(p));
        }
        assert_eq!(merged.max(), plain.max());
    }

    #[test]
    fn empty_histogram_is_all_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.sum(), 0);
        assert!(h.cumulative_buckets().is_empty());
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        // The algebra the sharded/mergeable design depends on: merge
        // order never matters, bucket-for-bucket, count and sum alike.
        crate::util::prop::check(0xA55C, 150, |g| {
            let mut hs = vec![Histogram::new(), Histogram::new(), Histogram::new()];
            for h in hs.iter_mut() {
                for _ in 0..g.usize_in(0, 60) {
                    h.record((g.usize_in(0, u32::MAX as usize) as u64) << g.usize_in(0, 12));
                }
            }
            // (a + b) + c
            let mut left = hs[0].clone();
            left.merge(&hs[1]);
            left.merge(&hs[2]);
            // a + (b + c)
            let mut bc = hs[1].clone();
            bc.merge(&hs[2]);
            let mut right = hs[0].clone();
            right.merge(&bc);
            // c + b + a
            let mut rev = hs[2].clone();
            rev.merge(&hs[1]);
            rev.merge(&hs[0]);
            for other in [&right, &rev] {
                assert_eq!(left.counts, other.counts);
                assert_eq!(left.total, other.total);
                assert_eq!(left.sum, other.sum);
            }
        });
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_consistent() {
        crate::util::prop::check(0xCBB1, 150, |g| {
            let mut h = Histogram::new();
            let mut exact_sum = 0u64;
            let n = g.usize_in(1, 200);
            for _ in 0..n {
                let v = (g.usize_in(0, 1 << 30) as u64) << g.usize_in(0, 8);
                h.record(v);
                exact_sum += v;
            }
            let cum = h.cumulative_buckets();
            assert!(!cum.is_empty());
            // `le` bounds strictly increase and cumulative counts are
            // non-decreasing, ending at the total count.
            for w in cum.windows(2) {
                assert!(w[0].0 < w[1].0, "le bounds not increasing");
                assert!(w[0].1 <= w[1].1, "cumulative counts decreased");
            }
            assert_eq!(cum.last().unwrap().1, h.count());
            // The exact sum is bracketed by the bucket lower/upper
            // reconstructions — `_sum` is consistent with the buckets.
            assert_eq!(h.sum(), exact_sum);
            let mut prev = 0u64;
            let (mut lo, mut hi) = (0u128, 0u128);
            for &(le, c) in &cum {
                let in_bucket = (c - prev) as u128;
                hi += in_bucket * le as u128;
                // lower bound of the bucket ending at `le` is at most le
                lo += in_bucket * (le / 2) as u128;
                prev = c;
            }
            assert!((h.sum() as u128) <= hi);
            assert!((h.sum() as u128) >= lo / 2); // loose but directional
        });
    }

    #[test]
    fn count_le_is_exact_at_bucket_upper_bounds() {
        let mut h = Histogram::new();
        for v in [3u64, 10, 31, 40, 100, 5000] {
            h.record(v);
        }
        assert_eq!(h.count_le(31), 3); // linear region is exact
        assert_eq!(h.count_le(2), 0);
        assert_eq!(h.count_le(u64::MAX), h.count());
        assert_eq!(h.count() - h.count_le(99), 2); // violations above 99: 100, 5000
    }

    #[test]
    fn diff_of_snapshots_is_the_in_between_samples() {
        let mut h = Histogram::new();
        h.record(5);
        h.record(700);
        let earlier = h.clone();
        h.record(5);
        h.record(12_000);
        let d = h.diff(&earlier);
        assert_eq!(d.count(), 2);
        assert_eq!(d.sum(), 5 + 12_000);
        let mut expect = Histogram::new();
        expect.record(5);
        expect.record(12_000);
        assert_eq!(d.counts, expect.counts);
    }
}
