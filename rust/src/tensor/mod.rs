//! Minimal owned tensors for the float reference path and data pipeline.
//!
//! The MCU engine works on raw slices for speed; this type exists for
//! ergonomic shape-checked code in the float layers, dataset generators
//! and the PJRT bridge.

/// Dense row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Row-major values.
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor from existing data (length-checked).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Flatten to 1-D (no copy semantics change; shape only).
    pub fn flattened(mut self) -> Tensor {
        self.shape = vec![self.data.len()];
        self
    }

    /// 3-D index (C,H,W).
    #[inline]
    pub fn at3(&self, c: usize, h: usize, w: usize) -> f32 {
        debug_assert_eq!(self.rank(), 3);
        let (_, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        self.data[(c * hh + h) * ww + w]
    }

    /// Mutable 3-D index.
    #[inline]
    pub fn at3_mut(&mut self, c: usize, h: usize, w: usize) -> &mut f32 {
        let (_, hh, ww) = (self.shape[0], self.shape[1], self.shape[2]);
        &mut self.data[(c * hh + h) * ww + w]
    }

    /// 4-D index (O,I,H,W) — weight layout.
    #[inline]
    pub fn at4(&self, o: usize, i: usize, h: usize, w: usize) -> f32 {
        debug_assert_eq!(self.rank(), 4);
        let (_, ii, hh, ww) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        self.data[((o * ii + i) * hh + h) * ww + w]
    }

    /// Largest absolute element (0 when empty).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0f32, |m, &v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn indexing_3d_row_major() {
        let t = Tensor::from_vec(&[2, 2, 3], (0..12).map(|i| i as f32).collect());
        assert_eq!(t.at3(0, 0, 0), 0.0);
        assert_eq!(t.at3(0, 1, 2), 5.0);
        assert_eq!(t.at3(1, 0, 0), 6.0);
        assert_eq!(t.at3(1, 1, 2), 11.0);
    }

    #[test]
    fn indexing_4d() {
        let t = Tensor::from_vec(&[2, 1, 2, 2], (0..8).map(|i| i as f32).collect());
        assert_eq!(t.at4(1, 0, 1, 1), 7.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_len() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn max_abs() {
        let t = Tensor::from_vec(&[3], vec![-5.0, 2.0, 4.0]);
        assert_eq!(t.max_abs(), 5.0);
    }
}
