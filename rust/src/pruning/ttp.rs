//! Train-time pruning baseline (TTP): global unstructured magnitude
//! pruning, as in the paper's §3.4 — "removing weights with the smallest
//! absolute values across the entire model", permanently and
//! input-independently.
//!
//! Zeroed weights never pass the UnIT comparison (`|0| > T/|x|` is
//! false for any `T ≥ 0`), so the engines automatically count them as
//! skipped MACs — exactly how a static sparse model behaves on the MCU.

use crate::models::Params;

/// Zero the globally smallest-|w| fraction `sparsity ∈ [0, 1]`.
/// Biases are never pruned (standard practice).
pub fn apply_global_magnitude(params: &Params, sparsity: f64) -> Params {
    assert!((0.0..=1.0).contains(&sparsity));
    let mut all: Vec<f32> = params
        .weights
        .iter()
        .flat_map(|w| w.iter().map(|v| v.abs()))
        .collect();
    if all.is_empty() || sparsity == 0.0 {
        return params.clone();
    }
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = ((all.len() as f64) * sparsity) as usize;
    let cut = if k == 0 { -1.0 } else { all[(k - 1).min(all.len() - 1)] };
    let mut out = params.clone();
    for w in out.weights.iter_mut() {
        for v in w.iter_mut() {
            if v.abs() <= cut {
                *v = 0.0;
            }
        }
    }
    out
}

/// Fraction of exactly-zero weights (verification helper).
pub fn zero_fraction(params: &Params) -> f64 {
    let total: usize = params.weights.iter().map(|w| w.len()).sum();
    let zeros: usize =
        params.weights.iter().map(|w| w.iter().filter(|&&v| v == 0.0).count()).sum();
    zeros as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Params};

    #[test]
    fn sparsity_levels_respected() {
        let def = zoo("mnist");
        let p = Params::random(&def, 9);
        for s in [0.0, 0.3, 0.5, 0.9] {
            let pruned = apply_global_magnitude(&p, s);
            let z = zero_fraction(&pruned);
            assert!((z - s).abs() < 0.02, "target {s} got {z}");
        }
    }

    #[test]
    fn prunes_smallest_weights_first() {
        let def = zoo("mnist");
        let p = Params::random(&def, 10);
        let pruned = apply_global_magnitude(&p, 0.5);
        // every surviving weight must be >= every pruned weight's magnitude
        let mut max_pruned = 0f32;
        let mut min_kept = f32::MAX;
        for (w0, w1) in p.weights.iter().zip(&pruned.weights) {
            for (a, b) in w0.iter().zip(w1) {
                if *b == 0.0 && *a != 0.0 {
                    max_pruned = max_pruned.max(a.abs());
                } else if *b != 0.0 {
                    min_kept = min_kept.min(b.abs());
                }
            }
        }
        assert!(min_kept >= max_pruned);
    }

    #[test]
    fn full_sparsity_zeroes_everything() {
        let def = zoo("mnist");
        let p = Params::random(&def, 11);
        let pruned = apply_global_magnitude(&p, 1.0);
        assert_eq!(zero_fraction(&pruned), 1.0);
    }

    #[test]
    fn biases_untouched() {
        let def = zoo("mnist");
        let mut p = Params::random(&def, 12);
        p.biases[0][0] = 0.001;
        let pruned = apply_global_magnitude(&p, 0.99);
        assert_eq!(pruned.biases[0][0], 0.001);
    }
}
