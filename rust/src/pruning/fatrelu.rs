//! FATReLU cut-off calibration (baseline, Kurtz et al. 2020).
//!
//! FATReLU raises the ReLU threshold so small positive activations are
//! zeroed at inference, inducing activation sparsity that downstream
//! layers can exploit by skipping zero rows. The cut-off is tuned on the
//! validation split: the given percentile of *positive* post-conv
//! activations.

use crate::data::Split;
use crate::models::{ModelDef, Params};
use crate::nn::{forward, ForwardOpts};
use crate::util::stats::percentile;

/// Pick `fat_t` as the `pct`-percentile of positive activations observed
/// at ReLU sites over `max_samples` validation samples.
///
/// Implementation note: we probe activations by running the dense
/// forward and collecting layer outputs indirectly — the forward API
/// returns only logits, so we re-run per layer prefix. Models here are
/// 3–5 layers, so this stays cheap.
pub fn calibrate_fatrelu(
    def: &ModelDef,
    params: &Params,
    val: &Split,
    pct: f64,
    max_samples: usize,
) -> f32 {
    // Collect positive pre-threshold activations by instrumenting a
    // truncated model: run each prefix ending right after a ReLU layer.
    // Cheaper and simpler: collect positive *logit-layer inputs* via the
    // penultimate prefix — in these small CNNs the first conv dominates
    // activation counts, so we probe after layer 0 and the final hidden
    // layer and pool the samples.
    let mut acts: Vec<f32> = Vec::new();
    let n = val.len().min(max_samples).max(1);
    for i in 0..n {
        // Prefix model: first layer only.
        let prefix = ModelDef {
            name: def.name.clone(),
            input_shape: def.input_shape,
            classes: 0,
            layers: vec![def.layers[0]],
        };
        let pp = Params {
            weights: vec![params.weights[0].clone()],
            biases: vec![params.biases[0].clone()],
        };
        let (out, _) = forward(&prefix, &pp, val.sample(i), &ForwardOpts::dense(1));
        acts.extend(out.iter().copied().filter(|v| *v > 0.0));
    }
    if acts.is_empty() {
        return 0.0;
    }
    percentile(&acts, pct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{mnist_like, Sizes};
    use crate::models::zoo;

    #[test]
    fn fat_t_positive_and_monotone_in_percentile() {
        let def = zoo("mnist");
        let params = Params::random(&def, 2);
        let ds = mnist_like::generate(4, Sizes { train: 2, val: 6, test: 2 });
        let lo = calibrate_fatrelu(&def, &params, &ds.val, 20.0, 4);
        let hi = calibrate_fatrelu(&def, &params, &ds.val, 60.0, 4);
        assert!(lo > 0.0);
        assert!(hi >= lo);
    }

    #[test]
    fn fatrelu_threshold_induces_sparsity() {
        let def = zoo("mnist");
        let params = Params::random(&def, 3);
        let ds = mnist_like::generate(5, Sizes { train: 2, val: 6, test: 2 });
        let fat = calibrate_fatrelu(&def, &params, &ds.val, 40.0, 4);
        let x = ds.test.sample(0);
        let base = forward(&def, &params, x, &ForwardOpts { t_vec: vec![0.0; 3], fat_t: 0.0 });
        let fatp = forward(&def, &params, x, &ForwardOpts { t_vec: vec![0.0; 3], fat_t: fat });
        assert!(fatp.1.total_skipped() > base.1.total_skipped());
    }
}
