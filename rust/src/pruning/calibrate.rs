//! Adaptive threshold calibration (paper §2.1).
//!
//! One-time pass over a held-out batch (the *validation* split — never
//! test data): collect the distribution of `|activation × weight|`
//! products per layer and set `T_layer` to a fixed percentile (the paper
//! suggests e.g. the 20th). Thresholds become constants baked into the
//! deployed model; no runtime cost.
//!
//! Product collection subsamples connections with a fixed stride into a
//! bounded [`Reservoir`] so calibration is cheap even for the KWS model
//! (5.6 M connections/sample).

use crate::data::Split;
use crate::models::{ModelDef, Params};
use crate::nn::layers::{conv2d_shape, Layer};
use crate::util::stats::Reservoir;

/// Calibration settings.
#[derive(Debug, Clone)]
pub struct CalibConfig {
    /// Percentile of |x·w| products pruned away (e.g. 20.0).
    pub percentile: f64,
    /// Number of validation samples used.
    pub max_samples: usize,
    /// Connection subsampling stride (1 = every connection).
    pub stride: usize,
    /// Reservoir capacity per layer.
    pub reservoir: usize,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig { percentile: 20.0, max_samples: 32, stride: 7, reservoir: 4096 }
    }
}

/// Walk the network on calibration samples, pushing |x·w| products into
/// per-layer reservoirs; `group_fn` optionally routes products to
/// per-output-channel reservoirs instead.
fn collect<F: FnMut(usize, usize, f32)>(
    def: &ModelDef,
    params: &Params,
    x: &[f32],
    stride: usize,
    push: &mut F,
) {
    let mut act = x.to_vec();
    let mut shape = def.input_shape;
    let mut tick = 0usize;
    for (li, layer) in def.layers.iter().enumerate() {
        let w = &params.weights[li];
        let b = &params.biases[li];
        match *layer {
            Layer::Conv { out_ch, in_ch, kh, kw, pool } => {
                let [_, h, wd] = shape;
                let (oh, ow) = conv2d_shape(h, wd, kh, kw);
                let mut out = vec![0.0f32; out_ch * oh * ow];
                for o in 0..out_ch {
                    let wrow = &w[o * in_ch * kh * kw..(o + 1) * in_ch * kh * kw];
                    for p in 0..oh {
                        for q in 0..ow {
                            let mut acc = b[o];
                            let mut ti = 0usize;
                            for ci in 0..in_ch {
                                for u in 0..kh {
                                    for v in 0..kw {
                                        let xv = act[(ci * h + p + u) * wd + q + v];
                                        let prod = xv * wrow[ti];
                                        acc += prod;
                                        tick += 1;
                                        if tick % stride == 0 && prod != 0.0 {
                                            push(li, o, prod.abs());
                                        }
                                        ti += 1;
                                    }
                                }
                            }
                            out[(o * oh + p) * ow + q] = acc.max(0.0); // ReLU
                        }
                    }
                }
                shape = [out_ch, oh, ow];
                act = out;
                if pool {
                    let (ph, pw) = (oh / 2, ow / 2);
                    let mut pooled = vec![0.0f32; out_ch * ph * pw];
                    for o in 0..out_ch {
                        for p in 0..ph {
                            for q in 0..pw {
                                let mut m = f32::MIN;
                                for du in 0..2 {
                                    for dv in 0..2 {
                                        m = m.max(act[(o * oh + 2 * p + du) * ow + 2 * q + dv]);
                                    }
                                }
                                pooled[(o * ph + p) * pw + q] = m;
                            }
                        }
                    }
                    shape = [out_ch, ph, pw];
                    act = pooled;
                }
            }
            Layer::Linear { n_in, n_out, relu } => {
                let mut out = b.clone();
                for k in 0..n_in {
                    let xv = act[k];
                    for j in 0..n_out {
                        let prod = xv * w[k * n_out + j];
                        out[j] += prod;
                        tick += 1;
                        if tick % stride == 0 && prod != 0.0 {
                            push(li, j, prod.abs());
                        }
                    }
                }
                if relu {
                    out.iter_mut().for_each(|v| *v = v.max(0.0));
                }
                shape = [n_out, 1, 1];
                act = out;
            }
        }
    }
}

/// Per-layer thresholds at the configured percentile of |x·w|.
pub fn calibrate(
    def: &ModelDef,
    params: &Params,
    val: &Split,
    cfg: &CalibConfig,
) -> super::Thresholds {
    let n_layers = def.layers.len();
    let mut res: Vec<Reservoir> =
        (0..n_layers).map(|i| Reservoir::new(cfg.reservoir, 100 + i as u64)).collect();
    let n = val.len().min(cfg.max_samples);
    assert!(n > 0, "empty calibration split");
    for i in 0..n {
        collect(def, params, val.sample(i), cfg.stride, &mut |li, _g, p| {
            res[li].push(p);
        });
    }
    let per_layer = res
        .iter()
        .map(|r| if r.is_empty() { 0.0 } else { r.percentile(cfg.percentile) })
        .collect();
    super::Thresholds { per_layer, groups: vec![Vec::new(); n_layers] }
}

/// Group-wise refinement (§2.1): per-output-channel thresholds for conv
/// layers (linear layers keep the layer-level threshold).
pub fn calibrate_groups(
    def: &ModelDef,
    params: &Params,
    val: &Split,
    cfg: &CalibConfig,
) -> super::Thresholds {
    let base = calibrate(def, params, val, cfg);
    let mut groups: Vec<Vec<Reservoir>> = def
        .layers
        .iter()
        .enumerate()
        .map(|(li, l)| match *l {
            Layer::Conv { out_ch, .. } => (0..out_ch)
                .map(|g| Reservoir::new(cfg.reservoir / 8, 500 + (li * 1000 + g) as u64))
                .collect(),
            Layer::Linear { .. } => Vec::new(),
        })
        .collect();
    let n = val.len().min(cfg.max_samples);
    for i in 0..n {
        collect(def, params, val.sample(i), cfg.stride, &mut |li, g, p| {
            if !groups[li].is_empty() {
                groups[li][g].push(p);
            }
        });
    }
    let group_t = groups
        .iter()
        .enumerate()
        .map(|(li, gs)| {
            gs.iter()
                .map(|r| {
                    if r.is_empty() {
                        base.per_layer[li]
                    } else {
                        r.percentile(cfg.percentile) as f32
                    }
                })
                .collect()
        })
        .collect();
    super::Thresholds { per_layer: base.per_layer, groups: group_t }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{mnist_like, Sizes};
    use crate::models::zoo;

    #[test]
    fn thresholds_positive_and_percentile_monotone() {
        let def = zoo("mnist");
        let params = Params::random(&def, 3);
        let ds = mnist_like::generate(1, Sizes { train: 4, val: 8, test: 4 });
        let lo = calibrate(
            &def,
            &params,
            &ds.val,
            &CalibConfig { percentile: 10.0, ..Default::default() },
        );
        let hi = calibrate(
            &def,
            &params,
            &ds.val,
            &CalibConfig { percentile: 60.0, ..Default::default() },
        );
        for (a, b) in lo.per_layer.iter().zip(&hi.per_layer) {
            assert!(*a > 0.0);
            assert!(b >= a, "higher percentile must not lower threshold");
        }
    }

    #[test]
    fn group_thresholds_cover_conv_channels() {
        let def = zoo("mnist");
        let params = Params::random(&def, 4);
        let ds = mnist_like::generate(2, Sizes { train: 4, val: 6, test: 4 });
        let t = calibrate_groups(&def, &params, &ds.val, &CalibConfig::default());
        assert_eq!(t.groups[0].len(), 6); // conv1 out channels
        assert_eq!(t.groups[1].len(), 16); // conv2
        assert!(t.groups[2].is_empty()); // linear: layer-level
        assert!(t.groups[0].iter().all(|&g| g > 0.0));
    }

    #[test]
    fn calibrated_thresholds_actually_prune() {
        // Fig. 5 sanity: the 20th-percentile threshold should skip a
        // nontrivial share of MACs on fresh inputs.
        let def = zoo("mnist");
        let params = Params::random(&def, 5);
        let ds = mnist_like::generate(3, Sizes { train: 4, val: 8, test: 8 });
        let t = calibrate(&def, &params, &ds.val, &CalibConfig::default());
        let (_l, stats) = crate::nn::forward(
            &def,
            &params,
            ds.test.sample(0),
            &crate::nn::ForwardOpts::unit(t.per_layer.clone()),
        );
        let frac = stats.skip_fraction();
        assert!(frac > 0.05, "skip fraction too low: {frac}");
        assert!(frac < 0.95, "skip fraction implausibly high: {frac}");
    }
}
