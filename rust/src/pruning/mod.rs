//! Pruning strategies: UnIT threshold calibration, train-time global
//! magnitude pruning (TTP baseline), and FATReLU cut-off tuning.
//!
//! The UnIT *mechanism* (reuse-aware MAC-free comparisons) lives in the
//! inner loops of [`crate::nn::forward`] (float) and [`crate::engine`]
//! (fixed-point MCU); this module owns the *policies* that produce the
//! thresholds those mechanisms consume.

pub mod calibrate;
pub mod fatrelu;
pub mod ttp;

pub use calibrate::{calibrate, calibrate_groups, CalibConfig};
pub use fatrelu::calibrate_fatrelu;
pub use ttp::apply_global_magnitude;

/// Per-layer UnIT thresholds, optionally refined per group
/// (conv output channel) — the paper's §2.1 "group-wise thresholding".
#[derive(Debug, Clone)]
pub struct Thresholds {
    /// One `T` per prunable layer.
    pub per_layer: Vec<f32>,
    /// Optional per-layer, per-output-channel refinement; empty inner
    /// vec ⇒ use the layer threshold.
    pub groups: Vec<Vec<f32>>,
}

impl Thresholds {
    /// One identical threshold per layer (no group refinement).
    pub fn uniform(n_layers: usize, t: f32) -> Thresholds {
        Thresholds { per_layer: vec![t; n_layers], groups: vec![Vec::new(); n_layers] }
    }

    /// All-zero thresholds (dense numerics).
    pub fn zero(n_layers: usize) -> Thresholds {
        Self::uniform(n_layers, 0.0)
    }

    /// Scale every threshold by a factor (the Fig. 5 sweep knob).
    pub fn scaled(&self, f: f32) -> Thresholds {
        Thresholds {
            per_layer: self.per_layer.iter().map(|t| t * f).collect(),
            groups: self
                .groups
                .iter()
                .map(|g| g.iter().map(|t| t * f).collect())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_and_scale() {
        let t = Thresholds::uniform(3, 0.5);
        assert_eq!(t.per_layer, vec![0.5, 0.5, 0.5]);
        let s = t.scaled(2.0);
        assert_eq!(s.per_layer, vec![1.0, 1.0, 1.0]);
        assert_eq!(s.groups.len(), 3);
    }
}
