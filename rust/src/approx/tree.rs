//! Binary-tree-search division approximation (paper Fig. 4; universal).
//!
//! Finds `e = ⌊log₂ c⌋` by comparing `c` against precomputed power-of-two
//! pivots, halving the candidate range at every level — ⌈log₂ ω⌉ = 5
//! comparisons for a 32-bit word regardless of the operand's magnitude.
//! The numeric estimate is identical to [`super::DivShift`]
//! (`t >> e`); only the *cost profile* differs:
//!
//! * bit shifting is cheaper for small `c` (few iterations) but costs
//!   linearly in `log₂ c`;
//! * the tree costs a constant ~5 compares, so it wins when operand
//!   magnitudes are large or span a wide dynamic range — exactly the
//!   trade-off the paper describes.
//!
//! The pivot table can be rebalanced from calibration data (frequent
//! magnitudes moved to shallower levels); [`DivTree::with_root`] exposes
//! the root pivot for that ablation.
//!
//! ### Cycle model
//! Each tree level is a compare against a constant + taken/untaken branch
//! (~5 cycles), plus the final `t >> e` shift (1 cycle/bit) and call
//! overhead (~6): `cycles = 5·5 + e + 6`.

use super::{ilog2, DivApprox};

/// `t / c ≈ t >> e`, `e` found by binary search over power-of-two pivots.
pub struct DivTree;

impl DivTree {
    /// Binary search for `⌊log₂ c⌋` over exponent range `[0, 31]`.
    /// Written as an explicit pivot walk to mirror the MCU implementation
    /// (and so the comparison count is auditable: always 5).
    #[inline]
    pub fn exponent(c: u32) -> u32 {
        debug_assert!(c >= 1);
        let mut lo = 0u32; // inclusive
        let mut hi = 31u32; // inclusive
        // 5 iterations: ceil(log2(32))
        for _ in 0..5 {
            let mid = (lo + hi + 1) / 2;
            if c >= (1u32 << mid) {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }
}

impl DivApprox for DivTree {
    fn name(&self) -> &'static str {
        "tree"
    }

    #[inline]
    fn div(&self, t: u32, c: u32) -> u32 {
        // Numerically identical to the pivot walk in `exponent()` (the
        // exhaustive test below pins them together); the host intrinsic
        // keeps simulator wallclock down (§Perf item 3) while the cycle
        // *model* still prices the 5-compare tree walk.
        t >> ilog2(c)
    }

    #[inline]
    fn cycles(&self, _t: u32, c: u32) -> u64 {
        let e = ilog2(c.max(1)) as u64;
        5 * 5 + e + 6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponent_matches_ilog2_exhaustive_16bit() {
        for c in 1u32..=65535 {
            assert_eq!(DivTree::exponent(c), ilog2(c), "c={c}");
        }
    }

    #[test]
    fn exponent_matches_ilog2_randomized_32bit() {
        crate::util::prop::check(19, 5000, |g| {
            let c = g.u32_in(1, u32::MAX - 1);
            assert_eq!(DivTree::exponent(c), ilog2(c));
        });
    }

    #[test]
    fn near_constant_cost() {
        // Tree cost varies only by the final shift, not the search.
        let small = DivTree.cycles(0, 2);
        let large = DivTree.cycles(0, 1 << 30);
        assert!(large - small <= 30);
    }

    #[test]
    fn crossover_vs_shift() {
        // Paper §2.2: the tree is "slower for small numbers but more
        // flexible" — verify the cost crossover exists.
        assert!(DivTree.cycles(0, 2) > super::super::DivShift.cycles(0, 2));
        assert!(DivTree.cycles(0, 1 << 14) < super::super::DivShift.cycles(0, 1 << 14));
    }
}
