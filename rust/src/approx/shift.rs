//! Bit-shifting division approximation (paper Fig. 3; fixed-point devices).
//!
//! Repeatedly right-shift the control term `c`, counting shifts until it
//! reaches 1 — this finds `e = ⌊log₂ c⌋` with at most ω (word size)
//! iterations — then estimate `t / c ≈ t >> e` (i.e. divide by the
//! power-of-two envelope of `c`). Since `2^e ≤ c < 2^{e+1}`, the estimate
//! satisfies `t/(2c) < t >> e ≤ 2·(t/c) + 1` — within a factor of two,
//! which only *coarsens the pruning threshold*, never breaks correctness
//! (the paper treats the quantized threshold as a tunable knob).
//!
//! ### Cycle model
//! Each loop iteration on the MSP430 is one register shift (`RRA`, 1
//! cycle) plus a test-and-branch (~3 cycles); the final `t >> e` costs one
//! cycle per bit. With loop setup (~6 cycles):
//!
//! `cycles = 4·(e+1) + e + 6`
//!
//! For Q8.8 activations (`c < 2^16`) this is ≤ 86 cycles and typically
//! ~30–50, versus ~140 for the software division — matching the paper's
//! measured 50–59.8 % reduction band.

use super::{ilog2, DivApprox};

/// `t / c ≈ t >> ⌊log₂ c⌋` with an iterative-shift cost model.
pub struct DivShift;

impl DivApprox for DivShift {
    fn name(&self) -> &'static str {
        "shift"
    }

    #[inline]
    fn div(&self, t: u32, c: u32) -> u32 {
        debug_assert!(c >= 1);
        t >> ilog2(c)
    }

    #[inline]
    fn cycles(&self, _t: u32, c: u32) -> u64 {
        let e = ilog2(c.max(1)) as u64;
        4 * (e + 1) + e + 6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_on_powers_of_two() {
        for e in 0..20 {
            let c = 1u32 << e;
            assert_eq!(DivShift.div(1 << 24, c), (1 << 24) / c);
        }
    }

    #[test]
    fn envelope_bound_randomized() {
        crate::util::prop::check(17, 3000, |g| {
            let t = g.u32_in(0, 1 << 28);
            let c = g.u32_in(1, 1 << 20);
            let est = DivShift.div(t, c) as u64;
            let exact = (t / c) as u64;
            assert!(est <= 2 * exact + 1);
            assert!(2 * (est + 1) >= exact);
        });
    }

    #[test]
    fn cost_grows_with_operand_magnitude() {
        assert!(DivShift.cycles(0, 3) < DivShift.cycles(0, 300));
        assert!(DivShift.cycles(0, 300) < DivShift.cycles(0, 30_000));
    }

    #[test]
    fn cost_below_software_division_for_16bit_operands() {
        for e in 0..16 {
            assert!(DivShift.cycles(0, 1 << e) < crate::mcu::cost::DIV_SW);
        }
    }
}
