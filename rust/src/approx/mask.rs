//! Bit-masking division approximation (paper Eq. 5/6; floating-point
//! devices such as the MAX78000).
//!
//! IEEE-754 floats are `(-1)^S · 2^(E−E₀) · (1 + M/M_max)`; dropping the
//! mantissa term, the quotient of two floats is approximately
//! `|X/T| ≈ 2^(E_X − E_T)` — an integer subtraction of the exponent
//! fields extracted by bit masking, with the bias re-applied afterwards.
//!
//! On the integer MCU engine we emulate the exponent fields of the raw
//! operands (`E(v) = ⌊log₂ v⌋`), returning the pure power-of-two estimate
//! `2^(E_t − E_c)` — this is the paper's roughest estimator (both
//! operands reduced to their exponent), bounded within a factor of 4 of
//! the exact quotient. [`DivMask::div_f32`] implements the literal
//! float-bit version used by the host-CPU benchmark (Fig. 8b).
//!
//! ### Cycle model
//! Two mask+shift extractions, one subtraction, one reconstruct — ~10
//! cycles on an FPU-class core, constant.

use super::{ilog2, DivApprox};

/// `t / c ≈ 2^(⌊log₂ t⌋ − ⌊log₂ c⌋)` via (emulated) exponent fields.
pub struct DivMask;

impl DivMask {
    /// The literal IEEE-754 bit-mask estimator on host floats:
    /// extract exponent fields, subtract, rebias, reinterpret.
    /// Requires positive finite normal inputs.
    #[inline]
    pub fn div_f32(t: f32, c: f32) -> f32 {
        debug_assert!(t > 0.0 && c > 0.0);
        let bt = t.to_bits();
        let bc = c.to_bits();
        let et = ((bt >> 23) & 0xFF) as i32;
        let ec = ((bc >> 23) & 0xFF) as i32;
        let eq = et - ec + 127;
        if eq <= 0 {
            return 0.0; // underflow: quotient below smallest normal
        }
        if eq >= 255 {
            return f32::INFINITY;
        }
        f32::from_bits((eq as u32) << 23) // mantissa zeroed: pure 2^(Et-Ec)
    }
}

impl DivApprox for DivMask {
    fn name(&self) -> &'static str {
        "mask"
    }

    #[inline]
    fn div(&self, t: u32, c: u32) -> u32 {
        debug_assert!(c >= 1);
        if t == 0 {
            return 0;
        }
        let et = ilog2(t);
        let ec = ilog2(c);
        if ec > et {
            0
        } else {
            1u32 << (et - ec).min(31)
        }
    }

    #[inline]
    fn cycles(&self, _t: u32, _c: u32) -> u64 {
        10
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integer_estimate_within_factor_four() {
        crate::util::prop::check(23, 3000, |g| {
            let t = g.u32_in(1, 1 << 28);
            let c = g.u32_in(1, 1 << 20);
            let est = DivMask.div(t, c) as f64;
            let exact = t as f64 / c as f64;
            // 2^(Et-Ec) vs t/c: each exponent truncation loses < 2x.
            assert!(est <= 2.0 * exact, "t={t} c={c} est={est} exact={exact}");
            assert!(est * 4.0 + 1.0 >= exact, "t={t} c={c} est={est} exact={exact}");
        });
    }

    #[test]
    fn float_bitmask_matches_exponent_difference() {
        for &(t, c) in &[(8.0f32, 2.0f32), (100.0, 3.0), (0.5, 4.0), (1.0, 1.0)] {
            let est = DivMask::div_f32(t, c);
            let exact = t / c;
            assert!(est <= 2.0 * exact && 4.0 * est >= exact, "{t}/{c}: {est} vs {exact}");
            // result must be a pure power of two
            assert_eq!(est.to_bits() & 0x007F_FFFF, 0);
        }
    }

    #[test]
    fn float_bitmask_underflow_and_overflow() {
        assert_eq!(DivMask::div_f32(1.0e-38, 1.0e38), 0.0);
        assert_eq!(DivMask::div_f32(1.0e38, 1.0e-38), f32::INFINITY);
    }

    #[test]
    fn integer_zero_numerator() {
        assert_eq!(DivMask.div(0, 5), 0);
    }

    #[test]
    fn constant_cost() {
        assert_eq!(DivMask.cycles(1, 1), DivMask.cycles(1 << 30, 1 << 15));
    }
}
