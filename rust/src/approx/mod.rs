//! Fast division approximation (paper §2.2).
//!
//! UnIT's pruning comparisons need `T / |c|` where `c` is the reused
//! control term (an activation in linear layers, a weight in convs). On
//! the MSP430 a software division costs on the order of a multiplication
//! (~140–170 cycles), so the paper replaces it with three hardware-
//! specific estimators, all implemented here behind the [`DivApprox`]
//! trait:
//!
//! * [`DivShift`] — Fig. 3: right-shift `c` until its MSB is reached,
//!   counting `e = ⌊log₂ c⌋`, then estimate `T/c ≈ T >> e`. For
//!   fixed-point / integer devices.
//! * [`DivTree`] — Fig. 4: find `e` by a binary search over precomputed
//!   power-of-two pivots (constant comparison count, good when operand
//!   magnitudes span a wide range).
//! * [`DivMask`] — Eq. 5/6: IEEE-754-style exponent-field arithmetic,
//!   `T/c ≈ 2^(E_T − E_c)`. For devices with floating-point formats; on
//!   the integer engine we emulate the exponent fields with `leading_zeros`
//!   (a host intrinsic — on a real FPU device this is a bit-mask + sub).
//! * [`DivExact`] — true integer division, the baseline the paper's
//!   Fig. 8 compares against.
//!
//! Every estimator reports its *modeled MSP430 cycle cost* per call so the
//! engine's ledger can account for pruning overhead exactly; Fig. 8 is
//! regenerated from these models plus a host-wallclock microbench.
//!
//! ## Approximation contract
//!
//! For `t ≥ 0, c ≥ 1` every estimator returns `d̂` with
//! `t/(2c) ≤ d̂ + 1` and `d̂ ≤ 2·t/c` (within a factor 2 of exact, the
//! power-of-two envelope). Property tests in this module enforce the
//! bound; the accuracy impact of the looser threshold is an ablation
//! (`benches/abl_thresholds.rs`).

mod exact;
mod mask;
mod shift;
mod shift_coarse;
mod tree;

pub use exact::DivExact;
pub use mask::DivMask;
pub use shift::DivShift;
pub use shift_coarse::DivShiftCoarse;
pub use tree::DivTree;

/// A `T / c` estimator with a modeled per-call MSP430 cycle cost.
pub trait DivApprox: Send + Sync {
    /// Estimator name for CLI/bench selection.
    fn name(&self) -> &'static str;

    /// Approximate `t / c`. `c` must be ≥ 1 (the engine prunes
    /// zero control terms unconditionally and never divides by them).
    fn div(&self, t: u32, c: u32) -> u32;

    /// Modeled MSP430FR5994 cycles for one call with these operands.
    fn cycles(&self, t: u32, c: u32) -> u64;
}

/// All estimator kinds, for CLI/bench selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DivKind {
    /// True integer division (the baseline; costs a real software divide).
    Exact,
    /// Bit-shifting estimator: `t >> ⌊log₂ c⌋` by repeated shifts.
    Shift,
    /// Binary-tree-search estimator: same quotient as `Shift`, pivot-compare cost.
    Tree,
    /// Bit-masking estimator over IEEE-754 exponent fields.
    Mask,
}

impl DivKind {
    /// Parse a CLI name (`exact`, `shift`, `tree`, `mask`).
    pub fn parse(s: &str) -> Option<DivKind> {
        match s {
            "exact" => Some(DivKind::Exact),
            "shift" => Some(DivKind::Shift),
            "tree" => Some(DivKind::Tree),
            "mask" => Some(DivKind::Mask),
            _ => None,
        }
    }

    /// Construct the estimator.
    pub fn build(self) -> Box<dyn DivApprox> {
        match self {
            DivKind::Exact => Box::new(DivExact),
            DivKind::Shift => Box::new(DivShift),
            DivKind::Tree => Box::new(DivTree),
            DivKind::Mask => Box::new(DivMask),
        }
    }

    /// Every kind, in CLI order.
    pub fn all() -> [DivKind; 4] {
        [DivKind::Exact, DivKind::Shift, DivKind::Tree, DivKind::Mask]
    }
}

/// `⌊log₂ v⌋` for `v ≥ 1`.
#[inline]
pub(crate) fn ilog2(v: u32) -> u32 {
    debug_assert!(v >= 1);
    31 - v.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx_kinds() -> Vec<Box<dyn DivApprox>> {
        vec![Box::new(DivShift), Box::new(DivTree), Box::new(DivMask)]
    }

    #[test]
    fn exact_is_exact() {
        let d = DivExact;
        assert_eq!(d.div(100, 7), 14);
        assert_eq!(d.div(0, 3), 0);
        assert_eq!(d.div(5, 10), 0);
    }

    #[test]
    fn all_estimators_within_power_of_two_envelope() {
        crate::util::prop::check(7, 2000, |g| {
            let t = g.u32_in(0, 1 << 24);
            let c = g.u32_in(1, 1 << 16);
            let exact = (t / c) as f64;
            for a in approx_kinds() {
                let est = a.div(t, c) as f64;
                assert!(
                    est <= 2.0 * exact + 1.0,
                    "{}: t={t} c={c} est={est} exact={exact}",
                    a.name()
                );
                assert!(
                    est + 1.0 >= exact / 2.0,
                    "{}: t={t} c={c} est={est} exact={exact}",
                    a.name()
                );
            }
        });
    }

    #[test]
    fn all_estimators_monotone_in_divisor() {
        // The planned conv layout's load-bearing contract: for a fixed
        // numerator, every estimator is non-increasing in its divisor,
        // so taps sorted by descending |w| have non-decreasing
        // w̄ = div(T, |w|) at every threshold scale — which is what
        // makes the |w| order scale-independent and the keep-set a
        // prefix (`engine::plan`). A new DivApprox that violates this
        // must not ship.
        let all: Vec<Box<dyn DivApprox>> = DivKind::all().iter().map(|k| k.build()).collect();
        crate::util::prop::check(9, 2000, |g| {
            let t = g.u32_in(0, 1 << 26);
            let c = g.u32_in(1, 1 << 16);
            let c2 = c + g.u32_in(1, 1 << 10); // strictly larger divisor
            for a in &all {
                assert!(
                    a.div(t, c2) <= a.div(t, c),
                    "{}: div({t}, {c2}) > div({t}, {c}) — not monotone",
                    a.name()
                );
            }
        });
    }

    #[test]
    fn shift_and_tree_agree() {
        // Same estimate (t >> floor(log2 c)), different cost model.
        crate::util::prop::check(8, 1000, |g| {
            let t = g.u32_in(0, 1 << 30);
            let c = g.u32_in(1, 1 << 20);
            assert_eq!(DivShift.div(t, c), DivTree.div(t, c));
        });
    }

    #[test]
    fn approximations_cheaper_than_exact() {
        // Fig. 8 precondition: every approximator must beat true division
        // in modeled cycles on representative operands.
        for a in approx_kinds() {
            for &(t, c) in &[(1000u32, 3u32), (65535, 255), (1 << 20, 1 << 10)] {
                assert!(
                    a.cycles(t, c) < DivExact.cycles(t, c),
                    "{} not cheaper at t={t} c={c}",
                    a.name()
                );
            }
        }
    }

    #[test]
    fn divkind_parse_roundtrip() {
        for k in DivKind::all() {
            let name = k.build().name();
            assert_eq!(DivKind::parse(name), Some(k));
        }
        assert_eq!(DivKind::parse("bogus"), None);
    }

    #[test]
    fn ilog2_values() {
        assert_eq!(ilog2(1), 0);
        assert_eq!(ilog2(2), 1);
        assert_eq!(ilog2(3), 1);
        assert_eq!(ilog2(255), 7);
        assert_eq!(ilog2(256), 8);
        assert_eq!(ilog2(u32::MAX), 31);
    }
}
