//! Coarse bit-shifting (paper §2.2: "the shift count can be initialized
//! from a nonzero value for coarser estimation, effectively quantizing
//! the threshold").
//!
//! Starting the Fig.-3 shift loop at `init` skips the first `init`
//! iterations: the estimated exponent becomes `max(⌊log₂ c⌋, init)`, so
//! small control terms are treated as if they were `2^init`. The
//! estimate only *shrinks* (`t >> e'` ≤ `t >> e`), which under Eq. 2/3
//! means coarse shifting can only prune *less*, never more — a safe,
//! cheaper knob: the loop runs `e − init` fewer iterations.

use super::{ilog2, DivApprox};

/// Bit shifting with a nonzero initial shift count.
pub struct DivShiftCoarse {
    /// Initial shift count (0 = plain [`super::DivShift`]).
    pub init: u32,
}

impl DivApprox for DivShiftCoarse {
    fn name(&self) -> &'static str {
        "shift-coarse"
    }

    #[inline]
    fn div(&self, t: u32, c: u32) -> u32 {
        debug_assert!(c >= 1);
        let e = ilog2(c).max(self.init);
        t >> e.min(31)
    }

    #[inline]
    fn cycles(&self, _t: u32, c: u32) -> u64 {
        let e = ilog2(c.max(1)) as u64;
        let iters = (e + 1).saturating_sub(self.init as u64).max(1);
        4 * iters + e + 6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::DivShift;

    #[test]
    fn init_zero_matches_plain_shift() {
        let coarse = DivShiftCoarse { init: 0 };
        crate::util::prop::check(71, 1000, |g| {
            let t = g.u32_in(0, 1 << 28);
            let c = g.u32_in(1, 1 << 20);
            assert_eq!(coarse.div(t, c), DivShift.div(t, c));
        });
    }

    #[test]
    fn coarse_estimate_never_exceeds_plain() {
        // t >> max(e, init) <= t >> e: coarse can only prune less.
        crate::util::prop::check(72, 2000, |g| {
            let t = g.u32_in(0, 1 << 28);
            let c = g.u32_in(1, 1 << 16);
            let init = g.u32_in(0, 12);
            let coarse = DivShiftCoarse { init };
            assert!(coarse.div(t, c) <= DivShift.div(t, c));
        });
    }

    #[test]
    fn coarse_is_cheaper_for_small_operands() {
        let coarse = DivShiftCoarse { init: 6 };
        assert!(coarse.cycles(0, 3) < DivShift.cycles(0, 3));
        // for large c (e > init) the loop length converges
        assert_eq!(
            coarse.cycles(0, 1 << 14),
            DivShift.cycles(0, 1 << 14) - 4 * 6
        );
    }

    #[test]
    fn exactness_on_large_powers_of_two() {
        let coarse = DivShiftCoarse { init: 4 };
        assert_eq!(coarse.div(1 << 20, 1 << 10), 1 << 10);
        // small c quantized up to 2^init
        assert_eq!(coarse.div(1 << 20, 2), (1 << 20) >> 4);
    }
}
