//! True integer division — the Fig. 8 baseline.
//!
//! The MSP430FR5994 has no divide instruction; compilers emit a software
//! routine. TI's SLAA329 app note measures a 16÷16 restoring division at
//! roughly twice the cost of the shift-and-add multiply (~77 cycles), and
//! the paper calls division "nearly as expensive as multiplication". We
//! model 140 cycles per 32÷16 software division (documented constant in
//! [`crate::mcu::cost`]).

use super::DivApprox;
use crate::mcu::cost;

/// Exact `t / c` via the (modeled) software division routine.
pub struct DivExact;

impl DivApprox for DivExact {
    fn name(&self) -> &'static str {
        "exact"
    }

    #[inline]
    fn div(&self, t: u32, c: u32) -> u32 {
        debug_assert!(c >= 1);
        t / c
    }

    #[inline]
    fn cycles(&self, _t: u32, _c: u32) -> u64 {
        cost::DIV_SW
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn division_identities() {
        assert_eq!(DivExact.div(12, 4), 3);
        assert_eq!(DivExact.div(13, 4), 3);
        assert_eq!(DivExact.div(u32::MAX, 1), u32::MAX);
    }

    #[test]
    fn constant_cost() {
        assert_eq!(DivExact.cycles(1, 1), DivExact.cycles(u32::MAX, 3));
    }
}
