//! Training driver: runs the AOT `train_<ds>` HLO step in a loop from
//! Rust (Python stays build-time only) and caches trained weights.

pub mod eval;
pub mod trainer;

pub use eval::{
    evaluate_float, evaluate_float_parallel, evaluate_float_plan, evaluate_quant,
    evaluate_quant_parallel, EvalResult, QuantEvalResult,
};
pub use trainer::{ensure_trained, ensure_trained_tagged, train, TrainConfig};
