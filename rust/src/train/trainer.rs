//! SGD training loop over the exported train-step HLO.
//!
//! The step executable computes `(params', momenta', loss)` from
//! `(params, momenta, x_batch, y_onehot, lr)` — the whole optimizer is
//! inside the AOT artifact, so the Rust side is just a data pump:
//! sample a batch, execute, swap buffers, log loss.
//!
//! [`ensure_trained`] caches weights under `artifacts/weights/<ds>.bin`
//! so every experiment reuses one training run.

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::models::{zoo, Params};
use crate::runtime::{ArtifactStore, Runtime};
use crate::util::Rng;

/// Training hyperparameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// SGD steps.
    pub steps: usize,
    /// Initial learning rate.
    pub lr: f32,
    /// Shuffle/init seed.
    pub seed: u64,
    /// Loss log period in steps (0 = silent).
    pub log_every: usize,
    /// Cosine-decay the learning rate to 10 % over the run.
    pub lr_decay: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 400, lr: 0.05, seed: 7, log_every: 50, lr_decay: true }
    }
}

impl TrainConfig {
    /// Per-model tuned defaults (single-seed, validated in EXPERIMENTS.md):
    /// the larger kws / widar models diverge at the small-model lr.
    pub fn for_model(model: &str) -> TrainConfig {
        let (steps, lr) = match model {
            "kws" => (300, 0.01),
            "widar" => (500, 0.015),
            _ => (400, 0.05),
        };
        TrainConfig { steps, lr, ..Default::default() }
    }
}

/// Minibatch size used by the trainer.
pub const TRAIN_BATCH: usize = 32;

/// Train `model` on `ds.train`, returning trained params and the loss
/// curve (one entry per step).
pub fn train(
    rt: &Runtime,
    store: &ArtifactStore,
    model: &str,
    ds: &Dataset,
    cfg: &TrainConfig,
) -> Result<(Params, Vec<f32>)> {
    let def = zoo(model);
    let manifest = store.manifest(model)?;
    manifest.check_against(&def).context("manifest/zoo consistency")?;
    let exe = store.load_train(rt, model)?;

    let init = Params::random(&def, cfg.seed);
    let mut flat: Vec<Vec<f32>> = init.flat_order().into_iter().map(|s| s.to_vec()).collect();
    let mut mom: Vec<Vec<f32>> = flat.iter().map(|t| vec![0.0; t.len()]).collect();
    let n_tensors = flat.len();

    let mut rng = Rng::new(cfg.seed ^ 0x7121);
    let mut losses = Vec::with_capacity(cfg.steps);
    let n_train = ds.train.len();
    anyhow::ensure!(n_train >= TRAIN_BATCH, "train split smaller than batch");

    for step in 0..cfg.steps {
        let idx: Vec<usize> =
            (0..TRAIN_BATCH).map(|_| rng.below(n_train as u64) as usize).collect();
        let (bx, by) = ds.train.batch(&idx, def.classes);
        let lr = if cfg.lr_decay {
            let t = step as f32 / cfg.steps.max(1) as f32;
            cfg.lr * (0.1 + 0.9 * 0.5 * (1.0 + (std::f32::consts::PI * t).cos()))
        } else {
            cfg.lr
        };
        let lr_arr = [lr];

        let mut args: Vec<&[f32]> = Vec::with_capacity(2 * n_tensors + 3);
        for t in &flat {
            args.push(t);
        }
        for m in &mom {
            args.push(m);
        }
        args.push(&bx);
        args.push(&by);
        args.push(&lr_arr);

        let mut out = exe.run_f32(&args)?;
        anyhow::ensure!(out.len() == 2 * n_tensors + 1, "train step arity");
        let loss = out.pop().unwrap()[0];
        let new_mom = out.split_off(n_tensors);
        flat = out;
        mom = new_mom;
        losses.push(loss);
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            eprintln!("[train {model}] step {step:4} loss {loss:.4} lr {lr:.4}");
        }
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
    }

    let params = Params::from_flat_order(flat)?;
    Ok((params, losses))
}

/// Load cached weights or train and cache them.
pub fn ensure_trained(
    rt: &Runtime,
    store: &ArtifactStore,
    model: &str,
    ds: &Dataset,
    cfg: &TrainConfig,
) -> Result<Params> {
    ensure_trained_tagged(rt, store, model, model, ds, cfg)
}

/// Like [`ensure_trained`] but with a distinct cache tag — used when the
/// same architecture is trained on several datasets (Table 2 trains the
/// widar model once per room).
pub fn ensure_trained_tagged(
    rt: &Runtime,
    store: &ArtifactStore,
    model: &str,
    tag: &str,
    ds: &Dataset,
    cfg: &TrainConfig,
) -> Result<Params> {
    let path = store.weights_path(tag);
    if path.is_file() {
        if let Ok(p) = Params::load(&path) {
            return Ok(p);
        }
        eprintln!("[train] cached weights at {path:?} unreadable; retraining");
    }
    let (params, losses) = train(rt, store, model, ds, cfg)?;
    let first = losses.first().copied().unwrap_or(0.0);
    let last = losses.last().copied().unwrap_or(0.0);
    eprintln!("[train {model}] loss {first:.4} -> {last:.4} over {} steps", losses.len());
    params.save(&path)?;
    Ok(params)
}
