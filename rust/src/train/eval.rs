//! Evaluation helpers: accuracy / macro-F1 / MAC statistics over a split
//! using the float forward pass (the paper's desktop-platform numbers)
//! — the MCU-platform equivalents come from [`crate::engine`].

use crate::data::Split;
use crate::models::{ModelDef, Params};
use crate::nn::{forward, ForwardOpts, ForwardStats};
use crate::util::stats::{accuracy, argmax, macro_f1};

/// Aggregated evaluation result.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub accuracy: f64,
    pub macro_f1: f64,
    /// Fraction of MACs skipped across the whole split.
    pub mac_skipped: f64,
    /// Per-layer aggregate stats.
    pub stats: ForwardStats,
    pub n: usize,
}

/// Evaluate `params` on up to `max_samples` of `split` under `opts`.
pub fn evaluate_float(
    def: &ModelDef,
    params: &Params,
    split: &Split,
    opts: &ForwardOpts,
    max_samples: usize,
) -> EvalResult {
    let n = split.len().min(max_samples);
    assert!(n > 0, "empty eval split");
    let mut preds = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let mut agg = ForwardStats::default();
    for i in 0..n {
        let (logits, stats) = forward(def, params, split.sample(i), opts);
        preds.push(argmax(&logits));
        labels.push(split.y[i]);
        agg.merge(&stats);
    }
    EvalResult {
        accuracy: accuracy(&preds, &labels),
        macro_f1: macro_f1(&preds, &labels, def.classes),
        mac_skipped: agg.skip_fraction(),
        stats: agg,
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{mnist_like, Sizes};
    use crate::models::zoo;

    #[test]
    fn random_model_near_chance() {
        let def = zoo("mnist");
        let params = Params::random(&def, 1);
        let ds = mnist_like::generate(2, Sizes { train: 4, val: 4, test: 40 });
        let r = evaluate_float(&def, &params, &ds.test, &ForwardOpts::dense(3), 40);
        assert!(r.accuracy < 0.5, "untrained model suspiciously good: {}", r.accuracy);
        assert_eq!(r.n, 40);
    }

    #[test]
    fn skip_fraction_rises_with_threshold() {
        let def = zoo("mnist");
        let params = Params::random(&def, 2);
        let ds = mnist_like::generate(3, Sizes { train: 4, val: 4, test: 10 });
        let lo = evaluate_float(&def, &params, &ds.test, &ForwardOpts::unit(vec![0.01; 3]), 10);
        let hi = evaluate_float(&def, &params, &ds.test, &ForwardOpts::unit(vec![0.5; 3]), 10);
        assert!(hi.mac_skipped > lo.mac_skipped);
    }
}
