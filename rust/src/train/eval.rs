//! Evaluation helpers: accuracy / macro-F1 / MAC statistics over a split
//! using the float forward pass (the paper's desktop-platform numbers)
//! — the MCU-platform equivalents come from [`crate::engine`].
//!
//! Both entry points run on the prepacked [`FloatPlan`] (compile once,
//! reuse scratch), which is bit-identical to the naive per-sample
//! [`crate::nn::forward`]:
//!
//! * [`evaluate_float`] — sequential, the drop-in original API;
//! * [`evaluate_float_parallel`] — the same evaluation fanned out over
//!   a simple `std::thread::scope` pool (no rayon in the vendored set),
//!   with deterministic, order-independent aggregation so its result
//!   is identical to the sequential one.
//!
//! The fixed-point twin mirrors the pair on the MCU engine's prepacked
//! plans ([`crate::engine::PlannedModel`]):
//!
//! * [`evaluate_quant`] — sequential plan-backed evaluation with the
//!   full merged [`crate::mcu::Ledger`];
//! * [`evaluate_quant_parallel`] — one [`crate::engine::Scratch`] per
//!   thread, per-slot predictions, per-layer `u64` kept/skipped sums
//!   and [`crate::mcu::Ledger::merge`]d totals. Every aggregate is an
//!   integer sum (commutative, associative), so the result is
//!   **bit-identical** to the sequential path for any thread count —
//!   which is what lets the Fig. 5–7 sweeps run multi-core without
//!   touching the modeled MCU costs.

use crate::data::Split;
use crate::engine::{PlanConfig, PlannedModel, QModel};
use crate::mcu::Ledger;
use crate::models::{ModelDef, Params};
use crate::nn::{FloatPlan, ForwardOpts, ForwardStats};
use crate::util::stats::{accuracy, argmax, macro_f1};

/// Aggregated evaluation result.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Top-1 accuracy.
    pub accuracy: f64,
    /// Macro-averaged F1.
    pub macro_f1: f64,
    /// Fraction of MACs skipped across the whole split.
    pub mac_skipped: f64,
    /// Per-layer aggregate stats.
    pub stats: ForwardStats,
    /// Samples evaluated.
    pub n: usize,
}

fn finish(
    def: &ModelDef,
    preds: Vec<usize>,
    labels: Vec<usize>,
    agg: ForwardStats,
    n: usize,
) -> EvalResult {
    EvalResult {
        accuracy: accuracy(&preds, &labels),
        macro_f1: macro_f1(&preds, &labels, def.classes),
        mac_skipped: agg.skip_fraction(),
        stats: agg,
        n,
    }
}

/// Evaluate `params` on up to `max_samples` of `split` under `opts`.
pub fn evaluate_float(
    def: &ModelDef,
    params: &Params,
    split: &Split,
    opts: &ForwardOpts,
    max_samples: usize,
) -> EvalResult {
    let plan = FloatPlan::compile(def, params, opts);
    evaluate_float_plan(def, &plan, split, max_samples)
}

/// Evaluate an already-compiled (or restamped) [`FloatPlan`] — the
/// sweep-friendly variant of [`evaluate_float`]: a threshold sweep
/// compiles the sorted tables once ([`FloatPlan::compile`]), then
/// pays only a [`FloatPlan::restamp`] + this call per setting.
pub fn evaluate_float_plan(
    def: &ModelDef,
    plan: &FloatPlan,
    split: &Split,
    max_samples: usize,
) -> EvalResult {
    let n = split.len().min(max_samples);
    assert!(n > 0, "empty eval split");
    let mut scratch = plan.new_scratch();
    let mut preds = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let mut agg = ForwardStats::default();
    for i in 0..n {
        let (logits, stats) = plan.forward(split.sample(i), &mut scratch);
        preds.push(argmax(&logits));
        labels.push(split.y[i]);
        agg.merge(&stats);
    }
    finish(def, preds, labels, agg, n)
}

/// Parallel batched evaluation: identical result to [`evaluate_float`]
/// (same plan, per-slot predictions, commutative stat sums), computed
/// on `threads` worker threads. `threads == 0` means "use available
/// parallelism".
pub fn evaluate_float_parallel(
    def: &ModelDef,
    params: &Params,
    split: &Split,
    opts: &ForwardOpts,
    max_samples: usize,
    threads: usize,
) -> EvalResult {
    let n = split.len().min(max_samples);
    assert!(n > 0, "empty eval split");
    let requested = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    };
    let threads = requested.clamp(1, n);
    let plan = FloatPlan::compile(def, params, opts);
    let chunk = n.div_ceil(threads);
    let mut preds = vec![0usize; n];
    let mut parts: Vec<ForwardStats> = Vec::with_capacity(threads);
    std::thread::scope(|sc| {
        let plan = &plan;
        let mut handles = Vec::with_capacity(threads);
        for (tid, pred_chunk) in preds.chunks_mut(chunk).enumerate() {
            handles.push(sc.spawn(move || {
                let mut scratch = plan.new_scratch();
                let mut agg = ForwardStats::default();
                let base = tid * chunk;
                for (off, slot) in pred_chunk.iter_mut().enumerate() {
                    let (logits, stats) = plan.forward(split.sample(base + off), &mut scratch);
                    *slot = argmax(&logits);
                    agg.merge(&stats);
                }
                agg
            }));
        }
        for h in handles {
            parts.push(h.join().expect("eval worker panicked"));
        }
    });
    let mut agg = ForwardStats::default();
    for p in &parts {
        agg.merge(p);
    }
    let labels: Vec<usize> = split.y[..n].to_vec();
    finish(def, preds, labels, agg, n)
}

/// Aggregated fixed-point evaluation result: accuracy plus the exact
/// per-layer MAC counts and the merged MCU ledger of the whole split.
#[derive(Debug, Clone)]
pub struct QuantEvalResult {
    /// Top-1 accuracy.
    pub accuracy: f64,
    /// Macro-averaged F1.
    pub macro_f1: f64,
    /// Global fraction of MACs skipped across the split.
    pub mac_skipped: f64,
    /// Per-sample argmax predictions (input order).
    pub preds: Vec<usize>,
    /// Per-layer kept MACs, summed over the split.
    pub kept: Vec<u64>,
    /// Per-layer skipped MACs, summed over the split.
    pub skipped: Vec<u64>,
    /// Merged execution ledger (op counts, compute + memory cycles).
    pub ledger: Ledger,
    /// Samples evaluated.
    pub n: usize,
}

/// Per-thread integer aggregates; all fields merge commutatively.
#[derive(Debug, Clone)]
struct QuantAgg {
    kept: Vec<u64>,
    skipped: Vec<u64>,
    ledger: Ledger,
}

impl QuantAgg {
    fn new(n_layers: usize) -> QuantAgg {
        QuantAgg { kept: vec![0; n_layers], skipped: vec![0; n_layers], ledger: Ledger::new() }
    }

    fn absorb(&mut self, kept: &[u64], skipped: &[u64], ledger: &Ledger) {
        for (a, b) in self.kept.iter_mut().zip(kept) {
            *a += *b;
        }
        for (a, b) in self.skipped.iter_mut().zip(skipped) {
            *a += *b;
        }
        self.ledger.merge(ledger);
    }

    fn merge(&mut self, other: &QuantAgg) {
        self.absorb(&other.kept, &other.skipped, &other.ledger);
    }
}

fn finish_quant(
    plan: &PlannedModel,
    preds: Vec<usize>,
    labels: Vec<usize>,
    agg: QuantAgg,
    n: usize,
) -> QuantEvalResult {
    let kept_total: u64 = agg.kept.iter().sum();
    let skip_total: u64 = agg.skipped.iter().sum();
    let total = kept_total + skip_total;
    QuantEvalResult {
        accuracy: accuracy(&preds, &labels),
        macro_f1: macro_f1(&preds, &labels, plan.def.classes),
        mac_skipped: if total == 0 { 0.0 } else { skip_total as f64 / total as f64 },
        preds,
        kept: agg.kept,
        skipped: agg.skipped,
        ledger: agg.ledger,
        n,
    }
}

/// Evaluate the quantized model on up to `max_samples` of `split`
/// through the prepacked fixed-point engine (sequential reference).
pub fn evaluate_quant(
    q: &QModel,
    cfg: PlanConfig,
    split: &Split,
    max_samples: usize,
) -> QuantEvalResult {
    let n = split.len().min(max_samples);
    assert!(n > 0, "empty eval split");
    let plan = PlannedModel::compile(q, cfg);
    let mut scratch = plan.new_scratch();
    let mut preds = Vec::with_capacity(n);
    let mut agg = QuantAgg::new(plan.def.layers.len());
    for i in 0..n {
        let xi = plan.quantize_input(split.sample(i));
        let out = plan.infer(&xi, &mut scratch);
        preds.push(out.argmax());
        agg.absorb(&out.kept, &out.skipped, &out.ledger);
    }
    let labels = split.y[..n].to_vec();
    finish_quant(&plan, preds, labels, agg, n)
}

/// Parallel fixed-point evaluation: bit-identical to [`evaluate_quant`]
/// (same compiled plan, per-slot predictions, commutative integer
/// sums and [`crate::mcu::Ledger::merge`]) on `threads` worker threads.
/// `threads == 0` means "use available parallelism".
pub fn evaluate_quant_parallel(
    q: &QModel,
    cfg: PlanConfig,
    split: &Split,
    max_samples: usize,
    threads: usize,
) -> QuantEvalResult {
    let n = split.len().min(max_samples);
    assert!(n > 0, "empty eval split");
    let requested = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    };
    let threads = requested.clamp(1, n);
    let plan = PlannedModel::compile(q, cfg);
    let n_layers = plan.def.layers.len();
    let chunk = n.div_ceil(threads);
    let mut preds = vec![0usize; n];
    let mut parts: Vec<QuantAgg> = Vec::with_capacity(threads);
    std::thread::scope(|sc| {
        let plan = &plan;
        let mut handles = Vec::with_capacity(threads);
        for (tid, pred_chunk) in preds.chunks_mut(chunk).enumerate() {
            handles.push(sc.spawn(move || {
                let mut scratch = plan.new_scratch();
                let mut agg = QuantAgg::new(n_layers);
                let base = tid * chunk;
                for (off, slot) in pred_chunk.iter_mut().enumerate() {
                    let xi = plan.quantize_input(split.sample(base + off));
                    let out = plan.infer(&xi, &mut scratch);
                    *slot = out.argmax();
                    agg.absorb(&out.kept, &out.skipped, &out.ledger);
                }
                agg
            }));
        }
        for h in handles {
            parts.push(h.join().expect("quant eval worker panicked"));
        }
    });
    let mut agg = QuantAgg::new(n_layers);
    for p in &parts {
        agg.merge(p);
    }
    let labels = split.y[..n].to_vec();
    finish_quant(&plan, preds, labels, agg, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{mnist_like, Sizes};
    use crate::models::zoo;

    #[test]
    fn random_model_near_chance() {
        let def = zoo("mnist");
        let params = Params::random(&def, 1);
        let ds = mnist_like::generate(2, Sizes { train: 4, val: 4, test: 40 });
        let r = evaluate_float(&def, &params, &ds.test, &ForwardOpts::dense(3), 40);
        assert!(r.accuracy < 0.5, "untrained model suspiciously good: {}", r.accuracy);
        assert_eq!(r.n, 40);
    }

    #[test]
    fn skip_fraction_rises_with_threshold() {
        let def = zoo("mnist");
        let params = Params::random(&def, 2);
        let ds = mnist_like::generate(3, Sizes { train: 4, val: 4, test: 10 });
        let lo = evaluate_float(&def, &params, &ds.test, &ForwardOpts::unit(vec![0.01; 3]), 10);
        let hi = evaluate_float(&def, &params, &ds.test, &ForwardOpts::unit(vec![0.5; 3]), 10);
        assert!(hi.mac_skipped > lo.mac_skipped);
    }

    #[test]
    fn parallel_identical_to_sequential() {
        let def = zoo("mnist");
        let params = Params::random(&def, 4);
        let ds = mnist_like::generate(5, Sizes { train: 4, val: 4, test: 30 });
        let opts = ForwardOpts::unit(vec![0.2; 3]);
        let seq = evaluate_float(&def, &params, &ds.test, &opts, 30);
        for threads in [1usize, 2, 3, 7, 0] {
            let par = evaluate_float_parallel(&def, &params, &ds.test, &opts, 30, threads);
            assert_eq!(par.n, seq.n, "threads={threads}");
            assert_eq!(par.accuracy, seq.accuracy, "threads={threads}");
            assert_eq!(par.macro_f1, seq.macro_f1, "threads={threads}");
            assert_eq!(par.mac_skipped, seq.mac_skipped, "threads={threads}");
            assert_eq!(par.stats.kept, seq.stats.kept, "threads={threads}");
            assert_eq!(par.stats.skipped, seq.stats.skipped, "threads={threads}");
        }
    }

    #[test]
    fn parallel_more_threads_than_samples() {
        let def = zoo("mnist");
        let params = Params::random(&def, 6);
        let ds = mnist_like::generate(7, Sizes { train: 4, val: 4, test: 3 });
        let r = evaluate_float_parallel(&def, &params, &ds.test, &ForwardOpts::dense(3), 3, 16);
        assert_eq!(r.n, 3);
    }

    mod quant {
        use super::super::{evaluate_quant, evaluate_quant_parallel};
        use crate::approx::DivKind;
        use crate::data::{mnist_like, Sizes};
        use crate::engine::{infer, EngineConfig, PlanConfig, PruneMode, QModel};
        use crate::mcu::Ledger;
        use crate::models::{zoo, Params};
        use crate::pruning::Thresholds;

        fn setup(mode: PruneMode) -> (QModel, crate::data::Dataset, PlanConfig) {
            let def = zoo("mnist");
            let params = Params::random(&def, 11);
            let mut q = QModel::quantize(&def, &params);
            if matches!(mode, PruneMode::Unit) {
                q = q.with_thresholds(&Thresholds::uniform(3, 0.2));
            }
            let ds = mnist_like::generate(13, Sizes { train: 4, val: 4, test: 24 });
            (q, ds, PlanConfig::for_mode(mode, DivKind::Shift))
        }

        #[test]
        fn quant_parallel_bit_identical_to_sequential_all_modes() {
            for mode in [PruneMode::Dense, PruneMode::ZeroSkip, PruneMode::Unit] {
                let (q, ds, cfg) = setup(mode);
                let seq = evaluate_quant(&q, cfg, &ds.test, 24);
                for threads in [1usize, 2, 3, 7, 0] {
                    let par = evaluate_quant_parallel(&q, cfg, &ds.test, 24, threads);
                    let tag = format!("{mode:?} threads={threads}");
                    assert_eq!(par.preds, seq.preds, "{tag}");
                    assert_eq!(par.accuracy, seq.accuracy, "{tag}");
                    assert_eq!(par.macro_f1, seq.macro_f1, "{tag}");
                    assert_eq!(par.mac_skipped, seq.mac_skipped, "{tag}");
                    assert_eq!(par.kept, seq.kept, "{tag}");
                    assert_eq!(par.skipped, seq.skipped, "{tag}");
                    assert_eq!(par.ledger, seq.ledger, "{tag}");
                }
            }
        }

        #[test]
        fn quant_parallel_matches_naive_engine_totals() {
            // The strongest form of the acceptance bar: the multi-core
            // sweep equals a hand-rolled loop over the *naive* reference
            // engine — not just the planned sequential path.
            let (q, ds, cfg) = setup(PruneMode::Unit);
            let div = DivKind::Shift.build();
            let ecfg = EngineConfig {
                mode: PruneMode::Unit,
                div: div.as_ref(),
                sonic_accumulators: true,
                precomputed_conv_thresholds: false,
                t_scale_q8: 256,
            };
            let n = 12usize;
            let mut preds = Vec::new();
            let mut ledger = Ledger::new();
            let mut kept = vec![0u64; 3];
            let mut skipped = vec![0u64; 3];
            for i in 0..n {
                let out = infer(&q, &q.quantize_input(ds.test.sample(i)), &ecfg);
                preds.push(out.argmax());
                for li in 0..3 {
                    kept[li] += out.kept[li];
                    skipped[li] += out.skipped[li];
                }
                ledger.merge(&out.ledger);
            }
            let par = evaluate_quant_parallel(&q, cfg, &ds.test, n, 3);
            assert_eq!(par.preds, preds);
            assert_eq!(par.kept, kept);
            assert_eq!(par.skipped, skipped);
            assert_eq!(par.ledger, ledger);
        }

        #[test]
        fn quant_skip_fraction_rises_with_threshold() {
            let def = zoo("mnist");
            let params = Params::random(&def, 15);
            let ds = mnist_like::generate(17, Sizes { train: 4, val: 4, test: 10 });
            let cfg = PlanConfig::for_mode(PruneMode::Unit, DivKind::Shift);
            let lo = evaluate_quant_parallel(
                &q_with(&def, &params, 0.01),
                cfg,
                &ds.test,
                10,
                2,
            );
            let hi = evaluate_quant_parallel(&q_with(&def, &params, 0.5), cfg, &ds.test, 10, 2);
            assert!(hi.mac_skipped > lo.mac_skipped);
        }

        fn q_with(def: &crate::models::ModelDef, params: &Params, t: f32) -> QModel {
            QModel::quantize(def, params).with_thresholds(&Thresholds::uniform(3, t))
        }
    }
}
