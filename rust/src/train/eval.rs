//! Evaluation helpers: accuracy / macro-F1 / MAC statistics over a split
//! using the float forward pass (the paper's desktop-platform numbers)
//! — the MCU-platform equivalents come from [`crate::engine`].
//!
//! Both entry points run on the prepacked [`FloatPlan`] (compile once,
//! reuse scratch), which is bit-identical to the naive per-sample
//! [`crate::nn::forward`]:
//!
//! * [`evaluate_float`] — sequential, the drop-in original API;
//! * [`evaluate_float_parallel`] — the same evaluation fanned out over
//!   a simple `std::thread::scope` pool (no rayon in the vendored set),
//!   with deterministic, order-independent aggregation so its result
//!   is identical to the sequential one.

use crate::data::Split;
use crate::models::{ModelDef, Params};
use crate::nn::{FloatPlan, ForwardOpts, ForwardStats};
use crate::util::stats::{accuracy, argmax, macro_f1};

/// Aggregated evaluation result.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub accuracy: f64,
    pub macro_f1: f64,
    /// Fraction of MACs skipped across the whole split.
    pub mac_skipped: f64,
    /// Per-layer aggregate stats.
    pub stats: ForwardStats,
    pub n: usize,
}

fn finish(
    def: &ModelDef,
    preds: Vec<usize>,
    labels: Vec<usize>,
    agg: ForwardStats,
    n: usize,
) -> EvalResult {
    EvalResult {
        accuracy: accuracy(&preds, &labels),
        macro_f1: macro_f1(&preds, &labels, def.classes),
        mac_skipped: agg.skip_fraction(),
        stats: agg,
        n,
    }
}

/// Evaluate `params` on up to `max_samples` of `split` under `opts`.
pub fn evaluate_float(
    def: &ModelDef,
    params: &Params,
    split: &Split,
    opts: &ForwardOpts,
    max_samples: usize,
) -> EvalResult {
    let n = split.len().min(max_samples);
    assert!(n > 0, "empty eval split");
    let plan = FloatPlan::compile(def, params, opts);
    let mut scratch = plan.new_scratch();
    let mut preds = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let mut agg = ForwardStats::default();
    for i in 0..n {
        let (logits, stats) = plan.forward(split.sample(i), &mut scratch);
        preds.push(argmax(&logits));
        labels.push(split.y[i]);
        agg.merge(&stats);
    }
    finish(def, preds, labels, agg, n)
}

/// Parallel batched evaluation: identical result to [`evaluate_float`]
/// (same plan, per-slot predictions, commutative stat sums), computed
/// on `threads` worker threads. `threads == 0` means "use available
/// parallelism".
pub fn evaluate_float_parallel(
    def: &ModelDef,
    params: &Params,
    split: &Split,
    opts: &ForwardOpts,
    max_samples: usize,
    threads: usize,
) -> EvalResult {
    let n = split.len().min(max_samples);
    assert!(n > 0, "empty eval split");
    let requested = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    };
    let threads = requested.clamp(1, n);
    let plan = FloatPlan::compile(def, params, opts);
    let chunk = (n + threads - 1) / threads;
    let mut preds = vec![0usize; n];
    let mut parts: Vec<ForwardStats> = Vec::with_capacity(threads);
    std::thread::scope(|sc| {
        let plan = &plan;
        let mut handles = Vec::with_capacity(threads);
        for (tid, pred_chunk) in preds.chunks_mut(chunk).enumerate() {
            handles.push(sc.spawn(move || {
                let mut scratch = plan.new_scratch();
                let mut agg = ForwardStats::default();
                let base = tid * chunk;
                for (off, slot) in pred_chunk.iter_mut().enumerate() {
                    let (logits, stats) = plan.forward(split.sample(base + off), &mut scratch);
                    *slot = argmax(&logits);
                    agg.merge(&stats);
                }
                agg
            }));
        }
        for h in handles {
            parts.push(h.join().expect("eval worker panicked"));
        }
    });
    let mut agg = ForwardStats::default();
    for p in &parts {
        agg.merge(p);
    }
    let labels: Vec<usize> = split.y[..n].to_vec();
    finish(def, preds, labels, agg, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{mnist_like, Sizes};
    use crate::models::zoo;

    #[test]
    fn random_model_near_chance() {
        let def = zoo("mnist");
        let params = Params::random(&def, 1);
        let ds = mnist_like::generate(2, Sizes { train: 4, val: 4, test: 40 });
        let r = evaluate_float(&def, &params, &ds.test, &ForwardOpts::dense(3), 40);
        assert!(r.accuracy < 0.5, "untrained model suspiciously good: {}", r.accuracy);
        assert_eq!(r.n, 40);
    }

    #[test]
    fn skip_fraction_rises_with_threshold() {
        let def = zoo("mnist");
        let params = Params::random(&def, 2);
        let ds = mnist_like::generate(3, Sizes { train: 4, val: 4, test: 10 });
        let lo = evaluate_float(&def, &params, &ds.test, &ForwardOpts::unit(vec![0.01; 3]), 10);
        let hi = evaluate_float(&def, &params, &ds.test, &ForwardOpts::unit(vec![0.5; 3]), 10);
        assert!(hi.mac_skipped > lo.mac_skipped);
    }

    #[test]
    fn parallel_identical_to_sequential() {
        let def = zoo("mnist");
        let params = Params::random(&def, 4);
        let ds = mnist_like::generate(5, Sizes { train: 4, val: 4, test: 30 });
        let opts = ForwardOpts::unit(vec![0.2; 3]);
        let seq = evaluate_float(&def, &params, &ds.test, &opts, 30);
        for threads in [1usize, 2, 3, 7, 0] {
            let par = evaluate_float_parallel(&def, &params, &ds.test, &opts, 30, threads);
            assert_eq!(par.n, seq.n, "threads={threads}");
            assert_eq!(par.accuracy, seq.accuracy, "threads={threads}");
            assert_eq!(par.macro_f1, seq.macro_f1, "threads={threads}");
            assert_eq!(par.mac_skipped, seq.mac_skipped, "threads={threads}");
            assert_eq!(par.stats.kept, seq.stats.kept, "threads={threads}");
            assert_eq!(par.stats.skipped, seq.stats.skipped, "threads={threads}");
        }
    }

    #[test]
    fn parallel_more_threads_than_samples() {
        let def = zoo("mnist");
        let params = Params::random(&def, 6);
        let ds = mnist_like::generate(7, Sizes { train: 4, val: 4, test: 3 });
        let r = evaluate_float_parallel(&def, &params, &ds.test, &ForwardOpts::dense(3), 3, 16);
        assert_eq!(r.n, 3);
    }
}
