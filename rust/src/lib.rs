//! # unit-pruner — UnIT: Unstructured Inference-Time Pruning for MCUs
//!
//! A full-system reproduction of *"UnIT: Scalable Unstructured
//! Inference-Time Pruning for MAC-efficient Neural Inference on MCUs"*
//! (Neth et al., 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): Pallas kernels implementing
//!   the paper's Eq. 2 (activation-relative thresholds for linear layers)
//!   and Eq. 3 (weight-relative thresholds for convolutions), verified
//!   against pure-jnp oracles.
//! * **Layer 2** (`python/compile/model.py`): the four Table-1
//!   architectures in JAX, AOT-lowered once to HLO text artifacts.
//! * **Layer 3** (this crate): everything at runtime — an MSP430-class
//!   MCU simulator with a cycle/energy cost model ([`mcu`]), the
//!   fixed-point inference engine with connection-level MAC skipping
//!   ([`engine`]), the UnIT pruning logic and baselines ([`pruning`]),
//!   the fast division approximations ([`approx`]), synthetic datasets
//!   ([`data`]), a PJRT runtime that loads the AOT artifacts
//!   ([`runtime`]), a training driver ([`train`]), a serving
//!   coordinator ([`coordinator`]), an adaptive control plane —
//!   scale-indexed plan cache, per-layer keep-ratio calibration, and a
//!   budget-driven governor ([`control`]) — and a streamed TCP serving
//!   layer — framed wire protocol, client sessions with backpressure,
//!   deadlines and cancellation ([`serve`]) — all made observable by a
//!   flight recorder, mergeable histograms, and a Prometheus/Chrome-trace
//!   exposition layer ([`obs`]). Python never runs on the request path.
//!
//! See `PAPER.md` for the source paper's abstract, `docs/architecture.md`
//! for a diagram-backed tour of the serving stack, `docs/wire-protocol.md`
//! for the normative framed TCP protocol, and `docs/operations.md` for the
//! operator's guide to `unit serve`.

#![warn(missing_docs)]

pub mod approx;
pub mod blas;
pub mod control;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod fixed;
pub mod mcu;
pub mod models;
pub mod nn;
pub mod obs;
pub mod pruning;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;
