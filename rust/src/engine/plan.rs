//! Prepacked execution plans: the host-fast path of the MCU simulator.
//!
//! [`infer`](super::infer::infer) walks the raw [`QModel`] and pays one
//! branchy compare — plus ledger bookkeeping — per *skipped*
//! connection, and reallocates every activation buffer per layer. That
//! is faithful to the modeled MSP430, but it means an 82 %-MAC-skipped
//! UnIT inference is no faster than dense *on the host*, which caps
//! every eval / bench / serving path.
//!
//! [`PlannedModel::compile`] pre-structures the weights once so that
//! irregular inference-time sparsity becomes contiguous, branch-free
//! inner loops (SparseRT-style):
//!
//! * **Linear layers (Eq. 2)** — each weight row is magnitude-sorted.
//!   Eq. 2's keep-set `|w| > T/|x|` is then exactly a *prefix* of the
//!   row, found by one binary search per activation; the kernel
//!   iterates kept taps only, so a skipped MAC costs O(log n)
//!   amortized instead of a compare.
//! * **Conv layers (Eq. 3)** — taps are regrouped into per-input-
//!   channel **segments** (one per distinct layer threshold) and
//!   sorted by **descending `|w|`** — a *scale-independent* order.
//!   Every division estimator is monotone non-increasing in its
//!   divisor, so the per-tap threshold `w̄ = T·s/|w|` is non-decreasing
//!   along each segment at *every* runtime scale `s`, and Eq. 3's
//!   keep-set `w̄ < |x|` stays a prefix. The scale-dependent state per
//!   segment collapses to a **cut table**: the stamped `w̄` values plus
//!   two `u16` prefix lengths (`always`: taps kept by every nonzero
//!   pixel, `live`: taps reachable by any `|x|` at all) that bound the
//!   per-pixel binary search. A scale change re-*stamps* the cut
//!   tables (`n` divisions, no sort) instead of recompiling the layer
//!   — the plan cache's miss cost.
//! * **Interior/border split + lane packing** — each conv segment is
//!   compiled into two tables: a lane-packed interior mirror
//!   (`[i16; 8]` weight groups / `[i32; 8]` accumulator-offset groups,
//!   scalar tail) whose kept-MAC multiply loop autovectorizes, used
//!   for pixels where every tap lands in-bounds; and the scalar
//!   `(w, kbase, u, v)` taps that keep the clipped per-tap path for
//!   border pixels.
//! * **Kernel backends** — [`KernelBackend`] selects how the interior
//!   scatter and the linear row sweep execute: the scalar reference,
//!   the lane-packed autovectorized path, or explicit SSE2/AVX2/NEON
//!   intrinsics with register-blocked accumulators (`Auto`, the
//!   default, picks the widest safe path via one-time runtime CPU
//!   dispatch — see [`super::kernels`]). All backends are
//!   bit-identical; they differ only in host speed.
//! * **Scratch arena** — [`Scratch`] owns the accumulator and
//!   ping-pong activation buffers, eliminating all per-inference
//!   `Vec` allocations.
//! * **Closed-form ledger** — per-layer charges are folded into
//!   precomputed constants plus one arithmetic update per layer
//!   (`mac_n` / `skip_n` / `div_n` / batched FRAM traffic) instead of
//!   per-connection `dyn DivApprox` calls.
//!
//! ## Host speed vs modeled MCU cost
//!
//! The plan changes *how the host computes* the inference, never *what
//! the modeled MCU is billed*. Logits, per-layer kept/skipped counts,
//! and the full [`Ledger`] (op counts, compute cycles, memory cycles)
//! are **bit-identical** to the reference engine for every
//! [`PruneMode`], division estimator, threshold configuration, and
//! FATReLU cut-off — the equivalence property tests in
//! `tests/engine_cross_layer.rs` pin this across the whole zoo (and
//! i64 accumulation is order-independent, so the lane-packed interior
//! path and the scalar reference produce identical accumulators). The
//! MCU never executes the sorted kernels; it is still modeled as the
//! naive loops. The plan is purely a simulator accelerator, which is
//! why serving, eval, and benches can all sit on it without touching
//! the paper's cost model.

use std::sync::Arc;

use super::infer::{requant, scaled_t, InferOutput, PruneMode};
use super::kernels;
use super::qmodel::QModel;
use crate::approx::{DivApprox, DivKind};
use crate::mcu::{cost, FramModel, Ledger};
use crate::models::ModelDef;
use crate::nn::layers::{conv2d_shape, Layer};

/// Lane width of the interior conv kernel: 8 × i16 weights / 8 × i32
/// offsets per group — one 128-bit vector register each, the narrowest
/// width every target this runs on can autovectorize.
pub const CONV_LANES: usize = 8;

/// The largest attainable `|x|` for Q8.8 activations (`|-32768|`,
/// inclusive). A tap whose stamped `w̄` is ≥ this can never satisfy
/// the strict keep predicate `w̄ < |x|` (since `|x| ≤ AX_CEIL`) and is
/// dead at that scale — the `live` cut excludes it from the search.
const AX_CEIL: u32 = 1 << 15;

/// Interior-pixel conv kernel flavor. `Lanes` (the default) runs the
/// lane-packed tables; `Scalar` runs the same taps through the plain
/// per-tap loop. Both are bit-identical (i64 accumulation is
/// order-independent); `Scalar` exists so benches and property tests
/// can price and pin the lane packing against its reference.
///
/// Superseded by [`KernelBackend`] (which adds the explicit-SIMD
/// path); kept as a compatibility knob: under `KernelBackend::Auto`, a
/// config pinned to `ConvInterior::Scalar` still resolves to the
/// scalar reference, so pre-existing scalar-reference configs keep
/// their meaning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ConvInterior {
    /// Lane-packed interior tables (the fast default).
    #[default]
    Lanes,
    /// Plain per-tap reference loop over the same taps.
    Scalar,
}

/// Which inner-kernel implementation a plan executes — the conv
/// interior scatter and the linear row sweep. Every variant is
/// **bit-identical** in logits, kept/skipped counts, and the full
/// ledger (exact i32 products, order-independent i64 accumulation;
/// pinned by the `engine_cross_layer` property suite); they differ
/// only in host speed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelBackend {
    /// Resolve at compile time: the process-wide `--kernel` /
    /// `UNIT_KERNEL` override if one is set, else the widest safe path
    /// — [`Simd`](KernelBackend::Simd) when runtime dispatch finds a
    /// usable CPU level (SSE2/AVX2/NEON), [`Lanes`](KernelBackend::Lanes)
    /// otherwise. Exception: a config whose [`ConvInterior`] knob is
    /// pinned to `Scalar` resolves to `Scalar` regardless of the
    /// override, preserving the scalar-reference meaning of existing
    /// configs (and of the reference legs in tests and benches).
    #[default]
    Auto,
    /// Plain per-tap / per-row scalar loops — the reference every other
    /// backend is pinned against.
    Scalar,
    /// Lane-packed `[i16; 8]` groups relying on autovectorization (the
    /// pre-SIMD default fast path).
    Lanes,
    /// Explicit SSE2/AVX2/NEON intrinsics over the SoA mirror tables
    /// with register-blocked accumulators (see [`super::kernels`]);
    /// resolves to `Scalar` on hosts with no usable SIMD level —
    /// explicit `Simd` is always safe to request.
    Simd,
}

/// Process-wide kernel override, encoded as `KernelBackend as u8`;
/// `u8::MAX` = unset. Seeded once from `UNIT_KERNEL`, settable from
/// the CLI before any plan compiles.
fn kernel_override_cell() -> &'static std::sync::atomic::AtomicU8 {
    static CELL: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(u8::MAX);
    static SEED: std::sync::Once = std::sync::Once::new();
    SEED.call_once(|| {
        if let Some(k) = std::env::var("UNIT_KERNEL").ok().and_then(|v| KernelBackend::parse(&v))
        {
            CELL.store(k as u8, std::sync::atomic::Ordering::Relaxed);
        }
    });
    &CELL
}

impl KernelBackend {
    /// Parse a `--kernel` / `UNIT_KERNEL` value.
    pub fn parse(s: &str) -> Option<KernelBackend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(KernelBackend::Auto),
            "scalar" => Some(KernelBackend::Scalar),
            "lanes" => Some(KernelBackend::Lanes),
            "simd" => Some(KernelBackend::Simd),
            _ => None,
        }
    }

    /// Display name (`"auto"`, `"scalar"`, `"lanes"`, `"simd"`).
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Auto => "auto",
            KernelBackend::Scalar => "scalar",
            KernelBackend::Lanes => "lanes",
            KernelBackend::Simd => "simd",
        }
    }

    /// Install the process-wide default that `Auto` configs resolve to
    /// (the `--kernel` CLI flag). Call once at startup, before plans
    /// compile; plans already compiled keep the backend they resolved.
    pub fn set_process_default(k: KernelBackend) {
        kernel_override_cell().store(k as u8, std::sync::atomic::Ordering::Relaxed);
    }

    fn process_default() -> Option<KernelBackend> {
        match kernel_override_cell().load(std::sync::atomic::Ordering::Relaxed) {
            0 => Some(KernelBackend::Auto),
            1 => Some(KernelBackend::Scalar),
            2 => Some(KernelBackend::Lanes),
            3 => Some(KernelBackend::Simd),
            _ => None,
        }
    }

    /// Ground an explicit (non-`Auto`) request against the host:
    /// `Simd` degrades to `Scalar` when no SIMD level is available.
    fn resolve_explicit(self) -> KernelBackend {
        match self {
            KernelBackend::Simd if !kernels::simd_available() => KernelBackend::Scalar,
            k => k,
        }
    }

    /// The backend a default (`Auto`, `ConvInterior::Lanes`) config
    /// resolves to on this host right now — what serve/eval actually
    /// run, and what the `unit_kernel_backend` gauge, the serve
    /// `[stats]` line, and `unit top` report.
    pub fn active_label() -> &'static str {
        match KernelBackend::process_default() {
            Some(k) if k != KernelBackend::Auto => k.resolve_explicit().name(),
            _ => {
                if kernels::simd_available() {
                    "simd"
                } else {
                    "lanes"
                }
            }
        }
    }

    /// Name of the SIMD level runtime dispatch found on this host
    /// (`"avx2"`, `"sse2"`, `"neon"`, or `"none"`).
    pub fn simd_level() -> &'static str {
        kernels::level_name()
    }
}

/// Build-time configuration a plan is compiled against (the plan
/// equivalent of [`super::infer::EngineConfig`], with the estimator
/// passed by kind so the plan owns its estimator and stays `Send`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanConfig {
    /// Pruning mechanism baked into the plan.
    pub mode: PruneMode,
    /// Division estimator kind.
    pub div: DivKind,
    /// Model SONIC-style FRAM-resident accumulator traffic.
    pub sonic_accumulators: bool,
    /// Bill conv tap thresholds at deploy time instead of per inference.
    pub precomputed_conv_thresholds: bool,
    /// Runtime threshold scale in Q8.8 (256 = 1.0), baked at compile.
    pub t_scale_q8: u32,
    /// Interior conv kernel flavor (bench/test knob; see
    /// [`ConvInterior`]).
    pub conv_interior: ConvInterior,
    /// Inner-kernel backend (see [`KernelBackend`]); `Auto` resolves
    /// at compile time via [`PlanConfig::resolved_kernel`].
    pub kernel: KernelBackend,
}

impl PlanConfig {
    /// UnIT-mode config with defaults.
    pub fn unit(div: DivKind) -> PlanConfig {
        PlanConfig::for_mode(PruneMode::Unit, div)
    }

    /// Config for any mechanism with defaults.
    pub fn for_mode(mode: PruneMode, div: DivKind) -> PlanConfig {
        PlanConfig {
            mode,
            div,
            sonic_accumulators: true,
            precomputed_conv_thresholds: false,
            t_scale_q8: 256,
            conv_interior: ConvInterior::default(),
            kernel: KernelBackend::default(),
        }
    }

    /// The concrete backend this config compiles to (never `Auto`):
    /// explicit values win (with `Simd` grounded against the host);
    /// `Auto` follows the precedence documented on
    /// [`KernelBackend::Auto`].
    pub fn resolved_kernel(&self) -> KernelBackend {
        match self.kernel {
            KernelBackend::Auto => {
                if self.conv_interior == ConvInterior::Scalar {
                    return KernelBackend::Scalar;
                }
                match KernelBackend::process_default() {
                    Some(k) if k != KernelBackend::Auto => k.resolve_explicit(),
                    _ => {
                        if kernels::simd_available() {
                            KernelBackend::Simd
                        } else {
                            KernelBackend::Lanes
                        }
                    }
                }
            }
            k => k.resolve_explicit(),
        }
    }
}

/// Per-layer ledger charges that are input-independent, summed at
/// compile time and billed with single calls per inference.
#[derive(Debug, Clone, Copy, Default)]
struct LayerCharges {
    control_cycles: u64,
    compares: u64,
    divs: u64,
    div_cycles: u64,
    fram_reads: u64,
    fram_writes: u64,
}

/// One streaming conv tap (Dense / StaticSparse: no per-position
/// predicate, plain row-wise accumulate).
#[derive(Debug, Clone, Copy)]
struct StreamTap {
    /// `o * oh * ow` — base of this tap's output-channel accumulators.
    acc_base: u32,
    /// `(ci*h + u)*wd + v` — input offset of the tap's first position.
    src_off: u32,
    w: i64,
}

/// One scatter conv tap in the canonical scale-independent order
/// (descending `|w|` within its segment). The border path reads all
/// four fields; the interior path reads the lane-packed mirror
/// instead.
#[derive(Debug, Clone, Copy)]
struct ConvTap {
    w: i16,
    /// `o*oh*ow - u*ow - v`: accumulator index is `kbase + iy*ow + ix`.
    kbase: i32,
    u: u8,
    v: u8,
}

/// One tap segment: a maximal run of taps sharing one input channel
/// and one raw threshold, sorted by descending `|w|` so the stamped
/// `w̄` values are non-decreasing along it at every scale.
#[derive(Debug, Clone, Copy)]
struct ConvSeg {
    /// `[start, end)` into `ConvTables::taps` / `abs_w` (and the
    /// plan's stamped `wbar`).
    start: u32,
    end: u32,
    /// First lane group of this segment in `lane_w` / `lane_off`.
    lane_start: u32,
    /// Raw (unscaled) Eq. 3 threshold shared by every tap here.
    t_raw: u32,
}

/// The scale-invariant packed tables of one conv layer: tap order,
/// lane-packed interior mirror, and the charge constants depend only
/// on the weights and mode — never on `t_scale_q8` — so every plan
/// compiled for a different runtime scale of the same model shares one
/// copy behind an `Arc`. A plan-cache miss stamps fresh cut tables
/// over these ([`stamp_conv_cuts`]) instead of re-sorting.
#[derive(Debug)]
struct ConvTables {
    /// Scatter taps in segment order (Unit / ZeroSkip; empty for the
    /// streaming modes).
    taps: Vec<ConvTap>,
    /// `|w|` per tap — the stamping input for `w̄ = T·s/|w|`.
    abs_w: Vec<u16>,
    /// Tap segments, grouped per input channel (see `ci_segs`).
    segs: Vec<ConvSeg>,
    /// Per input channel `[start, end)` into `segs`.
    ci_segs: Vec<(u32, u32)>,
    /// Interior mirror of `taps`: weights and accumulator offsets in
    /// [`CONV_LANES`]-wide groups, each segment padded to whole groups
    /// (padding is never read — the per-pixel cut bounds every loop).
    lane_w: Vec<[i16; CONV_LANES]>,
    lane_off: Vec<[i32; CONV_LANES]>,
    /// SoA mirror of `taps` for the explicit-SIMD backend: flat weight
    /// and accumulator-offset arrays aligned 1:1 with `taps` (indexed
    /// by `ConvSeg::start`, unpadded), so the intrinsic tile loops can
    /// issue contiguous vector loads — the AoS `ConvTap` stride makes
    /// that impossible. Same descending-`|w|` order, so a per-pixel
    /// cut is still a prefix and the blocked layout stays bit-identical.
    simd_w: Vec<i16>,
    simd_off: Vec<i32>,
    /// Streaming taps in reference order (Dense / StaticSparse only).
    stream_taps: Vec<StreamTap>,
    /// Input-independent ledger charges minus the division terms
    /// (those are scale-dependent and stamped per plan).
    charges_base: LayerCharges,
}

#[derive(Debug, Clone)]
struct ConvPlan {
    out_ch: usize,
    h: usize,
    wd: usize,
    kh: usize,
    kw: usize,
    oh: usize,
    ow: usize,
    pool: bool,
    /// `oh * ow`.
    n_pos: usize,
    /// Activation length this layer emits (post-pool).
    out_len: usize,
    bias_acc: Vec<i64>,
    requant_m: i64,
    /// Shared scale-invariant tables (tap order, lanes, stream taps).
    tables: Arc<ConvTables>,
    /// Stamped `w̄` per tap, aligned with `tables.taps` —
    /// non-decreasing within each segment (the prefix invariant).
    wbar: Vec<u32>,
    /// Per segment: taps with `w̄ == 0` (kept by every nonzero pixel).
    always: Vec<u16>,
    /// Per segment: taps with `w̄ < AX_CEIL` (reachable at all); the
    /// per-pixel binary search runs only over `[always, live)`.
    live: Vec<u16>,
    /// Resolved interior kernel backend baked from the config
    /// ([`PlanConfig::resolved_kernel`]; never `Auto`).
    kernel: KernelBackend,
    total_conn: u64,
    charges: LayerCharges,
}

/// The scale-invariant packed tables of one linear layer: the
/// magnitude-sorted rows depend only on the weights, never on
/// `t_scale_q8`, so every plan compiled for a different runtime scale
/// of the same model can share one copy behind an `Arc` (the plan
/// cache's "recompile only threshold-dependent tables" contract — for
/// the KWS model this is 5.6 M entries shared across ~20 scale steps).
#[derive(Debug)]
struct LinTables {
    /// Per input row: the weight row sorted by descending `|w|`.
    sorted_w: Vec<i16>,
    /// `|w|` of `sorted_w` (the binary-search key).
    sorted_abs: Vec<u16>,
    /// Original output index of each sorted tap.
    sorted_idx: Vec<u16>,
    /// Per input row: number of nonzero weights (prefix length, since
    /// zeros sort to the tail).
    nnz: Vec<u32>,
}

#[derive(Debug, Clone)]
struct LinPlan {
    n_in: usize,
    n_out: usize,
    relu: bool,
    bias_acc: Vec<i64>,
    requant_m: i64,
    /// Effective layer threshold (already `t_scale_q8`-scaled) — the
    /// only scale-dependent field of a linear plan.
    t_eff: u32,
    /// Run the register-blocked Unit-mode row kernel (resolved backend
    /// == `Simd`): live rows gathered in tiles of [`LIN_BLOCK`], the
    /// per-row threshold cut found at gather time, the MAC sweeps
    /// drained interleaved — bit-identical to the row-at-a-time loop.
    blocked: bool,
    tables: Arc<LinTables>,
    charges: LayerCharges,
}

#[derive(Debug, Clone)]
enum LayerPlan {
    Conv(ConvPlan),
    Linear(LinPlan),
}

/// Reusable per-thread buffers for [`PlannedModel::infer`]: one i64
/// accumulator arena plus two ping-pong activation buffers, sized at
/// compile time so the inference loop never allocates.
#[derive(Debug, Clone)]
pub struct Scratch {
    acc: Vec<i64>,
    act_a: Vec<i16>,
    act_b: Vec<i16>,
}

/// A `QModel` compiled for fast host execution (see module docs).
pub struct PlannedModel {
    /// The model definition this plan executes.
    pub def: ModelDef,
    /// The config the plan was compiled with.
    pub cfg: PlanConfig,
    /// The concrete kernel backend resolved at compile time (never
    /// `Auto`) — what the hot loops of this plan actually run.
    kernel: KernelBackend,
    div: Box<dyn DivApprox>,
    fat_t_raw: i16,
    layers: Vec<LayerPlan>,
    input_len: usize,
    max_acc: usize,
    max_act: usize,
}

impl std::fmt::Debug for PlannedModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlannedModel")
            .field("model", &self.def.name)
            .field("cfg", &self.cfg)
            .field("layers", &self.layers.len())
            .finish()
    }
}

impl PlannedModel {
    /// Compile `q` against `cfg`. One-time cost ~O(weights · log n_out);
    /// every subsequent [`infer`](Self::infer) reuses the packed tables.
    pub fn compile(q: &QModel, cfg: PlanConfig) -> PlannedModel {
        PlannedModel::compile_shared(q, cfg, None)
    }

    /// Compile `q` against `cfg`, sharing scale-invariant tables with
    /// `base` — a plan previously compiled from the **same model under
    /// the same mode/div**, differing only in `t_scale_q8`.
    ///
    /// Linear layers' magnitude-sorted rows *and* conv layers' tap
    /// order + lane packing depend only on the weights, so both are
    /// reused behind their `Arc`s (no copy, no re-sort). Only the
    /// scale-dependent residue is rebuilt: the linear `t_eff` scalars
    /// and the conv **cut tables** (stamped `w̄` values plus the
    /// `always`/`live` prefix lengths per segment) — `n` divisions per
    /// conv layer, no sorting. The result is bit-identical to a fresh
    /// [`PlannedModel::compile`] at the same `cfg` (property-tested
    /// across the zoo in `control::plan_cache`).
    pub fn compile_shared(
        q: &QModel,
        cfg: PlanConfig,
        base: Option<&PlannedModel>,
    ) -> PlannedModel {
        if let Some(b) = base {
            debug_assert_eq!(b.def.name, q.def.name, "shared compile across models");
            debug_assert_eq!(b.cfg.mode, cfg.mode, "shared compile across modes");
            debug_assert_eq!(b.cfg.div, cfg.div, "shared compile across div kinds");
        }
        let div = cfg.div.build();
        let mut shape = q.def.input_shape;
        let input_len = q.def.input_len();
        let mut max_acc = 1usize;
        let mut max_act = input_len;
        let mut layers = Vec::with_capacity(q.def.layers.len());
        for (li, layer) in q.def.layers.iter().enumerate() {
            let ql = &q.layers[li];
            match *layer {
                Layer::Conv { out_ch, in_ch, kh, kw, pool } => {
                    let [c, h, wd] = shape;
                    debug_assert_eq!(c, in_ch, "conv input channels");
                    // Reuse the donor's tap order + lane tables when
                    // sharing; only the cut tables are stamped fresh.
                    let reuse = base.and_then(|b| match &b.layers[li] {
                        LayerPlan::Conv(bc) => Some(Arc::clone(&bc.tables)),
                        _ => None,
                    });
                    let cp = compile_conv(
                        ql, &cfg, div.as_ref(), out_ch, in_ch, h, wd, kh, kw, pool, reuse,
                    );
                    max_acc = max_acc.max(out_ch * cp.n_pos);
                    max_act = max_act.max(out_ch * cp.n_pos);
                    shape = if pool {
                        [out_ch, cp.oh / 2, cp.ow / 2]
                    } else {
                        [out_ch, cp.oh, cp.ow]
                    };
                    layers.push(LayerPlan::Conv(cp));
                }
                Layer::Linear { n_in, n_out, relu } => {
                    debug_assert_eq!(
                        shape.iter().product::<usize>(),
                        n_in,
                        "linear input size"
                    );
                    // Reuse the donor's sorted tables when sharing.
                    let reuse = base.and_then(|b| match &b.layers[li] {
                        LayerPlan::Linear(bl) => Some(Arc::clone(&bl.tables)),
                        _ => None,
                    });
                    let lp = compile_linear(ql, &cfg, n_in, n_out, relu, reuse);
                    max_acc = max_acc.max(n_out);
                    max_act = max_act.max(n_out);
                    shape = [n_out, 1, 1];
                    layers.push(LayerPlan::Linear(lp));
                }
            }
        }
        PlannedModel {
            def: q.def.clone(),
            cfg,
            kernel: cfg.resolved_kernel(),
            div,
            fat_t_raw: q.fat_t_raw,
            layers,
            input_len,
            max_acc,
            max_act,
        }
    }

    /// The concrete kernel backend this plan was compiled to (never
    /// `Auto`; `Simd` only when the host actually has a SIMD level).
    pub fn kernel(&self) -> KernelBackend {
        self.kernel
    }

    /// Allocate a scratch arena sized for this plan (one per thread).
    pub fn new_scratch(&self) -> Scratch {
        Scratch {
            acc: vec![0i64; self.max_acc],
            act_a: vec![0i16; self.max_act],
            act_b: vec![0i16; self.max_act],
        }
    }

    /// Quantize an f32 input sample to Q8.8 raw values (identical to
    /// [`QModel::quantize_input`]; here so workers need only the plan).
    pub fn quantize_input(&self, x: &[f32]) -> Vec<i16> {
        x.iter().map(|&v| crate::fixed::Q88::from_f32(v).raw()).collect()
    }

    /// Run one inference on the packed tables. Output (logits, kept/
    /// skipped counts, full ledger) is bit-identical to
    /// [`super::infer::infer`] under the matching `EngineConfig`.
    pub fn infer(&self, x_raw: &[i16], s: &mut Scratch) -> InferOutput {
        self.infer_observed(x_raw, s, None)
    }

    /// [`PlannedModel::infer`] with an optional per-layer observability
    /// sink. With `Some(sink)`, each layer's wall time and executed/
    /// skipped MAC counts are reported as they complete (the serving
    /// workers' flight-recorder `Layer` spans); with `None` — the
    /// [`PlannedModel::infer`] path — not even a timestamp is taken,
    /// so the unobserved hot path and its outputs are bit-identical to
    /// the pre-observability engine (pinned by the cross-layer
    /// property tests).
    pub fn infer_observed(
        &self,
        x_raw: &[i16],
        s: &mut Scratch,
        sink: Option<&dyn crate::obs::LayerSink>,
    ) -> InferOutput {
        assert_eq!(x_raw.len(), self.input_len, "input length");
        let mode = self.cfg.mode;
        let sonic = self.cfg.sonic_accumulators;
        let n_layers = self.layers.len();
        let mut kept = vec![0u64; n_layers];
        let mut skipped = vec![0u64; n_layers];
        let mut ledger = Ledger::new();
        // Input transfer: sensor buffer -> FRAM working buffer.
        ledger.fram_write(x_raw.len() as u64);

        s.act_a[..x_raw.len()].copy_from_slice(x_raw);
        let mut in_a = true;
        let mut cur_len = x_raw.len();

        for (li, lp) in self.layers.iter().enumerate() {
            let t_layer = sink.map(|_| std::time::Instant::now());
            let acc = &mut s.acc;
            let (src_buf, dst_buf) = if in_a {
                (&mut s.act_a, &mut s.act_b)
            } else {
                (&mut s.act_b, &mut s.act_a)
            };
            let src: &[i16] = &src_buf[..cur_len];
            match lp {
                LayerPlan::Conv(cp) => {
                    // bias preload
                    for o in 0..cp.out_ch {
                        acc[o * cp.n_pos..(o + 1) * cp.n_pos].fill(cp.bias_acc[o]);
                    }
                    let k = match mode {
                        PruneMode::Unit | PruneMode::ZeroSkip => conv_scatter(cp, src, acc),
                        PruneMode::Dense | PruneMode::StaticSparse => {
                            conv_stream(cp, src, acc)
                        }
                    };
                    // requant + FATReLU
                    let n_out_elems = cp.out_ch * cp.n_pos;
                    for (d, &a) in dst_buf[..n_out_elems].iter_mut().zip(acc.iter()) {
                        let y = requant(a, cp.requant_m);
                        *d = if y > self.fat_t_raw { y } else { 0 };
                    }
                    if cp.pool {
                        pool2x2_in_place(&mut dst_buf[..n_out_elems], cp.out_ch, cp.oh, cp.ow);
                    }
                    kept[li] = k;
                    skipped[li] = cp.total_conn - k;
                    charge_layer(&mut ledger, &cp.charges, k, cp.total_conn, sonic);
                    cur_len = cp.out_len;
                }
                LayerPlan::Linear(lp) => {
                    acc[..lp.n_out].copy_from_slice(&lp.bias_acc);
                    let run = linear_exec(lp, mode, self.div.as_ref(), src, acc);
                    // requant (+ optional FATReLU on hidden linears)
                    for (j, d) in dst_buf[..lp.n_out].iter_mut().enumerate() {
                        let y = requant(acc[j], lp.requant_m);
                        *d = if lp.relu {
                            if y > self.fat_t_raw {
                                y
                            } else {
                                0
                            }
                        } else {
                            y
                        };
                    }
                    let total = (lp.n_in * lp.n_out) as u64;
                    kept[li] = run.kept;
                    skipped[li] = total - run.kept;
                    charge_layer(&mut ledger, &lp.charges, run.kept, total, sonic);
                    // Runtime-dependent linear charges: weight streams +
                    // row sweeps happen only for live (nonzero) rows, and
                    // Eq. 2 divisions depend on the activation values.
                    if matches!(mode, PruneMode::ZeroSkip | PruneMode::Unit) {
                        ledger.fram_read(run.live_rows * lp.n_out as u64);
                        ledger.compare_n(run.live_rows * lp.n_out as u64);
                    }
                    ledger.div_n(run.divs, run.div_cycles);
                    cur_len = lp.n_out;
                }
            }
            // (output-commit FRAM traffic is part of each layer's
            // compile-time charges — see compile_conv / compile_linear)
            if let Some(sk) = sink {
                let ns = t_layer.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0);
                sk.layer(li, ns, kept[li], skipped[li]);
            }
            in_a = !in_a;
        }

        // Executed-MAC ledger consistency, same invariant as the
        // reference engine.
        debug_assert_eq!(kept.iter().sum::<u64>(), ledger.counts.macs);

        let act = if in_a { &s.act_a } else { &s.act_b };
        let logits_raw: Vec<i16> = act[..cur_len].to_vec();
        let logits: Vec<f32> =
            logits_raw.iter().map(|&r| crate::fixed::Q88(r).to_f32()).collect();
        InferOutput { logits_raw, logits, kept, skipped, ledger }
    }
}

impl PlannedModel {
    /// Total connections (dense MACs) of the whole model — the
    /// input-independent ceiling of [`PlannedModel::estimate_macs`].
    pub fn dense_macs(&self) -> u64 {
        self.layers.iter().map(layer_total_conn).sum()
    }

    /// Estimate the MACs one sample will execute, **without running
    /// inference** — the admission/placement cost signal for the
    /// serving layer, where balancing mixed dense/pruned traffic by
    /// queue *length* is wrong because UnIT's per-sample work varies
    /// with activation sparsity.
    ///
    /// The estimate reuses the plan's sorted tables as prefix-sum
    /// queries: for the **first layer** (whose activations are the
    /// input itself) each nonzero input value binary-searches its
    /// keep-set cut exactly as the kernel would — Eq. 2's
    /// `|w| > T/|x|` prefix per linear row, Eq. 3's `w̄ < |x|` prefix
    /// per conv segment — and, since the interior/border split, border
    /// pixels count only their clipped in-bounds taps, so the layer-0
    /// count is **exact** (asserted by the plan tests). Deeper layers'
    /// activations are unknown before execution, so each one is billed
    /// its input-independent executed-MAC total scaled by the layer-0
    /// keep ratio, the plan's input-density proxy. `Dense` and
    /// `StaticSparse` have input-independent cost and return it
    /// exactly.
    ///
    /// Cost: O(input_len · log taps) — microseconds against a
    /// millisecond-scale inference; zeroing input values never raises
    /// the estimate (property-tested).
    pub fn estimate_macs(&self, x_raw: &[i16]) -> u64 {
        assert_eq!(x_raw.len(), self.input_len, "input length");
        let static_total: u64 = self.layers.iter().map(|l| layer_static_macs(l, self.cfg.mode)).sum();
        if matches!(self.cfg.mode, PruneMode::Dense | PruneMode::StaticSparse) {
            return static_total.max(1);
        }
        if self.layers.is_empty() {
            return 1;
        }
        let (kept0, total0) = self.layer0_exact_kept(x_raw);
        if total0 == 0 {
            return static_total.max(1);
        }
        let ratio = kept0 as f64 / total0 as f64;
        let mut est = kept0;
        for l in self.layers.iter().skip(1) {
            let cap = layer_static_macs(l, self.cfg.mode);
            est += ((cap as f64 * ratio).round() as u64).min(cap);
        }
        est.max(1)
    }

    /// Input-independent executed-MAC ceiling of every layer under this
    /// plan's mode (exact for `Dense`/`StaticSparse`, the
    /// all-activations-live ceiling otherwise) — the denominators the
    /// control plane's calibrated keep-ratio curves are expressed over.
    pub fn static_macs_per_layer(&self) -> Vec<u64> {
        self.layers.iter().map(|l| layer_static_macs(l, self.cfg.mode)).collect()
    }

    /// Exact kept-MAC count of the **first** layer for `x_raw`, as
    /// `(kept, ceiling)` — the input-density probe shared by
    /// [`PlannedModel::estimate_macs`] and the control plane's
    /// per-layer profiled estimator. Exact for conv first layers too:
    /// border pixels count only their clipped in-bounds taps, exactly
    /// as the split kernel executes them. For the input-independent
    /// modes (`Dense`/`StaticSparse`) this is `(ceiling, ceiling)`.
    pub fn layer0_exact_kept(&self, x_raw: &[i16]) -> (u64, u64) {
        assert_eq!(x_raw.len(), self.input_len, "input length");
        let Some(first) = self.layers.first() else { return (0, 0) };
        let total0 = layer_static_macs(first, self.cfg.mode);
        if matches!(self.cfg.mode, PruneMode::Dense | PruneMode::StaticSparse) {
            return (total0, total0);
        }
        let kept0 = match first {
            LayerPlan::Conv(cp) => conv_count_kept(cp, x_raw),
            LayerPlan::Linear(lp) => {
                let mut kept = 0u64;
                for (k, &xv) in x_raw.iter().enumerate() {
                    if xv == 0 {
                        continue;
                    }
                    match self.cfg.mode {
                        PruneMode::Unit => {
                            let tbar = if lp.t_eff == 0 {
                                0
                            } else {
                                self.div.div(lp.t_eff, (xv as i32).unsigned_abs())
                            };
                            let abs_row =
                                &lp.tables.sorted_abs[k * lp.n_out..(k + 1) * lp.n_out];
                            kept += abs_row.partition_point(|&a| a as u32 > tbar) as u64;
                        }
                        _ => kept += lp.tables.nnz[k] as u64,
                    }
                }
                kept
            }
        };
        (kept0, total0)
    }
}

/// Dense connection count of one compiled layer.
fn layer_total_conn(lp: &LayerPlan) -> u64 {
    match lp {
        LayerPlan::Conv(cp) => cp.total_conn,
        LayerPlan::Linear(lp) => (lp.n_in * lp.n_out) as u64,
    }
}

/// Input-independent executed-MAC total of one layer under `mode`: the
/// exact cost for `Dense`/`StaticSparse`, the all-activations-live
/// ceiling for `ZeroSkip`/`Unit`.
fn layer_static_macs(lp: &LayerPlan, mode: PruneMode) -> u64 {
    match lp {
        LayerPlan::Conv(cp) => match mode {
            PruneMode::Dense => cp.total_conn,
            PruneMode::StaticSparse => {
                cp.tables.stream_taps.len() as u64 * cp.n_pos as u64
            }
            // scatter modes store only live taps
            PruneMode::ZeroSkip | PruneMode::Unit => {
                cp.tables.taps.len() as u64 * cp.n_pos as u64
            }
        },
        LayerPlan::Linear(lin) => match mode {
            PruneMode::Dense => (lin.n_in * lin.n_out) as u64,
            _ => lin.tables.nnz.iter().map(|&z| z as u64).sum(),
        },
    }
}

/// Plan handle + private scratch: the drop-in "compile once, infer
/// many" front door used by workers and benches.
pub struct PlanBacked {
    /// The shared compiled plan.
    pub plan: Arc<PlannedModel>,
    scratch: Scratch,
}

impl PlanBacked {
    /// Compile `q` and wrap it with fresh scratch.
    pub fn new(q: &QModel, cfg: PlanConfig) -> PlanBacked {
        let plan = Arc::new(PlannedModel::compile(q, cfg));
        PlanBacked::from_plan(plan)
    }

    /// Share one compiled plan across threads; each `PlanBacked` owns
    /// its scratch.
    pub fn from_plan(plan: Arc<PlannedModel>) -> PlanBacked {
        let scratch = plan.new_scratch();
        PlanBacked { plan, scratch }
    }

    /// Run one raw Q8.8 sample through the plan.
    pub fn infer(&mut self, x_raw: &[i16]) -> InferOutput {
        self.plan.infer(x_raw, &mut self.scratch)
    }

    /// Quantize an f32 sample to the plan's Q8.8 input domain.
    pub fn quantize_input(&self, x: &[f32]) -> Vec<i16> {
        self.plan.quantize_input(x)
    }
}

/// Bill one layer's closed-form charges: compile-time constants plus
/// the kept-count-dependent terms, in totals identical to the reference
/// engine's per-connection calls.
fn charge_layer(ledger: &mut Ledger, ch: &LayerCharges, kept: u64, total_conn: u64, sonic: bool) {
    ledger.control(ch.control_cycles);
    ledger.compare_n(ch.compares);
    ledger.div_n(ch.divs, ch.div_cycles);
    ledger.mac_n(kept);
    ledger.skip_n(total_conn - kept);
    let mut reads = ch.fram_reads;
    let mut writes = ch.fram_writes;
    if sonic {
        // FRAM-resident partial sums: RMW per executed MAC only.
        reads += 2 * kept;
        writes += 2 * kept;
    }
    ledger.fram_read(reads);
    ledger.fram_write(writes);
}

/// Build the scale-invariant conv tables (see [`ConvTables`]): one
/// enumeration of the live taps, grouped into per-input-channel
/// segments by raw threshold, each segment sorted by descending `|w|`
/// (stable, so equal-magnitude taps keep their reference enumeration
/// order — deterministic tables for a given model), plus the
/// lane-packed interior mirror and the scale-independent charge
/// constants.
#[allow(clippy::too_many_arguments)]
fn build_conv_tables(
    ql: &super::qmodel::QLayer,
    mode: PruneMode,
    out_ch: usize,
    in_ch: usize,
    h: usize,
    wd: usize,
    kh: usize,
    kw: usize,
    pool: bool,
) -> ConvTables {
    let (oh, ow) = conv2d_shape(h, wd, kh, kw);
    let n_pos = oh * ow;
    let n_taps_total = (out_ch * in_ch * kh * kw) as u64;
    let scatter_mode = matches!(mode, PruneMode::Unit | PruneMode::ZeroSkip);

    // Per input channel, taps bucketed by raw threshold (BTreeMap ⇒
    // deterministic segment order). ZeroSkip has no threshold: one
    // bucket (t_raw = 0) per channel.
    let mut per_ci: Vec<std::collections::BTreeMap<u32, Vec<(u16, ConvTap)>>> =
        (0..in_ch).map(|_| std::collections::BTreeMap::new()).collect();
    let mut stream_taps = Vec::new();
    let mut n_live = 0u64;

    for o in 0..out_ch {
        let t_raw_o = if !ql.t_raw_groups.is_empty() { ql.t_raw_groups[o] } else { ql.t_raw };
        for ci in 0..in_ch {
            for u in 0..kh {
                for v in 0..kw {
                    let wv = ql.w[((o * in_ch + ci) * kh + u) * kw + v];
                    match mode {
                        PruneMode::Unit | PruneMode::ZeroSkip => {
                            if wv == 0 {
                                continue; // pruned for free at plan time
                            }
                            n_live += 1;
                            let key = if mode == PruneMode::Unit { t_raw_o } else { 0 };
                            per_ci[ci].entry(key).or_default().push((
                                wv.unsigned_abs() as u16,
                                ConvTap {
                                    w: wv as i16,
                                    kbase: (o * n_pos) as i32 - (u * ow) as i32 - v as i32,
                                    u: u as u8,
                                    v: v as u8,
                                },
                            ));
                        }
                        PruneMode::StaticSparse => {
                            if wv == 0 {
                                continue;
                            }
                            n_live += 1;
                            stream_taps.push(StreamTap {
                                acc_base: (o * n_pos) as u32,
                                src_off: ((ci * h + u) * wd + v) as u32,
                                w: wv as i64,
                            });
                        }
                        PruneMode::Dense => {
                            // Dense visits every tap, zero weights included.
                            n_live += 1;
                            stream_taps.push(StreamTap {
                                acc_base: (o * n_pos) as u32,
                                src_off: ((ci * h + u) * wd + v) as u32,
                                w: wv as i64,
                            });
                        }
                    }
                }
            }
        }
    }

    // Flatten buckets into segments: descending |w| inside each (the
    // stamped w̄ is then non-decreasing at every scale, because every
    // division estimator is monotone non-increasing in its divisor —
    // property-pinned in `crate::approx`), lane-packed mirror padded
    // per segment.
    let mut taps = Vec::new();
    let mut abs_w = Vec::new();
    let mut segs = Vec::new();
    let mut ci_segs = Vec::with_capacity(in_ch);
    let mut lane_w: Vec<[i16; CONV_LANES]> = Vec::new();
    let mut lane_off: Vec<[i32; CONV_LANES]> = Vec::new();
    let mut simd_w: Vec<i16> = Vec::new();
    let mut simd_off: Vec<i32> = Vec::new();
    if scatter_mode {
        for buckets in per_ci.iter_mut() {
            let seg_lo = segs.len() as u32;
            for (&t_raw, group) in buckets.iter_mut() {
                // Stable: ties in |w| keep reference enumeration order.
                group.sort_by_key(|&(a, _)| std::cmp::Reverse(a));
                assert!(
                    group.len() <= u16::MAX as usize,
                    "conv segment of {} taps overflows the u16 cut table",
                    group.len()
                );
                let start = taps.len() as u32;
                let lane_start = lane_w.len() as u32;
                for &(a, t) in group.iter() {
                    abs_w.push(a);
                    taps.push(t);
                    // SoA mirror for the explicit-SIMD tile loops:
                    // same order, contiguous per field.
                    simd_w.push(t.w);
                    simd_off.push(t.kbase);
                }
                for chunk in group.chunks(CONV_LANES) {
                    let mut wl = [0i16; CONV_LANES];
                    let mut ol = [0i32; CONV_LANES];
                    for (l, &(_, t)) in chunk.iter().enumerate() {
                        wl[l] = t.w;
                        ol[l] = t.kbase;
                    }
                    lane_w.push(wl);
                    lane_off.push(ol);
                }
                segs.push(ConvSeg { start, end: taps.len() as u32, lane_start, t_raw });
            }
            ci_segs.push((seg_lo, segs.len() as u32));
        }
    } else {
        for _ in 0..in_ch {
            ci_segs.push((0, 0));
        }
    }

    // Input-independent ledger charges (mirrors the reference loop's
    // per-tap billing exactly — see charge_layer for the kept-dependent
    // remainder; the division terms are scale-dependent and stamped in
    // compile_conv).
    let mut charges = LayerCharges::default();
    // bias preload: one MOV per output element
    charges.control_cycles += (out_ch * n_pos) as u64 * cost::MOV;
    // per-tap head: weight fetch (+ zero-compare in ZeroSkip)
    match mode {
        PruneMode::Unit | PruneMode::Dense => charges.fram_reads += n_taps_total,
        PruneMode::ZeroSkip => {
            charges.fram_reads += n_taps_total;
            charges.compares += n_taps_total;
        }
        PruneMode::StaticSparse => charges.fram_reads += n_live,
    }
    // per live tap: the OH*OW activation stream (+ Eq. 3 compares)
    charges.fram_reads += n_live * n_pos as u64;
    if matches!(mode, PruneMode::Unit | PruneMode::ZeroSkip) {
        charges.compares += n_live * n_pos as u64;
    }
    // requantization + activation threshold per output element
    charges.control_cycles += (out_ch * n_pos) as u64 * (cost::MUL_SW + cost::SHIFT * 8);
    charges.compares += (out_ch * n_pos) as u64;
    // 2x2 max pool: 4 reads + 4 compares per pooled element
    let out_len = if pool {
        let (ph, pw) = (oh / 2, ow / 2);
        charges.fram_reads += 4 * (out_ch * ph * pw) as u64;
        charges.compares += 4 * (out_ch * ph * pw) as u64;
        out_ch * ph * pw
    } else {
        out_ch * n_pos
    };
    // commit output activations (SONIC double buffer)
    charges.fram_writes += FramModel::default().commit_words(out_len as u64);

    ConvTables {
        taps,
        abs_w,
        segs,
        ci_segs,
        lane_w,
        lane_off,
        simd_w,
        simd_off,
        stream_taps,
        charges_base: charges,
    }
}

/// Stamp the scale-dependent cut tables over `tables` at `cfg`'s
/// scale: the per-tap `w̄ = T·s/|w|` values, the `always`/`live`
/// prefix lengths per segment, and the division ledger charges. This
/// is the whole per-scale cost of a conv layer — `n` divisions, no
/// sorting.
fn stamp_conv_cuts(
    tables: &ConvTables,
    cfg: &PlanConfig,
    div: &dyn DivApprox,
) -> (Vec<u32>, Vec<u16>, Vec<u16>, u64, u64) {
    let mut wbar = vec![0u32; tables.taps.len()];
    let mut always = Vec::with_capacity(tables.segs.len());
    let mut live = Vec::with_capacity(tables.segs.len());
    let mut divs = 0u64;
    let mut div_cycles = 0u64;
    for seg in &tables.segs {
        let (s, e) = (seg.start as usize, seg.end as usize);
        let t_layer = scaled_t(seg.t_raw, cfg.t_scale_q8);
        if t_layer != 0 {
            for i in s..e {
                let c = tables.abs_w[i] as u32;
                if !cfg.precomputed_conv_thresholds {
                    divs += 1;
                    div_cycles += div.cycles(t_layer, c);
                }
                wbar[i] = div.div(t_layer, c);
            }
        }
        // |w| descending + div monotone in its divisor ⇒ w̄
        // non-decreasing: the prefix invariant every per-pixel binary
        // search rests on.
        debug_assert!(
            wbar[s..e].windows(2).all(|p| p[0] <= p[1]),
            "w̄ not monotone along a |w|-sorted segment (non-monotone DivApprox?)"
        );
        always.push(wbar[s..e].partition_point(|&w| w == 0) as u16);
        live.push(wbar[s..e].partition_point(|&w| w < AX_CEIL) as u16);
    }
    (wbar, always, live, divs, div_cycles)
}

#[allow(clippy::too_many_arguments)]
fn compile_conv(
    ql: &super::qmodel::QLayer,
    cfg: &PlanConfig,
    div: &dyn DivApprox,
    out_ch: usize,
    in_ch: usize,
    h: usize,
    wd: usize,
    kh: usize,
    kw: usize,
    pool: bool,
    reuse: Option<Arc<ConvTables>>,
) -> ConvPlan {
    let (oh, ow) = conv2d_shape(h, wd, kh, kw);
    let n_pos = oh * ow;
    let n_taps_total = (out_ch * in_ch * kh * kw) as u64;
    let tables = match reuse {
        // The tap order and lane packing are a pure function of the
        // weights; a donor plan for the same model hands them over
        // without a re-sort.
        Some(t) => {
            debug_assert_eq!(t.ci_segs.len(), in_ch, "shared conv tables shape");
            t
        }
        None => Arc::new(build_conv_tables(ql, cfg.mode, out_ch, in_ch, h, wd, kh, kw, pool)),
    };
    let (wbar, always, live, divs, div_cycles) = stamp_conv_cuts(&tables, cfg, div);
    let mut charges = tables.charges_base;
    charges.divs = divs;
    charges.div_cycles = div_cycles;
    let out_len = if pool { out_ch * (oh / 2) * (ow / 2) } else { out_ch * n_pos };

    ConvPlan {
        out_ch,
        h,
        wd,
        kh,
        kw,
        oh,
        ow,
        pool,
        n_pos,
        out_len,
        bias_acc: ql.bias_acc.clone(),
        requant_m: ql.requant_m,
        tables,
        wbar,
        always,
        live,
        kernel: cfg.resolved_kernel(),
        total_conn: n_taps_total * n_pos as u64,
        charges,
    }
}

fn compile_linear(
    ql: &super::qmodel::QLayer,
    cfg: &PlanConfig,
    n_in: usize,
    n_out: usize,
    relu: bool,
    reuse: Option<Arc<LinTables>>,
) -> LinPlan {
    let t_eff = scaled_t(ql.t_raw, cfg.t_scale_q8);
    let tables = match reuse {
        // The sorted tables are a pure function of the weights; a donor
        // plan for the same model hands them over without a re-sort.
        Some(t) => {
            debug_assert_eq!(t.nnz.len(), n_in, "shared linear tables shape");
            t
        }
        None => {
            let mut sorted_w = Vec::with_capacity(n_in * n_out);
            let mut sorted_abs = Vec::with_capacity(n_in * n_out);
            let mut sorted_idx = Vec::with_capacity(n_in * n_out);
            let mut nnz = Vec::with_capacity(n_in);
            let mut order: Vec<u16> = Vec::with_capacity(n_out);
            for k in 0..n_in {
                let row = &ql.w[k * n_out..(k + 1) * n_out];
                order.clear();
                order.extend(0..n_out as u16);
                order.sort_by(|&a, &b| {
                    row[b as usize].unsigned_abs().cmp(&row[a as usize].unsigned_abs())
                });
                let mut nnz_k = 0u32;
                for &j in &order {
                    let wv = row[j as usize];
                    sorted_w.push(wv as i16);
                    sorted_abs.push(wv.unsigned_abs() as u16);
                    sorted_idx.push(j);
                    if wv != 0 {
                        nnz_k += 1;
                    }
                }
                nnz.push(nnz_k);
            }
            Arc::new(LinTables { sorted_w, sorted_abs, sorted_idx, nnz })
        }
    };

    let mut charges = LayerCharges::default();
    // bias preload
    charges.control_cycles += n_out as u64 * cost::MOV;
    // per input activation: one fetch (+ zero-compare in checking modes)
    charges.fram_reads += n_in as u64;
    if matches!(cfg.mode, PruneMode::ZeroSkip | PruneMode::Unit) {
        charges.compares += n_in as u64;
    }
    // weight streams that don't depend on the input
    match cfg.mode {
        PruneMode::Dense => charges.fram_reads += (n_in * n_out) as u64,
        PruneMode::StaticSparse => {
            charges.fram_reads += tables.nnz.iter().map(|&z| z as u64).sum::<u64>()
        }
        // ZeroSkip/Unit stream weights only for nonzero activations —
        // billed at runtime in infer().
        PruneMode::ZeroSkip | PruneMode::Unit => {}
    }
    // requantization per output element
    charges.control_cycles += n_out as u64 * (cost::MUL_SW + cost::SHIFT * 8);
    // commit output activations
    charges.fram_writes += FramModel::default().commit_words(n_out as u64);

    LinPlan {
        n_in,
        n_out,
        relu,
        bias_acc: ql.bias_acc.clone(),
        requant_m: ql.requant_m,
        t_eff,
        blocked: cfg.resolved_kernel() == KernelBackend::Simd,
        tables,
        charges,
    }
}

/// Per-pixel keep-set cut of segment `gi` for activation magnitude
/// `ax` (≥ 1): `always` taps have `w̄ == 0 < ax` unconditionally,
/// taps past `live` have `w̄ ≥ AX_CEIL ≥ ax` unconditionally, so the
/// binary search runs only over the window between them.
#[inline]
fn seg_cut(cp: &ConvPlan, gi: usize, ax: u32) -> usize {
    let seg = &cp.tables.segs[gi];
    let base = seg.start as usize;
    let always = cp.always[gi] as usize;
    let live = cp.live[gi] as usize;
    always + cp.wbar[base + always..base + live].partition_point(|&w| w < ax)
}

/// Interior-pixel accumulation over the lane-packed tables: the kept
/// prefix is walked in [`CONV_LANES`]-wide groups — the per-group
/// `i16 × i16 → i32` multiply autovectorizes — with a scalar tail for
/// the remainder. Bit-identical to the scalar tap loop (exact i32
/// products, order-independent i64 accumulation).
#[inline]
fn scatter_lanes(
    lane_w: &[[i16; CONV_LANES]],
    lane_off: &[[i32; CONV_LANES]],
    lane_start: usize,
    cut: usize,
    xv: i16,
    pix: i32,
    acc: &mut [i64],
) {
    let xv32 = xv as i32;
    let full = cut / CONV_LANES;
    for g in 0..full {
        let w = &lane_w[lane_start + g];
        let off = &lane_off[lane_start + g];
        let mut prod = [0i32; CONV_LANES];
        for l in 0..CONV_LANES {
            prod[l] = xv32 * w[l] as i32;
        }
        for l in 0..CONV_LANES {
            acc[(off[l] + pix) as usize] += prod[l] as i64;
        }
    }
    let tail = cut - full * CONV_LANES;
    if tail > 0 {
        let w = &lane_w[lane_start + full];
        let off = &lane_off[lane_start + full];
        for l in 0..tail {
            acc[(off[l] + pix) as usize] += (xv32 * w[l] as i32) as i64;
        }
    }
}

/// Scatter conv kernel (Unit / ZeroSkip): per nonzero input pixel and
/// tap segment, one bounded binary search finds the kept-tap prefix;
/// interior pixels run the lane-packed tables, border pixels the
/// clipped scalar taps. Returns the layer's kept-MAC count.
fn conv_scatter(cp: &ConvPlan, src: &[i16], acc: &mut [i64]) -> u64 {
    let t = &*cp.tables;
    let (h, wd, kh, kw, oh, ow) = (cp.h, cp.wd, cp.kh, cp.kw, cp.oh, cp.ow);
    let mut kept = 0u64;
    for (ci, &(g0, g1)) in t.ci_segs.iter().enumerate() {
        if g0 == g1 {
            continue;
        }
        let plane = &src[ci * h * wd..(ci + 1) * h * wd];
        for iy in 0..h {
            let row_interior = iy + 1 >= kh && iy < oh;
            let row_base = iy * wd;
            for ix in 0..wd {
                let xv = plane[row_base + ix];
                if xv == 0 {
                    continue; // |x| > w̄ ≥ 0 can never hold
                }
                let ax = (xv as i32).unsigned_abs();
                let pix = (iy * ow + ix) as i32;
                let interior = row_interior && ix + 1 >= kw && ix < ow;
                for gi in g0 as usize..g1 as usize {
                    // Eq. 3 keep-set is the segment prefix with w̄ < |x|.
                    let cut = seg_cut(cp, gi, ax);
                    if cut == 0 {
                        continue;
                    }
                    let seg = &t.segs[gi];
                    if interior {
                        // Interior pixel: every tap lands in-bounds.
                        match cp.kernel {
                            KernelBackend::Simd => {
                                let base = seg.start as usize;
                                kernels::scatter_simd(
                                    &t.simd_w[base..],
                                    &t.simd_off[base..],
                                    cut,
                                    xv,
                                    pix,
                                    acc,
                                );
                            }
                            KernelBackend::Lanes => scatter_lanes(
                                &t.lane_w,
                                &t.lane_off,
                                seg.lane_start as usize,
                                cut,
                                xv,
                                pix,
                                acc,
                            ),
                            _ => {
                                let base = seg.start as usize;
                                let xv64 = xv as i64;
                                for tp in &t.taps[base..base + cut] {
                                    acc[(tp.kbase + pix) as usize] += xv64 * tp.w as i64;
                                }
                            }
                        }
                        kept += cut as u64;
                    } else {
                        // Border pixel: keep only taps whose output
                        // position exists (p = iy-u, q = ix-v inside the
                        // OH×OW grid).
                        let base = seg.start as usize;
                        let xv64 = xv as i64;
                        for tp in &t.taps[base..base + cut] {
                            let (u, v) = (tp.u as usize, tp.v as usize);
                            if iy >= u && iy - u < oh && ix >= v && ix - v < ow {
                                acc[(tp.kbase + pix) as usize] += xv64 * tp.w as i64;
                                kept += 1;
                            }
                        }
                    }
                }
            }
        }
    }
    kept
}

/// Count (without accumulating) the kept MACs [`conv_scatter`] would
/// execute for `src` — the exact layer-0 probe behind
/// [`PlannedModel::layer0_exact_kept`]. Mirrors the kernel's
/// interior/border split tap for tap.
fn conv_count_kept(cp: &ConvPlan, src: &[i16]) -> u64 {
    let t = &*cp.tables;
    let (h, wd, kh, kw, oh, ow) = (cp.h, cp.wd, cp.kh, cp.kw, cp.oh, cp.ow);
    let mut kept = 0u64;
    for (ci, &(g0, g1)) in t.ci_segs.iter().enumerate() {
        if g0 == g1 {
            continue;
        }
        let plane = &src[ci * h * wd..(ci + 1) * h * wd];
        for iy in 0..h {
            let row_interior = iy + 1 >= kh && iy < oh;
            let row_base = iy * wd;
            for ix in 0..wd {
                let xv = plane[row_base + ix];
                if xv == 0 {
                    continue;
                }
                let ax = (xv as i32).unsigned_abs();
                let interior = row_interior && ix + 1 >= kw && ix < ow;
                for gi in g0 as usize..g1 as usize {
                    let cut = seg_cut(cp, gi, ax);
                    if cut == 0 {
                        continue;
                    }
                    if interior {
                        kept += cut as u64;
                    } else {
                        let base = t.segs[gi].start as usize;
                        for tp in &t.taps[base..base + cut] {
                            let (u, v) = (tp.u as usize, tp.v as usize);
                            if iy >= u && iy - u < oh && ix >= v && ix - v < ow {
                                kept += 1;
                            }
                        }
                    }
                }
            }
        }
    }
    kept
}

/// Streaming conv kernel (Dense / StaticSparse): contiguous row-wise
/// accumulate per tap, no per-position predicate.
fn conv_stream(cp: &ConvPlan, src: &[i16], acc: &mut [i64]) -> u64 {
    let (wd, oh, ow) = (cp.wd, cp.oh, cp.ow);
    for t in &cp.tables.stream_taps {
        let base = t.acc_base as usize;
        let src_off = t.src_off as usize;
        let w = t.w;
        for p in 0..oh {
            let arow = src_off + p * wd;
            let xrow = &src[arow..arow + ow];
            let dst = &mut acc[base + p * ow..base + p * ow + ow];
            for (d, &xv) in dst.iter_mut().zip(xrow) {
                *d += xv as i64 * w;
            }
        }
    }
    cp.tables.stream_taps.len() as u64 * cp.n_pos as u64
}

/// In-place 2×2 max pool over a `C×OH×OW` buffer (writes are always at
/// or before the reads: write index w reads from 4w..4w+ow+1, so the
/// shrinking output never clobbers unread input).
fn pool2x2_in_place(act: &mut [i16], out_ch: usize, oh: usize, ow: usize) {
    let (ph, pw) = (oh / 2, ow / 2);
    for o in 0..out_ch {
        for p in 0..ph {
            for q in 0..pw {
                let mut m = i16::MIN;
                for du in 0..2 {
                    for dv in 0..2 {
                        let v = act[(o * oh + 2 * p + du) * ow + 2 * q + dv];
                        if v > m {
                            m = v;
                        }
                    }
                }
                act[(o * ph + p) * pw + q] = m;
            }
        }
    }
}

/// Per-inference tallies the linear kernels hand back for ledger
/// billing.
struct LinRun {
    kept: u64,
    live_rows: u64,
    divs: u64,
    div_cycles: u64,
}

/// Sorted-row linear kernels. Eq. 2's keep-set `|w| > x̄` is a prefix of
/// the descending-|w| row; `partition_point` finds it in O(log n_out).
fn linear_exec(
    lp: &LinPlan,
    mode: PruneMode,
    div: &dyn DivApprox,
    src: &[i16],
    acc: &mut [i64],
) -> LinRun {
    let (n_in, n_out) = (lp.n_in, lp.n_out);
    let t = &*lp.tables;
    let mut kept = 0u64;
    let mut live_rows = 0u64;
    let mut divs = 0u64;
    let mut div_cycles = 0u64;
    match mode {
        PruneMode::Dense => {
            for k in 0..n_in {
                let xv = src[k];
                // Dense "executes" every MAC; zero activations contribute
                // exactly zero, so skipping the arithmetic is bit-identical.
                if xv != 0 {
                    let xv64 = xv as i64;
                    let row = &t.sorted_w[k * n_out..(k + 1) * n_out];
                    let idx = &t.sorted_idx[k * n_out..(k + 1) * n_out];
                    for (w, &j) in row.iter().zip(idx) {
                        acc[j as usize] += xv64 * *w as i64;
                    }
                }
            }
            kept = (n_in * n_out) as u64;
        }
        PruneMode::StaticSparse => {
            for k in 0..n_in {
                let xv = src[k];
                let nz = t.nnz[k] as usize;
                kept += nz as u64;
                if xv != 0 {
                    let xv64 = xv as i64;
                    let row = &t.sorted_w[k * n_out..k * n_out + nz];
                    let idx = &t.sorted_idx[k * n_out..k * n_out + nz];
                    for (w, &j) in row.iter().zip(idx) {
                        acc[j as usize] += xv64 * *w as i64;
                    }
                }
            }
        }
        PruneMode::ZeroSkip => {
            for k in 0..n_in {
                let xv = src[k];
                if xv == 0 {
                    continue; // whole row skipped with one compare
                }
                live_rows += 1;
                let nz = t.nnz[k] as usize;
                kept += nz as u64;
                let xv64 = xv as i64;
                let row = &t.sorted_w[k * n_out..k * n_out + nz];
                let idx = &t.sorted_idx[k * n_out..k * n_out + nz];
                for (w, &j) in row.iter().zip(idx) {
                    acc[j as usize] += xv64 * *w as i64;
                }
            }
        }
        PruneMode::Unit if lp.blocked => {
            // Register-blocked row kernel (the SIMD backend's linear
            // path): live rows are gathered into tiles of [`LIN_BLOCK`]
            // — each row's single Eq. 2 division and prefix lookup
            // happens at gather time, in row order, so the ledger
            // (divs, div_cycles, kept, live_rows) is identical one
            // operation for one operation — and each full tile is
            // drained with the MAC sweeps interleaved, keeping up to
            // four (row, activation, cursor) triples in registers so
            // one prefix lookup amortizes over a tile of dot products.
            // i64 accumulation of exact i32-range products is
            // order-independent, so interleaving rows is bit-identical
            // to the row-at-a-time reference below.
            let mut tile = [(0usize, 0i64, 0usize); LIN_BLOCK];
            let mut fill = 0usize;
            for k in 0..n_in {
                let xv = src[k];
                if xv == 0 {
                    continue;
                }
                live_rows += 1;
                let tbar = if lp.t_eff == 0 {
                    0
                } else {
                    let c = (xv as i32).unsigned_abs();
                    divs += 1;
                    div_cycles += div.cycles(lp.t_eff, c);
                    div.div(lp.t_eff, c)
                };
                let abs_row = &t.sorted_abs[k * n_out..(k + 1) * n_out];
                let cut = abs_row.partition_point(|&a| a as u32 > tbar);
                kept += cut as u64;
                if cut > 0 {
                    tile[fill] = (k, xv as i64, cut);
                    fill += 1;
                    if fill == LIN_BLOCK {
                        flush_lin_tile(t, n_out, &tile[..fill], acc);
                        fill = 0;
                    }
                }
            }
            if fill > 0 {
                flush_lin_tile(t, n_out, &tile[..fill], acc);
            }
        }
        PruneMode::Unit => {
            for k in 0..n_in {
                let xv = src[k];
                if xv == 0 {
                    continue;
                }
                live_rows += 1;
                let tbar = if lp.t_eff == 0 {
                    0
                } else {
                    let c = (xv as i32).unsigned_abs();
                    divs += 1;
                    div_cycles += div.cycles(lp.t_eff, c);
                    div.div(lp.t_eff, c)
                };
                let abs_row = &t.sorted_abs[k * n_out..(k + 1) * n_out];
                // Eq. 2: keep iff |w| > x̄ — a prefix of the sorted row.
                let cut = abs_row.partition_point(|&a| a as u32 > tbar);
                kept += cut as u64;
                if cut > 0 {
                    let xv64 = xv as i64;
                    let row = &t.sorted_w[k * n_out..k * n_out + cut];
                    let idx = &t.sorted_idx[k * n_out..k * n_out + cut];
                    for (w, &j) in row.iter().zip(idx) {
                        acc[j as usize] += xv64 * *w as i64;
                    }
                }
            }
        }
    }
    LinRun { kept, live_rows, divs, div_cycles }
}

/// Row-tile width of the blocked linear kernel: 4 gathered live rows
/// per flush — four (activation, cursor) pairs stay in registers
/// across the interleaved sweep.
const LIN_BLOCK: usize = 4;

/// Drain one gathered row tile `(k, xv, cut)` column-major: step `j`
/// touches every row whose kept prefix still covers `j`, so up to
/// [`LIN_BLOCK`] independent scatter-adds issue per step. Sequential
/// `+=` keeps colliding output indices across rows exact.
#[inline]
fn flush_lin_tile(t: &LinTables, n_out: usize, tile: &[(usize, i64, usize)], acc: &mut [i64]) {
    let max_cut = tile.iter().map(|&(_, _, c)| c).max().unwrap_or(0);
    for j in 0..max_cut {
        for &(k, xv64, cut) in tile {
            if j < cut {
                let base = k * n_out + j;
                acc[t.sorted_idx[base] as usize] += xv64 * t.sorted_w[base] as i64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::DivKind;
    use crate::engine::{infer, EngineConfig};
    use crate::models::{zoo, Params};
    use crate::pruning::Thresholds;

    fn assert_identical(q: &QModel, x: &[i16], mode: PruneMode, kind: DivKind) {
        let d = kind.build();
        let cfg = EngineConfig {
            mode,
            div: d.as_ref(),
            sonic_accumulators: true,
            precomputed_conv_thresholds: false,
            t_scale_q8: 256,
        };
        let naive = infer(q, x, &cfg);
        let mut pb = PlanBacked::new(q, PlanConfig::for_mode(mode, kind));
        let planned = pb.infer(x);
        assert_eq!(planned.logits_raw, naive.logits_raw, "{mode:?}/{kind:?} logits");
        assert_eq!(planned.kept, naive.kept, "{mode:?}/{kind:?} kept");
        assert_eq!(planned.skipped, naive.skipped, "{mode:?}/{kind:?} skipped");
        assert_eq!(planned.ledger.counts, naive.ledger.counts, "{mode:?}/{kind:?} op counts");
        assert_eq!(
            planned.ledger.compute_cycles, naive.ledger.compute_cycles,
            "{mode:?}/{kind:?} compute cycles"
        );
        assert_eq!(
            planned.ledger.mem_cycles, naive.ledger.mem_cycles,
            "{mode:?}/{kind:?} mem cycles"
        );
        // The scalar kernel is every other backend's reference:
        // identical output, always — including the explicit-SIMD path
        // (whatever level this host dispatches to) and the lane path.
        for kernel in [KernelBackend::Scalar, KernelBackend::Lanes, KernelBackend::Simd] {
            let mut ps = PlanBacked::new(
                q,
                PlanConfig { kernel, ..PlanConfig::for_mode(mode, kind) },
            );
            let out = ps.infer(x);
            let kn = kernel.name();
            assert_eq!(out.logits_raw, planned.logits_raw, "{mode:?}/{kind:?} {kn} logits");
            assert_eq!(out.kept, planned.kept, "{mode:?}/{kind:?} {kn} kept");
            assert_eq!(out.ledger.counts, planned.ledger.counts, "{mode:?}/{kind:?} {kn}");
            assert_eq!(
                out.ledger.compute_cycles, planned.ledger.compute_cycles,
                "{mode:?}/{kind:?} {kn} compute cycles"
            );
        }
        // The legacy ConvInterior::Scalar knob still means the scalar
        // reference, even under KernelBackend::Auto.
        let mut ps = PlanBacked::new(
            q,
            PlanConfig {
                conv_interior: ConvInterior::Scalar,
                ..PlanConfig::for_mode(mode, kind)
            },
        );
        assert_eq!(ps.plan.kernel(), KernelBackend::Scalar);
        let scalar = ps.infer(x);
        assert_eq!(scalar.logits_raw, planned.logits_raw, "{mode:?}/{kind:?} lane/scalar");
        assert_eq!(scalar.kept, planned.kept, "{mode:?}/{kind:?} lane/scalar kept");
        assert_eq!(scalar.ledger.counts, planned.ledger.counts);
    }

    #[test]
    fn planned_matches_naive_all_modes_mnist() {
        let def = zoo("mnist");
        let params = Params::random(&def, 21);
        let th = Thresholds::uniform(3, 0.25);
        let x_f: Vec<f32> = (0..def.input_len())
            .map(|i| (((i * 29) % 31) as f32 - 15.0) / 9.0)
            .collect();
        for mode in [
            PruneMode::Dense,
            PruneMode::StaticSparse,
            PruneMode::ZeroSkip,
            PruneMode::Unit,
        ] {
            let mut q = QModel::quantize(&def, &params);
            if mode == PruneMode::Unit {
                q = q.with_thresholds(&th);
            }
            let x = q.quantize_input(&x_f);
            for kind in [DivKind::Exact, DivKind::Shift] {
                assert_identical(&q, &x, mode, kind);
            }
        }
    }

    /// Border-heavy shape: the kernel spans the whole input, so every
    /// pixel takes the clipped border path (oh = ow = 1, no interior
    /// pixels at all). The split kernel must stay bit-identical to the
    /// naive engine here — this is the shape where an interior/border
    /// bookkeeping bug cannot hide.
    #[test]
    fn planned_matches_naive_on_border_only_shapes() {
        let def = ModelDef {
            name: "border-heavy".into(),
            input_shape: [2, 5, 5],
            classes: 4,
            layers: vec![
                Layer::Conv { out_ch: 3, in_ch: 2, kh: 5, kw: 5, pool: false },
                Layer::Linear { n_in: 3, n_out: 4, relu: false },
            ],
        };
        let params = Params::random(&def, 29);
        let th = Thresholds::uniform(2, 0.3);
        for mode in [PruneMode::Unit, PruneMode::ZeroSkip] {
            let mut q = QModel::quantize(&def, &params);
            if mode == PruneMode::Unit {
                q = q.with_thresholds(&th);
            }
            for seed in 0..4u64 {
                let x_f: Vec<f32> = (0..def.input_len())
                    .map(|i| (((i as u64 * 13 + seed * 7) % 27) as f32 - 13.0) / 7.0)
                    .collect();
                let x = q.quantize_input(&x_f);
                for kind in [DivKind::Exact, DivKind::Shift, DivKind::Mask] {
                    assert_identical(&q, &x, mode, kind);
                }
            }
        }
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // Two consecutive inferences through one scratch must not leak
        // state between calls.
        let def = zoo("mnist");
        let params = Params::random(&def, 22);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2));
        let mut pb = PlanBacked::new(&q, PlanConfig::unit(DivKind::Shift));
        let flat = vec![0.37f32; def.input_len()];
        let xa = q.quantize_input(&flat);
        let xb = q.quantize_input(
            &(0..def.input_len()).map(|i| ((i % 13) as f32 - 6.0) / 5.0).collect::<Vec<_>>(),
        );
        let first_a = pb.infer(&xa);
        let _b = pb.infer(&xb);
        let again_a = pb.infer(&xa);
        assert_eq!(first_a.logits_raw, again_a.logits_raw);
        assert_eq!(first_a.kept, again_a.kept);
        assert_eq!(first_a.ledger.counts, again_a.ledger.counts);
    }

    #[test]
    fn group_thresholds_and_fatrelu_match() {
        let def = zoo("mnist");
        let params = Params::random(&def, 23);
        let mut th = Thresholds::uniform(3, 0.2);
        // per-output-channel refinement on the conv layers: exercises
        // the multi-segment (one per distinct t_raw) tap grouping
        th.groups[0] = (0..6).map(|i| 0.1 + 0.05 * i as f32).collect();
        th.groups[1] = (0..16).map(|i| 0.05 + 0.02 * i as f32).collect();
        let q = QModel::quantize(&def, &params).with_thresholds(&th).with_fatrelu(0.3);
        let x = q.quantize_input(
            &(0..def.input_len()).map(|i| ((i % 17) as f32 - 8.0) / 6.0).collect::<Vec<_>>(),
        );
        assert_identical(&q, &x, PruneMode::Unit, DivKind::Tree);
    }

    #[test]
    fn precomputed_thresholds_drop_div_charges_only() {
        let def = zoo("mnist");
        let params = Params::random(&def, 24);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.3));
        let flat = vec![0.4f32; def.input_len()];
        let x = q.quantize_input(&flat);
        let base = PlanConfig::unit(DivKind::Shift);
        let pre = PlanConfig { precomputed_conv_thresholds: true, ..base };
        let mut a = PlanBacked::new(&q, base);
        let mut b = PlanBacked::new(&q, pre);
        let oa = a.infer(&x);
        let ob = b.infer(&x);
        assert_eq!(oa.logits_raw, ob.logits_raw);
        assert!(ob.ledger.compute_cycles < oa.ledger.compute_cycles);
        assert!(ob.ledger.counts.divs < oa.ledger.counts.divs);
    }

    #[test]
    fn estimate_macs_bounds_and_monotonicity() {
        let def = zoo("mnist");
        let params = Params::random(&def, 26);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.25));
        for mode in [
            PruneMode::Dense,
            PruneMode::StaticSparse,
            PruneMode::ZeroSkip,
            PruneMode::Unit,
        ] {
            let plan = PlannedModel::compile(&q, PlanConfig::for_mode(mode, DivKind::Shift));
            let dense = plan.dense_macs();
            assert!(dense > 0);
            let x_f: Vec<f32> = (0..def.input_len())
                .map(|i| (((i * 13) % 29) as f32 - 14.0) / 8.0)
                .collect();
            let x = plan.quantize_input(&x_f);
            let est = plan.estimate_macs(&x);
            assert!(est >= 1 && est <= dense, "{mode:?}: est {est} vs dense {dense}");
            // Since the interior/border split, the layer-0 probe is
            // EXACT for the conv first layer: it must equal the kept
            // count the kernel actually executes.
            let mut scratch = plan.new_scratch();
            let out = plan.infer(&x, &mut scratch);
            let (kept0, total0) = plan.layer0_exact_kept(&x);
            assert_eq!(kept0, out.kept[0], "{mode:?}: layer-0 probe not exact");
            assert_eq!(
                total0,
                out.kept[0] + out.skipped[0],
                "{mode:?}: layer-0 ceiling off"
            );
            // Zeroing inputs never raises the estimate.
            let mut sparser = x.clone();
            for v in sparser.iter_mut().step_by(3) {
                *v = 0;
            }
            let est_sparse = plan.estimate_macs(&sparser);
            assert!(
                est_sparse <= est,
                "{mode:?}: sparser input raised estimate {est_sparse} > {est}"
            );
            // All-zero input is the floor.
            let zeros = vec![0i16; def.input_len()];
            assert!(plan.estimate_macs(&zeros) <= est_sparse.max(1));
            match mode {
                // Input-independent modes report their exact cost.
                PruneMode::Dense => assert_eq!(plan.estimate_macs(&x), dense),
                PruneMode::StaticSparse => {
                    assert_eq!(plan.estimate_macs(&zeros), plan.estimate_macs(&x))
                }
                _ => {}
            }
        }
    }

    #[test]
    fn estimate_tracks_actual_work_ordering() {
        // The estimate's job is placement: ranking a denser sample
        // above a sparser one. Check it agrees with the executed MACs
        // on a clearly separated pair.
        let def = zoo("mnist");
        let params = Params::random(&def, 27);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2));
        let plan = PlannedModel::compile(&q, PlanConfig::unit(DivKind::Shift));
        let mut scratch = plan.new_scratch();
        let dense_f: Vec<f32> =
            (0..def.input_len()).map(|i| ((i % 7) as f32 - 3.0) / 2.0).collect();
        let dense_x = plan.quantize_input(&dense_f);
        let sparse_x = plan.quantize_input(
            &(0..def.input_len())
                .map(|i| if i % 11 == 0 { 0.4 } else { 0.0 })
                .collect::<Vec<_>>(),
        );
        let (ed, es) = (plan.estimate_macs(&dense_x), plan.estimate_macs(&sparse_x));
        let kd: u64 = plan.infer(&dense_x, &mut scratch).kept.iter().sum();
        let ks: u64 = plan.infer(&sparse_x, &mut scratch).kept.iter().sum();
        assert!(kd > ks, "setup: dense sample must execute more MACs");
        assert!(ed > es, "estimate ordering disagrees: {ed} vs {es} (actual {kd} vs {ks})");
    }

    #[test]
    fn shared_recompile_is_bit_identical_and_shares_tables() {
        // The plan cache's contract: a plan recompiled at a new scale
        // with a donor's scale-invariant tables must be bit-identical
        // to a fresh compile at that scale, while actually sharing BOTH
        // the linear tables and the conv tap/lane tables (no copy, no
        // re-sort — only the cut tables and t_eff are stamped).
        let def = zoo("mnist");
        let params = Params::random(&def, 28);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2));
        let base_cfg = PlanConfig::unit(DivKind::Shift);
        let base = PlannedModel::compile(&q, base_cfg);
        let x = q.quantize_input(
            &(0..def.input_len()).map(|i| ((i % 19) as f32 - 9.0) / 6.0).collect::<Vec<_>>(),
        );
        for scale in [64u32, 256, 700, 2048] {
            let cfg = PlanConfig { t_scale_q8: scale, ..base_cfg };
            let fresh = PlannedModel::compile(&q, cfg);
            let shared = PlannedModel::compile_shared(&q, cfg, Some(&base));
            let (mut sa, mut sb) = (fresh.new_scratch(), shared.new_scratch());
            let (oa, ob) = (fresh.infer(&x, &mut sa), shared.infer(&x, &mut sb));
            assert_eq!(oa.logits_raw, ob.logits_raw, "scale {scale} logits");
            assert_eq!(oa.kept, ob.kept, "scale {scale} kept");
            assert_eq!(oa.ledger.counts, ob.ledger.counts, "scale {scale} counts");
            assert_eq!(oa.ledger.compute_cycles, ob.ledger.compute_cycles);
            assert_eq!(oa.ledger.mem_cycles, ob.ledger.mem_cycles);
            assert_eq!(fresh.estimate_macs(&x), shared.estimate_macs(&x));
            let (mut linear_seen, mut conv_seen) = (false, false);
            for (ls, lb) in shared.layers.iter().zip(&base.layers) {
                match (ls, lb) {
                    (LayerPlan::Linear(a), LayerPlan::Linear(b)) => {
                        assert!(Arc::ptr_eq(&a.tables, &b.tables), "linear tables copied");
                        linear_seen = true;
                    }
                    (LayerPlan::Conv(a), LayerPlan::Conv(b)) => {
                        assert!(Arc::ptr_eq(&a.tables, &b.tables), "conv tables copied");
                        conv_seen = true;
                    }
                    _ => {}
                }
            }
            assert!(linear_seen && conv_seen, "mnist plan must have conv + linear layers");
        }
    }

    #[test]
    fn simd_mirror_tables_match_tap_order() {
        // The SoA mirror the intrinsic tile loops load from must be a
        // field-for-field transpose of the canonical taps — same
        // descending-|w| segment order, unpadded, indexed by seg.start.
        let def = zoo("mnist");
        let params = Params::random(&def, 31);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2));
        let plan = PlannedModel::compile(&q, PlanConfig::unit(DivKind::Shift));
        let mut conv_seen = false;
        for lp in &plan.layers {
            let LayerPlan::Conv(cp) = lp else { continue };
            conv_seen = true;
            let t = &*cp.tables;
            assert_eq!(t.simd_w.len(), t.taps.len());
            assert_eq!(t.simd_off.len(), t.taps.len());
            for (i, tp) in t.taps.iter().enumerate() {
                assert_eq!(t.simd_w[i], tp.w, "mirror weight at {i}");
                assert_eq!(t.simd_off[i], tp.kbase, "mirror offset at {i}");
            }
        }
        assert!(conv_seen, "mnist plan must have conv layers");
    }

    #[test]
    fn explicit_simd_request_is_always_safe() {
        // KernelBackend::Simd must resolve to a runnable backend on
        // every host: Simd where a CPU level exists, Scalar otherwise —
        // never an unresolved Auto, never a crash.
        let def = zoo("mnist");
        let params = Params::random(&def, 32);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2));
        let plan = PlannedModel::compile(
            &q,
            PlanConfig { kernel: KernelBackend::Simd, ..PlanConfig::unit(DivKind::Shift) },
        );
        assert!(matches!(plan.kernel(), KernelBackend::Simd | KernelBackend::Scalar));
        assert_ne!(plan.kernel(), KernelBackend::Auto);
        // And Auto resolves to something concrete too.
        let auto = PlannedModel::compile(&q, PlanConfig::unit(DivKind::Shift));
        assert_ne!(auto.kernel(), KernelBackend::Auto);
        assert!(["scalar", "lanes", "simd"].contains(&KernelBackend::active_label()));
        assert!(["avx2", "sse2", "neon", "none"].contains(&KernelBackend::simd_level()));
    }

    #[test]
    fn cut_tables_bound_the_search_window() {
        // The always/live prefix lengths must bracket exactly the taps
        // the per-pixel search can distinguish: w̄ == 0 before
        // `always`, 0 < w̄ < AX_CEIL inside the window, w̄ ≥ AX_CEIL
        // after `live`.
        let def = zoo("mnist");
        let params = Params::random(&def, 30);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2));
        for scale in [0u32, 64, 256, 1024, 60000] {
            let plan = PlannedModel::compile(
                &q,
                PlanConfig { t_scale_q8: scale, ..PlanConfig::unit(DivKind::Shift) },
            );
            for lp in &plan.layers {
                let LayerPlan::Conv(cp) = lp else { continue };
                for (gi, seg) in cp.tables.segs.iter().enumerate() {
                    let (s, e) = (seg.start as usize, seg.end as usize);
                    let (a, l) = (cp.always[gi] as usize, cp.live[gi] as usize);
                    assert!(a <= l && l <= e - s, "cut order");
                    assert!(cp.wbar[s..s + a].iter().all(|&w| w == 0));
                    assert!(cp.wbar[s + a..s + l].iter().all(|&w| w > 0 && w < AX_CEIL));
                    assert!(cp.wbar[s + l..e].iter().all(|&w| w >= AX_CEIL));
                    // And the segment is monotone — the prefix invariant.
                    assert!(cp.wbar[s..e].windows(2).all(|p| p[0] <= p[1]));
                }
            }
        }
    }

    #[test]
    fn t_scale_knob_respected() {
        // A higher runtime scale must skip at least as much, matching
        // the naive engine bit-for-bit at each setting.
        let def = zoo("mnist");
        let params = Params::random(&def, 25);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2));
        let x = q.quantize_input(
            &(0..def.input_len()).map(|i| ((i % 23) as f32 - 11.0) / 7.0).collect::<Vec<_>>(),
        );
        let mut last_skip = 0u64;
        for scale in [0u32, 128, 256, 512] {
            let d = DivKind::Exact.build();
            let cfg = EngineConfig {
                mode: PruneMode::Unit,
                div: d.as_ref(),
                sonic_accumulators: true,
                precomputed_conv_thresholds: false,
                t_scale_q8: scale,
            };
            let naive = infer(&q, &x, &cfg);
            let mut pb = PlanBacked::new(
                &q,
                PlanConfig { t_scale_q8: scale, ..PlanConfig::unit(DivKind::Exact) },
            );
            let planned = pb.infer(&x);
            assert_eq!(planned.logits_raw, naive.logits_raw, "scale {scale}");
            assert_eq!(planned.skipped, naive.skipped, "scale {scale}");
            let s: u64 = planned.skipped.iter().sum();
            assert!(s >= last_skip, "scale {scale}: skips decreased");
            last_skip = s;
        }
    }
}
