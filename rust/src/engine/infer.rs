//! The MCU inference inner loops: integer-only conv/linear with
//! connection-level MAC skipping, charged cycle-by-cycle to the ledger.
//!
//! ## Loop order = reuse structure (paper §2.1)
//!
//! * **Conv (Eq. 3)** — *weight-stationary*: the outer loops walk output
//!   channels and kernel taps; each tap's threshold `w̄ = T_raw/|wr|` is
//!   computed ONCE (one approximate division) and held in a register
//!   while the inner loop sweeps all OH×OW positions with a 3-cycle
//!   compare each. Skipped connections also skip the accumulator
//!   read-modify-write (SONIC keeps partial sums in FRAM for
//!   idempotence, so a skip saves memory traffic too).
//! * **Linear (Eq. 2)** — *input-stationary*: the outer loop walks input
//!   activations; each activation's threshold `x̄ = T_raw/|xr|` is one
//!   approximate division reused across the whole weight row. A zero
//!   activation skips its entire row with a single compare.
//!
//! ## Pruning modes
//!
//! * [`PruneMode::Dense`] — no checks at all: every MAC executes
//!   (the paper's "Unpruned" cost baseline).
//! * [`PruneMode::ZeroSkip`] — zero-operand skipping only (what a
//!   FATReLU-sparsified network exploits at runtime).
//! * [`PruneMode::Unit`] — full UnIT: reuse-aware thresholds +
//!   approximate division + per-connection compare.
//!
//! FATReLU composes with any mode via `QModel::with_fatrelu` (it only
//! changes the activation nonlinearity).

use super::qmodel::QModel;
use crate::approx::DivApprox;
use crate::mcu::{cost, FramModel, Ledger};
use crate::nn::layers::{conv2d_shape, Layer};

/// Pruning mode for the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PruneMode {
    /// Every MAC executes; no comparisons.
    Dense,
    /// Train-time pruned deployment: zero *weights* are skipped for free
    /// (a statically sparse model neither stores nor visits them — no
    /// compare, no weight fetch), but there are no runtime checks, so
    /// zero *activations* still execute. The fair TTP cost baseline.
    StaticSparse,
    /// Skip on zero operands only (runtime sparsity à la FATReLU).
    ZeroSkip,
    /// UnIT reuse-aware thresholding (uses each layer's `t_raw`).
    Unit,
}

/// Engine configuration.
pub struct EngineConfig<'a> {
    /// Pruning mechanism to run.
    pub mode: PruneMode,
    /// Division estimator for UnIT thresholds.
    pub div: &'a dyn DivApprox,
    /// Model SONIC-style loop-state FRAM traffic (accumulators resident
    /// in FRAM for idempotent task restart).
    pub sonic_accumulators: bool,
    /// If true, conv tap thresholds `T_raw/|w|` are charged once at
    /// deploy time instead of per inference (the paper's "store the
    /// precomputed thresholds" memory/compute trade-off ablation).
    pub precomputed_conv_thresholds: bool,
    /// Runtime threshold scale in Q8.8 (256 = 1.0). The energy-adaptive
    /// controller (paper §6.1: "environments where computational and
    /// energy resources fluctuate") raises/lowers the effective
    /// aggressiveness without re-baking the model: one multiply + shift
    /// per layer, charged to the ledger.
    pub t_scale_q8: u32,
}

/// Apply the runtime threshold scale: `(t_raw * scale) >> 8`, saturating.
/// Shared with the planned engine ([`super::plan`]) so both paths bake
/// the identical effective threshold.
#[inline]
pub(crate) fn scaled_t(t_raw: u32, scale_q8: u32) -> u32 {
    ((t_raw as u64 * scale_q8 as u64) >> 8).min(u32::MAX as u64) as u32
}

impl<'a> EngineConfig<'a> {
    /// UnIT thresholding with the given division estimator.
    pub fn unit(div: &'a dyn DivApprox) -> EngineConfig<'a> {
        EngineConfig {
            mode: PruneMode::Unit,
            div,
            sonic_accumulators: true,
            precomputed_conv_thresholds: false,
            t_scale_q8: 256,
        }
    }

    /// Dense execution (no skipping).
    pub fn dense(div: &'a dyn DivApprox) -> EngineConfig<'a> {
        EngineConfig { mode: PruneMode::Dense, div, sonic_accumulators: true, precomputed_conv_thresholds: false, t_scale_q8: 256 }
    }

    /// Skip on zero operands only.
    pub fn zero_skip(div: &'a dyn DivApprox) -> EngineConfig<'a> {
        EngineConfig { mode: PruneMode::ZeroSkip, div, sonic_accumulators: true, precomputed_conv_thresholds: false, t_scale_q8: 256 }
    }

    /// Static (train-time-pruned) sparsity.
    pub fn static_sparse(div: &'a dyn DivApprox) -> EngineConfig<'a> {
        EngineConfig { mode: PruneMode::StaticSparse, div, sonic_accumulators: true, precomputed_conv_thresholds: false, t_scale_q8: 256 }
    }
}

/// Result of one inference.
#[derive(Debug, Clone)]
pub struct InferOutput {
    /// Logits in Q8.8 raw units.
    pub logits_raw: Vec<i16>,
    /// Logits dequantized to f32.
    pub logits: Vec<f32>,
    /// Per-layer kept MACs.
    pub kept: Vec<u64>,
    /// Per-layer skipped MACs.
    pub skipped: Vec<u64>,
    /// Execution ledger (cycles, energy inputs, op counts).
    pub ledger: Ledger,
}

impl InferOutput {
    /// Index of the largest logit.
    pub fn argmax(&self) -> usize {
        crate::util::stats::argmax(&self.logits)
    }

    /// Fraction of all MACs skipped (0 when nothing ran).
    pub fn skip_fraction(&self) -> f64 {
        let k: u64 = self.kept.iter().sum();
        let s: u64 = self.skipped.iter().sum();
        if k + s == 0 {
            0.0
        } else {
            s as f64 / (k + s) as f64
        }
    }
}

#[inline(always)]
pub(crate) fn requant(acc: i64, m: i64) -> i16 {
    let v = (acc * m) >> 16;
    v.clamp(i16::MIN as i64, i16::MAX as i64) as i16
}

/// Run one quantized inference, charging the ledger.
pub fn infer(q: &QModel, x_raw: &[i16], cfg: &EngineConfig) -> InferOutput {
    assert_eq!(x_raw.len(), q.def.input_len());
    let mut ledger = Ledger::new();
    let fram = FramModel::default();
    let n_layers = q.def.layers.len();
    let mut kept = vec![0u64; n_layers];
    let mut skipped = vec![0u64; n_layers];

    // Input transfer: sensor buffer -> FRAM working buffer.
    ledger.fram_write(x_raw.len() as u64);

    let mut act: Vec<i16> = x_raw.to_vec();
    let mut shape = q.def.input_shape;

    for li in 0..n_layers {
        let ql = &q.layers[li];
        let layer = q.def.layers[li];
        match layer {
            Layer::Conv { out_ch, in_ch, kh, kw, pool } => {
                let [_, h, wd] = shape;
                let (oh, ow) = conv2d_shape(h, wd, kh, kw);

                let mut out_acc = vec![0i64; out_ch * oh * ow];
                // bias preload (one add per output element)
                for o in 0..out_ch {
                    let b = ql.bias_acc[o];
                    for p in 0..oh * ow {
                        out_acc[o * oh * ow + p] = b;
                    }
                    ledger.control((oh * ow) as u64 * cost::MOV);
                }
                // weight-stationary sweep
                for o in 0..out_ch {
                    let t_layer = scaled_t(
                        if !ql.t_raw_groups.is_empty() { ql.t_raw_groups[o] } else { ql.t_raw },
                        cfg.t_scale_q8,
                    );
                    for ci in 0..in_ch {
                        for u in 0..kh {
                            for v in 0..kw {
                                let wv = ql.w[((o * in_ch + ci) * kh + u) * kw + v];
                                // Reuse-aware threshold: one division per
                                // tap, amortized over OH*OW compares.
                                let (wbar, prune_all) = match cfg.mode {
                                    PruneMode::Unit => {
                                        ledger.fram_read(1); // the tap itself
                                        if wv == 0 {
                                            (u32::MAX, true)
                                        } else if t_layer == 0 {
                                            (0, false)
                                        } else {
                                            let c = wv.unsigned_abs() as u32;
                                            if !cfg.precomputed_conv_thresholds {
                                                ledger.div(cfg.div.cycles(t_layer, c));
                                            }
                                            (cfg.div.div(t_layer, c), false)
                                        }
                                    }
                                    PruneMode::ZeroSkip => {
                                        ledger.fram_read(1);
                                        ledger.compare();
                                        if wv == 0 {
                                            (u32::MAX, true)
                                        } else {
                                            (0, false)
                                        }
                                    }
                                    PruneMode::StaticSparse => {
                                        // pruned taps are not stored: free
                                        if wv == 0 {
                                            (u32::MAX, true)
                                        } else {
                                            ledger.fram_read(1);
                                            (0, false)
                                        }
                                    }
                                    PruneMode::Dense => {
                                        ledger.fram_read(1);
                                        (0, false)
                                    }
                                };
                                if prune_all {
                                    skipped[li] += (oh * ow) as u64;
                                    ledger.counts.skipped += (oh * ow) as u64;
                                    continue;
                                }
                                // Inner position sweep: branch on the
                                // mode OUTSIDE the loop and batch the
                                // ledger charge per tap (§Perf item 1-2:
                                // identical totals, ~14 % faster sim).
                                let acc_base = o * oh * ow;
                                let n_pos = (oh * ow) as u64;
                                let wv64 = wv as i64;
                                let mut tap_kept = 0u64;
                                match cfg.mode {
                                    PruneMode::Dense | PruneMode::StaticSparse => {
                                        for p in 0..oh {
                                            let arow = (ci * h + p + u) * wd + v;
                                            let dst = &mut out_acc
                                                [acc_base + p * ow..acc_base + p * ow + ow];
                                            for (qq, d) in dst.iter_mut().enumerate() {
                                                *d += act[arow + qq] as i64 * wv64;
                                            }
                                        }
                                        tap_kept = n_pos;
                                    }
                                    PruneMode::ZeroSkip => {
                                        for p in 0..oh {
                                            let arow = (ci * h + p + u) * wd + v;
                                            for qq in 0..ow {
                                                let xv = act[arow + qq];
                                                if xv != 0 {
                                                    out_acc[acc_base + p * ow + qq] +=
                                                        xv as i64 * wv64;
                                                    tap_kept += 1;
                                                }
                                            }
                                        }
                                        ledger.compare_n(n_pos);
                                    }
                                    PruneMode::Unit => {
                                        for p in 0..oh {
                                            let arow = (ci * h + p + u) * wd + v;
                                            for qq in 0..ow {
                                                let xv = act[arow + qq];
                                                // Eq. 3: keep iff |x| > w̄
                                                if (xv as i32).unsigned_abs() > wbar {
                                                    out_acc[acc_base + p * ow + qq] +=
                                                        xv as i64 * wv64;
                                                    tap_kept += 1;
                                                }
                                            }
                                        }
                                        ledger.compare_n(n_pos);
                                    }
                                }
                                kept[li] += tap_kept;
                                skipped[li] += n_pos - tap_kept;
                                ledger.mac_n(tap_kept);
                                ledger.skip_n(n_pos - tap_kept);
                                ledger.fram_read(n_pos); // activation stream
                                if cfg.sonic_accumulators {
                                    // FRAM-resident partial sums (RMW per
                                    // executed MAC only — skips save it)
                                    ledger.fram_read(2 * tap_kept);
                                    ledger.fram_write(2 * tap_kept);
                                }
                            }
                        }
                    }
                }
                // requantize + FATReLU
                let mut out = vec![0i16; out_ch * oh * ow];
                for (i, &a) in out_acc.iter().enumerate() {
                    let y = requant(a, ql.requant_m);
                    out[i] = if y > q.fat_t_raw { y } else { 0 };
                    ledger.control(cost::MUL_SW + cost::SHIFT * 8); // requant mul
                    ledger.compare(); // activation threshold
                }
                shape = [out_ch, oh, ow];
                act = out;
                if pool {
                    let (ph, pw) = (oh / 2, ow / 2);
                    let mut pooled = vec![0i16; out_ch * ph * pw];
                    for o in 0..out_ch {
                        for p in 0..ph {
                            for qq in 0..pw {
                                let mut m = i16::MIN;
                                for du in 0..2 {
                                    for dv in 0..2 {
                                        let v = act[(o * oh + 2 * p + du) * ow + 2 * qq + dv];
                                        ledger.fram_read(1);
                                        ledger.compare();
                                        if v > m {
                                            m = v;
                                        }
                                    }
                                }
                                pooled[(o * ph + p) * pw + qq] = m;
                            }
                        }
                    }
                    shape = [out_ch, ph, pw];
                    act = pooled;
                }
                // commit output activations (SONIC double buffer)
                fram.charge_layer(&mut ledger, 0, 0, (act.len()) as u64);
            }
            Layer::Linear { n_in, n_out, relu } => {
                let mut acc: Vec<i64> = ql.bias_acc.clone();
                ledger.control(n_out as u64 * cost::MOV);
                for k in 0..n_in {
                    let xv = act[k];
                    ledger.fram_read(1); // activation
                    // zero activation: skip the entire row with ONE
                    // compare — only in the *runtime-checking* modes.
                    // Dense executes every MAC; StaticSparse has no
                    // runtime checks at all (its sparsity is in the
                    // weights, handled below).
                    if cfg.mode == PruneMode::ZeroSkip || cfg.mode == PruneMode::Unit {
                        ledger.compare();
                        if xv == 0 {
                            skipped[li] += n_out as u64;
                            ledger.counts.skipped += n_out as u64;
                            continue;
                        }
                    }
                    let t_eff = scaled_t(ql.t_raw, cfg.t_scale_q8);
                    let tbar = match cfg.mode {
                        PruneMode::Unit => {
                            if t_eff == 0 {
                                0
                            } else {
                                let c = (xv as i32).unsigned_abs();
                                ledger.div(cfg.div.cycles(t_eff, c));
                                cfg.div.div(t_eff, c)
                            }
                        }
                        _ => 0,
                    };
                    // Row sweep with the mode branch hoisted out and
                    // ledger charges batched per row (§Perf items 1-2).
                    let row = &ql.w[k * n_out..(k + 1) * n_out];
                    let xv64 = xv as i64;
                    let mut row_kept = 0u64;
                    match cfg.mode {
                        PruneMode::Dense => {
                            for (j, &wv) in row.iter().enumerate() {
                                acc[j] += xv64 * wv as i64;
                            }
                            row_kept = n_out as u64;
                            ledger.fram_read(n_out as u64); // weight stream
                        }
                        PruneMode::StaticSparse => {
                            // pruned weights are not stored: free skips,
                            // and only surviving weights are fetched
                            for (j, &wv) in row.iter().enumerate() {
                                if wv != 0 {
                                    acc[j] += xv64 * wv as i64;
                                    row_kept += 1;
                                }
                            }
                            ledger.fram_read(row_kept);
                        }
                        PruneMode::ZeroSkip => {
                            for (j, &wv) in row.iter().enumerate() {
                                if wv != 0 {
                                    acc[j] += xv64 * wv as i64;
                                    row_kept += 1;
                                }
                            }
                            ledger.fram_read(n_out as u64);
                            ledger.compare_n(n_out as u64);
                        }
                        PruneMode::Unit => {
                            // Eq. 2: keep iff |w| > x̄
                            for (j, &wv) in row.iter().enumerate() {
                                if (wv as i32).unsigned_abs() > tbar {
                                    acc[j] += xv64 * wv as i64;
                                    row_kept += 1;
                                }
                            }
                            ledger.fram_read(n_out as u64);
                            ledger.compare_n(n_out as u64);
                        }
                    }
                    kept[li] += row_kept;
                    skipped[li] += n_out as u64 - row_kept;
                    ledger.mac_n(row_kept);
                    ledger.skip_n(n_out as u64 - row_kept);
                    if cfg.sonic_accumulators {
                        ledger.fram_read(2 * row_kept);
                        ledger.fram_write(2 * row_kept);
                    }
                }
                let mut out = vec![0i16; n_out];
                for (j, &a) in acc.iter().enumerate() {
                    let y = requant(a, ql.requant_m);
                    out[j] = if relu {
                        if y > q.fat_t_raw {
                            y
                        } else {
                            0
                        }
                    } else {
                        y
                    };
                    ledger.control(cost::MUL_SW + cost::SHIFT * 8);
                }
                shape = [n_out, 1, 1];
                act = out;
                fram.charge_layer(&mut ledger, 0, 0, act.len() as u64);
            }
        }
    }

    // Executed-MAC ledger consistency: engine-level kept counts must
    // equal what the ledger billed.
    debug_assert_eq!(kept.iter().sum::<u64>(), ledger.counts.macs);

    let logits: Vec<f32> = act.iter().map(|&r| crate::fixed::Q88(r).to_f32()).collect();
    InferOutput { logits_raw: act, logits, kept, skipped, ledger }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::{DivExact, DivShift};
    use crate::models::{zoo, Params};
    use crate::nn::{forward, ForwardOpts};

    fn setup(name: &str, seed: u64) -> (crate::models::ModelDef, Params, QModel) {
        let def = zoo(name);
        let params = Params::random(&def, seed);
        let q = QModel::quantize(&def, &params);
        (def, params, q)
    }

    #[test]
    fn dense_engine_matches_float_forward() {
        let (def, params, q) = setup("mnist", 1);
        let x: Vec<f32> = (0..def.input_len())
            .map(|i| (((i * 31) % 17) as f32 - 8.0) / 8.0)
            .collect();
        let (want, _) = forward(&def, &params, &x, &ForwardOpts::dense(3));
        let out = infer(&q, &q.quantize_input(&x), &EngineConfig::dense(&DivExact));
        // quantization tolerance: logits within ~0.35 absolute
        for (a, b) in out.logits.iter().zip(&want) {
            assert!((a - b).abs() < 0.35, "{a} vs {b}");
        }
        // dense mode executes every MAC
        assert_eq!(out.kept.iter().sum::<u64>(), def.total_dense_macs());
        assert_eq!(out.skipped.iter().sum::<u64>(), 0);
    }

    #[test]
    fn unit_exact_div_matches_float_pruned_counts_approximately() {
        let (def, params, q) = setup("mnist", 2);
        let t = crate::pruning::Thresholds::uniform(3, 0.2);
        let q = q.with_thresholds(&t);
        let x: Vec<f32> = (0..def.input_len())
            .map(|i| (((i * 13) % 29) as f32 - 14.0) / 10.0)
            .collect();
        let (_, fstats) = forward(&def, &params, &x, &ForwardOpts::unit(t.per_layer.clone()));
        let out = infer(&q, &q.quantize_input(&x), &EngineConfig::unit(&DivExact));
        let ffrac = fstats.skip_fraction();
        let qfrac = out.skip_fraction();
        assert!(
            (ffrac - qfrac).abs() < 0.08,
            "float skip {ffrac:.3} vs fixed skip {qfrac:.3}"
        );
    }

    #[test]
    fn unit_reduces_cycles_vs_dense() {
        let (def, _params, q) = setup("mnist", 3);
        let t = crate::pruning::Thresholds::uniform(3, 0.3);
        let qp = q.clone().with_thresholds(&t);
        let x: Vec<f32> =
            (0..def.input_len()).map(|i| ((i % 23) as f32 - 11.0) / 6.0).collect();
        let xi = q.quantize_input(&x);
        let dense = infer(&q, &xi, &EngineConfig::dense(&DivShift));
        let unit = infer(&qp, &xi, &EngineConfig::unit(&DivShift));
        assert!(unit.skip_fraction() > 0.2, "skip {:.3}", unit.skip_fraction());
        assert!(
            unit.ledger.total_cycles() < dense.ledger.total_cycles(),
            "unit {} >= dense {}",
            unit.ledger.total_cycles(),
            dense.ledger.total_cycles()
        );
    }

    #[test]
    fn zero_skip_mode_skips_zeros_only() {
        let (def, _params, q) = setup("mnist", 4);
        // input with many exact zeros
        let x: Vec<f32> = (0..def.input_len())
            .map(|i| if i % 3 == 0 { 0.0 } else { 0.5 })
            .collect();
        let out = infer(&q, &q.quantize_input(&x), &EngineConfig::zero_skip(&DivExact));
        assert!(out.skipped.iter().sum::<u64>() > 0);
        // logits must equal dense logits exactly (skipping zeros is lossless)
        let dense = infer(&q, &q.quantize_input(&x), &EngineConfig::dense(&DivExact));
        assert_eq!(out.logits_raw, dense.logits_raw);
    }

    #[test]
    fn approx_div_prunes_at_least_as_coarsely_but_sound() {
        // Approximate divisions change WHICH connections are pruned but
        // the output must stay finite and the counts must still total.
        let (def, _params, q) = setup("mnist", 5);
        let t = crate::pruning::Thresholds::uniform(3, 0.25);
        let q = q.with_thresholds(&t);
        let x: Vec<f32> =
            (0..def.input_len()).map(|i| ((i % 19) as f32 - 9.0) / 7.0).collect();
        let xi = q.quantize_input(&x);
        for div in [&DivExact as &dyn crate::approx::DivApprox, &DivShift] {
            let cfg = EngineConfig {
                mode: PruneMode::Unit,
                div,
                sonic_accumulators: true,
                precomputed_conv_thresholds: false,
            t_scale_q8: 256,
            };
            let out = infer(&q, &xi, &cfg);
            assert_eq!(
                out.kept.iter().sum::<u64>() + out.skipped.iter().sum::<u64>(),
                def.total_dense_macs()
            );
            assert!(out.logits.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn precomputed_thresholds_save_divisions() {
        let (def, _params, q) = setup("mnist", 6);
        let _ = def;
        let t = crate::pruning::Thresholds::uniform(3, 0.25);
        let q = q.with_thresholds(&t);
        let x = vec![0.4f32; q.def.input_len()];
        let xi = q.quantize_input(&x);
        let per_inf = EngineConfig {
            mode: PruneMode::Unit,
            div: &DivShift,
            sonic_accumulators: true,
            precomputed_conv_thresholds: false,
            t_scale_q8: 256,
        };
        let pre = EngineConfig { precomputed_conv_thresholds: true, ..per_inf };
        let a = infer(&q, &xi, &per_inf);
        let b = infer(&q, &xi, &pre);
        assert_eq!(a.logits_raw, b.logits_raw); // numerics identical
        assert!(b.ledger.compute_cycles < a.ledger.compute_cycles);
    }

    #[test]
    fn ledger_consistency_mac_counts() {
        let (_def, _params, q) = setup("cifar", 7);
        let x = vec![0.3f32; q.def.input_len()];
        let out = infer(&q, &q.quantize_input(&x), &EngineConfig::dense(&DivExact));
        assert_eq!(out.ledger.counts.macs, out.kept.iter().sum::<u64>());
        assert_eq!(out.ledger.counts.skipped, out.skipped.iter().sum::<u64>());
    }
}
