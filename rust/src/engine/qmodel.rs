//! Quantized model: int8 weights + per-layer scale, integer biases, and
//! raw-domain UnIT thresholds.
//!
//! Quantization scheme (see `fixed/mod.rs` for the algebra):
//!
//! * activations: Q8.8 (`i16`, scale 1/256),
//! * weights: symmetric int8 with per-layer scale `s = max|w|/127`,
//! * accumulator: `i64` in the raw product domain (a physical MSP430
//!   build would manage 32-bit ranges; the simulator uses 64-bit so
//!   quantization error — not overflow — is the only artifact),
//! * bias folded into the accumulator as `round(b·256/s)`,
//! * requantization back to Q8.8: `y = (acc · m) >> 16` with
//!   `m = round(s·2^16)` — one fixed-point multiply per output element,
//! * UnIT threshold per layer: `T_raw = T·256/s` (one u32), shared by
//!   the Eq. 2 and Eq. 3 comparisons.

use crate::fixed::{quantize_weights, t_raw};
use crate::models::{ModelDef, Params};
use crate::nn::Layer;

/// One quantized layer.
#[derive(Debug, Clone)]
pub struct QLayer {
    /// int8 weights, same layout as the float layer.
    pub w: Vec<i8>,
    /// Weight scale `s` (f32, build-time constant).
    pub scale: f32,
    /// Bias in accumulator domain: `round(b·256/s)`.
    pub bias_acc: Vec<i64>,
    /// Requantization multiplier `round(s·2^16)`.
    pub requant_m: i64,
    /// Layer-level UnIT threshold in the raw domain (0 ⇒ keep-all).
    pub t_raw: u32,
    /// Optional per-output-channel thresholds (group-wise refinement).
    pub t_raw_groups: Vec<u32>,
}

/// A fully quantized Table-1 model ready for the MCU engine.
#[derive(Debug, Clone)]
pub struct QModel {
    /// The source model definition.
    pub def: ModelDef,
    /// Quantized layers in execution order.
    pub layers: Vec<QLayer>,
    /// FATReLU cut-off in Q8.8 raw units (0 ⇒ plain ReLU).
    pub fat_t_raw: i16,
}

impl QModel {
    /// Quantize float params with all thresholds zero (dense numerics).
    pub fn quantize(def: &ModelDef, params: &Params) -> QModel {
        let layers = def
            .layers
            .iter()
            .enumerate()
            .map(|(li, _l)| {
                let (w, scale) = quantize_weights(&params.weights[li]);
                let bias_acc = params.biases[li]
                    .iter()
                    .map(|&b| (b * 256.0 / scale).round() as i64)
                    .collect();
                QLayer {
                    w,
                    scale,
                    bias_acc,
                    requant_m: (scale * 65536.0).round() as i64,
                    t_raw: 0,
                    t_raw_groups: Vec::new(),
                }
            })
            .collect();
        QModel { def: def.clone(), layers, fat_t_raw: 0 }
    }

    /// Bake real-valued UnIT thresholds into the raw domain.
    pub fn with_thresholds(mut self, t: &crate::pruning::Thresholds) -> QModel {
        assert_eq!(t.per_layer.len(), self.layers.len());
        for (li, ql) in self.layers.iter_mut().enumerate() {
            ql.t_raw = t_raw(t.per_layer[li], ql.scale);
            ql.t_raw_groups =
                t.groups[li].iter().map(|&g| t_raw(g, ql.scale)).collect();
        }
        self
    }

    /// Bake a FATReLU cut-off (real-valued) into Q8.8.
    pub fn with_fatrelu(mut self, fat_t: f32) -> QModel {
        self.fat_t_raw = crate::fixed::Q88::from_f32(fat_t).raw();
        self
    }

    /// Quantize an f32 input sample to Q8.8 raw values.
    pub fn quantize_input(&self, x: &[f32]) -> Vec<i16> {
        x.iter().map(|&v| crate::fixed::Q88::from_f32(v).raw()).collect()
    }

    /// Model size in bytes as deployed (int8 weights + i16 biases +
    /// thresholds), the 256 KB FRAM budget check.
    pub fn deployed_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.w.len() + 2 * l.bias_acc.len() + 4 + 4 * l.t_raw_groups.len())
            .sum()
    }

    /// Weight-quantization layer defs (convenience passthrough).
    pub fn layer_defs(&self) -> &[Layer] {
        &self.def.layers
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn quantize_all_models_fit_fram() {
        // MSP430FR5994 has 256 KB FRAM; every MCU-deployed Table-1 model
        // (mnist/cifar/kws) must fit. (widar is the desktop stress test.)
        for name in ["mnist", "cifar", "kws"] {
            let def = zoo(name);
            let q = QModel::quantize(&def, &Params::random(&def, 1));
            assert!(q.deployed_bytes() < 256 * 1024, "{name}: {}", q.deployed_bytes());
        }
    }

    #[test]
    fn thresholds_baked_per_layer_scale() {
        let def = zoo("mnist");
        let q = QModel::quantize(&def, &Params::random(&def, 2));
        let t = crate::pruning::Thresholds::uniform(3, 0.5);
        let q = q.with_thresholds(&t);
        for l in &q.layers {
            let expect = (0.5 * 256.0 / l.scale).round() as u32;
            assert_eq!(l.t_raw, expect);
        }
    }

    #[test]
    fn input_quantization_roundtrip() {
        let def = zoo("mnist");
        let q = QModel::quantize(&def, &Params::random(&def, 3));
        let x = [0.5f32, -1.25, 3.0];
        let xi = q.quantize_input(&x);
        assert_eq!(xi, vec![128, -320, 768]);
    }

    #[test]
    fn requant_multiplier_matches_scale() {
        let def = zoo("cifar");
        let q = QModel::quantize(&def, &Params::random(&def, 4));
        for l in &q.layers {
            let back = l.requant_m as f32 / 65536.0;
            assert!((back - l.scale).abs() < 1e-4);
        }
    }
}
