//! Fixed-point MCU inference engine with connection-level MAC skipping.
//!
//! This is the deployed artifact the paper measures: the Table-1 models
//! quantized to 8-bit weights / Q8.8 activations ([`qmodel`]), executed
//! by integer-only inner loops that implement UnIT's reuse-aware
//! MAC-free pruning with approximate divisions, charging every
//! operation to the MCU ledger ([`infer`]).
//!
//! Two execution paths produce bit-identical results:
//!
//! * [`infer`] — the reference loops, structured exactly like the
//!   modeled MSP430 code (one compare per pruning decision);
//! * [`plan`] — prepacked execution plans (magnitude-sorted rows,
//!   scratch arenas, closed-form ledger charging) that make skipped
//!   MACs nearly free *on the host* while billing the MCU identically.
//!   Serving workers, batched eval, and the benches run on this path.

pub mod infer;
pub mod kernels;
pub mod plan;
pub mod qmodel;

pub use infer::{infer, EngineConfig, InferOutput, PruneMode};
pub use kernels::level_name as simd_level_name;
pub use plan::{
    ConvInterior, KernelBackend, PlanBacked, PlanConfig, PlannedModel, Scratch, CONV_LANES,
};
pub use qmodel::QModel;
