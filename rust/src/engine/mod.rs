//! Fixed-point MCU inference engine with connection-level MAC skipping.
//!
//! This is the deployed artifact the paper measures: the Table-1 models
//! quantized to 8-bit weights / Q8.8 activations ([`qmodel`]), executed
//! by integer-only inner loops that implement UnIT's reuse-aware
//! MAC-free pruning with approximate divisions, charging every
//! operation to the MCU ledger ([`infer`]).

pub mod infer;
pub mod qmodel;

pub use infer::{infer, EngineConfig, InferOutput, PruneMode};
pub use qmodel::QModel;
