//! Explicit-SIMD inner kernels with one-time runtime CPU dispatch.
//!
//! [`super::plan`]'s `KernelBackend::Simd` path lands here: the conv
//! interior scatter loop rewritten over `core::arch` intrinsics —
//! AVX2 or SSE2 on x86_64, NEON on aarch64, a scalar mirror elsewhere
//! — so a plain `cargo build --release` binary runs the widest safe
//! path without `-C target-cpu` flags. The level is probed **once**
//! per process ([`level`]); an explicit `Simd` config on a host with
//! no usable level degrades to the scalar mirror, never to UB.
//!
//! Shape of the kernel: the plan emits a flat SoA mirror of the tap
//! tables (`simd_w: &[i16]`, `simd_off: &[i32]`, same descending-`|w|`
//! order as the scalar taps, so a per-pixel cut is still a prefix).
//! [`scatter_simd`] walks the kept prefix in [`SIMD_TILE`]-tap tiles:
//! the 16 exact `i16 × i16 → i32` products of one tile are computed
//! into two–four vector registers, then drained by a 4-wide unrolled
//! scatter-add — up to four accumulator cells (typically 2–4 distinct
//! output channels, since consecutive taps in magnitude order
//! interleave channels) are in flight per step. Products are exact in
//! i32 (`|x|·|w| ≤ 2^30`) and the i64 accumulator adds are
//! associative/commutative, so every path here is bit-identical to
//! the scalar reference loop — pinned by the plan unit tests and the
//! `engine_cross_layer` property suite.

/// Tap-tile width of the explicit-SIMD interior kernel: 16 × i16
/// weights is one 256-bit load on AVX2 and two 128-bit loads on
/// SSE2/NEON, and the resulting 16 × i32 products fill 2–4 vector
/// registers — the register block the scatter-adds drain.
pub(crate) const SIMD_TILE: usize = 16;

/// The SIMD level runtime dispatch selected for this process.
// Which variants are ever *constructed* is target-dependent (x86_64
// never builds Neon/None, aarch64 never builds Sse2/Avx2), so the
// dead-code analysis must not judge the enum per-target.
#[allow(dead_code)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Level {
    /// No usable SIMD path (non-x86_64/aarch64 targets): the `Simd`
    /// backend degrades to the scalar mirror.
    None,
    /// x86_64 baseline: always available there.
    Sse2,
    /// x86_64 with AVX2 detected at runtime.
    Avx2,
    /// aarch64 baseline: always available there.
    Neon,
}

fn detect() -> Level {
    #[cfg(target_arch = "x86_64")]
    {
        // SSE2 is part of the x86_64 baseline, so the only runtime
        // question is whether the wider path is safe.
        if is_x86_feature_detected!("avx2") {
            Level::Avx2
        } else {
            Level::Sse2
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Level::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Level::None
    }
}

/// The probed SIMD level, cached after the first call — the one-time
/// runtime dispatch every `Simd`-flavored kernel call goes through.
pub(crate) fn level() -> Level {
    static LEVEL: std::sync::OnceLock<Level> = std::sync::OnceLock::new();
    *LEVEL.get_or_init(detect)
}

/// Whether this host has an explicit-SIMD path at all (false only on
/// targets outside x86_64/aarch64).
pub(crate) fn simd_available() -> bool {
    level() != Level::None
}

/// Name of the SIMD level runtime dispatch found on this host
/// (`"avx2"`, `"sse2"`, `"neon"`, or `"none"`) — display only.
pub fn level_name() -> &'static str {
    match level() {
        Level::Avx2 => "avx2",
        Level::Sse2 => "sse2",
        Level::Neon => "neon",
        Level::None => "none",
    }
}

/// Drain one tile of products into the accumulator arena, 4-wide
/// unrolled: four independent (offset, product) pairs are resolved per
/// step, so 2–4 accumulator cells live in registers across the sweep.
/// Sequential `+=` keeps colliding offsets (two taps of one output
/// cell in the same tile) exact.
#[inline(always)]
fn scatter_adds(prod: &[i32; SIMD_TILE], off: &[i32], pix: i32, acc: &mut [i64]) {
    for q in (0..SIMD_TILE).step_by(4) {
        let i0 = (off[q] + pix) as usize;
        let i1 = (off[q + 1] + pix) as usize;
        let i2 = (off[q + 2] + pix) as usize;
        let i3 = (off[q + 3] + pix) as usize;
        acc[i0] += prod[q] as i64;
        acc[i1] += prod[q + 1] as i64;
        acc[i2] += prod[q + 2] as i64;
        acc[i3] += prod[q + 3] as i64;
    }
}

/// Scalar mirror of the tiled kernel (the `Level::None` fallback, and
/// the shape the intrinsic paths must reproduce bit for bit).
fn scatter_full_generic(w: &[i16], off: &[i32], full: usize, xv: i16, pix: i32, acc: &mut [i64]) {
    let xv32 = xv as i32;
    let mut prod = [0i32; SIMD_TILE];
    let mut base = 0usize;
    while base < full {
        for (p, &wv) in prod.iter_mut().zip(&w[base..base + SIMD_TILE]) {
            *p = xv32 * wv as i32;
        }
        scatter_adds(&prod, &off[base..base + SIMD_TILE], pix, acc);
        base += SIMD_TILE;
    }
}

/// AVX2 tile loop: 16 weights sign-extend to two 8 × i32 registers,
/// one `mullo` each against the broadcast activation.
///
/// SAFETY: caller must guarantee `level() == Level::Avx2` (the CPU
/// supports AVX2), `w`/`off` hold at least `full` elements, and every
/// `off[j] + pix` for `j < full` indexes inside `acc` (the plan's tap
/// tables guarantee this — same values the scalar path indexes with).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scatter_full_avx2(
    w: &[i16],
    off: &[i32],
    full: usize,
    xv: i16,
    pix: i32,
    acc: &mut [i64],
) {
    use core::arch::x86_64::*;
    let xvv = _mm256_set1_epi32(xv as i32);
    let mut prod = [0i32; SIMD_TILE];
    let mut base = 0usize;
    while base < full {
        let w0 = _mm_loadu_si128(w.as_ptr().add(base) as *const __m128i);
        let w1 = _mm_loadu_si128(w.as_ptr().add(base + 8) as *const __m128i);
        // cvtepi16_epi32 preserves lane order, so prod[j] is tap j's
        // exact 32-bit product — the scatter pairing stays aligned.
        let p0 = _mm256_mullo_epi32(_mm256_cvtepi16_epi32(w0), xvv);
        let p1 = _mm256_mullo_epi32(_mm256_cvtepi16_epi32(w1), xvv);
        _mm256_storeu_si256(prod.as_mut_ptr() as *mut __m256i, p0);
        _mm256_storeu_si256(prod.as_mut_ptr().add(8) as *mut __m256i, p1);
        scatter_adds(&prod, &off[base..base + SIMD_TILE], pix, acc);
        base += SIMD_TILE;
    }
}

/// SSE2 tile loop. SSE2 has no 32-bit `mullo`, so the exact products
/// come from the classic `mullo_epi16`/`mulhi_epi16` interleave: for
/// each i16 lane the signed 32-bit product is `(hi << 16) | lo`, and
/// `unpacklo/hi_epi16(lo, hi)` assembles exactly that, in lane order.
///
/// SAFETY: same contract as `scatter_full_avx2`, minus the feature
/// check — SSE2 is the x86_64 baseline.
#[cfg(target_arch = "x86_64")]
unsafe fn scatter_full_sse2(
    w: &[i16],
    off: &[i32],
    full: usize,
    xv: i16,
    pix: i32,
    acc: &mut [i64],
) {
    use core::arch::x86_64::*;
    let xvv = _mm_set1_epi16(xv);
    let mut prod = [0i32; SIMD_TILE];
    let mut base = 0usize;
    while base < full {
        for half in [0usize, 8] {
            let wv = _mm_loadu_si128(w.as_ptr().add(base + half) as *const __m128i);
            let lo = _mm_mullo_epi16(wv, xvv);
            let hi = _mm_mulhi_epi16(wv, xvv);
            _mm_storeu_si128(
                prod.as_mut_ptr().add(half) as *mut __m128i,
                _mm_unpacklo_epi16(lo, hi),
            );
            _mm_storeu_si128(
                prod.as_mut_ptr().add(half + 4) as *mut __m128i,
                _mm_unpackhi_epi16(lo, hi),
            );
        }
        scatter_adds(&prod, &off[base..base + SIMD_TILE], pix, acc);
        base += SIMD_TILE;
    }
}

/// NEON tile loop: `vmull_s16` widens 4 × i16 pairs straight to their
/// exact 4 × i32 products, in lane order.
///
/// SAFETY: same contract as `scatter_full_avx2`; NEON is the aarch64
/// baseline so the feature is always present there.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn scatter_full_neon(
    w: &[i16],
    off: &[i32],
    full: usize,
    xv: i16,
    pix: i32,
    acc: &mut [i64],
) {
    use core::arch::aarch64::*;
    let xvv = vdup_n_s16(xv);
    let mut prod = [0i32; SIMD_TILE];
    let mut base = 0usize;
    while base < full {
        for half in [0usize, 8] {
            let wv = vld1q_s16(w.as_ptr().add(base + half));
            vst1q_s32(prod.as_mut_ptr().add(half), vmull_s16(vget_low_s16(wv), xvv));
            vst1q_s32(prod.as_mut_ptr().add(half + 4), vmull_s16(vget_high_s16(wv), xvv));
        }
        scatter_adds(&prod, &off[base..base + SIMD_TILE], pix, acc);
        base += SIMD_TILE;
    }
}

/// Interior-pixel accumulation over the SoA mirror tables for the
/// explicit-SIMD backend: full [`SIMD_TILE`]-tap tiles of the kept
/// prefix go through the dispatched intrinsic loop, the `< SIMD_TILE`
/// remainder through a scalar tail. `w`/`off` are segment-based slices
/// of the plan's `simd_w`/`simd_off` (same order as the scalar taps);
/// only indices `< cut` are ever read, so the unpadded layout needs no
/// sentinel taps.
pub(crate) fn scatter_simd(w: &[i16], off: &[i32], cut: usize, xv: i16, pix: i32, acc: &mut [i64]) {
    debug_assert!(w.len() >= cut && off.len() >= cut, "simd mirror shorter than cut");
    let full = cut - cut % SIMD_TILE;
    if full > 0 {
        match level() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: level() proved the feature; slice lengths and
            // offset bounds are the plan-table invariants asserted
            // above (identical to what the scalar path indexes with).
            Level::Avx2 => unsafe { scatter_full_avx2(w, off, full, xv, pix, acc) },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: SSE2 is unconditionally available on x86_64.
            Level::Sse2 => unsafe { scatter_full_sse2(w, off, full, xv, pix, acc) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is unconditionally available on aarch64.
            Level::Neon => unsafe { scatter_full_neon(w, off, full, xv, pix, acc) },
            _ => scatter_full_generic(w, off, full, xv, pix, acc),
        }
    }
    let xv32 = xv as i32;
    for j in full..cut {
        acc[(off[j] + pix) as usize] += (xv32 * w[j] as i32) as i64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference: plain per-tap scalar scatter over the same slices.
    fn scatter_ref(w: &[i16], off: &[i32], cut: usize, xv: i16, pix: i32, acc: &mut [i64]) {
        for j in 0..cut {
            acc[(off[j] + pix) as usize] += (xv as i32 * w[j] as i32) as i64;
        }
    }

    /// The dispatched kernel (whatever level this host probes) must be
    /// bit-identical to the scalar reference for every cut, including
    /// extreme Q8.8 operands, colliding offsets, and cuts straddling
    /// the tile boundary.
    #[test]
    fn tiled_scatter_matches_scalar_reference() {
        let n = 3 * SIMD_TILE + 5;
        // Deterministic "worst-case-ish" taps: extreme magnitudes and
        // repeated offsets (two taps landing on one accumulator cell).
        let w: Vec<i16> = (0..n)
            .map(|j| match j % 5 {
                0 => i16::MAX,
                1 => i16::MIN + 1,
                2 => -3,
                3 => 17,
                _ => -(j as i16) * 7,
            })
            .collect();
        let off: Vec<i32> = (0..n).map(|j| ((j * 13) % 31) as i32).collect();
        for xv in [1i16, -1, 127, -128, i16::MAX, -32768] {
            for cut in [0usize, 1, SIMD_TILE - 1, SIMD_TILE, SIMD_TILE + 3, 2 * SIMD_TILE, n] {
                let mut a = vec![0i64; 64];
                let mut b = vec![0i64; 64];
                scatter_simd(&w, &off, cut, xv, 2, &mut a);
                scatter_ref(&w, &off, cut, xv, 2, &mut b);
                assert_eq!(a, b, "xv={xv} cut={cut} level={}", level_name());
            }
        }
    }

    /// The generic mirror (the no-SIMD fallback) must match too, on
    /// every host — this is what non-x86/ARM targets execute.
    #[test]
    fn generic_fallback_matches_scalar_reference() {
        let n = 2 * SIMD_TILE;
        let w: Vec<i16> = (0..n).map(|j| (j as i16 - 9) * 11).collect();
        let off: Vec<i32> = (0..n).map(|j| (j % 7) as i32).collect();
        let full = n; // whole-tile multiple
        let mut a = vec![0i64; 16];
        let mut b = vec![0i64; 16];
        scatter_full_generic(&w, &off, full, -255, 1, &mut a);
        scatter_ref(&w, &off, full, -255, 1, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn level_is_cached_and_named() {
        assert_eq!(level(), level());
        assert!(["avx2", "sse2", "neon", "none"].contains(&level_name()));
        #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
        assert!(simd_available());
    }
}
