//! The serve-side budget governor: the loop that makes the adaptive
//! controller act on the serving stack.
//!
//! Ownership: the governor owns the [`EnergyController`] and a handle
//! to every knob it turns —
//!
//! * it is installed as the coordinator's
//!   [`EnergyTap`](crate::coordinator::EnergyTap), so every McuSim
//!   worker reports each request's modeled ledger energy after
//!   delivering the reply;
//! * each observation runs one AIMD update, snaps the resulting scale
//!   to the [`ScaleGrid`](super::ScaleGrid), and — only when the step
//!   actually changed — swaps the coordinator's
//!   [`PlanSlot`](crate::coordinator::PlanSlot) atomically (workers
//!   pick the new plan up at their next dequeue; in-flight requests
//!   finish on the plan they started with);
//! * on every swap it also retargets the placement cost oracle
//!   ([`ProfiledCost`](super::ProfiledCost)) to the new step, when a
//!   calibrated [`KeepProfile`] is attached;
//! * [`Governor::set_budget`] is the wire-facing knob (the `SetBudget`
//!   admin frame lands here), [`Governor::status`] the wire-facing
//!   gauge (the `Stats` frame).
//!
//! ## Background compiles — misses never stall the swap path
//!
//! A step change whose plan is already resident swaps inline (an `Arc`
//! clone). A step change that **misses** the cache used to compile
//! under the cache lock on the observing worker's thread; now the
//! governor hands the compile to its own **background compile thread**
//! and the swap path keeps moving:
//!
//! 1. the miss enqueues the wanted step (deduplicated — a step is
//!    compiled at most once per residency);
//! 2. the swap path immediately publishes the **nearest resident**
//!    plan ([`PlanCache::nearest_resident`]) so the pool tracks the
//!    budget direction without waiting;
//! 3. when the background stamp lands, the thread re-checks the
//!    controller's *current* wanted step under the controller lock and
//!    — if still wanted — **upgrades** the [`PlanSlot`] to the exact
//!    plan (a stale compile is interned for later but not swapped).
//!
//! All slot swaps (inline and upgrade) are serialized under the
//! controller mutex, so the published plan always corresponds to the
//! stored step. Background compiles go through the same
//! [`PlanCache`] template config as inline ones, so every plan the
//! governor publishes — inline, upgrade, or recalibration reseed —
//! carries the cache's resolved
//! [`KernelBackend`](crate::engine::KernelBackend) (pinned by the
//! plan-cache backend test). The pending/completed/upgrade counters surface through
//! [`GovernorStatus`], the `Stats` admin frame, and
//! [`Metrics`](crate::coordinator::Metrics) so load tests can assert
//! the swap path never blocked on a compile.
//!
//! With a profile attached, installation is **feed-forward seeded**:
//! the initial step is the cheapest step whose calibrated mean energy
//! fits the budget, so the loop starts near its operating point
//! instead of walking there one AIMD nudge at a time.
//!
//! ## Drift-triggered live recalibration
//!
//! The calibrated [`KeepProfile`] is a snapshot of the traffic it was
//! measured on. When the serving distribution shifts (new sensor
//! placement, new speaker population), its curves go stale: placement
//! prices drift from real costs and the feed-forward seed points at
//! the wrong step. The governor closes this loop **live**:
//!
//! 1. workers report each inference's *observed* model keep ratio
//!    (via [`EnergyTap::observe_keep`]) and offer its raw input to a
//!    bounded [`InputReservoir`];
//! 2. a [`DriftTracker`] (two-sided CUSUM over observed − calibrated
//!    residuals) declares **sustained** divergence — stationary noise
//!    below its slack can never trip it;
//! 3. a trip enqueues one `Recalibrate` job on the background compile
//!    thread (deduplicated while pending), which re-measures the
//!    profile from the reservoir **off-lock**, then — under the
//!    controller lock — publishes the fresh profile, swaps the
//!    re-seeded plan, retargets [`ProfiledCost`], and re-arms the
//!    tracker.
//!
//! Publishes are additionally accounted in a **published-vs-wanted
//! step distance histogram** ([`Governor::publish_distance_histogram`]):
//! bucket 0 is an exact publish, bucket `d` a nearest-resident stand-in
//! `d` grid steps away — the observable cost of compiling off the swap
//! path.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::thread::JoinHandle;

use super::calibrate::{DriftCfg, DriftTracker, InputReservoir, KeepProfile, ProfiledCost};
use super::plan_cache::PlanCache;
use crate::coordinator::{
    Coordinator, CostEstimator, CostEstimatorSlot, EnergyController, EnergyTap, Metrics,
    PlanSlot,
};
use crate::obs::{EventKind, TraceRing};
use crate::util::{lock_recover, read_recover, write_recover};

/// A point-in-time view of the governor (the `Stats` admin frame's
/// payload).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GovernorStatus {
    /// Active threshold scale in Q8.8.
    pub scale_q8: u32,
    /// Active grid step.
    pub step: usize,
    /// Total steps in the grid.
    pub steps_total: usize,
    /// Energy budget (mJ/inference).
    pub budget_mj: f64,
    /// EWMA of observed per-request energy (mJ).
    pub ewma_mj: f64,
    /// Calibrated whole-model keep ratio at the active step (0 when no
    /// profile is attached).
    pub keep_ratio: f64,
    /// Plan-cache hits since install.
    pub cache_hits: u64,
    /// Plan-cache misses since install.
    pub cache_misses: u64,
    /// Plan swaps performed since installation (inline + upgrades).
    pub swaps: u64,
    /// Background compiles currently queued or in flight (gauge).
    pub bg_pending: u64,
    /// Background compiles completed since installation.
    pub bg_compiled: u64,
    /// Completed background compiles that upgraded the live plan slot
    /// (the rest were stale by the time they landed — interned, not
    /// swapped).
    pub bg_upgrades: u64,
    /// Sustained-divergence trips of the drift tracker since
    /// installation.
    pub drift_trips: u64,
    /// Live recalibrations completed (profile re-measured from the
    /// reservoir and republished).
    pub recalibrations: u64,
}

/// Work items for the governor's background thread: plan compiles and
/// profile recalibrations share it, so neither ever runs on a worker's
/// observation path.
enum Job {
    Compile(usize),
    Recalibrate,
}

/// The budget-driven plan governor (see module docs).
pub struct Governor {
    cache: Arc<PlanCache>,
    slot: Arc<PlanSlot>,
    cost_slot: CostEstimatorSlot,
    /// The live calibrated profile. Behind a lock (unlike everything
    /// else the request path reads) because recalibration *replaces*
    /// it at runtime; readers clone the `Arc` out and never block on a
    /// measurement.
    profile: RwLock<Option<Arc<KeepProfile>>>,
    /// Controller + swap path, serialized: concurrent worker
    /// observations queue here, so step transitions (and the
    /// background thread's upgrades) are single-file.
    ctrl: Mutex<EnergyController>,
    step: AtomicUsize,
    swaps: AtomicU64,
    /// Steps queued for (or undergoing) a background compile — the
    /// dedup set; its size is the `bg_pending` gauge.
    compiling: Mutex<HashSet<usize>>,
    compile_tx: Mutex<Option<Sender<Job>>>,
    compile_handle: Mutex<Option<JoinHandle<()>>>,
    bg_compiled: AtomicU64,
    bg_upgrades: AtomicU64,
    /// Sustained-divergence detector over observed keep ratios.
    drift: Mutex<DriftTracker>,
    /// Bounded uniform sample of recent inputs — the recalibration
    /// batch.
    reservoir: Mutex<InputReservoir>,
    /// A `Recalibrate` job is queued or running (dedup: one at a time).
    recal_pending: AtomicBool,
    drift_trips: AtomicU64,
    recalibrations: AtomicU64,
    /// Published-vs-wanted step distance histogram (bucket 7 = ≥7).
    publish_dist: [AtomicU64; 8],
    /// Coordinator metrics mirror for the bg counters (serve stats
    /// line / snapshots).
    metrics: Arc<Metrics>,
    /// Flight-recorder ring ("control") for plan swaps, background
    /// compiles, drift trips, and recalibrations. `None` when the
    /// coordinator runs with observability off.
    ring: Option<Arc<TraceRing>>,
}

impl std::fmt::Debug for Governor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.status();
        f.debug_struct("Governor")
            .field("step", &s.step)
            .field("scale_q8", &s.scale_q8)
            .field("budget_mj", &s.budget_mj)
            .field("swaps", &s.swaps)
            .field("bg_pending", &s.bg_pending)
            .finish()
    }
}

impl Governor {
    /// Build a governor over `cache` and install it on `coord`: seeds
    /// the scale (feed-forward from `profile` when given, else scale
    /// 1.0 snapped to the grid), swaps the seeded plan into the
    /// coordinator's slot, installs the profiled cost oracle, starts
    /// the background compile thread, and registers the energy tap.
    ///
    /// Errors when `coord` has no plan slot (Pjrt backend — nothing to
    /// govern).
    pub fn install(
        coord: &Coordinator,
        cache: Arc<PlanCache>,
        profile: Option<Arc<KeepProfile>>,
        budget_mj: f64,
    ) -> Result<Arc<Governor>, &'static str> {
        let slot = coord
            .plan_slot()
            .ok_or("adaptive governor needs the McuSim backend (no plan slot)")?;
        let mut ctrl = EnergyController::new(budget_mj);
        ctrl.snap_to_grid(cache.grid());
        let step = match &profile {
            Some(p) => p.seed_step(budget_mj),
            None => cache.grid().snap_q8(ctrl.t_scale_q8()),
        };
        ctrl.set_scale(cache.grid().scale(step));
        let (tx, rx) = channel::<Job>();
        let gov = Arc::new(Governor {
            cache: Arc::clone(&cache),
            slot: Arc::clone(&slot),
            cost_slot: coord.cost_estimator_slot(),
            profile: RwLock::new(profile),
            ctrl: Mutex::new(ctrl),
            step: AtomicUsize::new(step),
            swaps: AtomicU64::new(0),
            compiling: Mutex::new(HashSet::new()),
            compile_tx: Mutex::new(Some(tx)),
            compile_handle: Mutex::new(None),
            bg_compiled: AtomicU64::new(0),
            bg_upgrades: AtomicU64::new(0),
            drift: Mutex::new(DriftTracker::new(DriftCfg::default())),
            reservoir: Mutex::new(InputReservoir::new(64, 0x5EED_D81F)),
            recal_pending: AtomicBool::new(false),
            drift_trips: AtomicU64::new(0),
            recalibrations: AtomicU64::new(0),
            publish_dist: Default::default(),
            metrics: Arc::clone(&coord.metrics),
            ring: coord.recorder().map(|r| r.ring("control")),
        });
        // The compile thread holds only a Weak: the governor's Drop
        // closes the channel and joins it.
        let weak = Arc::downgrade(&gov);
        let handle = std::thread::spawn(move || compile_loop(weak, rx));
        *lock_recover(&gov.compile_handle) = Some(handle);
        // Startup seed compiles synchronously: nothing is serving yet.
        slot.swap(cache.plan_at(step));
        gov.trace(EventKind::PlanSwap, step as u64);
        gov.retarget_cost(step);
        gov.publish_bg_metrics();
        coord.set_energy_tap(Some(Arc::clone(&gov) as Arc<dyn EnergyTap>));
        Ok(gov)
    }

    /// Emit one flight-recorder event on the "control" ring (no-op
    /// when observability is off). `id` is 0: the single-model
    /// governor always governs model 0; the fleet scheduler stamps
    /// real model ids on its own ring.
    fn trace(&self, kind: EventKind, a: u64) {
        if let Some(r) = &self.ring {
            r.emit(kind, 0, a, 0, 0);
        }
    }

    fn retarget_cost(&self, step: usize) {
        let profile = read_recover(&self.profile).clone();
        if let Some(p) = profile {
            let est: Arc<dyn CostEstimator> = Arc::new(ProfiledCost { profile: p, step });
            *write_recover(&self.cost_slot) = Some(est);
        }
    }

    /// The live calibrated profile (replaced wholesale by
    /// recalibration — compare `Arc::ptr_eq` to detect a republish).
    pub fn profile(&self) -> Option<Arc<KeepProfile>> {
        read_recover(&self.profile).clone()
    }

    /// Published-vs-wanted step distance histogram: bucket `d` counts
    /// plan publishes that landed `d` grid steps from the wanted step
    /// (bucket 7 aggregates everything farther). Bucket 0 is an exact
    /// publish — inline hits, background upgrades, recalibration
    /// re-seeds; nonzero buckets are nearest-resident stand-ins.
    pub fn publish_distance_histogram(&self) -> [u64; 8] {
        let mut out = [0u64; 8];
        for (o, c) in out.iter_mut().zip(&self.publish_dist) {
            *o = c.load(Ordering::Relaxed);
        }
        out
    }

    fn record_publish_distance(&self, dist: usize) {
        self.publish_dist[dist.min(7)].fetch_add(1, Ordering::Relaxed);
    }

    /// Queue one live recalibration (deduplicated while pending).
    fn request_recalibrate(&self) {
        if self.recal_pending.swap(true, Ordering::AcqRel) {
            return; // already queued or running
        }
        let sent = matches!(
            lock_recover(&self.compile_tx).as_ref().map(|tx| tx.send(Job::Recalibrate)),
            Some(Ok(()))
        );
        if !sent {
            // Channel gone (shutdown race): release the reservation.
            self.recal_pending.store(false, Ordering::Release);
        }
    }

    /// Mirror the background-compile counters into the coordinator's
    /// [`Metrics`] (gauge + counters, replace-style). Called only from
    /// single-writer contexts — `install` (before any compile activity
    /// can exist) and the compile thread (serial) — because a
    /// replace-style publish from a concurrent path could land a stale
    /// snapshot *after* a newer one and wedge the mirror. A pending
    /// request enqueued between publishes is picked up by the compile
    /// thread's next end-of-item publish, so the mirror is eventually
    /// exact in every quiescent state. (`GovernorStatus` reads the
    /// true counters directly and is never stale.)
    fn publish_bg_metrics(&self) {
        self.metrics.record_bg_compile(
            lock_recover(&self.compiling).len() as u64,
            self.bg_compiled.load(Ordering::Relaxed),
            self.bg_upgrades.load(Ordering::Relaxed),
        );
    }

    /// Queue `step` for a background compile (deduplicated). Returns
    /// immediately; the compile thread upgrades the slot when done.
    /// Does NOT publish the metrics mirror (see `publish_bg_metrics`):
    /// the compile thread this enqueues to will.
    fn request_compile(&self, step: usize) {
        let mut compiling = lock_recover(&self.compiling);
        if !compiling.insert(step) {
            return; // already queued or in flight
        }
        drop(compiling);
        let tx = lock_recover(&self.compile_tx);
        match tx.as_ref().map(|tx| tx.send(Job::Compile(step))) {
            Some(Ok(())) => {}
            // Channel gone (shutdown race): forget the reservation.
            _ => {
                lock_recover(&self.compiling).remove(&step);
            }
        }
    }

    /// Change the energy budget (the `SetBudget` admin frame; also the
    /// harvester-forecast path). Takes effect on the next observation.
    pub fn set_budget(&self, budget_mj: f64) {
        lock_recover(&self.ctrl).set_budget(budget_mj);
    }

    /// Active grid step.
    pub fn step(&self) -> usize {
        self.step.load(Ordering::Acquire)
    }

    /// Snapshot of the governor's control state.
    pub fn status(&self) -> GovernorStatus {
        let (scale_q8, budget_mj, ewma_mj) = {
            let c = lock_recover(&self.ctrl);
            (c.t_scale_q8(), c.budget_mj, c.ewma_mj())
        };
        let step = self.step();
        let keep_ratio = match read_recover(&self.profile).as_ref() {
            Some(p) => p.model_keep_ratio(step),
            None => 0.0,
        };
        GovernorStatus {
            scale_q8,
            step,
            steps_total: self.cache.grid().len(),
            budget_mj,
            ewma_mj,
            keep_ratio,
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            swaps: self.swaps.load(Ordering::Relaxed),
            bg_pending: lock_recover(&self.compiling).len() as u64,
            bg_compiled: self.bg_compiled.load(Ordering::Relaxed),
            bg_upgrades: self.bg_upgrades.load(Ordering::Relaxed),
            drift_trips: self.drift_trips.load(Ordering::Relaxed),
            recalibrations: self.recalibrations.load(Ordering::Relaxed),
        }
    }
}

impl EnergyTap for Governor {
    /// One request's measured energy: AIMD update, snap, and — on a
    /// step change — a plan swap. Serialized under the controller
    /// mutex so two workers finishing simultaneously cannot race the
    /// swap. **Never compiles**: a resident plan swaps inline, a miss
    /// publishes the nearest resident and hands the compile to the
    /// background thread.
    fn observe(&self, energy_mj: f64) {
        let mut ctrl = lock_recover(&self.ctrl);
        ctrl.observe(energy_mj);
        let want = self.cache.grid().snap_q8(ctrl.t_scale_q8());
        let cur = self.step.load(Ordering::Acquire);
        if want == cur {
            return;
        }
        if let Some(plan) = self.cache.try_get(want) {
            self.slot.swap(plan);
            self.step.store(want, Ordering::Release);
            self.swaps.fetch_add(1, Ordering::Relaxed);
            self.record_publish_distance(0);
            self.trace(EventKind::PlanSwap, want as u64);
            self.retarget_cost(want);
            return;
        }
        // Miss: compile off-thread, serve the nearest ready plan now —
        // but only if it actually moves the pool CLOSER to the wanted
        // scale. (The current step's entry can be LRU-evicted from a
        // capacity-bounded cache even while it is being served, so the
        // nearest resident may be farther from `want` than the plan
        // already in the slot; swapping there would walk the pool in
        // the wrong budget direction.)
        self.request_compile(want);
        if let Some((near, plan)) = self.cache.nearest_resident(want) {
            let grid = self.cache.grid();
            let dist = |s: usize| (grid.q8(s) as i64 - grid.q8(want) as i64).abs();
            if near != cur && dist(near) < dist(cur) {
                self.slot.swap(plan);
                self.step.store(near, Ordering::Release);
                self.swaps.fetch_add(1, Ordering::Relaxed);
                self.record_publish_distance(near.abs_diff(want));
                self.trace(EventKind::PlanSwap, near as u64);
                self.retarget_cost(near);
            }
        }
    }

    /// One request's observed model keep ratio — the drift detector's
    /// feed. Compares against the calibrated expectation at the active
    /// step; a sustained-divergence trip queues one live recalibration
    /// on the background thread. No profile ⇒ no expectation ⇒ no-op.
    fn observe_keep(&self, ratio: f64) {
        let Some(profile) = read_recover(&self.profile).clone() else {
            return;
        };
        let expected = profile.model_keep_ratio(self.step.load(Ordering::Acquire));
        let tripped = lock_recover(&self.drift).observe(ratio, expected);
        if tripped {
            self.drift_trips.fetch_add(1, Ordering::Relaxed);
            self.trace(EventKind::DriftTrip, 0);
            self.request_recalibrate();
        }
    }

    /// Offer a served input to the recalibration reservoir (only while
    /// a profile is attached — drift is only detectable against one).
    fn sample_input(&self, x: &[f32]) {
        if read_recover(&self.profile).is_some() {
            lock_recover(&self.reservoir).push(x);
        }
    }
}

/// The background compile loop: stamp each requested step's plan off
/// every worker thread (and off the cache lock — `plan_at` compiles
/// lock-free and interns after), then upgrade the live slot if the
/// step is still wanted.
fn compile_loop(gov: Weak<Governor>, rx: Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        let Some(gov) = gov.upgrade() else { return };
        match job {
            Job::Compile(step) => {
                let plan = gov.cache.plan_at(step);
                lock_recover(&gov.compiling).remove(&step);
                gov.bg_compiled.fetch_add(1, Ordering::Relaxed);
                gov.trace(EventKind::BgCompile, step as u64);
                // Upgrade under the controller lock so inline swaps and
                // upgrades are serialized against each other. A stale
                // step (controller moved on while we compiled) stays
                // interned in the cache but does not touch the slot.
                {
                    let ctrl = lock_recover(&gov.ctrl);
                    let want = gov.cache.grid().snap_q8(ctrl.t_scale_q8());
                    if want == step && gov.step.load(Ordering::Acquire) != step {
                        gov.slot.swap(plan);
                        gov.step.store(step, Ordering::Release);
                        gov.swaps.fetch_add(1, Ordering::Relaxed);
                        gov.bg_upgrades.fetch_add(1, Ordering::Relaxed);
                        gov.record_publish_distance(0);
                        gov.trace(EventKind::PlanSwap, step as u64);
                        gov.retarget_cost(step);
                    }
                }
                gov.publish_bg_metrics();
            }
            Job::Recalibrate => recalibrate(&gov),
        }
        // Drop the strong handle before blocking on the next request,
        // so the governor can be torn down while the queue is idle.
        drop(gov);
    }
}

/// Live recalibration (background thread only). Measurement — the
/// expensive part, `grid.len() × reservoir` inferences that also warm
/// every cache step — runs **off** the controller lock; only the
/// publish (profile swap, re-seeded plan swap, cost retarget, tracker
/// re-arm) holds it, the same discipline as a background upgrade.
fn recalibrate(gov: &Arc<Governor>) {
    let xs = lock_recover(&gov.reservoir).samples();
    if xs.is_empty() {
        // Nothing observed yet (trip raced an empty reservoir): drop
        // the reservation; a later trip retries with data.
        gov.recal_pending.store(false, Ordering::Release);
        return;
    }
    let fresh = Arc::new(KeepProfile::measure(&gov.cache, &xs));
    {
        let mut ctrl = lock_recover(&gov.ctrl);
        *write_recover(&gov.profile) = Some(Arc::clone(&fresh));
        // Feed-forward re-seed off the fresh energy curve, exactly as
        // installation does — the AIMD loop then fine-tunes from a
        // point the *current* traffic says fits the budget.
        let seed = fresh.seed_step(ctrl.budget_mj);
        ctrl.set_scale(gov.cache.grid().scale(seed));
        if gov.step.load(Ordering::Acquire) != seed {
            // measure() warmed every grid step, so the seed is
            // resident by construction.
            if let Some(plan) = gov.cache.try_get(seed) {
                gov.slot.swap(plan);
                gov.step.store(seed, Ordering::Release);
                gov.swaps.fetch_add(1, Ordering::Relaxed);
                gov.record_publish_distance(0);
                gov.trace(EventKind::PlanSwap, seed as u64);
            }
        }
        gov.retarget_cost(gov.step.load(Ordering::Acquire));
        // Re-arm against the new baseline; the trip count survives.
        lock_recover(&gov.drift).reset();
    }
    lock_recover(&gov.reservoir).clear();
    gov.recalibrations.fetch_add(1, Ordering::Relaxed);
    gov.trace(EventKind::Recalibrate, 0);
    gov.recal_pending.store(false, Ordering::Release);
    gov.publish_bg_metrics();
}

/// Close the compile channel and join the thread. The compile thread
/// itself can hold the last strong reference transiently — joining
/// from that thread would deadlock, so it detaches instead (the thread
/// is already on its way out once the channel is gone).
impl Drop for Governor {
    fn drop(&mut self) {
        drop(lock_recover(&self.compile_tx).take());
        if let Some(h) = lock_recover(&self.compile_handle).take() {
            if h.thread().id() != std::thread::current().id() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::DivKind;
    use crate::control::ScaleGrid;
    use crate::coordinator::{BackendChoice, ServeConfig};
    use crate::engine::{PlanConfig, PruneMode, QModel};
    use crate::models::{zoo, Params};
    use crate::pruning::Thresholds;
    use std::time::{Duration, Instant};

    fn boot(workers: usize) -> (Coordinator, Arc<PlanCache>, Vec<Vec<f32>>) {
        boot_with_capacity(workers, usize::MAX)
    }

    fn boot_with_capacity(
        workers: usize,
        capacity: usize,
    ) -> (Coordinator, Arc<PlanCache>, Vec<Vec<f32>>) {
        let def = zoo("mnist");
        let params = Params::random(&def, 91);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.15));
        let coord = Coordinator::start(
            BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Shift },
            ServeConfig { workers, ..Default::default() },
        );
        let grid = ScaleGrid::geometric(0.25, 8.0, 10);
        let capacity = capacity.min(grid.len());
        let cache = Arc::new(PlanCache::with_capacity(
            q,
            PlanConfig::unit(DivKind::Shift),
            grid,
            capacity,
        ));
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|s| {
                (0..def.input_len())
                    .map(|i| (((i * 11 + s * 5) % 19) as f32 - 9.0) / 7.0)
                    .collect()
            })
            .collect();
        (coord, cache, xs)
    }

    #[test]
    fn tight_budget_raises_the_step_and_relief_lowers_it() {
        let (coord, cache, xs) = boot(2);
        let gov = Governor::install(&coord, Arc::clone(&cache), None, 1e9).unwrap();
        assert_eq!(gov.step(), cache.grid().snap_q8(256), "generous budget should seed ~1.0");
        // Starve the budget: each served request feeds the tap; the
        // governor must climb the grid (misses compile in the
        // background, so give the loop enough observations).
        gov.set_budget(1e-6);
        for _ in 0..120 {
            let rx = coord.submit(xs[0].clone());
            rx.recv().unwrap();
        }
        let high = gov.step();
        assert!(high > cache.grid().snap_q8(256), "step never rose: {high}");
        assert!(gov.status().swaps > 0);
        // Relief: the step walks back down.
        gov.set_budget(1e9);
        for _ in 0..160 {
            let rx = coord.submit(xs[1 % xs.len()].clone());
            rx.recv().unwrap();
        }
        assert!(gov.step() < high, "step never fell after budget relief");
        // Walking back revisits compiled steps: hits, no fresh misses
        // beyond the distinct steps visited.
        assert!(cache.hits() > 0, "no cache hits on the walk back");
        assert!(cache.misses() <= cache.grid().len() as u64);
        coord.shutdown();
    }

    #[test]
    fn misses_compile_in_the_background_and_the_climb_still_lands() {
        // Cold cache beyond the seeded step: every climb step is a
        // miss. The swap path must keep answering (publishing nearest
        // residents) while the background thread compiles; the pool
        // still reaches the top step under starvation.
        let (coord, cache, xs) = boot(1);
        let gov = Governor::install(&coord, Arc::clone(&cache), None, 1e9).unwrap();
        assert_eq!(cache.len(), 1, "install must seed exactly one resident step");
        gov.set_budget(1e-9);
        let top = cache.grid().len() - 1;
        let deadline = Instant::now() + Duration::from_secs(60);
        while gov.step() != top {
            assert!(Instant::now() < deadline, "never climbed to the top step");
            coord.submit(xs[0].clone()).recv().unwrap();
        }
        let st = gov.status();
        assert!(st.bg_compiled > 0, "no background compiles ran");
        assert!(
            st.bg_compiled >= st.bg_upgrades,
            "more upgrades than compiles: {} vs {}",
            st.bg_upgrades,
            st.bg_compiled
        );
        // Wait for the queue to drain, then the pending gauge is zero.
        let deadline = Instant::now() + Duration::from_secs(30);
        while gov.status().bg_pending != 0 {
            assert!(Instant::now() < deadline, "compile queue never drained");
            std::thread::sleep(Duration::from_millis(10));
        }
        // The coordinator metrics mirror converges to the governor's
        // counters (published at the end of each compile iteration, so
        // give the last publish a moment to land).
        let want = gov.status().bg_compiled;
        let deadline = Instant::now() + Duration::from_secs(10);
        while coord.metrics.snapshot().bg_compiled != want {
            assert!(
                Instant::now() < deadline,
                "metrics mirror never converged: {} vs {}",
                coord.metrics.snapshot().bg_compiled,
                want
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        coord.shutdown();
    }

    #[test]
    fn capacity_bounded_cache_still_climbs_under_eviction_churn() {
        // A 2-entry LRU under a 10-step grid: background compiles
        // evict each other constantly and the currently served step's
        // entry can vanish from the cache while it is live in the
        // slot. The pool must still converge upward under starvation —
        // the nearest-resident guard never walks it AWAY from the
        // wanted scale — and the LRU bound must hold throughout.
        let (coord, cache, xs) = boot_with_capacity(1, 2);
        let gov = Governor::install(&coord, Arc::clone(&cache), None, 1e9).unwrap();
        gov.set_budget(1e-9);
        let top = cache.grid().len() - 1;
        let deadline = Instant::now() + Duration::from_secs(60);
        while gov.step() != top {
            assert!(
                Instant::now() < deadline,
                "eviction churn stalled the climb at step {}",
                gov.step()
            );
            coord.submit(xs[0].clone()).recv().unwrap();
            assert!(cache.len() <= 2, "LRU capacity violated");
        }
        assert!(gov.status().bg_compiled > 0, "capacity-bounded climb never compiled");
        coord.shutdown();
    }

    #[test]
    fn a_miss_publishes_the_nearest_resident_and_upgrades_when_ready() {
        // Deterministic upgrade: feed observations directly (no worker
        // traffic racing us), stop as soon as a background compile is
        // pending, and watch the slot upgrade to the exact step once
        // the stamp lands — the controller cannot move in between.
        let (coord, cache, _xs) = boot(1);
        let slot = coord.plan_slot().unwrap();
        let gov = Governor::install(&coord, Arc::clone(&cache), None, 1e9).unwrap();
        let seeded = gov.step();
        gov.set_budget(1e-9);
        let deadline = Instant::now() + Duration::from_secs(30);
        while gov.status().bg_pending == 0 {
            assert!(Instant::now() < deadline, "starvation never produced a miss");
            gov.observe(1e9);
        }
        // The swap path answered without compiling: whatever is
        // published now is a resident plan (the nearest one), and the
        // observe calls above returned immediately.
        let published = cache.grid().snap_q8(slot.get().cfg.t_scale_q8);
        assert!(
            cache.try_get(published).is_some(),
            "published step {published} is not resident"
        );
        // With observations stopped, only the background thread can
        // move the step — to exactly the wanted (pending) one.
        let want = {
            let st = gov.status();
            cache.grid().snap_q8(st.scale_q8)
        };
        assert_ne!(want, seeded, "setup: the wanted step never left the seed");
        let deadline = Instant::now() + Duration::from_secs(30);
        while gov.step() != want {
            assert!(Instant::now() < deadline, "background upgrade never landed");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(gov.status().bg_upgrades >= 1, "upgrade not counted");
        assert_eq!(
            cache.grid().snap_q8(slot.get().cfg.t_scale_q8),
            want,
            "slot plan does not match the upgraded step"
        );
        coord.shutdown();
    }

    #[test]
    fn profiled_install_seeds_from_the_energy_curve() {
        let (coord, cache, xs) = boot(1);
        let profile = Arc::new(KeepProfile::measure(&cache, &xs));
        // A budget between the extremes must seed a step the curve
        // says fits it.
        let mid = profile.mean_mj(profile.n_steps() / 2);
        let gov =
            Governor::install(&coord, Arc::clone(&cache), Some(Arc::clone(&profile)), mid)
                .unwrap();
        let s = gov.step();
        assert!(profile.mean_mj(s) <= mid, "seeded step overruns the budget curve");
        // The profiled cost oracle is installed.
        let est = coord.cost_estimator_slot().read().unwrap().clone();
        assert!(est.is_some(), "profiled cost estimator not installed");
        let st = gov.status();
        assert!(st.keep_ratio > 0.0 && st.keep_ratio <= 1.0);
        assert_eq!(st.steps_total, cache.grid().len());
        coord.shutdown();
    }

    #[test]
    fn reinstall_replaces_the_previous_governor() {
        // Installing twice (e.g. a reconfigured budget loop) must not
        // wedge: the second governor takes over the tap and the slot,
        // and the first one's compile thread shuts down cleanly when
        // its last handle drops.
        let (coord, cache, xs) = boot(1);
        let g1 = Governor::install(&coord, Arc::clone(&cache), None, 1.0).unwrap();
        let g2 = Governor::install(&coord, Arc::clone(&cache), None, 1e-6).unwrap();
        drop(g1);
        for _ in 0..40 {
            coord.submit(xs[0].clone()).recv().unwrap();
        }
        assert!(g2.step() > 0, "replacement governor not receiving observations");
        coord.shutdown();
    }

    #[test]
    fn sustained_drift_trips_and_recalibrates_live() {
        let (coord, cache, xs) = boot(1);
        let profile = Arc::new(KeepProfile::measure(&cache, &xs));
        let budget = profile.mean_mj(profile.n_steps() / 2);
        let gov =
            Governor::install(&coord, Arc::clone(&cache), Some(Arc::clone(&profile)), budget)
                .unwrap();
        let before = gov.profile().unwrap();
        // Fill the reservoir with the post-shift inputs recalibration
        // will re-measure on.
        for x in &xs {
            for _ in 0..10 {
                gov.sample_input(x);
            }
        }
        // A sustained keep-ratio shift well past the CUSUM slack: the
        // tracker must trip within min_samples + λ/(Δ−δ) observations.
        let expected = profile.model_keep_ratio(gov.step());
        let shifted = if expected > 0.25 { expected - 0.2 } else { expected + 0.2 };
        for _ in 0..200 {
            gov.observe_keep(shifted);
        }
        assert!(gov.status().drift_trips >= 1, "sustained shift never tripped");
        // The background thread re-measures and republishes.
        let deadline = Instant::now() + Duration::from_secs(60);
        while gov.status().recalibrations == 0 {
            assert!(Instant::now() < deadline, "recalibration never landed");
            std::thread::sleep(Duration::from_millis(5));
        }
        let after = gov.profile().unwrap();
        assert!(!Arc::ptr_eq(&before, &after), "profile not republished");
        // Pricing was retargeted (a profiled estimator is installed).
        assert!(coord.cost_estimator_slot().read().unwrap().is_some());
        coord.shutdown();
    }

    #[test]
    fn stationary_keep_ratios_never_trigger_recalibration() {
        let (coord, cache, xs) = boot(1);
        let profile = Arc::new(KeepProfile::measure(&cache, &xs));
        let gov =
            Governor::install(&coord, Arc::clone(&cache), Some(Arc::clone(&profile)), 1e9)
                .unwrap();
        let expected = profile.model_keep_ratio(gov.step());
        // 1000 observations fluctuating inside the CUSUM slack.
        for i in 0..1000 {
            let noise = 0.015 * if i % 2 == 0 { 1.0 } else { -1.0 };
            gov.observe_keep(expected + noise);
        }
        let st = gov.status();
        assert_eq!(st.drift_trips, 0, "stationary load tripped the detector");
        assert_eq!(st.recalibrations, 0);
        assert!(Arc::ptr_eq(&gov.profile().unwrap(), &profile));
        coord.shutdown();
    }

    #[test]
    fn publish_distance_histogram_accounts_every_swap() {
        let (coord, cache, _xs) = boot(1);
        let gov = Governor::install(&coord, Arc::clone(&cache), None, 1e9).unwrap();
        gov.set_budget(1e-9);
        // Drive the controller directly (no worker traffic racing us):
        // every swap — inline, nearest-resident stand-in, or background
        // upgrade — must land in exactly one histogram bucket.
        for _ in 0..200 {
            gov.observe(1e9);
        }
        // Quiesce: with observations stopped, the histogram converges
        // onto the swap counter once in-flight upgrades land.
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let st = gov.status();
            let hist = gov.publish_distance_histogram();
            if st.bg_pending == 0 && hist.iter().sum::<u64>() == st.swaps {
                assert!(st.swaps > 0, "starvation produced no swaps");
                assert!(hist[0] > 0, "no exact publishes recorded");
                break;
            }
            assert!(
                Instant::now() < deadline,
                "histogram never converged: {:?} vs {} swaps",
                hist,
                st.swaps
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        coord.shutdown();
    }
}
