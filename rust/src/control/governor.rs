//! The serve-side budget governor: the loop that makes the adaptive
//! controller act on the serving stack.
//!
//! Ownership: the governor owns the [`EnergyController`] and a handle
//! to every knob it turns —
//!
//! * it is installed as the coordinator's
//!   [`EnergyTap`](crate::coordinator::EnergyTap), so every McuSim
//!   worker reports each request's modeled ledger energy after
//!   delivering the reply;
//! * each observation runs one AIMD update, snaps the resulting scale
//!   to the [`ScaleGrid`](super::ScaleGrid), and — only when the step
//!   actually changed — fetches the step's plan from the
//!   [`PlanCache`] and swaps the coordinator's
//!   [`PlanSlot`](crate::coordinator::PlanSlot) atomically (workers
//!   pick the new plan up at their next dequeue; in-flight requests
//!   finish on the plan they started with);
//! * on every swap it also retargets the placement cost oracle
//!   ([`ProfiledCost`](super::ProfiledCost)) to the new step, when a
//!   calibrated [`KeepProfile`] is attached;
//! * [`Governor::set_budget`] is the wire-facing knob (the `SetBudget`
//!   admin frame lands here), [`Governor::status`] the wire-facing
//!   gauge (the `Stats` frame).
//!
//! With a profile attached, installation is **feed-forward seeded**:
//! the initial step is the cheapest step whose calibrated mean energy
//! fits the budget, so the loop starts near its operating point
//! instead of walking there one AIMD nudge at a time.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::calibrate::{KeepProfile, ProfiledCost};
use super::plan_cache::PlanCache;
use crate::coordinator::{
    Coordinator, CostEstimator, CostEstimatorSlot, EnergyController, EnergyTap, PlanSlot,
};

/// A point-in-time view of the governor (the `Stats` admin frame's
/// payload).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GovernorStatus {
    /// Active threshold scale in Q8.8.
    pub scale_q8: u32,
    /// Active grid step.
    pub step: usize,
    /// Total steps in the grid.
    pub steps_total: usize,
    pub budget_mj: f64,
    /// EWMA of observed per-request energy (mJ).
    pub ewma_mj: f64,
    /// Calibrated whole-model keep ratio at the active step (0 when no
    /// profile is attached).
    pub keep_ratio: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Plan swaps performed since installation.
    pub swaps: u64,
}

/// The budget-driven plan governor (see module docs).
pub struct Governor {
    cache: Arc<PlanCache>,
    slot: Arc<PlanSlot>,
    cost_slot: CostEstimatorSlot,
    profile: Option<Arc<KeepProfile>>,
    /// Controller + swap path, serialized: concurrent worker
    /// observations queue here, so step transitions (and their
    /// cache lookups) are single-file.
    ctrl: Mutex<EnergyController>,
    step: AtomicUsize,
    swaps: AtomicU64,
}

impl std::fmt::Debug for Governor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.status();
        f.debug_struct("Governor")
            .field("step", &s.step)
            .field("scale_q8", &s.scale_q8)
            .field("budget_mj", &s.budget_mj)
            .field("swaps", &s.swaps)
            .finish()
    }
}

impl Governor {
    /// Build a governor over `cache` and install it on `coord`: seeds
    /// the scale (feed-forward from `profile` when given, else scale
    /// 1.0 snapped to the grid), swaps the seeded plan into the
    /// coordinator's slot, installs the profiled cost oracle, and
    /// registers the energy tap.
    ///
    /// Errors when `coord` has no plan slot (Pjrt backend — nothing to
    /// govern).
    pub fn install(
        coord: &Coordinator,
        cache: Arc<PlanCache>,
        profile: Option<Arc<KeepProfile>>,
        budget_mj: f64,
    ) -> Result<Arc<Governor>, &'static str> {
        let slot = coord
            .plan_slot()
            .ok_or("adaptive governor needs the McuSim backend (no plan slot)")?;
        let mut ctrl = EnergyController::new(budget_mj);
        ctrl.snap_to_grid(cache.grid());
        let step = match &profile {
            Some(p) => p.seed_step(budget_mj),
            None => cache.grid().snap_q8(ctrl.t_scale_q8()),
        };
        ctrl.set_scale(cache.grid().scale(step));
        let gov = Arc::new(Governor {
            cache: Arc::clone(&cache),
            slot: Arc::clone(&slot),
            cost_slot: coord.cost_estimator_slot(),
            profile,
            ctrl: Mutex::new(ctrl),
            step: AtomicUsize::new(step),
            swaps: AtomicU64::new(0),
        });
        slot.swap(cache.plan_at(step));
        gov.retarget_cost(step);
        coord.set_energy_tap(Some(Arc::clone(&gov) as Arc<dyn EnergyTap>));
        Ok(gov)
    }

    fn retarget_cost(&self, step: usize) {
        if let Some(p) = &self.profile {
            let est: Arc<dyn CostEstimator> =
                Arc::new(ProfiledCost { profile: Arc::clone(p), step });
            *self.cost_slot.write().unwrap() = Some(est);
        }
    }

    /// Change the energy budget (the `SetBudget` admin frame; also the
    /// harvester-forecast path). Takes effect on the next observation.
    pub fn set_budget(&self, budget_mj: f64) {
        self.ctrl.lock().unwrap().set_budget(budget_mj);
    }

    /// Active grid step.
    pub fn step(&self) -> usize {
        self.step.load(Ordering::Acquire)
    }

    pub fn status(&self) -> GovernorStatus {
        let (scale_q8, budget_mj, ewma_mj) = {
            let c = self.ctrl.lock().unwrap();
            (c.t_scale_q8(), c.budget_mj, c.ewma_mj())
        };
        let step = self.step();
        let keep_ratio = match &self.profile {
            Some(p) => p.model_keep_ratio(step),
            None => 0.0,
        };
        GovernorStatus {
            scale_q8,
            step,
            steps_total: self.cache.grid().len(),
            budget_mj,
            ewma_mj,
            keep_ratio,
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            swaps: self.swaps.load(Ordering::Relaxed),
        }
    }
}

impl EnergyTap for Governor {
    /// One request's measured energy: AIMD update, snap, and — on a
    /// step change — a plan swap. Serialized under the controller
    /// mutex so two workers finishing simultaneously cannot race the
    /// swap; the losing worker just queues behind a (rare, cache-hit
    /// cheap) transition.
    fn observe(&self, energy_mj: f64) {
        let mut ctrl = self.ctrl.lock().unwrap();
        ctrl.observe(energy_mj);
        let new_step = self.cache.grid().snap_q8(ctrl.t_scale_q8());
        let cur = self.step.load(Ordering::Acquire);
        if new_step != cur {
            let plan = self.cache.plan_at(new_step);
            self.slot.swap(plan);
            self.step.store(new_step, Ordering::Release);
            self.swaps.fetch_add(1, Ordering::Relaxed);
            self.retarget_cost(new_step);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::DivKind;
    use crate::control::ScaleGrid;
    use crate::coordinator::{BackendChoice, ServeConfig};
    use crate::engine::{PlanConfig, PruneMode, QModel};
    use crate::models::{zoo, Params};
    use crate::pruning::Thresholds;

    fn boot(workers: usize) -> (Coordinator, Arc<PlanCache>, Vec<Vec<f32>>) {
        let def = zoo("mnist");
        let params = Params::random(&def, 91);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.15));
        let coord = Coordinator::start(
            BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Shift },
            ServeConfig { workers, ..Default::default() },
        );
        let cache = Arc::new(PlanCache::new(
            q,
            PlanConfig::unit(DivKind::Shift),
            ScaleGrid::geometric(0.25, 8.0, 10),
        ));
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|s| {
                (0..def.input_len())
                    .map(|i| (((i * 11 + s * 5) % 19) as f32 - 9.0) / 7.0)
                    .collect()
            })
            .collect();
        (coord, cache, xs)
    }

    #[test]
    fn tight_budget_raises_the_step_and_relief_lowers_it() {
        let (coord, cache, xs) = boot(2);
        let gov = Governor::install(&coord, Arc::clone(&cache), None, 1e9).unwrap();
        assert_eq!(gov.step(), cache.grid().snap_q8(256), "generous budget should seed ~1.0");
        // Starve the budget: each served request feeds the tap; the
        // governor must climb the grid.
        gov.set_budget(1e-6);
        for _ in 0..60 {
            let rx = coord.submit(xs[0].clone());
            rx.recv().unwrap();
        }
        let high = gov.step();
        assert!(high > cache.grid().snap_q8(256), "step never rose: {high}");
        assert!(gov.status().swaps > 0);
        // Relief: the step walks back down.
        gov.set_budget(1e9);
        for _ in 0..120 {
            let rx = coord.submit(xs[1 % xs.len()].clone());
            rx.recv().unwrap();
        }
        assert!(gov.step() < high, "step never fell after budget relief");
        // Walking back revisits compiled steps: hits, no fresh misses
        // beyond the distinct steps visited.
        assert!(cache.hits() > 0, "no cache hits on the walk back");
        assert!(cache.misses() <= cache.grid().len() as u64);
        coord.shutdown();
    }

    #[test]
    fn profiled_install_seeds_from_the_energy_curve() {
        let (coord, cache, xs) = boot(1);
        let profile = Arc::new(KeepProfile::measure(&cache, &xs));
        // A budget between the extremes must seed a step the curve
        // says fits it.
        let mid = profile.mean_mj(profile.n_steps() / 2);
        let gov =
            Governor::install(&coord, Arc::clone(&cache), Some(Arc::clone(&profile)), mid)
                .unwrap();
        let s = gov.step();
        assert!(profile.mean_mj(s) <= mid, "seeded step overruns the budget curve");
        // The profiled cost oracle is installed.
        let est = coord.cost_estimator_slot().read().unwrap().clone();
        assert!(est.is_some(), "profiled cost estimator not installed");
        let st = gov.status();
        assert!(st.keep_ratio > 0.0 && st.keep_ratio <= 1.0);
        assert_eq!(st.steps_total, cache.grid().len());
        coord.shutdown();
    }

    #[test]
    fn reinstall_replaces_the_previous_governor() {
        // Installing twice (e.g. a reconfigured budget loop) must not
        // wedge: the second governor takes over the tap and the slot.
        let (coord, cache, xs) = boot(1);
        let _g1 = Governor::install(&coord, Arc::clone(&cache), None, 1.0).unwrap();
        let g2 = Governor::install(&coord, Arc::clone(&cache), None, 1e-6).unwrap();
        for _ in 0..40 {
            coord.submit(xs[0].clone()).recv().unwrap();
        }
        assert!(g2.step() > 0, "replacement governor not receiving observations");
        coord.shutdown();
    }
}
