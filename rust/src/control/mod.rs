//! Adaptive control plane: the runtime subsystem that makes
//! budget-driven inference-time pruning **servable** end to end.
//!
//! The paper's flexibility claim (§6.1) is that UnIT's aggressiveness
//! is a runtime knob — scaling every threshold trades MACs for
//! accuracy per input with no retraining. The serving stack could not
//! act on it: the [`EnergyController`](crate::coordinator::adaptive)
//! adjusts `t_scale_q8`, but a [`PlannedModel`](crate::engine) bakes
//! the scale into its sorted tables at compile time. This module
//! closes that gap with three pieces:
//!
//! * [`plan_cache`] — [`ScaleGrid`] quantizes the controller's
//!   continuous scale to ~20 geometric Q8.8 steps, and [`PlanCache`]
//!   interns one compiled plan per step (LRU-bounded; every
//!   weight-derived table — linear sorted rows and conv tap/lane
//!   tables — shared across scales, misses stamp only the per-scale
//!   cut tables, bit-identical to fresh compiles);
//! * [`calibrate`] — [`KeepProfile`] measures per-layer keep-ratio
//!   curves (and per-step mean energy) over a calibration batch,
//!   replacing layer-0 extrapolation with per-layer interpolation for
//!   placement pricing ([`ProfiledCost`]) and seeding the governor's
//!   scale feed-forward; [`DriftTracker`] + [`InputReservoir`] keep
//!   that profile honest at runtime — sustained divergence between
//!   observed and calibrated keep ratios triggers a live
//!   re-measurement from a reservoir of recent inputs;
//! * [`governor`] — [`Governor`] owns the controller, observes each
//!   request's ledger energy through the coordinator's
//!   [`EnergyTap`](crate::coordinator::EnergyTap), and swaps the
//!   active plan `Arc` between requests through the
//!   [`PlanSlot`](crate::coordinator::PlanSlot). Cache misses never
//!   run on the swap path: the governor's background compile thread
//!   stamps them while the pool serves the nearest resident plan,
//!   upgrading the slot when the compile lands. The serve layer's
//!   `SetBudget`/`Stats` admin frames are its wire front door;
//! * [`scheduler`] — [`FleetScheduler`] generalizes the governor to a
//!   multi-model coordinator: one fleet-wide budget allocated across
//!   every hosted model by greedy buy-down on the calibrated marginal
//!   keep-per-millijoule curves, with per-tenant caps, per-tenant
//!   drift tracking / live recalibration, and one background solve
//!   thread publishing per-model plan swaps.
//!
//! Dependency direction: `coordinator` ← `control` ← `serve` — the
//! coordinator knows only the two traits it exposes, the serve layer
//! holds an optional [`Governor`] or [`FleetScheduler`].

pub mod calibrate;
pub mod governor;
pub mod plan_cache;
pub mod scheduler;

pub use calibrate::{DriftCfg, DriftTracker, InputReservoir, KeepProfile, ProfiledCost};
pub use governor::{Governor, GovernorStatus};
pub use plan_cache::{PlanCache, ScaleGrid, DEFAULT_GRID_STEPS};
pub use scheduler::{allocate_fleet, FleetScheduler, FleetStatus, TenantCurve, TenantStatus};

use std::sync::Arc;

use crate::engine::{PlanConfig, QModel};

/// The standard control-plane bootstrap: intern a plan cache over
/// `grid` and measure the keep-ratio profile on `cal` (which warms
/// every grid step as a side effect). Shared by `unit serve
/// --budget-mj`, `unit eval --adaptive`, and the `adaptive_serve`
/// example so calibration inputs evolve in one place.
pub fn calibrated_cache(
    q: QModel,
    cfg: PlanConfig,
    grid: ScaleGrid,
    cal: &[Vec<f32>],
) -> (Arc<PlanCache>, Arc<KeepProfile>) {
    let cache = Arc::new(PlanCache::new(q, cfg, grid));
    let profile = Arc::new(KeepProfile::measure(&cache, cal));
    (cache, profile)
}
