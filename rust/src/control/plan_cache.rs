//! Scale-indexed plan cache: the compiled-plan store that makes the
//! runtime threshold knob servable.
//!
//! The AIMD [`EnergyController`](crate::coordinator::EnergyController)
//! moves the threshold scale continuously, but a [`PlannedModel`] bakes
//! `t_scale_q8` at compile time (the sorted conv tables are *ordered
//! by* the scaled threshold). Recompiling on every controller nudge
//! would put an O(weights·log) sort on the serve path. The fix is the
//! SparseRT insight turned into a cache: specialize ahead of time per
//! sparsity configuration, and make the configuration space finite by
//! **snapping the continuous scale to a bounded grid** ([`ScaleGrid`],
//! ~20 geometric Q8.8 steps). The controller then only ever visits grid
//! steps, each step's plan is compiled at most once, and a budget swing
//! that revisits a step costs one `Arc` clone.
//!
//! Two cost controls keep the cache cheap:
//!
//! * **shared tables** — linear layers' magnitude-sorted rows *and*
//!   conv layers' `|w|`-sorted tap/lane tables are pure functions of
//!   the weights, so every cached plan shares the first-compiled
//!   plan's tables behind `Arc`s ([`PlannedModel::compile_shared`]);
//!   only the scale-dependent residue is rebuilt per step — the
//!   linear `t_eff` scalars and the conv **cut tables** (stamped `w̄`
//!   values + `always`/`live` prefix lengths per segment). A cache
//!   miss is therefore a cut-table *stamp* (`n` divisions per conv
//!   layer), not a re-sort and not a full recompile.
//! * **LRU eviction** — bounded capacity (default: the whole grid, so
//!   nothing evicts in practice; smaller capacities are honored for
//!   memory-tight deployments and exercised by tests).
//!
//! Misses that do remain (cold steps, tight capacities) can further be
//! taken **off the serve path entirely**: [`PlanCache::try_get`] and
//! [`PlanCache::nearest_resident`] are the non-compiling lookups the
//! [`Governor`](super::Governor)'s background compile thread builds
//! on — the swap path publishes the nearest ready plan immediately and
//! upgrades when the background stamp lands.
//!
//! Every cache-served plan is **bit-identical** to a fresh
//! [`PlannedModel::compile`] at the same step — the property tests
//! below pin logits, kept/skipped counts, and the full ledger across
//! the model zoo.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::{PlanConfig, PlannedModel, QModel};

/// Quantized threshold-scale grid: a fixed, sorted set of Q8.8 scale
/// steps the adaptive controller is clamped to. Geometric spacing
/// (equal *ratios* between steps) matches the controller's
/// multiplicative AIMD moves: one controller step crosses roughly one
/// grid step anywhere in the range.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScaleGrid {
    /// Strictly increasing Q8.8 scale values (deduped after rounding).
    q8: Vec<u32>,
}

/// Default grid span and resolution: the controller's historical
/// clamp range [0.25, 8.0] at 20 steps (~20 % per step).
pub const DEFAULT_GRID_STEPS: usize = 20;

impl ScaleGrid {
    /// A geometric grid of `n` steps spanning `[min_scale, max_scale]`.
    /// Steps are rounded to Q8.8 and deduped, so very tight spans may
    /// yield fewer than `n` distinct steps.
    pub fn geometric(min_scale: f64, max_scale: f64, n: usize) -> ScaleGrid {
        assert!(min_scale > 0.0 && max_scale >= min_scale, "bad grid span");
        let n = n.max(1);
        let mut q8 = Vec::with_capacity(n);
        for i in 0..n {
            let s = if n == 1 {
                min_scale
            } else {
                min_scale * (max_scale / min_scale).powf(i as f64 / (n - 1) as f64)
            };
            let v = (s * 256.0).round().max(1.0) as u32;
            if q8.last() != Some(&v) {
                q8.push(v);
            }
        }
        ScaleGrid { q8 }
    }

    /// The default serving grid: `[0.25, 8.0]` at
    /// [`DEFAULT_GRID_STEPS`] steps.
    pub fn default_grid() -> ScaleGrid {
        ScaleGrid::geometric(0.25, 8.0, DEFAULT_GRID_STEPS)
    }

    /// Number of distinct steps.
    pub fn len(&self) -> usize {
        self.q8.len()
    }

    /// Whether the grid has no steps.
    pub fn is_empty(&self) -> bool {
        self.q8.is_empty()
    }

    /// The Q8.8 scale of `step` (panics out of range).
    pub fn q8(&self, step: usize) -> u32 {
        self.q8[step]
    }

    /// The real-valued scale of `step`.
    pub fn scale(&self, step: usize) -> f64 {
        self.q8[step] as f64 / 256.0
    }

    /// Smallest / largest representable scale — the exact clamp bounds
    /// an [`EnergyController`](crate::coordinator::EnergyController)
    /// snapped to this grid must use so its output is always on-grid.
    pub fn min_scale(&self) -> f64 {
        self.scale(0)
    }

    /// Largest representable scale.
    pub fn max_scale(&self) -> f64 {
        self.scale(self.len() - 1)
    }

    /// Nearest grid step to a Q8.8 scale (out-of-range values clamp to
    /// the end steps; exact midpoints round down). This is the one
    /// place controller output becomes a cache key, so
    /// `snap_q8(q8(s)) == s` for every step `s` by construction.
    ///
    /// ```
    /// use unit_pruner::control::ScaleGrid;
    ///
    /// let grid = ScaleGrid::default_grid();
    /// // Every step snaps to itself…
    /// for s in 0..grid.len() {
    ///     assert_eq!(grid.snap_q8(grid.q8(s)), s);
    /// }
    /// // …and out-of-range scales clamp to the end steps.
    /// assert_eq!(grid.snap_q8(1), 0);
    /// assert_eq!(grid.snap_q8(u32::MAX), grid.len() - 1);
    /// ```
    pub fn snap_q8(&self, q8: u32) -> usize {
        match self.q8.binary_search(&q8) {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) if i == self.q8.len() => self.q8.len() - 1,
            Err(i) => {
                // Between steps i-1 and i: pick the nearer one.
                let lo = self.q8[i - 1];
                let hi = self.q8[i];
                if q8 - lo <= hi - q8 {
                    i - 1
                } else {
                    i
                }
            }
        }
    }
}

struct Entry {
    plan: Arc<PlannedModel>,
    last_used: u64,
}

struct Inner {
    slots: HashMap<usize, Entry>,
    /// Monotone use counter backing the LRU order.
    tick: u64,
    /// First plan ever compiled — pinned for the lifetime of the cache
    /// as the donor of the shared (scale-invariant) linear tables, so
    /// eviction can never force a full re-sort.
    donor: Option<Arc<PlannedModel>>,
}

/// Interning cache of compiled plans keyed by [`ScaleGrid`] step.
pub struct PlanCache {
    q: QModel,
    /// Template config; `t_scale_q8` is overwritten per step. Every
    /// other field — mode, div kind, and the resolved
    /// [`KernelBackend`](crate::engine::KernelBackend) — is carried
    /// verbatim into each step's compile (including the governor's
    /// background compiles), so all plans a cache serves run the same
    /// kernel backend.
    base_cfg: PlanConfig,
    grid: ScaleGrid,
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl std::fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanCache")
            .field("model", &self.q.def.name)
            .field("grid", &self.grid.len())
            .field("capacity", &self.capacity)
            .field("hits", &self.hits())
            .field("misses", &self.misses())
            .finish()
    }
}

impl PlanCache {
    /// A cache over `grid` for `q` under `cfg` (whose `t_scale_q8` is
    /// ignored — each step supplies its own), holding up to the whole
    /// grid.
    pub fn new(q: QModel, cfg: PlanConfig, grid: ScaleGrid) -> PlanCache {
        let capacity = grid.len();
        PlanCache::with_capacity(q, cfg, grid, capacity)
    }

    /// As [`PlanCache::new`] with an explicit LRU capacity (≥ 1).
    pub fn with_capacity(
        q: QModel,
        cfg: PlanConfig,
        grid: ScaleGrid,
        capacity: usize,
    ) -> PlanCache {
        PlanCache {
            q,
            base_cfg: cfg,
            grid,
            capacity: capacity.max(1),
            inner: Mutex::new(Inner { slots: HashMap::new(), tick: 0, donor: None }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The grid this cache is indexed by.
    pub fn grid(&self) -> &ScaleGrid {
        &self.grid
    }

    /// The plan for `step` **only if it is already resident** — a
    /// non-compiling lookup for callers that must never block on a
    /// compile (the governor's swap path). Counts a hit when it
    /// returns `Some`; a `None` is not counted as a miss (the caller
    /// decides whether to compile, and [`PlanCache::plan_at`] counts
    /// the miss when it does).
    pub fn try_get(&self, step: usize) -> Option<Arc<PlannedModel>> {
        assert!(step < self.grid.len(), "scale step {step} outside the grid");
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let e = inner.slots.get_mut(&step)?;
        e.last_used = tick;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(Arc::clone(&e.plan))
    }

    /// The resident plan whose grid scale is nearest to `step`'s
    /// (`None` on an empty cache) — what the governor publishes while
    /// a background compile of the exact step is in flight. Does not
    /// touch the hit/miss counters or the LRU order: it is a fallback
    /// probe, not a demand signal for the returned step.
    pub fn nearest_resident(&self, step: usize) -> Option<(usize, Arc<PlannedModel>)> {
        assert!(step < self.grid.len(), "scale step {step} outside the grid");
        let want_q8 = self.grid.q8(step) as i64;
        let inner = self.inner.lock().unwrap();
        inner
            .slots
            .iter()
            .min_by_key(|(&s, _)| ((self.grid.q8(s) as i64 - want_q8).abs(), s))
            .map(|(&s, e)| (s, Arc::clone(&e.plan)))
    }

    /// The plan for `step`, compiling (and interning) it on first
    /// visit. The compile itself runs **outside the cache lock** —
    /// the lock protects only the lookup/intern bookkeeping — so
    /// non-compiling callers ([`PlanCache::try_get`],
    /// [`PlanCache::nearest_resident`], i.e. the governor's swap path)
    /// are never blocked behind a stamp. Two threads racing the same
    /// cold step may both compile; the loser's (bit-identical) plan is
    /// dropped in favor of the interned one — a cheap, rare duplicate
    /// now that a miss is a cut-table stamp rather than a full sort.
    pub fn plan_at(&self, step: usize) -> Arc<PlannedModel> {
        assert!(step < self.grid.len(), "scale step {step} outside the grid");
        let donor = {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.slots.get_mut(&step) {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Arc::clone(&e.plan);
            }
            self.misses.fetch_add(1, Ordering::Relaxed);
            inner.donor.clone()
        };
        let cfg = PlanConfig { t_scale_q8: self.grid.q8(step), ..self.base_cfg };
        let plan = Arc::new(PlannedModel::compile_shared(&self.q, cfg, donor.as_deref()));
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        // Lost a compile race? Serve the interned plan; ours drops.
        if let Some(e) = inner.slots.get_mut(&step) {
            e.last_used = tick;
            return Arc::clone(&e.plan);
        }
        if inner.donor.is_none() {
            inner.donor = Some(Arc::clone(&plan));
        }
        if inner.slots.len() >= self.capacity {
            // Evict the least recently used step. (The donor stays
            // pinned in `donor` even if its slot goes.)
            let victim =
                inner.slots.iter().min_by_key(|(_, e)| e.last_used).map(|(&k, _)| k);
            if let Some(v) = victim {
                inner.slots.remove(&v);
            }
        }
        inner.slots.insert(step, Entry { plan: Arc::clone(&plan), last_used: tick });
        plan
    }

    /// Compile every grid step (startup warm-up; also what the
    /// keep-ratio calibration pass does implicitly).
    pub fn warm(&self) {
        for step in 0..self.grid.len().min(self.capacity) {
            self.plan_at(step);
        }
    }

    /// Steps currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    /// Whether no plans are resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache hits since creation.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (inline compiles) since creation.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::DivKind;
    use crate::models::{zoo, Params};
    use crate::pruning::Thresholds;

    #[test]
    fn grid_is_strictly_increasing_and_snap_roundtrips() {
        let g = ScaleGrid::default_grid();
        assert!(g.len() >= 2);
        for s in 1..g.len() {
            assert!(g.q8(s) > g.q8(s - 1), "grid not strictly increasing at {s}");
        }
        for s in 0..g.len() {
            assert_eq!(g.snap_q8(g.q8(s)), s, "snap(q8({s})) != {s}");
        }
        // Out-of-range clamps to the ends.
        assert_eq!(g.snap_q8(0), 0);
        assert_eq!(g.snap_q8(1), 0);
        assert_eq!(g.snap_q8(u32::MAX), g.len() - 1);
    }

    #[test]
    fn snap_picks_the_nearest_step() {
        let g = ScaleGrid::default_grid();
        crate::util::prop::check(0x5CA1E, 300, |gen| {
            let q8 = gen.u32_in(1, g.q8(g.len() - 1) + 512);
            let s = g.snap_q8(q8);
            let d = |step: usize| (g.q8(step) as i64 - q8 as i64).abs();
            for other in 0..g.len() {
                assert!(
                    d(s) <= d(other),
                    "snap({q8}) -> step {s} (q8 {}) but step {other} (q8 {}) is nearer",
                    g.q8(s),
                    g.q8(other)
                );
            }
        });
    }

    #[test]
    fn degenerate_grids_are_safe() {
        let g = ScaleGrid::geometric(1.0, 1.0, 10);
        assert_eq!(g.len(), 1);
        assert_eq!(g.snap_q8(0), 0);
        assert_eq!(g.snap_q8(9999), 0);
        let g = ScaleGrid::geometric(1.0, 1.001, 8); // rounds to one q8 value
        assert!(g.len() <= 2);
    }

    fn q_for(name: &str, seed: u64) -> QModel {
        let def = zoo(name);
        let params = Params::random(&def, seed);
        QModel::quantize(&def, &params)
            .with_thresholds(&Thresholds::uniform(def.layers.len(), 0.2))
    }

    fn assert_cache_matches_fresh(name: &str, mode: crate::engine::PruneMode, steps: &[usize]) {
        use crate::engine::PruneMode;
        let q = match mode {
            // ZeroSkip needs no thresholds; Unit gets the uniform set.
            PruneMode::Unit => q_for(name, 0xCAFE + name.len() as u64),
            _ => {
                let def = zoo(name);
                let params = Params::random(&def, 0xCAFE + name.len() as u64);
                QModel::quantize(&def, &params)
            }
        };
        let grid = ScaleGrid::default_grid();
        let cfg = PlanConfig::for_mode(mode, DivKind::Shift);
        let cache = PlanCache::new(q.clone(), cfg, grid.clone());
        let def = zoo(name);
        let x_f: Vec<f32> = (0..def.input_len())
            .map(|i| (((i * 31) % 37) as f32 - 18.0) / 11.0)
            .collect();
        for &step in steps {
            let cached = cache.plan_at(step);
            let fresh =
                PlannedModel::compile(&q, PlanConfig { t_scale_q8: grid.q8(step), ..cfg });
            let x = cached.quantize_input(&x_f);
            let (mut sa, mut sb) = (cached.new_scratch(), fresh.new_scratch());
            let (oa, ob) = (cached.infer(&x, &mut sa), fresh.infer(&x, &mut sb));
            assert_eq!(oa.logits_raw, ob.logits_raw, "{name}/{mode:?} step {step} logits");
            assert_eq!(oa.kept, ob.kept, "{name}/{mode:?} step {step} kept");
            assert_eq!(oa.skipped, ob.skipped, "{name}/{mode:?} step {step} skipped");
            assert_eq!(oa.ledger.counts, ob.ledger.counts, "{name}/{mode:?} step {step}");
            assert_eq!(oa.ledger.compute_cycles, ob.ledger.compute_cycles);
            assert_eq!(oa.ledger.mem_cycles, ob.ledger.mem_cycles);
        }
    }

    /// Satellite property (a): a cache-served plan — cut tables
    /// stamped over the donor's shared `|w|`-sorted tables — is
    /// bit-identical (logits, counts, ledger) to a freshly compiled
    /// plan at the same scale step, across the model zoo, in both
    /// scatter modes.
    #[test]
    fn cached_plans_bit_identical_to_fresh_compiles_across_zoo() {
        use crate::engine::PruneMode;
        let all: Vec<usize> = (0..ScaleGrid::default_grid().len()).collect();
        // mnist: every grid step, both scatter modes. cifar/kws are
        // heavier compiles: sweep cifar on a stride, probe kws at the
        // ends and middle.
        assert_cache_matches_fresh("mnist", PruneMode::Unit, &all);
        assert_cache_matches_fresh("mnist", PruneMode::ZeroSkip, &[0, 9, 19]);
        let cifar: Vec<usize> = all.iter().copied().step_by(3).collect();
        assert_cache_matches_fresh("cifar", PruneMode::Unit, &cifar);
        assert_cache_matches_fresh("cifar", PruneMode::ZeroSkip, &[5, 16]);
        assert_cache_matches_fresh("kws", PruneMode::Unit, &[0, 19]);
        assert_cache_matches_fresh("kws", PruneMode::ZeroSkip, &[10]);
    }

    /// Border-heavy shape (kernel spans the whole input: every pixel
    /// is a border pixel) through the cache at every grid step.
    #[test]
    fn cached_plans_bit_identical_on_border_only_shapes() {
        use crate::models::ModelDef;
        use crate::nn::Layer;
        let def = ModelDef {
            name: "border-heavy".into(),
            input_shape: [2, 4, 6],
            classes: 3,
            layers: vec![
                Layer::Conv { out_ch: 4, in_ch: 2, kh: 4, kw: 6, pool: false },
                Layer::Linear { n_in: 4, n_out: 3, relu: false },
            ],
        };
        let params = Params::random(&def, 0xB0D3);
        let q = QModel::quantize(&def, &params)
            .with_thresholds(&Thresholds::uniform(def.layers.len(), 0.25));
        let grid = ScaleGrid::default_grid();
        let cfg = PlanConfig::unit(DivKind::Exact);
        let cache = PlanCache::new(q.clone(), cfg, grid.clone());
        let x_f: Vec<f32> = (0..def.input_len())
            .map(|i| (((i * 11) % 23) as f32 - 11.0) / 6.0)
            .collect();
        for step in 0..grid.len() {
            let cached = cache.plan_at(step);
            let fresh =
                PlannedModel::compile(&q, PlanConfig { t_scale_q8: grid.q8(step), ..cfg });
            let x = cached.quantize_input(&x_f);
            let (mut sa, mut sb) = (cached.new_scratch(), fresh.new_scratch());
            let (oa, ob) = (cached.infer(&x, &mut sa), fresh.infer(&x, &mut sb));
            assert_eq!(oa.logits_raw, ob.logits_raw, "border step {step}");
            assert_eq!(oa.kept, ob.kept, "border step {step}");
            assert_eq!(oa.ledger.counts, ob.ledger.counts, "border step {step}");
        }
    }

    #[test]
    fn cached_plans_carry_kernel_backend() {
        // The kernel backend rides in the template config: every step
        // the cache compiles (and every donor-shared recompile) must
        // resolve to the backend the cache was built with.
        use crate::engine::KernelBackend;
        let q = q_for("mnist", 82);
        let grid = ScaleGrid::default_grid();
        for kernel in [KernelBackend::Scalar, KernelBackend::Lanes, KernelBackend::Simd] {
            let cfg = PlanConfig { kernel, ..PlanConfig::unit(DivKind::Shift) };
            let cache = PlanCache::new(q.clone(), cfg, grid.clone());
            let expect = cfg.resolved_kernel();
            for step in [0usize, 7, 19] {
                assert_eq!(
                    cache.plan_at(step).kernel(),
                    expect,
                    "step {step} lost the {} backend",
                    kernel.name()
                );
            }
        }
    }

    #[test]
    fn try_get_serves_residents_without_compiling() {
        let q = q_for("mnist", 80);
        let cache = PlanCache::new(q, PlanConfig::unit(DivKind::Shift), ScaleGrid::default_grid());
        assert!(cache.try_get(4).is_none(), "cold step served from nowhere");
        assert_eq!(cache.misses(), 0, "try_get must not count a miss");
        let a = cache.plan_at(4);
        let b = cache.try_get(4).expect("resident step not served");
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
    }

    #[test]
    fn nearest_resident_picks_the_closest_scale() {
        let q = q_for("mnist", 81);
        let grid = ScaleGrid::default_grid();
        let cache = PlanCache::new(q, PlanConfig::unit(DivKind::Shift), grid.clone());
        assert!(cache.nearest_resident(0).is_none(), "empty cache has no nearest");
        cache.plan_at(2);
        cache.plan_at(10);
        let (hits0, misses0) = (cache.hits(), cache.misses());
        let (s, _) = cache.nearest_resident(3).unwrap();
        assert_eq!(s, 2, "step 3 is nearer to 2 than to 10 on a geometric grid");
        let (s, plan) = cache.nearest_resident(9).unwrap();
        assert_eq!(s, 10);
        assert_eq!(plan.cfg.t_scale_q8, grid.q8(10));
        let (s, _) = cache.nearest_resident(10).unwrap();
        assert_eq!(s, 10, "an exact resident is its own nearest");
        // A fallback probe, not demand: counters untouched.
        assert_eq!((cache.hits(), cache.misses()), (hits0, misses0));
    }

    #[test]
    fn repeat_visits_hit_without_recompiling() {
        let q = q_for("mnist", 77);
        let cache = PlanCache::new(q, PlanConfig::unit(DivKind::Shift), ScaleGrid::default_grid());
        let a = cache.plan_at(5);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let b = cache.plan_at(5);
        assert!(Arc::ptr_eq(&a, &b), "hit returned a different plan");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        cache.plan_at(9);
        cache.plan_at(5);
        assert_eq!((cache.hits(), cache.misses()), (2, 2));
    }

    #[test]
    fn lru_evicts_least_recent_and_recompiles_on_return() {
        let q = q_for("mnist", 78);
        let cache = PlanCache::with_capacity(
            q,
            PlanConfig::unit(DivKind::Shift),
            ScaleGrid::default_grid(),
            2,
        );
        cache.plan_at(0);
        cache.plan_at(1);
        cache.plan_at(0); // 1 is now LRU
        cache.plan_at(2); // evicts 1
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.misses(), 3);
        cache.plan_at(0); // still resident
        assert_eq!(cache.misses(), 3);
        cache.plan_at(1); // evicted: recompile
        assert_eq!(cache.misses(), 4);
    }

    #[test]
    fn warm_fills_the_grid() {
        let q = q_for("mnist", 79);
        let grid = ScaleGrid::geometric(0.5, 2.0, 5);
        let n = grid.len();
        let cache = PlanCache::new(q, PlanConfig::unit(DivKind::Shift), grid);
        cache.warm();
        assert_eq!(cache.len(), n);
        assert_eq!(cache.misses(), n as u64);
    }
}
