//! Scale-indexed plan cache: the compiled-plan store that makes the
//! runtime threshold knob servable.
//!
//! The AIMD [`EnergyController`](crate::coordinator::EnergyController)
//! moves the threshold scale continuously, but a [`PlannedModel`] bakes
//! `t_scale_q8` at compile time (the sorted conv tables are *ordered
//! by* the scaled threshold). Recompiling on every controller nudge
//! would put an O(weights·log) sort on the serve path. The fix is the
//! SparseRT insight turned into a cache: specialize ahead of time per
//! sparsity configuration, and make the configuration space finite by
//! **snapping the continuous scale to a bounded grid** ([`ScaleGrid`],
//! ~20 geometric Q8.8 steps). The controller then only ever visits grid
//! steps, each step's plan is compiled at most once, and a budget swing
//! that revisits a step costs one `Arc` clone.
//!
//! Two cost controls keep the cache cheap:
//!
//! * **shared tables** — linear layers' magnitude-sorted rows are a
//!   pure function of the weights, so every cached plan shares the
//!   first-compiled plan's tables behind an `Arc`
//!   ([`PlannedModel::compile_shared`]); only conv tables (whose sort
//!   key `w̄ = T·s/|w|` is scale-dependent) and the linear `t_eff`
//!   scalars are rebuilt per step. A cache miss is therefore a conv
//!   re-sort, not a full recompile.
//! * **LRU eviction** — bounded capacity (default: the whole grid, so
//!   nothing evicts in practice; smaller capacities are honored for
//!   memory-tight deployments and exercised by tests).
//!
//! Every cache-served plan is **bit-identical** to a fresh
//! [`PlannedModel::compile`] at the same step — the property tests
//! below pin logits, kept/skipped counts, and the full ledger across
//! the model zoo.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::{PlanConfig, PlannedModel, QModel};

/// Quantized threshold-scale grid: a fixed, sorted set of Q8.8 scale
/// steps the adaptive controller is clamped to. Geometric spacing
/// (equal *ratios* between steps) matches the controller's
/// multiplicative AIMD moves: one controller step crosses roughly one
/// grid step anywhere in the range.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScaleGrid {
    /// Strictly increasing Q8.8 scale values (deduped after rounding).
    q8: Vec<u32>,
}

/// Default grid span and resolution: the controller's historical
/// clamp range [0.25, 8.0] at 20 steps (~20 % per step).
pub const DEFAULT_GRID_STEPS: usize = 20;

impl ScaleGrid {
    /// A geometric grid of `n` steps spanning `[min_scale, max_scale]`.
    /// Steps are rounded to Q8.8 and deduped, so very tight spans may
    /// yield fewer than `n` distinct steps.
    pub fn geometric(min_scale: f64, max_scale: f64, n: usize) -> ScaleGrid {
        assert!(min_scale > 0.0 && max_scale >= min_scale, "bad grid span");
        let n = n.max(1);
        let mut q8 = Vec::with_capacity(n);
        for i in 0..n {
            let s = if n == 1 {
                min_scale
            } else {
                min_scale * (max_scale / min_scale).powf(i as f64 / (n - 1) as f64)
            };
            let v = (s * 256.0).round().max(1.0) as u32;
            if q8.last() != Some(&v) {
                q8.push(v);
            }
        }
        ScaleGrid { q8 }
    }

    /// The default serving grid: `[0.25, 8.0]` at
    /// [`DEFAULT_GRID_STEPS`] steps.
    pub fn default_grid() -> ScaleGrid {
        ScaleGrid::geometric(0.25, 8.0, DEFAULT_GRID_STEPS)
    }

    /// Number of distinct steps.
    pub fn len(&self) -> usize {
        self.q8.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q8.is_empty()
    }

    /// The Q8.8 scale of `step` (panics out of range).
    pub fn q8(&self, step: usize) -> u32 {
        self.q8[step]
    }

    /// The real-valued scale of `step`.
    pub fn scale(&self, step: usize) -> f64 {
        self.q8[step] as f64 / 256.0
    }

    /// Smallest / largest representable scale — the exact clamp bounds
    /// an [`EnergyController`](crate::coordinator::EnergyController)
    /// snapped to this grid must use so its output is always on-grid.
    pub fn min_scale(&self) -> f64 {
        self.scale(0)
    }

    pub fn max_scale(&self) -> f64 {
        self.scale(self.len() - 1)
    }

    /// Nearest grid step to a Q8.8 scale (out-of-range values clamp to
    /// the end steps; exact midpoints round down). This is the one
    /// place controller output becomes a cache key, so
    /// `snap_q8(q8(s)) == s` for every step `s` by construction.
    pub fn snap_q8(&self, q8: u32) -> usize {
        match self.q8.binary_search(&q8) {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) if i == self.q8.len() => self.q8.len() - 1,
            Err(i) => {
                // Between steps i-1 and i: pick the nearer one.
                let lo = self.q8[i - 1];
                let hi = self.q8[i];
                if q8 - lo <= hi - q8 {
                    i - 1
                } else {
                    i
                }
            }
        }
    }
}

struct Entry {
    plan: Arc<PlannedModel>,
    last_used: u64,
}

struct Inner {
    slots: HashMap<usize, Entry>,
    /// Monotone use counter backing the LRU order.
    tick: u64,
    /// First plan ever compiled — pinned for the lifetime of the cache
    /// as the donor of the shared (scale-invariant) linear tables, so
    /// eviction can never force a full re-sort.
    donor: Option<Arc<PlannedModel>>,
}

/// Interning cache of compiled plans keyed by [`ScaleGrid`] step.
pub struct PlanCache {
    q: QModel,
    /// Template config; `t_scale_q8` is overwritten per step.
    base_cfg: PlanConfig,
    grid: ScaleGrid,
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl std::fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanCache")
            .field("model", &self.q.def.name)
            .field("grid", &self.grid.len())
            .field("capacity", &self.capacity)
            .field("hits", &self.hits())
            .field("misses", &self.misses())
            .finish()
    }
}

impl PlanCache {
    /// A cache over `grid` for `q` under `cfg` (whose `t_scale_q8` is
    /// ignored — each step supplies its own), holding up to the whole
    /// grid.
    pub fn new(q: QModel, cfg: PlanConfig, grid: ScaleGrid) -> PlanCache {
        let capacity = grid.len();
        PlanCache::with_capacity(q, cfg, grid, capacity)
    }

    /// As [`PlanCache::new`] with an explicit LRU capacity (≥ 1).
    pub fn with_capacity(
        q: QModel,
        cfg: PlanConfig,
        grid: ScaleGrid,
        capacity: usize,
    ) -> PlanCache {
        PlanCache {
            q,
            base_cfg: cfg,
            grid,
            capacity: capacity.max(1),
            inner: Mutex::new(Inner { slots: HashMap::new(), tick: 0, donor: None }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn grid(&self) -> &ScaleGrid {
        &self.grid
    }

    /// The plan for `step`, compiling (and interning) it on first
    /// visit. Compilation happens under the cache lock: concurrent
    /// lookups of the *same* step wait instead of compiling twice, and
    /// misses are rare by design (≤ one per grid step per eviction).
    pub fn plan_at(&self, step: usize) -> Arc<PlannedModel> {
        assert!(step < self.grid.len(), "scale step {step} outside the grid");
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.slots.get_mut(&step) {
            e.last_used = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(&e.plan);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let cfg = PlanConfig { t_scale_q8: self.grid.q8(step), ..self.base_cfg };
        let plan = Arc::new(PlannedModel::compile_shared(&self.q, cfg, inner.donor.as_deref()));
        if inner.donor.is_none() {
            inner.donor = Some(Arc::clone(&plan));
        }
        if inner.slots.len() >= self.capacity {
            // Evict the least recently used step. (The donor stays
            // pinned in `donor` even if its slot goes.)
            let victim =
                inner.slots.iter().min_by_key(|(_, e)| e.last_used).map(|(&k, _)| k);
            if let Some(v) = victim {
                inner.slots.remove(&v);
            }
        }
        inner.slots.insert(step, Entry { plan: Arc::clone(&plan), last_used: tick });
        plan
    }

    /// Compile every grid step (startup warm-up; also what the
    /// keep-ratio calibration pass does implicitly).
    pub fn warm(&self) {
        for step in 0..self.grid.len().min(self.capacity) {
            self.plan_at(step);
        }
    }

    /// Steps currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::DivKind;
    use crate::models::{zoo, Params};
    use crate::pruning::Thresholds;

    #[test]
    fn grid_is_strictly_increasing_and_snap_roundtrips() {
        let g = ScaleGrid::default_grid();
        assert!(g.len() >= 2);
        for s in 1..g.len() {
            assert!(g.q8(s) > g.q8(s - 1), "grid not strictly increasing at {s}");
        }
        for s in 0..g.len() {
            assert_eq!(g.snap_q8(g.q8(s)), s, "snap(q8({s})) != {s}");
        }
        // Out-of-range clamps to the ends.
        assert_eq!(g.snap_q8(0), 0);
        assert_eq!(g.snap_q8(1), 0);
        assert_eq!(g.snap_q8(u32::MAX), g.len() - 1);
    }

    #[test]
    fn snap_picks_the_nearest_step() {
        let g = ScaleGrid::default_grid();
        crate::util::prop::check(0x5CA1E, 300, |gen| {
            let q8 = gen.u32_in(1, g.q8(g.len() - 1) + 512);
            let s = g.snap_q8(q8);
            let d = |step: usize| (g.q8(step) as i64 - q8 as i64).abs();
            for other in 0..g.len() {
                assert!(
                    d(s) <= d(other),
                    "snap({q8}) -> step {s} (q8 {}) but step {other} (q8 {}) is nearer",
                    g.q8(s),
                    g.q8(other)
                );
            }
        });
    }

    #[test]
    fn degenerate_grids_are_safe() {
        let g = ScaleGrid::geometric(1.0, 1.0, 10);
        assert_eq!(g.len(), 1);
        assert_eq!(g.snap_q8(0), 0);
        assert_eq!(g.snap_q8(9999), 0);
        let g = ScaleGrid::geometric(1.0, 1.001, 8); // rounds to one q8 value
        assert!(g.len() <= 2);
    }

    fn q_for(name: &str, seed: u64) -> QModel {
        let def = zoo(name);
        let params = Params::random(&def, seed);
        QModel::quantize(&def, &params)
            .with_thresholds(&Thresholds::uniform(def.layers.len(), 0.2))
    }

    /// Satellite property (a): a cache-served plan is bit-identical —
    /// logits, counts, ledger — to a freshly compiled plan at the same
    /// scale step, across the model zoo.
    #[test]
    fn cached_plans_bit_identical_to_fresh_compiles_across_zoo() {
        // kws/widar compiles are heavy; probe them at one step each,
        // sweep mnist/cifar more densely.
        let cases: &[(&str, &[usize])] =
            &[("mnist", &[0, 7, 13, 19]), ("cifar", &[3, 16]), ("kws", &[10])];
        for &(name, steps) in cases {
            let q = q_for(name, 0xCAFE + name.len() as u64);
            let grid = ScaleGrid::default_grid();
            let cache =
                PlanCache::new(q.clone(), PlanConfig::unit(DivKind::Shift), grid.clone());
            let def = zoo(name);
            let x_f: Vec<f32> = (0..def.input_len())
                .map(|i| (((i * 31) % 37) as f32 - 18.0) / 11.0)
                .collect();
            for &step in steps {
                let cached = cache.plan_at(step);
                let fresh = PlannedModel::compile(
                    &q,
                    PlanConfig {
                        t_scale_q8: grid.q8(step),
                        ..PlanConfig::unit(DivKind::Shift)
                    },
                );
                let x = cached.quantize_input(&x_f);
                let (mut sa, mut sb) = (cached.new_scratch(), fresh.new_scratch());
                let (oa, ob) = (cached.infer(&x, &mut sa), fresh.infer(&x, &mut sb));
                assert_eq!(oa.logits_raw, ob.logits_raw, "{name} step {step} logits");
                assert_eq!(oa.kept, ob.kept, "{name} step {step} kept");
                assert_eq!(oa.skipped, ob.skipped, "{name} step {step} skipped");
                assert_eq!(oa.ledger.counts, ob.ledger.counts, "{name} step {step} counts");
                assert_eq!(oa.ledger.compute_cycles, ob.ledger.compute_cycles);
                assert_eq!(oa.ledger.mem_cycles, ob.ledger.mem_cycles);
            }
        }
    }

    #[test]
    fn repeat_visits_hit_without_recompiling() {
        let q = q_for("mnist", 77);
        let cache = PlanCache::new(q, PlanConfig::unit(DivKind::Shift), ScaleGrid::default_grid());
        let a = cache.plan_at(5);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let b = cache.plan_at(5);
        assert!(Arc::ptr_eq(&a, &b), "hit returned a different plan");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        cache.plan_at(9);
        cache.plan_at(5);
        assert_eq!((cache.hits(), cache.misses()), (2, 2));
    }

    #[test]
    fn lru_evicts_least_recent_and_recompiles_on_return() {
        let q = q_for("mnist", 78);
        let cache = PlanCache::with_capacity(
            q,
            PlanConfig::unit(DivKind::Shift),
            ScaleGrid::default_grid(),
            2,
        );
        cache.plan_at(0);
        cache.plan_at(1);
        cache.plan_at(0); // 1 is now LRU
        cache.plan_at(2); // evicts 1
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.misses(), 3);
        cache.plan_at(0); // still resident
        assert_eq!(cache.misses(), 3);
        cache.plan_at(1); // evicted: recompile
        assert_eq!(cache.misses(), 4);
    }

    #[test]
    fn warm_fills_the_grid() {
        let q = q_for("mnist", 79);
        let grid = ScaleGrid::geometric(0.5, 2.0, 5);
        let n = grid.len();
        let cache = PlanCache::new(q, PlanConfig::unit(DivKind::Shift), grid);
        cache.warm();
        assert_eq!(cache.len(), n);
        assert_eq!(cache.misses(), n as u64);
    }
}
