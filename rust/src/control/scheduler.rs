//! The fleet scheduler: one global MAC/energy budget allocated across
//! every model a multi-tenant coordinator hosts.
//!
//! The single-model [`Governor`](super::Governor) closes its budget
//! loop with AIMD: nudge the threshold scale until observed energy
//! meets the budget. With several models sharing one process that
//! feedback rule has no notion of *who deserves the energy* — the
//! interesting question becomes an allocation problem: every
//! `(model, grid-step)` pair has a calibrated mean energy and a
//! calibrated whole-model keep ratio (the [`KeepProfile`] curves), and
//! keep ratio is the marginal accuracy-per-MAC signal UnIT exposes at
//! runtime. Ranking those marginals globally and spending a fleet-wide
//! budget on the best ones is exactly the compile-time MAC-budget
//! search of Liberis & Lane (arXiv 2110.08350), re-solved live.
//!
//! ## The allocation ([`allocate_fleet`])
//!
//! Greedy buy-down on isotonized curves:
//!
//! 1. every model starts at its **cheapest** grid step (max pruning);
//! 2. the candidate move for a model is one step down (less pruning):
//!    it buys `Δkeep` calibrated keep ratio for `Δmj` energy;
//! 3. repeatedly take the globally best `Δkeep/Δmj` move that a
//!    per-tenant cap does not forbid, until the **first** move the
//!    fleet budget cannot afford.
//!
//! Stopping at the first unaffordable best move (rather than skipping
//! to a cheaper one) makes the chosen moves a *prefix of a
//! budget-independent chain*: raising the budget can only extend the
//! prefix, so no model's step ever moves toward more pruning when the
//! fleet gets richer — the monotonicity the property tests pin. It
//! also yields the acceptance-test shape: the **flattest** marginal
//! curve (least keep ratio bought per millijoule) is bought down last,
//! i.e. starved first when the budget tightens. With a single model
//! loaded the buy-down walks the one curve and stops exactly at
//! [`KeepProfile::seed_step`]'s choice — the governor's feed-forward
//! seed.
//!
//! ## The runtime ([`FleetScheduler`])
//!
//! Installed on a multi-model [`Coordinator`] the same way the
//! governor is installed on a single-model one: it is the pool's
//! [`EnergyTap`], but consumes the **model-attributed** observation
//! variants. Per tenant it keeps an energy EWMA (stats), a
//! [`DriftTracker`] CUSUM over observed-vs-calibrated keep ratios, and
//! an [`InputReservoir`] of recent inputs. Budget or cap changes and
//! drift trips enqueue work on one background **solve thread** (the
//! governor's compile-thread idiom: jobs over a channel, `Weak` back
//! reference, `Drop` closes the channel and joins): a re-solve
//! recomputes the allocation and swaps each changed tenant's
//! [`PlanSlot`] + [`ProfiledCost`]; a drift trip first re-measures
//! that tenant's profile from its reservoir, then re-solves. Plan
//! compiles therefore never run on a worker's observation path.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::thread::JoinHandle;

use super::calibrate::{DriftCfg, DriftTracker, InputReservoir, KeepProfile, ProfiledCost};
use super::plan_cache::PlanCache;
use crate::coordinator::{Coordinator, CostEstimator, CostEstimatorSlot, EnergyTap, PlanSlot};
use crate::obs::{EventKind, TraceRing};
use crate::util::{lock_recover, read_recover, write_recover};

/// One model's allocation inputs: the calibrated per-step curves (grid
/// step indexes both) and an optional per-tenant energy cap.
#[derive(Debug, Clone)]
pub struct TenantCurve {
    /// Calibrated mean energy per request at each grid step (mJ).
    pub mean_mj: Vec<f64>,
    /// Calibrated whole-model keep ratio at each grid step.
    pub keep_ratio: Vec<f64>,
    /// Per-tenant cap: this model may not occupy a step whose mean
    /// energy exceeds it (`None` = uncapped).
    pub cap_mj: Option<f64>,
}

/// Solve the fleet allocation: given every tenant's calibrated curves
/// and a fleet-wide budget (mJ per request, summed across tenants),
/// return the grid step each model should serve at.
///
/// Curves are isotonized first (mean energy and keep ratio forced
/// non-increasing in step by a running minimum — raw measured curves
/// can wiggle), then bought down greedily by marginal `Δkeep/Δmj`; see
/// the module docs for why the result is monotone in the budget and
/// starves the flattest curve first. Tenants whose curves are empty
/// stay at step 0.
pub fn allocate_fleet(curves: &[TenantCurve], fleet_budget_mj: f64) -> Vec<usize> {
    // Isotonize: non-increasing mean energy and keep ratio in step.
    let iso: Vec<(Vec<f64>, Vec<f64>)> = curves
        .iter()
        .map(|c| {
            let mut m = c.mean_mj.clone();
            let mut k = c.keep_ratio.clone();
            for i in 1..m.len() {
                m[i] = m[i].min(m[i - 1]);
            }
            for i in 1..k.len() {
                k[i] = k[i].min(k[i - 1]);
            }
            (m, k)
        })
        .collect();
    // Baseline: everyone at the cheapest (last) step.
    let mut steps: Vec<usize> = iso.iter().map(|(m, _)| m.len().saturating_sub(1)).collect();
    let mut spend: f64 = iso
        .iter()
        .zip(&steps)
        .map(|((m, _), &s)| m.get(s).copied().unwrap_or(0.0))
        .sum();
    loop {
        // The candidate move per model is one step down; take the
        // globally best marginal keep-per-millijoule. Ties break on
        // the lowest model index (strict `>`), so the move chain is
        // deterministic — and, crucially, independent of the budget.
        let mut best: Option<(usize, f64)> = None;
        for (i, (m, k)) in iso.iter().enumerate() {
            let s = steps[i];
            if s == 0 {
                continue;
            }
            if curves[i].cap_mj.is_some_and(|cap| m[s - 1] > cap) {
                continue; // capped out: this tenant descends no further
            }
            let dmj = m[s - 1] - m[s];
            let dkeep = k[s - 1] - k[s];
            let ratio = if dmj > 0.0 { dkeep / dmj } else { f64::INFINITY };
            if best.is_none_or(|(_, r)| ratio > r) {
                best = Some((i, ratio));
            }
        }
        let Some((i, _)) = best else { break };
        let s = steps[i];
        let m = &iso[i].0;
        let next_spend = spend - m[s] + m[s - 1];
        // First unaffordable best move ends the allocation — no
        // skipping to cheaper moves, which would break the prefix
        // property budget monotonicity rests on.
        if next_spend > fleet_budget_mj {
            break;
        }
        steps[i] = s - 1;
        spend = next_spend;
    }
    steps
}

/// Work items for the scheduler's background solve thread.
enum Job {
    /// Recompute the allocation (budget / cap change, post-recal).
    Resolve,
    /// Re-measure one tenant's profile from its reservoir, then
    /// re-solve.
    Recalibrate(usize),
}

/// Everything the scheduler tracks per hosted model.
struct Tenant {
    name: String,
    cache: Arc<PlanCache>,
    slot: Arc<PlanSlot>,
    cost_slot: CostEstimatorSlot,
    /// Live calibrated profile (replaced wholesale by recalibration).
    profile: RwLock<Arc<KeepProfile>>,
    /// The published grid step (what the last solve allocated).
    step: AtomicUsize,
    /// Per-tenant energy cap (`SetBudget` with a model id), if any.
    cap_mj: RwLock<Option<f64>>,
    /// EWMA of this tenant's observed per-request energy (stats).
    ewma_mj: Mutex<Option<f64>>,
    drift: Mutex<DriftTracker>,
    reservoir: Mutex<InputReservoir>,
    /// A `Recalibrate` job for this tenant is queued or running.
    recal_pending: AtomicBool,
    /// The SLO engine reported this tenant's burn rate tripped: while
    /// set, re-solves pin the tenant to its cheapest grid step so the
    /// freed fleet budget flows to healthy tenants.
    throttled: AtomicBool,
    drift_trips: AtomicU64,
    recalibrations: AtomicU64,
    swaps: AtomicU64,
}

/// A point-in-time view of one tenant (the per-model `Stats` frame).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStatus {
    /// Tenant name (zoo model name).
    pub name: String,
    /// Published grid step.
    pub step: usize,
    /// Total steps in this tenant's grid.
    pub steps_total: usize,
    /// Published threshold scale in Q8.8.
    pub scale_q8: u32,
    /// Calibrated whole-model keep ratio at the published step.
    pub keep_ratio: f64,
    /// Calibrated mean energy at the published step (mJ).
    pub mean_mj: f64,
    /// EWMA of observed per-request energy (0 until traffic flows).
    pub ewma_mj: f64,
    /// Per-tenant energy cap, if one is set.
    pub cap_mj: Option<f64>,
    /// This tenant's plan-cache hits since construction.
    pub cache_hits: u64,
    /// This tenant's plan-cache misses since construction.
    pub cache_misses: u64,
    /// Whether the SLO engine currently reports this tenant tripped
    /// (its allocation is pinned to the cheapest step while set).
    pub throttled: bool,
    /// Drift-tracker trips for this tenant since installation.
    pub drift_trips: u64,
    /// Live recalibrations completed for this tenant.
    pub recalibrations: u64,
    /// Plan swaps published for this tenant (solve-driven).
    pub swaps: u64,
}

/// A point-in-time view of the whole fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetStatus {
    /// Fleet-wide budget (mJ per request, summed across tenants).
    pub fleet_budget_mj: f64,
    /// Hosted model count.
    pub models: usize,
    /// Allocation solves completed since installation (the initial
    /// synchronous seed counts as the first).
    pub resolves: u64,
}

/// The fleet-wide budget scheduler (see module docs).
pub struct FleetScheduler {
    tenants: Vec<Tenant>,
    fleet_budget_mj: RwLock<f64>,
    /// Serializes solves: the background thread is single, but the
    /// synchronous install seed shares this discipline for clarity.
    solve_lock: Mutex<()>,
    resolves: AtomicU64,
    job_tx: Mutex<Option<Sender<Job>>>,
    handle: Mutex<Option<JoinHandle<()>>>,
    /// Flight-recorder ring ("fleet") for re-solves, per-tenant plan
    /// swaps, drift trips, and recalibrations. `None` when the
    /// coordinator runs with observability off.
    ring: Option<Arc<TraceRing>>,
}

impl std::fmt::Debug for FleetScheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.fleet_status();
        f.debug_struct("FleetScheduler")
            .field("models", &s.models)
            .field("fleet_budget_mj", &s.fleet_budget_mj)
            .field("resolves", &s.resolves)
            .finish()
    }
}

impl FleetScheduler {
    /// Build a scheduler over per-model `(cache, profile)` pairs —
    /// index-aligned with `coord`'s model table — and install it:
    /// solves the initial allocation synchronously (nothing is serving
    /// yet), swaps each tenant's seeded plan into its slot, installs
    /// the per-model profiled cost oracles, starts the background
    /// solve thread, and registers itself as the energy tap.
    ///
    /// Errors when the tenant count does not match the coordinator's
    /// model table, or any model lacks a plan slot (Pjrt backend).
    pub fn install(
        coord: &Coordinator,
        tenants: Vec<(Arc<PlanCache>, Arc<KeepProfile>)>,
        fleet_budget_mj: f64,
    ) -> Result<Arc<FleetScheduler>, &'static str> {
        if tenants.len() != coord.model_count() {
            return Err("fleet scheduler tenant list must match the coordinator's model table");
        }
        if tenants.is_empty() {
            return Err("fleet scheduler needs at least one model");
        }
        let mut rows = Vec::with_capacity(tenants.len());
        for (i, (cache, profile)) in tenants.into_iter().enumerate() {
            let model = i as u32;
            let slot = coord
                .plan_slot_of(model)
                .ok_or("fleet scheduler needs the McuSim backend (no plan slot)")?;
            let cost_slot = coord
                .cost_estimator_slot_of(model)
                .ok_or("fleet scheduler model id out of range")?;
            let name = coord.model_name(model).unwrap_or("?").to_string();
            rows.push(Tenant {
                name,
                cache,
                slot,
                cost_slot,
                profile: RwLock::new(profile),
                step: AtomicUsize::new(usize::MAX), // forces the seed publish
                cap_mj: RwLock::new(None),
                ewma_mj: Mutex::new(None),
                drift: Mutex::new(DriftTracker::new(DriftCfg::default())),
                reservoir: Mutex::new(InputReservoir::new(64, 0x5EED_F1EE + i as u64)),
                recal_pending: AtomicBool::new(false),
                throttled: AtomicBool::new(false),
                drift_trips: AtomicU64::new(0),
                recalibrations: AtomicU64::new(0),
                swaps: AtomicU64::new(0),
            });
        }
        let (tx, rx) = channel::<Job>();
        let sched = Arc::new(FleetScheduler {
            tenants: rows,
            fleet_budget_mj: RwLock::new(fleet_budget_mj),
            solve_lock: Mutex::new(()),
            resolves: AtomicU64::new(0),
            job_tx: Mutex::new(Some(tx)),
            handle: Mutex::new(None),
            ring: coord.recorder().map(|r| r.ring("fleet")),
        });
        // Startup seed solves synchronously: nothing is serving yet,
        // so the (possibly cache-missing) plan compiles are free.
        sched.resolve();
        // The solve thread holds only a Weak: Drop closes the channel
        // and joins it.
        let weak = Arc::downgrade(&sched);
        let handle = std::thread::spawn(move || solve_loop(weak, rx));
        *lock_recover(&sched.handle) = Some(handle);
        coord.set_energy_tap(Some(Arc::clone(&sched) as Arc<dyn EnergyTap>));
        Ok(sched)
    }

    /// Recompute the allocation from the live curves and publish it:
    /// per changed tenant, swap the plan slot (compiling here — off
    /// every worker thread — when the step is not resident) and
    /// retarget the profiled cost oracle.
    fn resolve(&self) {
        let _g = lock_recover(&self.solve_lock);
        let budget = *read_recover(&self.fleet_budget_mj);
        let profiles: Vec<Arc<KeepProfile>> =
            self.tenants.iter().map(|t| read_recover(&t.profile).clone()).collect();
        let curves: Vec<TenantCurve> = self
            .tenants
            .iter()
            .zip(&profiles)
            .map(|(t, p)| TenantCurve {
                mean_mj: (0..p.n_steps()).map(|s| p.mean_mj(s)).collect(),
                keep_ratio: (0..p.n_steps()).map(|s| p.model_keep_ratio(s)).collect(),
                cap_mj: {
                    let declared = *read_recover(&t.cap_mj);
                    if t.throttled.load(Ordering::Acquire) {
                        // SLO-tripped: cap at the cheapest step's
                        // energy so the descent never allocates this
                        // tenant more than its floor — the headroom
                        // goes to healthy tenants until the burn
                        // clears.
                        let floor = p.mean_mj(p.n_steps().saturating_sub(1));
                        Some(declared.map_or(floor, |c| c.min(floor)))
                    } else {
                        declared
                    }
                },
            })
            .collect();
        let steps = allocate_fleet(&curves, budget);
        for (i, ((t, p), &s)) in self.tenants.iter().zip(&profiles).zip(&steps).enumerate() {
            if t.step.load(Ordering::Acquire) != s {
                t.slot.swap(t.cache.plan_at(s));
                t.step.store(s, Ordering::Release);
                t.swaps.fetch_add(1, Ordering::Relaxed);
                self.trace(EventKind::PlanSwap, i as u64, s as u64);
            }
            // Always retarget pricing: the profile may have been
            // republished even when the step held still.
            let est: Arc<dyn CostEstimator> =
                Arc::new(ProfiledCost { profile: Arc::clone(p), step: s });
            *write_recover(&t.cost_slot) = Some(est);
        }
        self.resolves.fetch_add(1, Ordering::Relaxed);
        self.trace(EventKind::FleetResolve, 0, 0);
    }

    /// Emit one flight-recorder event on the "fleet" ring (no-op when
    /// observability is off). `id` carries the model index for
    /// tenant-scoped events, 0 for fleet-wide ones.
    fn trace(&self, kind: EventKind, id: u64, a: u64) {
        if let Some(r) = &self.ring {
            r.emit(kind, id, a, 0, 0);
        }
    }

    /// Enqueue a background re-solve (budget/cap changes, tests).
    fn request_resolve(&self) {
        let tx = lock_recover(&self.job_tx);
        if let Some(tx) = tx.as_ref() {
            let _ = tx.send(Job::Resolve);
        }
    }

    /// Queue one live recalibration of tenant `i` (deduplicated while
    /// pending).
    fn request_recalibrate(&self, i: usize) {
        let t = &self.tenants[i];
        if t.recal_pending.swap(true, Ordering::AcqRel) {
            return; // already queued or running
        }
        let sent = matches!(
            lock_recover(&self.job_tx).as_ref().map(|tx| tx.send(Job::Recalibrate(i))),
            Some(Ok(()))
        );
        if !sent {
            // Channel gone (shutdown race): release the reservation.
            t.recal_pending.store(false, Ordering::Release);
        }
    }

    /// Change the fleet-wide budget (the fleet-scoped `SetBudget`
    /// admin frame). The re-solve runs on the background thread; the
    /// published steps move shortly after.
    pub fn set_fleet_budget(&self, budget_mj: f64) {
        *write_recover(&self.fleet_budget_mj) = budget_mj;
        self.request_resolve();
    }

    /// Report one tenant's SLO trip state (wired to
    /// [`SloEngine::set_on_trip`](crate::obs::SloEngine::set_on_trip)).
    /// A transition queues a background re-solve so the allocation
    /// reacts within one solve-thread hop; repeated reports of the
    /// same state are free. Returns `false` for an unknown model id.
    pub fn set_tenant_throttled(&self, model: u32, throttled: bool) -> bool {
        let Some(t) = self.tenants.get(model as usize) else {
            return false;
        };
        if t.throttled.swap(throttled, Ordering::AcqRel) != throttled {
            self.trace(EventKind::SloTrip, model as u64, throttled as u64);
            self.request_resolve();
        }
        true
    }

    /// Whether the SLO engine currently reports `model` tripped.
    pub fn tenant_throttled(&self, model: u32) -> bool {
        self.tenants
            .get(model as usize)
            .is_some_and(|t| t.throttled.load(Ordering::Acquire))
    }

    /// Set (or clear, with `None`) one tenant's energy cap — the
    /// model-scoped `SetBudget` admin frame. Returns `false` for an
    /// unknown model id.
    pub fn set_tenant_cap(&self, model: u32, cap_mj: Option<f64>) -> bool {
        let Some(t) = self.tenants.get(model as usize) else {
            return false;
        };
        *write_recover(&t.cap_mj) = cap_mj;
        self.request_resolve();
        true
    }

    /// The current fleet-wide budget (mJ per request, summed).
    pub fn fleet_budget_mj(&self) -> f64 {
        *read_recover(&self.fleet_budget_mj)
    }

    /// The published grid step of `model`, if the id is known.
    pub fn step(&self, model: u32) -> Option<usize> {
        self.tenants.get(model as usize).map(|t| t.step.load(Ordering::Acquire))
    }

    /// Point-in-time view of one tenant; `None` for an unknown id.
    pub fn status(&self, model: u32) -> Option<TenantStatus> {
        let t = self.tenants.get(model as usize)?;
        let step = t.step.load(Ordering::Acquire);
        let profile = read_recover(&t.profile).clone();
        Some(TenantStatus {
            name: t.name.clone(),
            step,
            steps_total: t.cache.grid().len(),
            scale_q8: t.cache.grid().q8(step.min(t.cache.grid().len().saturating_sub(1))),
            keep_ratio: profile.model_keep_ratio(step),
            mean_mj: profile.mean_mj(step),
            ewma_mj: lock_recover(&t.ewma_mj).unwrap_or(0.0),
            cap_mj: *read_recover(&t.cap_mj),
            cache_hits: t.cache.hits(),
            cache_misses: t.cache.misses(),
            throttled: t.throttled.load(Ordering::Acquire),
            drift_trips: t.drift_trips.load(Ordering::Relaxed),
            recalibrations: t.recalibrations.load(Ordering::Relaxed),
            swaps: t.swaps.load(Ordering::Relaxed),
        })
    }

    /// Point-in-time view of the whole fleet.
    pub fn fleet_status(&self) -> FleetStatus {
        FleetStatus {
            fleet_budget_mj: self.fleet_budget_mj(),
            models: self.tenants.len(),
            resolves: self.resolves.load(Ordering::Relaxed),
        }
    }

    /// The live calibrated profile of `model` (replaced wholesale by
    /// recalibration — compare `Arc::ptr_eq` to detect a republish).
    pub fn profile(&self, model: u32) -> Option<Arc<KeepProfile>> {
        self.tenants.get(model as usize).map(|t| read_recover(&t.profile).clone())
    }
}

impl EnergyTap for FleetScheduler {
    /// Unattributed observation (a worker predating model attribution,
    /// or a single-model pool): account it to model 0.
    fn observe(&self, energy_mj: f64) {
        self.observe_model(0, energy_mj);
    }

    /// Per-tenant energy EWMA — observability only; unlike the AIMD
    /// governor, allocation moves on budget changes and drift trips,
    /// not on every observation.
    fn observe_model(&self, model: u32, energy_mj: f64) {
        let Some(t) = self.tenants.get(model as usize) else {
            return;
        };
        let mut e = lock_recover(&t.ewma_mj);
        *e = Some(match *e {
            Some(prev) => 0.8 * prev + 0.2 * energy_mj,
            None => energy_mj,
        });
    }

    /// One request's observed keep ratio, attributed to its model:
    /// compared against that tenant's calibrated expectation at its
    /// published step; a sustained-divergence trip queues one live
    /// recalibration (and the re-solve that follows it).
    fn observe_keep_model(&self, model: u32, ratio: f64) {
        let Some(t) = self.tenants.get(model as usize) else {
            return;
        };
        let expected =
            read_recover(&t.profile).model_keep_ratio(t.step.load(Ordering::Acquire));
        let tripped = lock_recover(&t.drift).observe(ratio, expected);
        if tripped {
            t.drift_trips.fetch_add(1, Ordering::Relaxed);
            self.trace(EventKind::DriftTrip, model as u64, 0);
            self.request_recalibrate(model as usize);
        }
    }

    /// Offer a served input to its model's recalibration reservoir.
    fn sample_input_model(&self, model: u32, x: &[f32]) {
        if let Some(t) = self.tenants.get(model as usize) {
            lock_recover(&t.reservoir).push(x);
        }
    }
}

/// The background solve loop: allocation re-solves and per-tenant
/// recalibrations run here, off every worker thread (the governor's
/// compile-loop idiom).
fn solve_loop(sched: Weak<FleetScheduler>, rx: Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        let Some(sched) = sched.upgrade() else { return };
        match job {
            Job::Resolve => sched.resolve(),
            Job::Recalibrate(i) => recalibrate_tenant(&sched, i),
        }
        // Drop the strong handle before blocking on the next job, so
        // the scheduler can be torn down while the queue is idle.
        drop(sched);
    }
}

/// Live recalibration of one tenant (background thread only).
/// Measurement — `grid.len() × reservoir` inferences — runs off every
/// lock; the republish is the subsequent `resolve`, which re-allocates
/// the whole fleet under the fresh curve.
fn recalibrate_tenant(sched: &Arc<FleetScheduler>, i: usize) {
    let t = &sched.tenants[i];
    let xs = lock_recover(&t.reservoir).samples();
    if xs.is_empty() {
        // Nothing observed yet (trip raced an empty reservoir): drop
        // the reservation; a later trip retries with data.
        t.recal_pending.store(false, Ordering::Release);
        return;
    }
    let fresh = Arc::new(KeepProfile::measure(&t.cache, &xs));
    *write_recover(&t.profile) = fresh;
    // Re-arm against the new baseline; the trip count survives.
    lock_recover(&t.drift).reset();
    lock_recover(&t.reservoir).clear();
    t.recalibrations.fetch_add(1, Ordering::Relaxed);
    sched.trace(EventKind::Recalibrate, i as u64, 0);
    t.recal_pending.store(false, Ordering::Release);
    sched.resolve();
}

/// Close the solve channel and join the thread; the thread itself can
/// transiently hold the last strong reference, in which case it
/// detaches instead of self-joining (the governor's Drop discipline).
impl Drop for FleetScheduler {
    fn drop(&mut self) {
        drop(lock_recover(&self.job_tx).take());
        if let Some(h) = lock_recover(&self.handle).take() {
            if h.thread().id() != std::thread::current().id() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::DivKind;
    use crate::control::ScaleGrid;
    use crate::coordinator::{BackendChoice, Coordinator, ModelSpec, ServeConfig};
    use crate::engine::{PlanConfig, PruneMode, QModel};
    use crate::models::{zoo, Params};
    use crate::pruning::Thresholds;
    use std::time::{Duration, Instant};

    /// Deterministic xorshift for synthetic-curve property tests.
    struct Lcg(u64);
    impl Lcg {
        fn next_f64(&mut self) -> f64 {
            self.0 = self
                .0
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((self.0 >> 33) as f64) / (u32::MAX as f64 + 1.0)
        }
    }

    /// A strictly decreasing synthetic (energy, keep) curve pair —
    /// the isotonic shape real calibration measures.
    fn synth_curve(seed: u64, steps: usize) -> TenantCurve {
        let mut rng = Lcg(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1);
        let mut mean = Vec::with_capacity(steps);
        let mut keep = Vec::with_capacity(steps);
        let mut m = 5.0 + 10.0 * rng.next_f64();
        let mut k = 1.0;
        for _ in 0..steps {
            mean.push(m);
            keep.push(k);
            m *= 0.55 + 0.35 * rng.next_f64(); // decay 10%..45% per step
            k -= (0.02 + 0.1 * rng.next_f64()) * k;
        }
        TenantCurve { mean_mj: mean, keep_ratio: keep, cap_mj: None }
    }

    /// The single-model governor's feed-forward choice: the first step
    /// whose calibrated mean energy fits the budget (the cheapest step
    /// when none does) — `KeepProfile::seed_step`'s rule.
    fn governor_choice(curve: &TenantCurve, budget: f64) -> usize {
        curve
            .mean_mj
            .iter()
            .position(|&m| m <= budget)
            .unwrap_or(curve.mean_mj.len().saturating_sub(1))
    }

    #[test]
    fn allocation_is_monotone_in_fleet_budget() {
        // Property: for every random fleet, raising the budget never
        // raises any model's step (more budget ⇒ no model prunes
        // harder).
        for trial in 0..50u64 {
            let n_models = 1 + (trial % 4) as usize;
            let curves: Vec<TenantCurve> =
                (0..n_models).map(|i| synth_curve(trial * 31 + i as u64, 10)).collect();
            let ceiling: f64 = curves.iter().map(|c| c.mean_mj[0]).sum::<f64>() * 1.2;
            let mut prev: Option<Vec<usize>> = None;
            // Sweep the budget upward; each allocation must dominate
            // the previous (component-wise ≤ in step).
            for pct in 0..=20 {
                let budget = ceiling * (pct as f64) / 20.0;
                let steps = allocate_fleet(&curves, budget);
                if let Some(prev) = &prev {
                    for (i, (&now, &before)) in steps.iter().zip(prev).enumerate() {
                        assert!(
                            now <= before,
                            "trial {trial}: budget rose but model {i} stepped {before} -> {now}"
                        );
                    }
                }
                prev = Some(steps);
            }
        }
    }

    #[test]
    fn allocation_respects_per_tenant_caps() {
        for trial in 0..50u64 {
            let n_models = 2 + (trial % 3) as usize;
            let mut curves: Vec<TenantCurve> =
                (0..n_models).map(|i| synth_curve(trial * 47 + i as u64, 10)).collect();
            let mut rng = Lcg(trial + 99);
            for c in &mut curves {
                // A cap somewhere inside the curve's range (always at
                // or above the cheapest step, which is a fallback no
                // cap can forbid).
                let lo = *c.mean_mj.last().unwrap();
                let hi = c.mean_mj[0];
                c.cap_mj = Some(lo + (hi - lo) * rng.next_f64());
            }
            // Generous fleet budget: only the caps constrain.
            let budget: f64 = curves.iter().map(|c| c.mean_mj[0]).sum::<f64>() * 2.0;
            let steps = allocate_fleet(&curves, budget);
            for (i, (c, &s)) in curves.iter().zip(&steps).enumerate() {
                assert!(
                    c.mean_mj[s] <= c.cap_mj.unwrap() + 1e-12,
                    "trial {trial}: model {i} at step {s} spends {} over its cap {:?}",
                    c.mean_mj[s],
                    c.cap_mj
                );
            }
        }
    }

    #[test]
    fn single_model_degrades_to_the_governor_choice() {
        // With one model loaded the buy-down must stop exactly where
        // the single-model governor's feed-forward seed would.
        for trial in 0..60u64 {
            let curve = synth_curve(trial * 13 + 1, 12);
            let mut rng = Lcg(trial);
            let budget = curve.mean_mj[0] * 1.1 * rng.next_f64();
            let got = allocate_fleet(std::slice::from_ref(&curve), budget)[0];
            let want = governor_choice(&curve, budget);
            assert_eq!(got, want, "trial {trial}: allocator {got} vs governor {want}");
        }
    }

    #[test]
    fn tight_budget_starves_the_flattest_marginal_curve_first() {
        // Two tenants, identical energy curves; A's keep curve is
        // steep (pruning costs a lot of signal), B's is flat (pruning
        // is nearly free). Any budget that affords only part of the
        // buy-down must spend it on A — B is starved at deeper
        // pruning.
        let mean: Vec<f64> = (0..8).map(|s| 8.0 * 0.7f64.powi(s)).collect();
        let steep = TenantCurve {
            mean_mj: mean.clone(),
            keep_ratio: (0..8).map(|s| 1.0 - 0.1 * s as f64).collect(),
            cap_mj: None,
        };
        let flat = TenantCurve {
            mean_mj: mean.clone(),
            keep_ratio: (0..8).map(|s| 1.0 - 0.005 * s as f64).collect(),
            cap_mj: None,
        };
        // Mid-range budget: enough to walk one tenant most of the way
        // down, not both.
        let budget = mean[0] + mean[7];
        let steps = allocate_fleet(&[steep, flat], budget);
        assert!(
            steps[0] < steps[1],
            "steep curve should be bought down first: {steps:?}"
        );
        assert_eq!(steps[1], 7, "flat curve should be fully starved: {steps:?}");
    }

    // ---- runtime (FleetScheduler over a live coordinator) ----

    fn boot_fleet(
        seeds: &[u64],
        workers: usize,
    ) -> (Coordinator, Vec<(Arc<PlanCache>, Arc<KeepProfile>)>, Vec<Vec<f32>>) {
        let def = zoo("mnist");
        let mut specs = Vec::new();
        let mut tenants = Vec::new();
        let xs: Vec<Vec<f32>> = (0..4)
            .map(|s| {
                (0..def.input_len())
                    .map(|i| (((i * 11 + s * 5) % 19) as f32 - 9.0) / 7.0)
                    .collect()
            })
            .collect();
        for (i, &seed) in seeds.iter().enumerate() {
            let params = Params::random(&def, seed);
            let q = QModel::quantize(&def, &params)
                .with_thresholds(&Thresholds::uniform(3, 0.15));
            specs.push(ModelSpec {
                name: format!("m{i}"),
                q: q.clone(),
                mode: PruneMode::Unit,
                div: DivKind::Shift,
            });
            let grid = ScaleGrid::geometric(0.25, 8.0, 10);
            let cache =
                Arc::new(PlanCache::new(q, PlanConfig::unit(DivKind::Shift), grid));
            let profile = Arc::new(KeepProfile::measure(&cache, &xs));
            tenants.push((cache, profile));
        }
        let coord = Coordinator::start_multi(
            specs,
            ServeConfig { workers, ..Default::default() },
        );
        (coord, tenants, xs)
    }

    #[test]
    fn install_seeds_each_tenant_and_prices_it() {
        let (coord, tenants, xs) = boot_fleet(&[31, 32], 2);
        let budget: f64 = tenants.iter().map(|(_, p)| p.mean_mj(p.n_steps() / 2)).sum();
        let sched = FleetScheduler::install(&coord, tenants.clone(), budget).unwrap();
        assert_eq!(sched.fleet_status().models, 2);
        assert!(sched.fleet_status().resolves >= 1, "install must seed-solve");
        // The seeded steps are exactly what the pure allocator says.
        let curves: Vec<TenantCurve> = tenants
            .iter()
            .map(|(_, p)| TenantCurve {
                mean_mj: (0..p.n_steps()).map(|s| p.mean_mj(s)).collect(),
                keep_ratio: (0..p.n_steps()).map(|s| p.model_keep_ratio(s)).collect(),
                cap_mj: None,
            })
            .collect();
        let want = allocate_fleet(&curves, budget);
        for m in 0..2u32 {
            assert_eq!(sched.step(m), Some(want[m as usize]), "tenant {m} mis-seeded");
        }
        // Both cost oracles are installed.
        for m in 0..2u32 {
            assert!(
                coord.cost_estimator_slot_of(m).unwrap().read().unwrap().is_some(),
                "tenant {m} has no profiled cost oracle"
            );
        }
        // Serving still works and feeds the per-tenant EWMA.
        for m in 0..2u32 {
            coord.submit_to(m, xs[0].clone()).unwrap().recv().unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while (0..2u32).any(|m| sched.status(m).unwrap().ewma_mj == 0.0) {
            assert!(Instant::now() < deadline, "tenant EWMA never fed");
            std::thread::sleep(Duration::from_millis(2));
        }
        coord.shutdown();
    }

    #[test]
    fn single_tenant_install_matches_the_governor_seed() {
        let (coord, tenants, _xs) = boot_fleet(&[33], 1);
        let profile = Arc::clone(&tenants[0].1);
        let budget = profile.mean_mj(profile.n_steps() / 2);
        let sched = FleetScheduler::install(&coord, tenants, budget).unwrap();
        assert_eq!(
            sched.step(0),
            Some(profile.seed_step(budget)),
            "one loaded model must degrade to the governor's feed-forward seed"
        );
        coord.shutdown();
    }

    #[test]
    fn budget_changes_republish_steps_monotonically() {
        let (coord, tenants, _xs) = boot_fleet(&[34, 35], 1);
        let rich: f64 = tenants.iter().map(|(_, p)| p.mean_mj(0)).sum::<f64>() * 2.0;
        let poor: f64 = tenants.iter().map(|(_, p)| p.mean_mj(p.n_steps() - 1)).sum();
        let sched = FleetScheduler::install(&coord, tenants, rich).unwrap();
        let generous: Vec<usize> = (0..2).map(|m| sched.step(m).unwrap()).collect();
        assert_eq!(generous, vec![0, 0], "a rich fleet serves both models unpruned");
        sched.set_fleet_budget(poor);
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let starved: Vec<usize> = (0..2).map(|m| sched.step(m).unwrap()).collect();
            if starved.iter().zip(&generous).all(|(s, g)| s > g) {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "starvation never republished: {starved:?}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // And relief walks every tenant back down.
        sched.set_fleet_budget(rich);
        let deadline = Instant::now() + Duration::from_secs(30);
        while (0..2).map(|m| sched.step(m).unwrap()).sum::<usize>() != 0 {
            assert!(Instant::now() < deadline, "relief never republished");
            std::thread::sleep(Duration::from_millis(5));
        }
        coord.shutdown();
    }

    #[test]
    fn tenant_cap_constrains_one_model_only() {
        let (coord, tenants, _xs) = boot_fleet(&[36, 37], 1);
        let rich: f64 = tenants.iter().map(|(_, p)| p.mean_mj(0)).sum::<f64>() * 2.0;
        let profile0 = Arc::clone(&tenants[0].1);
        let sched = FleetScheduler::install(&coord, tenants, rich).unwrap();
        assert_eq!(sched.step(0), Some(0));
        // Cap tenant 0 at its mid-curve spend: it must retreat to a
        // step whose calibrated mean fits the cap; tenant 1 stays.
        let cap = profile0.mean_mj(profile0.n_steps() / 2);
        assert!(sched.set_tenant_cap(0, Some(cap)));
        assert!(!sched.set_tenant_cap(9, Some(cap)), "unknown tenant must be rejected");
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let st = sched.status(0).unwrap();
            if st.mean_mj <= cap + 1e-12 {
                break;
            }
            assert!(Instant::now() < deadline, "cap never enforced: {st:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(sched.step(1), Some(0), "uncapped tenant must not move");
        assert_eq!(sched.status(0).unwrap().cap_mj, Some(cap));
        coord.shutdown();
    }

    #[test]
    fn slo_throttle_pins_tenant_to_its_cheapest_step() {
        let (coord, tenants, _xs) = boot_fleet(&[44, 45], 1);
        let rich: f64 = tenants.iter().map(|(_, p)| p.mean_mj(0)).sum::<f64>() * 2.0;
        let profile0 = Arc::clone(&tenants[0].1);
        let sched = FleetScheduler::install(&coord, tenants, rich).unwrap();
        assert_eq!(sched.step(0), Some(0), "rich fleet starts unpruned");
        // Trip tenant 0: its allocation must retreat to the cheapest
        // step's spend while the healthy tenant keeps its slice.
        assert!(sched.set_tenant_throttled(0, true));
        assert!(!sched.set_tenant_throttled(9, true), "unknown tenant must be rejected");
        assert!(sched.tenant_throttled(0));
        let floor = profile0.mean_mj(profile0.n_steps() - 1);
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let st = sched.status(0).unwrap();
            if st.mean_mj <= floor + 1e-12 {
                assert!(st.throttled, "status must surface the trip");
                break;
            }
            assert!(Instant::now() < deadline, "throttle never pinned: {st:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(sched.step(1), Some(0), "healthy tenant must not move");
        // Clearing the trip walks the tenant back to the generous
        // allocation (same relief path as a budget raise).
        assert!(sched.set_tenant_throttled(0, false));
        let deadline = Instant::now() + Duration::from_secs(30);
        while sched.step(0) != Some(0) {
            assert!(Instant::now() < deadline, "recovery never republished");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(!sched.status(0).unwrap().throttled);
        coord.shutdown();
    }

    #[test]
    fn per_tenant_drift_recalibrates_that_tenant_live() {
        let (coord, tenants, xs) = boot_fleet(&[38, 39], 1);
        let rich: f64 = tenants.iter().map(|(_, p)| p.mean_mj(0)).sum::<f64>() * 2.0;
        let sched = FleetScheduler::install(&coord, tenants, rich).unwrap();
        let before = sched.profile(0).unwrap();
        // Fill tenant 0's reservoir, then feed it a sustained keep
        // shift; tenant 1 sees stationary traffic.
        for x in &xs {
            for _ in 0..10 {
                sched.sample_input_model(0, x);
            }
        }
        let expected = before.model_keep_ratio(sched.step(0).unwrap());
        let shifted = if expected > 0.25 { expected - 0.2 } else { expected + 0.2 };
        for _ in 0..200 {
            sched.observe_keep_model(0, shifted);
        }
        assert!(sched.status(0).unwrap().drift_trips >= 1, "shift never tripped");
        let deadline = Instant::now() + Duration::from_secs(60);
        while sched.status(0).unwrap().recalibrations == 0 {
            assert!(Instant::now() < deadline, "recalibration never landed");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            !Arc::ptr_eq(&before, &sched.profile(0).unwrap()),
            "tenant 0's profile not republished"
        );
        let st1 = sched.status(1).unwrap();
        assert_eq!(st1.drift_trips, 0, "stationary tenant tripped");
        assert_eq!(st1.recalibrations, 0, "stationary tenant recalibrated");
        coord.shutdown();
    }
}
