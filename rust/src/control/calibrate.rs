//! Per-layer keep-ratio calibration: measured cost curves for the
//! control plane.
//!
//! [`PlannedModel::estimate_macs`] extrapolates deeper layers from the
//! layer-0 keep ratio — the only thing knowable *per input* without
//! running inference. That is the right input-density probe, but the
//! wrong per-layer shape: UnIT's skip fraction varies strongly by layer
//! (conv vs linear, threshold percentile, activation statistics), and
//! Daghero et al.'s per-layer kernel-selection results on MCUs show the
//! per-layer structure is where the cost signal lives. This module
//! measures it once, at threshold-calibration time:
//!
//! * [`KeepProfile::measure`] runs the calibration batch through the
//!   plan cache at **every grid step** (warming the cache as a side
//!   effect) and records, per step and per layer, the mean executed
//!   fraction of that layer's static MAC ceiling — plus the mean
//!   modeled energy per step, the governor's feed-forward seed.
//! * Curves are **isotonically projected** (running minimum over
//!   increasing scale): a larger threshold scale can only shrink each
//!   keep set, so the physical curve is non-increasing and the
//!   projection removes calibration-batch sampling noise. Estimate
//!   monotonicity in scale then holds by construction (property-tested
//!   below).
//! * [`KeepProfile::estimate_macs`] combines the calibrated per-layer
//!   curve with two per-input signals: the exact layer-0 keep count
//!   (from the plan's prefix-sum tables) and the input's nonzero
//!   density relative to the calibration batch. Deeper layers are
//!   billed `ceiling × curve[step][layer] × density_mod` — per-layer
//!   interpolation instead of layer-0 extrapolation.
//!
//! [`ProfiledCost`] packages a profile + step as a
//! [`CostEstimator`](crate::coordinator::CostEstimator) so the
//! coordinator's cost-weighted shard placement prices samples off the
//! calibrated curves; the governor swaps the step on every plan swap.

use std::sync::Arc;

use super::plan_cache::PlanCache;
use crate::coordinator::CostEstimator;
use crate::engine::PlannedModel;
use crate::mcu::EnergyModel;

/// How far the per-input density modulation may swing the calibrated
/// curves (guards a pathological input from inflating the estimate
/// past anything the profile has evidence for).
const DENSITY_MOD_MAX: f64 = 2.0;

/// Calibrated per-layer keep-ratio curves over a [`ScaleGrid`]
/// (one curve point per `(step, layer)`), plus per-step mean energy.
///
/// [`ScaleGrid`]: super::ScaleGrid
#[derive(Debug, Clone)]
pub struct KeepProfile {
    /// `ratios[step][layer]`: mean executed fraction of the layer's
    /// static MAC ceiling, in `[0, 1]`, non-increasing in `step`.
    ratios: Vec<Vec<f64>>,
    /// Per-layer static MAC ceilings, captured once at measure time —
    /// they depend only on the weights and mode, never on the scale,
    /// so the per-sample estimate on the placement hot path reuses
    /// them instead of rebuilding a `Vec` per priced sample.
    caps: Vec<u64>,
    /// Mean modeled energy (mJ) per inference at each step.
    mean_mj: Vec<f64>,
    /// Mean fraction of nonzero input values over the calibration
    /// batch (the denominator of the density modulation).
    input_density: f64,
}

impl KeepProfile {
    /// Measure the profile for `cache`'s model over `xs` (one flat
    /// `C·H·W` f32 sample per entry — typically the validation split
    /// already used for threshold calibration). Runs
    /// `grid.len() × xs.len()` plan-backed inferences and warms every
    /// cache step.
    pub fn measure(cache: &PlanCache, xs: &[Vec<f32>]) -> KeepProfile {
        assert!(!xs.is_empty(), "empty calibration batch");
        let energy = EnergyModel::default();
        let n_steps = cache.grid().len();
        // The ceilings are scale-invariant (live-weight counts only),
        // so one capture covers every step.
        let caps = cache.plan_at(0).static_macs_per_layer();
        let mut ratios = Vec::with_capacity(n_steps);
        let mut mean_mj = Vec::with_capacity(n_steps);
        let mut input_density = 0.0f64;
        for step in 0..n_steps {
            let plan = cache.plan_at(step);
            let mut scratch = plan.new_scratch();
            let mut kept = vec![0u64; caps.len()];
            let mut mj = 0.0f64;
            for x in xs {
                let xi = plan.quantize_input(x);
                if step == 0 {
                    let nz = xi.iter().filter(|&&v| v != 0).count();
                    input_density += nz as f64 / xi.len().max(1) as f64;
                }
                let out = plan.infer(&xi, &mut scratch);
                for (k, o) in kept.iter_mut().zip(&out.kept) {
                    *k += o;
                }
                mj += out.ledger.millijoules(&energy);
            }
            let n = xs.len() as f64;
            ratios.push(
                kept.iter()
                    .zip(&caps)
                    .map(|(&k, &cap)| {
                        if cap == 0 {
                            0.0
                        } else {
                            (k as f64 / (cap as f64 * n)).clamp(0.0, 1.0)
                        }
                    })
                    .collect(),
            );
            mean_mj.push(mj / n);
        }
        input_density /= xs.len() as f64;
        // Isotonic projection: a larger scale can only shrink keep
        // sets, so enforce non-increasing curves (and energies) over
        // steps — this is what makes profiled estimates provably
        // monotone in scale.
        for step in 1..n_steps {
            for l in 0..ratios[step].len() {
                let prev = ratios[step - 1][l];
                if ratios[step][l] > prev {
                    ratios[step][l] = prev;
                }
            }
            if mean_mj[step] > mean_mj[step - 1] {
                mean_mj[step] = mean_mj[step - 1];
            }
        }
        KeepProfile { ratios, caps, mean_mj, input_density }
    }

    /// Calibrated keep ratio of `layer` at `step`.
    pub fn ratio(&self, step: usize, layer: usize) -> f64 {
        self.ratios[step][layer]
    }

    /// Grid steps covered.
    pub fn n_steps(&self) -> usize {
        self.ratios.len()
    }

    /// Mean calibrated energy per inference at `step` (mJ).
    pub fn mean_mj(&self, step: usize) -> f64 {
        self.mean_mj[step]
    }

    /// Whole-model calibrated keep ratio at `step`: profiled MACs over
    /// the summed static ceilings (the `Stats` frame's keep-ratio
    /// gauge).
    pub fn model_keep_ratio(&self, step: usize) -> f64 {
        let total: u64 = self.caps.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let est: f64 = self
            .caps
            .iter()
            .enumerate()
            .map(|(l, &cap)| cap as f64 * self.ratio(step, l))
            .sum();
        est / total as f64
    }

    /// Smallest grid step whose calibrated mean energy fits
    /// `budget_mj` (scales are non-decreasing in step, energies
    /// non-increasing), or the last step when even maximum pruning
    /// overruns the budget — the governor's feed-forward seed before
    /// the AIMD loop takes over.
    pub fn seed_step(&self, budget_mj: f64) -> usize {
        for (step, &mj) in self.mean_mj.iter().enumerate() {
            if mj <= budget_mj {
                return step;
            }
        }
        self.mean_mj.len().saturating_sub(1)
    }

    /// Per-layer interpolated MAC estimate for one sample under the
    /// plan compiled at `step` (see module docs). Bounded by the
    /// plan's [`dense_macs`](PlannedModel::dense_macs); monotone
    /// non-increasing in `step` for a fixed input.
    pub fn estimate_macs(&self, plan: &PlannedModel, step: usize, x_raw: &[i16]) -> u64 {
        let (kept0, total0) = plan.layer0_exact_kept(x_raw);
        let caps = &self.caps;
        if caps.is_empty() {
            return 1;
        }
        // Density modulation: how dense this input is relative to the
        // calibration batch. Scale-independent, so it cannot break
        // step-monotonicity.
        let nz = x_raw.iter().filter(|&&v| v != 0).count();
        let density = nz as f64 / x_raw.len().max(1) as f64;
        let density_mod = if self.input_density > 0.0 {
            (density / self.input_density).clamp(0.0, DENSITY_MOD_MAX)
        } else {
            1.0
        };
        let mut est = kept0.min(total0);
        for (l, &cap) in caps.iter().enumerate().skip(1) {
            let scaled = (cap as f64 * self.ratio(step, l) * density_mod).round() as u64;
            est += scaled.min(cap);
        }
        est.max(1)
    }
}

/// A [`KeepProfile`] bound to the currently served grid step: the
/// coordinator's placement cost oracle while the governor is attached.
/// Immutable — the governor installs a fresh one on every plan swap
/// rather than mutating shared state under the request path.
#[derive(Debug, Clone)]
pub struct ProfiledCost {
    /// The calibrated profile.
    pub profile: Arc<KeepProfile>,
    /// Grid step the profile is bound to.
    pub step: usize,
}

impl CostEstimator for ProfiledCost {
    fn estimate(&self, plan: &PlannedModel, x_raw: &[i16]) -> u64 {
        self.profile.estimate_macs(plan, self.step, x_raw)
    }
}

/// [`DriftTracker`] tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct DriftCfg {
    /// CUSUM slack δ: per-observation residual magnitude absorbed
    /// without accumulating evidence. Calibration-batch sampling noise
    /// must live below this for a stationary stream to never trip
    /// (property-tested below).
    pub slack: f64,
    /// CUSUM decision threshold λ: accumulated one-sided evidence
    /// needed to declare sustained divergence.
    pub threshold: f64,
    /// Smoothing factor of the published EWMA residual gauge.
    pub ewma_alpha: f64,
    /// Observations required before the tracker may trip, so a cold
    /// (or freshly recalibrated) tracker never fires off its first few
    /// samples.
    pub min_samples: u64,
}

impl Default for DriftCfg {
    fn default() -> DriftCfg {
        DriftCfg { slack: 0.02, threshold: 0.5, ewma_alpha: 0.1, min_samples: 32 }
    }
}

/// Sustained-divergence detector over keep-ratio residuals: the
/// recalibration trigger.
///
/// Each served inference reports its observed model-level keep ratio;
/// the tracker compares it against the calibrated expectation
/// ([`KeepProfile::model_keep_ratio`] at the active step) with a
/// two-sided CUSUM (the Page–Hinkley scheme): evidence accumulators
/// `g⁺ ← max(0, g⁺ + r − δ)` and `g⁻ ← max(0, g⁻ − r − δ)` over the
/// residual `r = observed − expected`, tripping when either exceeds
/// `λ`. Mean-zero noise of magnitude below the slack `δ` cancels
/// before it accumulates — a stationary stream never trips — while a
/// sustained shift of `Δ > δ` trips within about `λ / (Δ − δ)`
/// observations. An EWMA of the residual rides along as the
/// observability gauge (it does not gate the trigger).
#[derive(Debug, Clone)]
pub struct DriftTracker {
    cfg: DriftCfg,
    ewma: f64,
    seen: u64,
    g_pos: f64,
    g_neg: f64,
    trips: u64,
}

impl DriftTracker {
    /// Armed tracker with zeroed accumulators.
    pub fn new(cfg: DriftCfg) -> DriftTracker {
        DriftTracker { cfg, ewma: 0.0, seen: 0, g_pos: 0.0, g_neg: 0.0, trips: 0 }
    }

    /// Feed one observation; returns `true` when sustained divergence
    /// trips the detector (which also re-arms it: accumulators reset,
    /// trip counted).
    pub fn observe(&mut self, observed: f64, expected: f64) -> bool {
        let r = observed - expected;
        self.seen += 1;
        self.ewma = if self.seen == 1 {
            r
        } else {
            self.cfg.ewma_alpha * r + (1.0 - self.cfg.ewma_alpha) * self.ewma
        };
        self.g_pos = (self.g_pos + r - self.cfg.slack).max(0.0);
        self.g_neg = (self.g_neg - r - self.cfg.slack).max(0.0);
        if self.seen >= self.cfg.min_samples
            && (self.g_pos > self.cfg.threshold || self.g_neg > self.cfg.threshold)
        {
            self.g_pos = 0.0;
            self.g_neg = 0.0;
            self.trips += 1;
            return true;
        }
        false
    }

    /// Smoothed residual gauge (observed − expected).
    pub fn ewma_residual(&self) -> f64 {
        self.ewma
    }

    /// Sustained-divergence trips since construction.
    pub fn trips(&self) -> u64 {
        self.trips
    }

    /// Re-arm after a recalibration rebased the expectation: evidence
    /// and the warm-up gate reset (the stream effectively restarts
    /// against a new baseline); the trip count survives.
    pub fn reset(&mut self) {
        self.g_pos = 0.0;
        self.g_neg = 0.0;
        self.ewma = 0.0;
        self.seen = 0;
    }
}

/// Fixed-capacity uniform sample of recently served inputs — the
/// recalibration batch source. Classic reservoir sampling (Algorithm
/// R): after `n` offers each one is present with probability
/// `cap / n`, so the held batch tracks the *current* traffic mix
/// without unbounded memory.
#[derive(Debug, Clone)]
pub struct InputReservoir {
    cap: usize,
    seen: u64,
    xs: Vec<Vec<f32>>,
    rng: crate::util::Rng,
}

impl InputReservoir {
    /// Empty reservoir holding at most `cap` inputs.
    pub fn new(cap: usize, seed: u64) -> InputReservoir {
        assert!(cap > 0, "reservoir capacity must be positive");
        let rng = crate::util::Rng::new(seed);
        InputReservoir { cap, seen: 0, xs: Vec::with_capacity(cap), rng }
    }

    /// Offer one served input.
    pub fn push(&mut self, x: &[f32]) {
        self.seen += 1;
        if self.xs.len() < self.cap {
            self.xs.push(x.to_vec());
        } else {
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.xs[j as usize] = x.to_vec();
            }
        }
    }

    /// Snapshot of the held batch (cloned: measurement runs off-lock).
    pub fn samples(&self) -> Vec<Vec<f32>> {
        self.xs.clone()
    }

    /// Inputs currently held.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// Whether no inputs are held.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Inputs offered since construction or the last [`clear`].
    ///
    /// [`clear`]: InputReservoir::clear
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Drop the held batch (a recalibration consumed it) so the next
    /// one reflects post-shift traffic only.
    pub fn clear(&mut self) {
        self.xs.clear();
        self.seen = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::DivKind;
    use crate::control::ScaleGrid;
    use crate::engine::{PlanConfig, QModel};
    use crate::models::{zoo, Params};
    use crate::pruning::Thresholds;

    fn setup(seed: u64, n_cal: usize) -> (PlanCache, Vec<Vec<f32>>) {
        let def = zoo("mnist");
        let params = Params::random(&def, seed);
        let q = QModel::quantize(&def, &params).with_thresholds(&Thresholds::uniform(3, 0.2));
        let cache = PlanCache::new(
            q,
            PlanConfig::unit(DivKind::Shift),
            ScaleGrid::geometric(0.25, 8.0, 8),
        );
        let xs: Vec<Vec<f32>> = (0..n_cal)
            .map(|s| {
                (0..def.input_len())
                    .map(|i| (((i * 7 + s * 13) % 23) as f32 - 11.0) / 8.0)
                    .collect()
            })
            .collect();
        (cache, xs)
    }

    #[test]
    fn curves_are_bounded_and_monotone_in_scale() {
        let (cache, xs) = setup(41, 4);
        let p = KeepProfile::measure(&cache, &xs);
        let n_layers = cache.plan_at(0).static_macs_per_layer().len();
        for step in 0..p.n_steps() {
            for l in 0..n_layers {
                let r = p.ratio(step, l);
                assert!((0.0..=1.0).contains(&r), "ratio out of range: {r}");
                if step > 0 {
                    assert!(
                        r <= p.ratio(step - 1, l),
                        "layer {l} ratio rose with scale at step {step}"
                    );
                }
            }
            if step > 0 {
                assert!(p.mean_mj(step) <= p.mean_mj(step - 1));
            }
        }
        // Measuring warmed the whole grid.
        assert_eq!(cache.len(), cache.grid().len());
    }

    /// Satellite property (b): profiled estimates are monotone in
    /// scale and bounded by `dense_macs`, across random inputs.
    #[test]
    fn profiled_estimates_monotone_in_scale_and_bounded() {
        let (cache, xs) = setup(42, 4);
        let p = KeepProfile::measure(&cache, &xs);
        let def = zoo("mnist");
        crate::util::prop::check(0xE571, 30, |g| {
            let x_f: Vec<f32> = (0..def.input_len())
                .map(|_| if g.bool() { g.f32_in(-2.0, 2.0) } else { 0.0 })
                .collect();
            let mut last = u64::MAX;
            for step in 0..p.n_steps() {
                let plan = cache.plan_at(step);
                let xi = plan.quantize_input(&x_f);
                let est = p.estimate_macs(&plan, step, &xi);
                assert!(est >= 1 && est <= plan.dense_macs(), "step {step}: est {est}");
                assert!(est <= last, "estimate rose with scale at step {step}");
                last = est;
            }
        });
    }

    #[test]
    fn sparser_inputs_never_raise_the_estimate() {
        let (cache, xs) = setup(43, 4);
        let p = KeepProfile::measure(&cache, &xs);
        let plan = cache.plan_at(3);
        let def = zoo("mnist");
        let x_f: Vec<f32> =
            (0..def.input_len()).map(|i| (((i * 13) % 29) as f32 - 14.0) / 8.0).collect();
        let xi = plan.quantize_input(&x_f);
        let est = p.estimate_macs(&plan, 3, &xi);
        let mut sparse = xi.clone();
        for v in sparse.iter_mut().step_by(2) {
            *v = 0;
        }
        assert!(p.estimate_macs(&plan, 3, &sparse) <= est);
        let zeros = vec![0i16; xi.len()];
        assert!(p.estimate_macs(&plan, 3, &zeros) <= p.estimate_macs(&plan, 3, &sparse));
    }

    #[test]
    fn profiled_estimate_tracks_actual_work_better_than_layer0_extrapolation() {
        // The refinement's reason to exist: across calibration-like
        // inputs, the profiled estimate's error against the actually
        // executed MACs is no worse (summed over probes) than the
        // layer-0 extrapolation's.
        let (cache, xs) = setup(44, 6);
        let p = KeepProfile::measure(&cache, &xs);
        let step = 4;
        let plan = cache.plan_at(step);
        let mut scratch = plan.new_scratch();
        let (mut err_prof, mut err_l0) = (0f64, 0f64);
        for x in &xs {
            let xi = plan.quantize_input(x);
            let actual: u64 = plan.infer(&xi, &mut scratch).kept.iter().sum();
            let prof = p.estimate_macs(&plan, step, &xi);
            let l0 = plan.estimate_macs(&xi);
            err_prof += (prof as f64 - actual as f64).abs();
            err_l0 += (l0 as f64 - actual as f64).abs();
        }
        // Regression guard with a small tolerance (both are estimates;
        // the profiled one must not be meaningfully worse on the very
        // distribution it calibrated on).
        assert!(
            err_prof <= err_l0 * 1.1 + 1.0,
            "profiled estimate worse than layer-0 extrapolation: {err_prof:.0} vs {err_l0:.0}"
        );
    }

    /// Drift property (no false positives): under stationary load —
    /// observations fluctuating around the calibrated expectation with
    /// noise bounded below the CUSUM slack — the tracker never trips,
    /// at **every** grid step, over 1000 batches.
    #[test]
    fn stationary_load_never_trips_at_any_grid_step() {
        let (cache, xs) = setup(46, 3);
        let p = KeepProfile::measure(&cache, &xs);
        let cfg = DriftCfg::default();
        for step in 0..p.n_steps() {
            let expected = p.model_keep_ratio(step);
            let mut tr = DriftTracker::new(cfg);
            crate::util::prop::check(0xD21F + step as u64, 1000, |g| {
                // |noise| < slack: evidence can never accumulate.
                let noise = g.f32_in(-0.015, 0.015) as f64;
                assert!(
                    !tr.observe(expected + noise, expected),
                    "stationary trip at step {step} after {} obs",
                    tr.trips()
                );
            });
            assert_eq!(tr.trips(), 0);
            assert!(tr.ewma_residual().abs() < cfg.slack);
        }
    }

    /// Drift property (guaranteed detection): a sustained step change
    /// larger than the slack trips the detector within a bounded
    /// number of observations — on either side — and re-arms itself.
    #[test]
    fn sustained_shift_trips_within_bounded_observations() {
        let cfg = DriftCfg::default();
        for delta in [0.15f64, -0.15] {
            let mut tr = DriftTracker::new(cfg);
            let expected = 0.6;
            // Warm up stationary, then shift. Bound: min_samples plus
            // λ/(|Δ|−δ) ≈ 32 + 4 observations, doubled for slack.
            for _ in 0..16 {
                assert!(!tr.observe(expected, expected));
            }
            let mut tripped_at = None;
            for i in 0..64 {
                if tr.observe(expected + delta, expected) {
                    tripped_at = Some(i);
                    break;
                }
            }
            let at = tripped_at.unwrap_or_else(|| panic!("no trip for shift {delta}"));
            assert!(at < 40, "shift {delta} tripped too late: {at}");
            assert_eq!(tr.trips(), 1);
            // Re-armed: the warm-up gate holds right after a trip.
            assert!(!tr.observe(expected + delta, expected));
            // And reset() rebases for a recalibrated expectation.
            tr.reset();
            for _ in 0..100 {
                assert!(!tr.observe(expected + delta, expected + delta));
            }
            assert_eq!(tr.trips(), 1);
        }
    }

    /// Drift property (recalibration safety): a profile re-measured
    /// from a *different* input distribution — exactly what the
    /// governor's live recalibration does from its reservoir — still
    /// yields isotonic curves with estimates bounded by `dense_macs`.
    #[test]
    fn recalibrated_curves_stay_isotonic_and_bounded() {
        let (cache, _) = setup(47, 3);
        // A sparser, shifted distribution standing in for post-drift
        // traffic.
        let def = zoo("mnist");
        let shifted: Vec<Vec<f32>> = (0..4)
            .map(|s| {
                (0..def.input_len())
                    .map(|i| {
                        if (i + s) % 3 == 0 {
                            0.0
                        } else {
                            (((i * 11 + s * 17) % 19) as f32 - 5.0) / 4.0
                        }
                    })
                    .collect()
            })
            .collect();
        let p = KeepProfile::measure(&cache, &shifted);
        let n_layers = cache.plan_at(0).static_macs_per_layer().len();
        for step in 0..p.n_steps() {
            for l in 0..n_layers {
                assert!((0.0..=1.0).contains(&p.ratio(step, l)));
                if step > 0 {
                    assert!(p.ratio(step, l) <= p.ratio(step - 1, l));
                }
            }
        }
        crate::util::prop::check(0x5ECA, 20, |g| {
            let x_f = g.vec_sparse_normal(def.input_len(), 0.4);
            let mut last = u64::MAX;
            for step in 0..p.n_steps() {
                let plan = cache.plan_at(step);
                let xi = plan.quantize_input(&x_f);
                let est = p.estimate_macs(&plan, step, &xi);
                assert!(est >= 1 && est <= plan.dense_macs());
                assert!(est <= last);
                last = est;
            }
        });
    }

    #[test]
    fn reservoir_is_bounded_uniform_and_deterministic() {
        let mut r = InputReservoir::new(8, 77);
        for i in 0..500u64 {
            r.push(&[i as f32]);
        }
        assert_eq!(r.len(), 8);
        assert_eq!(r.seen(), 500);
        // Every held sample is one of the offered ones and they are
        // not simply the first (or last) eight: replacement happened.
        let held: Vec<f32> = r.samples().iter().map(|x| x[0]).collect();
        assert!(held.iter().all(|&v| v >= 0.0 && v < 500.0));
        assert!(held.iter().any(|&v| v >= 8.0), "reservoir never replaced");
        // Same seed, same offers, same sample.
        let mut r2 = InputReservoir::new(8, 77);
        for i in 0..500u64 {
            r2.push(&[i as f32]);
        }
        assert_eq!(r.samples(), r2.samples());
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.seen(), 0);
    }

    #[test]
    fn seed_step_inverts_the_energy_curve() {
        let (cache, xs) = setup(45, 3);
        let p = KeepProfile::measure(&cache, &xs);
        // Generous budget: cheapest step (no pruning pressure).
        assert_eq!(p.seed_step(f64::INFINITY), 0);
        // Impossible budget: saturates at the last step.
        assert_eq!(p.seed_step(0.0), p.n_steps() - 1);
        // A budget exactly at some step's mean energy seeds that step.
        let mid = p.n_steps() / 2;
        let s = p.seed_step(p.mean_mj(mid));
        assert!(s <= mid, "seed overshot: {s} > {mid}");
        assert!(p.mean_mj(s) <= p.mean_mj(mid));
    }
}
