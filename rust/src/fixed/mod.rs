//! Fixed-point arithmetic for the MCU engine.
//!
//! The MSP430FR5994 has no FPU; SONIC-style deployments run in 16-bit
//! fixed point with 8-bit quantized weights. This module provides:
//!
//! * [`Q88`] — Q8.8 activations (i16 raw, 1/256 resolution, ±128 range),
//! * [`quantize_weights`] — symmetric int8 weight quantization with a
//!   per-layer scale,
//! * the raw-domain threshold transform used by the UnIT comparisons
//!   (see [`t_raw`]).
//!
//! ## Raw-domain UnIT comparisons
//!
//! Let `xr = round(x·256)` (Q8.8) and `wr = round(w/s)` (int8, per-layer
//! scale `s`). The paper's Eq. 2/3 comparisons translate to a *single*
//! integer threshold `T_raw = T·256/s` for both layer types:
//!
//! * linear (Eq. 2): `|w| ≤ T/|x|  ⇔  |wr| ≤ T_raw / |xr|`
//! * conv   (Eq. 3): `|x| ≤ T/|w|  ⇔  |xr| ≤ T_raw / |wr|`
//!
//! so the whole pruning decision stays in integer arithmetic on the MCU,
//! and the division `T_raw / |c|` is what the [`crate::approx`] estimators
//! approximate.

pub mod q;

pub use q::{clamp_i16, Q88, Q_ONE, Q_SHIFT};

/// Symmetric int8 quantization: `wr = round(w / s)`, `s = max|w| / 127`.
///
/// Returns `(raw, scale)`. An all-zero tensor gets scale 1.0.
pub fn quantize_weights(w: &[f32]) -> (Vec<i8>, f32) {
    let maxabs = w.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
    let raw = w
        .iter()
        .map(|&v| {
            let q = (v / scale).round();
            q.clamp(-127.0, 127.0) as i8
        })
        .collect();
    (raw, scale)
}

/// Dequantize int8 weights back to f32 (for error analysis / tests).
pub fn dequantize_weights(raw: &[i8], scale: f32) -> Vec<f32> {
    raw.iter().map(|&r| r as f32 * scale).collect()
}

/// Transform a real-valued layer threshold `T` into the raw integer
/// domain shared by both UnIT comparisons: `T_raw = T * 256 / s`.
///
/// `s` is the layer's weight scale from [`quantize_weights`].
pub fn t_raw(t_real: f32, weight_scale: f32) -> u32 {
    if t_real <= 0.0 {
        return 0;
    }
    let v = (t_real * Q_ONE as f32 / weight_scale).round();
    if v >= u32::MAX as f32 {
        u32::MAX
    } else {
        v as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let w: Vec<f32> = (0..256).map(|i| (i as f32 - 128.0) / 37.0).collect();
        let (raw, s) = quantize_weights(&w);
        let back = dequantize_weights(&raw, s);
        for (a, b) in w.iter().zip(&back) {
            assert!((a - b).abs() <= s * 0.5 + 1e-6, "{a} vs {b} (s={s})");
        }
    }

    #[test]
    fn quantize_zero_tensor() {
        let (raw, s) = quantize_weights(&[0.0, 0.0]);
        assert_eq!(raw, vec![0, 0]);
        assert_eq!(s, 1.0);
    }

    #[test]
    fn quantize_saturates_at_127() {
        let (raw, _) = quantize_weights(&[1.0, -1.0, 0.5]);
        assert_eq!(raw[0], 127);
        assert_eq!(raw[1], -127);
    }

    #[test]
    fn t_raw_equivalence_linear() {
        // |w| <= T/|x|  must match  |wr| <= T_raw/|xr| on representative
        // values (up to quantization rounding at the boundary).
        let t = 0.8f32;
        let s = 0.01f32;
        let traw = t_raw(t, s);
        for &(x, w) in &[(0.5f32, 0.9f32), (2.0, 0.3), (0.1, 1.2), (4.0, 0.21)] {
            let real = w.abs() <= t / x.abs();
            let xr = (x * 256.0).round() as i64;
            let wr = (w / s).round() as i64;
            let raw = wr.abs() as u128 * xr.abs() as u128 <= traw as u128 * 1u128;
            // compare via product form to avoid integer-division rounding
            let raw_div = wr.unsigned_abs() <= (traw as u64 / xr.unsigned_abs()) as u64;
            // Both raw forms must agree with the real comparison away from
            // the quantization boundary.
            let margin = (w.abs() - t / x.abs()).abs();
            if margin > 0.05 {
                assert_eq!(real, raw, "product form x={x} w={w}");
                assert_eq!(real, raw_div, "division form x={x} w={w}");
            }
        }
    }

    #[test]
    fn t_raw_zero_and_saturation() {
        assert_eq!(t_raw(0.0, 0.01), 0);
        assert_eq!(t_raw(-1.0, 0.01), 0);
        assert_eq!(t_raw(1e30, 1e-10), u32::MAX);
    }
}
