//! Q8.8 fixed-point scalar type (i16 raw, 8 fractional bits).
//!
//! This matches the SONIC runtime's fixed-point representation on the
//! MSP430: activations live in Q8.8, products accumulate in i32, and the
//! result is rescaled back with a right shift (plus the per-layer weight
//! scale folded in by the engine's requantization step).

/// Number of fractional bits.
pub const Q_SHIFT: i32 = 8;
/// 1.0 in raw units.
pub const Q_ONE: i32 = 1 << Q_SHIFT;

/// Saturating clamp of an i32 into the i16 raw range.
#[inline]
pub fn clamp_i16(v: i32) -> i16 {
    v.clamp(i16::MIN as i32, i16::MAX as i32) as i16
}

/// Q8.8 fixed-point value.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Q88(pub i16);

impl Q88 {
    /// 0.0.
    pub const ZERO: Q88 = Q88(0);
    /// 1.0.
    pub const ONE: Q88 = Q88(Q_ONE as i16);
    /// Largest representable value (≈ 127.996).
    pub const MAX: Q88 = Q88(i16::MAX);
    /// Most negative representable value (−128.0).
    pub const MIN: Q88 = Q88(i16::MIN);

    /// Convert from f32 with rounding and saturation.
    #[inline]
    pub fn from_f32(v: f32) -> Q88 {
        let r = (v * Q_ONE as f32).round();
        if r >= i16::MAX as f32 {
            Q88(i16::MAX)
        } else if r <= i16::MIN as f32 {
            Q88(i16::MIN)
        } else {
            Q88(r as i16)
        }
    }

    /// Convert to `f32`.
    #[inline]
    pub fn to_f32(self) -> f32 {
        self.0 as f32 / Q_ONE as f32
    }

    /// The raw `i16` representation.
    #[inline]
    pub fn raw(self) -> i16 {
        self.0
    }

    /// Absolute value of the raw representation (no overflow at `MIN`).
    #[inline]
    pub fn abs_raw(self) -> u32 {
        (self.0 as i32).unsigned_abs()
    }

    /// Saturating addition.
    #[inline]
    pub fn sat_add(self, other: Q88) -> Q88 {
        Q88(self.0.saturating_add(other.0))
    }

    /// Saturating subtraction.
    #[inline]
    pub fn sat_sub(self, other: Q88) -> Q88 {
        Q88(self.0.saturating_sub(other.0))
    }

    /// Q8.8 × Q8.8 → Q8.8 with i32 intermediate and saturation.
    #[inline]
    pub fn sat_mul(self, other: Q88) -> Q88 {
        let p = (self.0 as i32 * other.0 as i32) >> Q_SHIFT;
        Q88(clamp_i16(p))
    }

    /// ReLU in raw domain.
    #[inline]
    pub fn relu(self) -> Q88 {
        if self.0 > 0 {
            self
        } else {
            Q88::ZERO
        }
    }

    /// FATReLU in raw domain: zero unless strictly above `t`.
    #[inline]
    pub fn fatrelu(self, t: Q88) -> Q88 {
        if self.0 > t.0 {
            self
        } else {
            Q88::ZERO
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small_values() {
        for v in [-10.0f32, -0.5, 0.0, 0.25, 1.0, 100.0] {
            let q = Q88::from_f32(v);
            assert!((q.to_f32() - v).abs() <= 0.5 / Q_ONE as f32 + 1e-6, "{v}");
        }
    }

    #[test]
    fn saturation_at_extremes() {
        assert_eq!(Q88::from_f32(1e9), Q88::MAX);
        assert_eq!(Q88::from_f32(-1e9), Q88::MIN);
        assert_eq!(Q88::MAX.sat_add(Q88::ONE), Q88::MAX);
        assert_eq!(Q88::MIN.sat_sub(Q88::ONE), Q88::MIN);
    }

    #[test]
    fn mul_matches_float() {
        let a = Q88::from_f32(1.5);
        let b = Q88::from_f32(-2.25);
        let p = a.sat_mul(b);
        assert!((p.to_f32() - (-3.375)).abs() < 0.01);
    }

    #[test]
    fn mul_saturates() {
        let a = Q88::from_f32(127.0);
        let p = a.sat_mul(a);
        assert_eq!(p, Q88::MAX);
    }

    #[test]
    fn relu_and_fatrelu() {
        assert_eq!(Q88::from_f32(-1.0).relu(), Q88::ZERO);
        assert_eq!(Q88::from_f32(2.0).relu(), Q88::from_f32(2.0));
        let t = Q88::from_f32(0.5);
        assert_eq!(Q88::from_f32(0.4).fatrelu(t), Q88::ZERO);
        assert_eq!(Q88::from_f32(0.6).fatrelu(t), Q88::from_f32(0.6));
        // boundary: exactly t is pruned (strict >)
        assert_eq!(t.fatrelu(t), Q88::ZERO);
    }

    #[test]
    fn prop_add_commutes_and_saturates() {
        crate::util::prop::check(41, 300, |g| {
            let a = Q88(g.i32_in(-32768, 32767) as i16);
            let b = Q88(g.i32_in(-32768, 32767) as i16);
            assert_eq!(a.sat_add(b), b.sat_add(a));
            let wide = a.0 as i32 + b.0 as i32;
            assert_eq!(a.sat_add(b).0 as i32, wide.clamp(-32768, 32767));
        });
    }

    #[test]
    fn prop_mul_close_to_float() {
        crate::util::prop::check(42, 300, |g| {
            let x = g.f32_in(-8.0, 8.0);
            let y = g.f32_in(-8.0, 8.0);
            let q = Q88::from_f32(x).sat_mul(Q88::from_f32(y));
            // error bound: quantization of both operands + truncation
            let tol = (x.abs() + y.abs()) * (1.0 / Q_ONE as f32) + 2.0 / Q_ONE as f32;
            assert!((q.to_f32() - x * y).abs() <= tol, "{x}*{y} -> {}", q.to_f32());
        });
    }
}
