//! CIFAR-like synthetic color scenes: 3×32×32, 10 classes.
//!
//! Each class is a color composition: 2–3 colored gaussian blobs at
//! class-fixed relative positions plus an oriented sinusoidal texture
//! with class-specific frequency/orientation. Per-sample jitter moves
//! the scene, modulates color gains and adds noise. Harder than the
//! MNIST-like set (three channels, textures), mirroring the paper's
//! complexity ordering.

use super::{Dataset, Sizes, Split};
use crate::data::synth::{add_noise, stamp_gauss, standardize};
use crate::util::Rng;

/// Input channels.
pub const C: usize = 3;
/// Input height.
pub const H: usize = 32;
/// Input width.
pub const W: usize = 32;
/// Number of classes.
pub const CLASSES: usize = 10;

struct Blob {
    x: f32,
    y: f32,
    sigma: f32,
    rgb: [f32; 3],
}

struct Texture {
    freq: f32,
    angle: f32,
    rgb: [f32; 3],
}

struct Scene {
    blobs: Vec<Blob>,
    texture: Texture,
}

fn class_scene(class: usize, base_seed: u64) -> Scene {
    let mut rng = Rng::new(base_seed ^ (0xC1FA_0 + class as u64 * 104_729));
    let nb = 2 + rng.below(2) as usize;
    let blobs = (0..nb)
        .map(|_| Blob {
            x: rng.range(6.0, 26.0),
            y: rng.range(6.0, 26.0),
            sigma: rng.range(2.0, 5.0),
            rgb: [rng.range(0.2, 1.0), rng.range(0.2, 1.0), rng.range(0.2, 1.0)],
        })
        .collect();
    let texture = Texture {
        freq: rng.range(0.2, 0.9),
        angle: rng.range(0.0, std::f32::consts::PI),
        rgb: [rng.range(0.0, 0.5), rng.range(0.0, 0.5), rng.range(0.0, 0.5)],
    };
    Scene { blobs, texture }
}

fn render_sample(scene: &Scene, rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0.0f32; C * H * W];
    let dx = rng.range(-2.5, 2.5);
    let dy = rng.range(-2.5, 2.5);
    let gain = [rng.range(0.8, 1.2), rng.range(0.8, 1.2), rng.range(0.8, 1.2)];
    for blob in &scene.blobs {
        for ch in 0..C {
            let amp = blob.rgb[ch] * gain[ch];
            let (plane, rest) = img[ch * H * W..].split_at_mut(H * W);
            let _ = rest;
            stamp_gauss(plane, H, W, blob.x + dx, blob.y + dy, blob.sigma, amp);
        }
    }
    let (ca, sa) = (scene.texture.angle.cos(), scene.texture.angle.sin());
    let phase = rng.range(0.0, std::f32::consts::TAU);
    for y in 0..H {
        for x in 0..W {
            let u = ca * x as f32 + sa * y as f32;
            let v = (scene.texture.freq * u + phase).sin();
            for ch in 0..C {
                img[ch * H * W + y * W + x] += scene.texture.rgb[ch] * gain[ch] * v * 0.4;
            }
        }
    }
    add_noise(&mut img, rng, 0.1);
    standardize(&mut img);
    img
}

fn fill_split(split: &mut Split, n: usize, scenes: &[Scene], rng: &mut Rng) {
    for i in 0..n {
        let class = i % CLASSES;
        split.push(&render_sample(&scenes[class], rng), class);
    }
}

/// Generate the dataset deterministically from `seed`.
pub fn generate(seed: u64, sizes: Sizes) -> Dataset {
    let scenes: Vec<Scene> = (0..CLASSES).map(|c| class_scene(c, seed)).collect();
    let mut root = Rng::new(seed ^ 0xC1FA_7);
    let mut train = Split::new(C * H * W);
    let mut val = Split::new(C * H * W);
    let mut test = Split::new(C * H * W);
    fill_split(&mut train, sizes.train, &scenes, &mut root.fork(1));
    fill_split(&mut val, sizes.val, &scenes, &mut root.fork(2));
    fill_split(&mut test, sizes.test, &scenes, &mut root.fork(3));
    Dataset {
        name: "cifar".into(),
        input_shape: [C, H, W],
        classes: CLASSES,
        train,
        val,
        test,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_balance() {
        let ds = generate(1, Sizes { train: 40, val: 10, test: 10 });
        assert_eq!(ds.input_shape, [3, 32, 32]);
        assert_eq!(ds.train.sample(0).len(), 3 * 32 * 32);
        let mut counts = [0usize; CLASSES];
        for &y in &ds.train.y {
            counts[y] += 1;
        }
        assert!(counts.iter().all(|&c| c == 4));
    }

    #[test]
    fn channels_differ() {
        // Color structure: channels must not be identical copies.
        let ds = generate(2, Sizes { train: 4, val: 2, test: 2 });
        let s = ds.train.sample(0);
        let (r, g) = (&s[0..H * W], &s[H * W..2 * H * W]);
        assert_ne!(r, g);
    }

    #[test]
    fn deterministic() {
        let a = generate(9, Sizes { train: 6, val: 2, test: 2 });
        let b = generate(9, Sizes { train: 6, val: 2, test: 2 });
        assert_eq!(a.train.x, b.train.x);
    }
}
