//! Shared rendering primitives for the synthetic dataset generators.

use crate::util::Rng;

/// Draw an anti-aliased line segment onto a (H, W) canvas, accumulating
/// intensity `amp` with a gaussian cross-section of width `sigma`.
pub fn draw_line(
    canvas: &mut [f32],
    h: usize,
    w: usize,
    x0: f32,
    y0: f32,
    x1: f32,
    y1: f32,
    sigma: f32,
    amp: f32,
) {
    let steps = (((x1 - x0).abs() + (y1 - y0).abs()) * 2.0).ceil().max(2.0) as usize;
    for s in 0..=steps {
        let t = s as f32 / steps as f32;
        let cx = x0 + t * (x1 - x0);
        let cy = y0 + t * (y1 - y0);
        stamp_gauss(canvas, h, w, cx, cy, sigma, amp / steps as f32 * 4.0);
    }
}

/// Accumulate a 2-D gaussian bump centred at (cx, cy).
pub fn stamp_gauss(canvas: &mut [f32], h: usize, w: usize, cx: f32, cy: f32, sigma: f32, amp: f32) {
    let r = (3.0 * sigma).ceil() as i64;
    let ix = cx.round() as i64;
    let iy = cy.round() as i64;
    for dy in -r..=r {
        for dx in -r..=r {
            let px = ix + dx;
            let py = iy + dy;
            if px < 0 || py < 0 || px >= w as i64 || py >= h as i64 {
                continue;
            }
            let fx = px as f32 - cx;
            let fy = py as f32 - cy;
            let g = (-(fx * fx + fy * fy) / (2.0 * sigma * sigma)).exp();
            canvas[py as usize * w + px as usize] += amp * g;
        }
    }
}

/// Add i.i.d. gaussian noise.
pub fn add_noise(canvas: &mut [f32], rng: &mut Rng, sigma: f32) {
    for v in canvas.iter_mut() {
        *v += sigma * rng.normal();
    }
}

/// Standardize in place to zero mean, unit-ish std (clamped to ±4), the
/// input range the Q8.8 engine is calibrated for.
pub fn standardize(canvas: &mut [f32]) {
    let n = canvas.len() as f32;
    let mean = canvas.iter().sum::<f32>() / n;
    let var = canvas.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    for v in canvas.iter_mut() {
        *v = ((*v - mean) / std).clamp(-4.0, 4.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stamp_gauss_peak_at_centre() {
        let mut c = vec![0.0; 11 * 11];
        stamp_gauss(&mut c, 11, 11, 5.0, 5.0, 1.0, 1.0);
        let peak = c[5 * 11 + 5];
        assert!(peak > 0.9);
        assert!(c.iter().all(|&v| v <= peak + 1e-6));
    }

    #[test]
    fn stamp_gauss_clips_at_borders() {
        let mut c = vec![0.0; 5 * 5];
        stamp_gauss(&mut c, 5, 5, 0.0, 0.0, 2.0, 1.0);
        assert!(c[0] > 0.0); // corner received energy, no panic
    }

    #[test]
    fn draw_line_touches_endpoints() {
        let mut c = vec![0.0; 20 * 20];
        draw_line(&mut c, 20, 20, 2.0, 2.0, 17.0, 17.0, 0.8, 1.0);
        assert!(c[2 * 20 + 2] > 0.0);
        assert!(c[17 * 20 + 17] > 0.0);
        assert!(c[19 * 20 + 0] < 1e-4); // off-diagonal corner untouched
    }

    #[test]
    fn standardize_moments() {
        let mut rng = Rng::new(3);
        let mut c: Vec<f32> = (0..1000).map(|_| 5.0 + 2.0 * rng.normal()).collect();
        standardize(&mut c);
        let mean = c.iter().sum::<f32>() / 1000.0;
        let var = c.iter().map(|v| v * v).sum::<f32>() / 1000.0;
        assert!(mean.abs() < 0.01);
        assert!((var - 1.0).abs() < 0.1);
    }
}
