//! Widar-like synthetic WiFi CSI gestures: 22×13×13, 6 classes, with a
//! **room** domain-shift knob (paper Table 2 protocol).
//!
//! Widar3.0 derives a body-coordinate velocity profile (BVP) from CSI:
//! a stack of Doppler-range maps. We synthesize per-gesture trajectories
//! through the 13×13 velocity plane evolving across the 22 channel
//! slices, then apply **room-specific distortions**:
//!
//! * a fixed per-room channel mixing matrix (multipath),
//! * per-room static clutter pattern added to every sample,
//! * per-room noise level and gain (Room 1 = cluttered classroom, noisy;
//!   Room 2 = empty hallway, cleaner but different mixing).
//!
//! Training in one room and testing in the other reproduces the paper's
//! deployment-drift setting: same gesture structure, shifted marginals.

use super::{Dataset, Sizes, Split};
use crate::data::synth::{add_noise, stamp_gauss, standardize};
use crate::util::Rng;

/// Input channels (CSI slices).
pub const C: usize = 22; // channel slices
/// Input height.
pub const H: usize = 13;
/// Input width.
pub const W: usize = 13;
/// Number of gesture classes.
pub const CLASSES: usize = 6;

/// Deployment environment (Table 2 contexts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Room {
    /// Cluttered classroom: strong multipath mixing, higher noise.
    Room1,
    /// Nearly empty hallway: weaker mixing, lower noise, different gain.
    Room2,
}

impl Room {
    /// Lowercase room label.
    pub fn name(self) -> &'static str {
        match self {
            Room::Room1 => "room1",
            Room::Room2 => "room2",
        }
    }

    fn mixing_seed(self) -> u64 {
        match self {
            Room::Room1 => 0xA11CE,
            Room::Room2 => 0xB0B00,
        }
    }

    fn noise(self) -> f32 {
        match self {
            Room::Room1 => 0.85,
            Room::Room2 => 0.55,
        }
    }

    fn gain(self) -> f32 {
        match self {
            Room::Room1 => 1.0,
            Room::Room2 => 0.65,
        }
    }

    fn mix_strength(self) -> f32 {
        match self {
            Room::Room1 => 0.65,
            Room::Room2 => 0.25,
        }
    }
}

struct Gesture {
    // trajectory control points in the velocity plane, per phase
    path: Vec<(f32, f32)>,
    sigma: f32,
}

fn class_gesture(class: usize, base_seed: u64) -> Gesture {
    let mut rng = Rng::new(base_seed ^ (0x31DA_0 + class as u64 * 6_700_417));
    let n = 3 + rng.below(3) as usize;
    let path = (0..n).map(|_| (rng.range(2.0, 11.0), rng.range(2.0, 11.0))).collect();
    Gesture { path, sigma: rng.range(1.0, 1.8) }
}

/// Per-room channel mixing: y_c = x_c + strength * x_{perm(c)} + clutter_c.
struct RoomModel {
    perm: Vec<usize>,
    clutter: Vec<f32>, // C*H*W static background
    room: Room,
}

fn room_model(room: Room, base_seed: u64) -> RoomModel {
    let mut rng = Rng::new(base_seed ^ room.mixing_seed());
    let mut perm: Vec<usize> = (0..C).collect();
    rng.shuffle(&mut perm);
    let mut clutter = vec![0.0f32; C * H * W];
    // static reflectors: strong enough to shadow weak gesture energy
    for _ in 0..14 {
        let ch = rng.below(C as u64) as usize;
        let cx = rng.range(1.0, 12.0);
        let cy = rng.range(1.0, 12.0);
        let amp = rng.range(0.3, 1.0);
        let plane = &mut clutter[ch * H * W..(ch + 1) * H * W];
        stamp_gauss(plane, H, W, cx, cy, rng.range(1.2, 2.5), amp);
    }
    RoomModel { perm, clutter, room }
}

fn render_sample(g: &Gesture, rm: &RoomModel, rng: &mut Rng) -> Vec<f32> {
    let mut cube = vec![0.0f32; C * H * W];
    // user variability: speed + spatial offset + amplitude (wide — the
    // paper's protocol swaps users between train and test too)
    let speed = rng.range(0.7, 1.3);
    let dx = rng.range(-2.2, 2.2);
    let dy = rng.range(-2.2, 2.2);
    let amp = rng.range(0.6, 1.2);
    let segs = g.path.len() - 1;
    for ch in 0..C {
        // gesture phase for this channel slice
        let phase = (ch as f32 / (C - 1) as f32) * speed;
        let pos = (phase.min(0.999)) * segs as f32;
        let i = pos.floor() as usize;
        let frac = pos - i as f32;
        let (x0, y0) = g.path[i.min(segs - 1)];
        let (x1, y1) = g.path[(i + 1).min(segs)];
        let cx = x0 + frac * (x1 - x0) + dx;
        let cy = y0 + frac * (y1 - y0) + dy;
        let plane = &mut cube[ch * H * W..(ch + 1) * H * W];
        stamp_gauss(plane, H, W, cx, cy, g.sigma, amp);
    }
    // room multipath: mix permuted channels + clutter
    let strength = rm.room.mix_strength();
    let gain = rm.room.gain();
    let orig = cube.clone();
    for ch in 0..C {
        let src = rm.perm[ch];
        for p in 0..H * W {
            cube[ch * H * W + p] = gain
                * (orig[ch * H * W + p]
                    + strength * orig[src * H * W + p]
                    + rm.clutter[ch * H * W + p]);
        }
    }
    add_noise(&mut cube, rng, rm.room.noise());
    standardize(&mut cube);
    cube
}

fn fill_split(split: &mut Split, n: usize, gestures: &[Gesture], rm: &RoomModel, rng: &mut Rng) {
    for i in 0..n {
        let class = i % CLASSES;
        split.push(&render_sample(&gestures[class], rm, rng), class);
    }
}

/// Generate a dataset whose *every* split comes from the given room.
/// Cross-context evaluation pairs `generate_room(seed, _, Room1).train`
/// with `generate_room(seed, _, Room2).test`: the gesture skeletons are
/// shared (same base seed), only the environment changes.
pub fn generate_room(seed: u64, sizes: Sizes, room: Room) -> Dataset {
    let gestures: Vec<Gesture> = (0..CLASSES).map(|c| class_gesture(c, seed)).collect();
    let rm = room_model(room, seed);
    let mut root = Rng::new(seed ^ 0x31DA_7 ^ room.mixing_seed());
    let mut train = Split::new(C * H * W);
    let mut val = Split::new(C * H * W);
    let mut test = Split::new(C * H * W);
    fill_split(&mut train, sizes.train, &gestures, &rm, &mut root.fork(1));
    fill_split(&mut val, sizes.val, &gestures, &rm, &mut root.fork(2));
    fill_split(&mut test, sizes.test, &gestures, &rm, &mut root.fork(3));
    Dataset {
        name: format!("widar-{}", room.name()),
        input_shape: [C, H, W],
        classes: CLASSES,
        train,
        val,
        test,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rooms_shift_distribution() {
        let sizes = Sizes { train: 24, val: 6, test: 6 };
        let r1 = generate_room(5, sizes, Room::Room1);
        let r2 = generate_room(5, sizes, Room::Room2);
        // Same gesture skeletons, different environments: samples differ.
        assert_ne!(r1.train.x, r2.train.x);
        // Distribution shift metric: mean absolute difference of class
        // centroids across rooms is nonzero.
        let centroid = |ds: &Dataset, class: usize| -> Vec<f32> {
            let mut acc = vec![0.0f32; ds.sample_len()];
            let mut n = 0;
            for i in 0..ds.train.len() {
                if ds.train.y[i] == class {
                    for (a, b) in acc.iter_mut().zip(ds.train.sample(i)) {
                        *a += b;
                    }
                    n += 1;
                }
            }
            acc.iter_mut().for_each(|a| *a /= n as f32);
            acc
        };
        let c1 = centroid(&r1, 0);
        let c2 = centroid(&r2, 0);
        let mad: f32 =
            c1.iter().zip(&c2).map(|(a, b)| (a - b).abs()).sum::<f32>() / c1.len() as f32;
        assert!(mad > 0.05, "rooms too similar: mad={mad}");
    }

    #[test]
    fn gesture_structure_survives_room_change() {
        // Intra-class correlation across rooms must still beat
        // inter-class within a room — otherwise cross-room transfer
        // would be impossible and Table 2 meaningless.
        let sizes = Sizes { train: 60, val: 6, test: 6 };
        let r1 = generate_room(7, sizes, Room::Room1);
        let r2 = generate_room(7, sizes, Room::Room2);
        let corr = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>() / a.len() as f32
        };
        let mut cross_same = 0.0;
        let mut cross_diff = 0.0;
        let mut ns = 0;
        let mut nd = 0;
        for i in 0..30 {
            for j in 0..30 {
                let c = corr(r1.train.sample(i), r2.train.sample(j));
                if r1.train.y[i] == r2.train.y[j] {
                    cross_same += c;
                    ns += 1;
                } else {
                    cross_diff += c;
                    nd += 1;
                }
            }
        }
        assert!(cross_same / ns as f32 > cross_diff / nd as f32);
    }

    #[test]
    fn deterministic_per_room() {
        let sizes = Sizes { train: 6, val: 2, test: 2 };
        let a = generate_room(3, sizes, Room::Room2);
        let b = generate_room(3, sizes, Room::Room2);
        assert_eq!(a.train.x, b.train.x);
    }
}
