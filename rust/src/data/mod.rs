//! Synthetic datasets standing in for MNIST / CIFAR-10 / Google KWS /
//! Widar3.0 (no dataset downloads in this image; DESIGN.md §2).
//!
//! Each generator is deterministic given a seed and produces
//! class-conditional structure *learnable by the Table-1 models*, so the
//! paper's accuracy-vs-MACs trends are meaningful:
//!
//! * [`mnist_like`] — 1×28×28 stroke-rendered "digits" (10 classes),
//! * [`cifar_like`] — 3×32×32 colored blob/texture scenes (10 classes),
//! * [`kws_like`] — 1×124×80 spectrograms with class-specific formant
//!   trajectories (12 keywords),
//! * [`widar_like`] — 22×13×13 CSI Doppler tensors with a **room**
//!   domain-shift knob reproducing Table 2's cross-context protocol.
//!
//! Splits follow the paper: train (90 % of the non-test pool) / val
//! (10 %, used *only* for threshold calibration) / test.

pub mod cifar_like;
pub mod kws_like;
pub mod mnist_like;
pub mod synth;
pub mod widar_like;

/// One split of samples, stored flat (n × C·H·W, row-major).
#[derive(Debug, Clone)]
pub struct Split {
    /// Flat sample values, n × `sample_len`.
    pub x: Vec<f32>,
    /// Labels, one per sample.
    pub y: Vec<usize>,
    /// Values per sample (C·H·W).
    pub sample_len: usize,
}

impl Split {
    /// Empty split for samples of `sample_len` values.
    pub fn new(sample_len: usize) -> Split {
        Split { x: Vec::new(), y: Vec::new(), sample_len }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// Whether the split has no samples.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Append one sample (length-checked).
    pub fn push(&mut self, sample: &[f32], label: usize) {
        assert_eq!(sample.len(), self.sample_len);
        self.x.extend_from_slice(sample);
        self.y.push(label);
    }

    /// Borrow sample `i`.
    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.sample_len..(i + 1) * self.sample_len]
    }

    /// Gather a batch `(x, y_onehot)` for the PJRT trainer.
    pub fn batch(&self, idx: &[usize], classes: usize) -> (Vec<f32>, Vec<f32>) {
        let mut bx = Vec::with_capacity(idx.len() * self.sample_len);
        let mut by = vec![0.0; idx.len() * classes];
        for (row, &i) in idx.iter().enumerate() {
            bx.extend_from_slice(self.sample(i));
            by[row * classes + self.y[i]] = 1.0;
        }
        (bx, by)
    }
}

/// A full dataset: three splits plus shape metadata.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Dataset name.
    pub name: String,
    /// Input shape as `[C, H, W]`.
    pub input_shape: [usize; 3],
    /// Number of classes.
    pub classes: usize,
    /// Training split.
    pub train: Split,
    /// Calibration/validation split.
    pub val: Split,
    /// Test split.
    pub test: Split,
}

impl Dataset {
    /// Values per sample (C·H·W).
    pub fn sample_len(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// Standard generation sizes used across experiments (kept modest so the
/// single-core PJRT trainer converges in minutes).
#[derive(Debug, Clone, Copy)]
pub struct Sizes {
    /// Training samples to generate.
    pub train: usize,
    /// Validation samples to generate.
    pub val: usize,
    /// Test samples to generate.
    pub test: usize,
}

impl Default for Sizes {
    fn default() -> Self {
        Sizes { train: 1800, val: 200, test: 600 }
    }
}

/// Build a dataset by model name ("mnist", "cifar", "kws", "widar").
/// `widar` defaults to Room 1; use [`widar_like::generate_room`] for the
/// cross-context protocol.
pub fn by_name(name: &str, seed: u64, sizes: Sizes) -> Dataset {
    match name {
        "mnist" => mnist_like::generate(seed, sizes),
        "cifar" => cifar_like::generate(seed, sizes),
        "kws" => kws_like::generate(seed, sizes),
        "widar" => widar_like::generate_room(seed, sizes, widar_like::Room::Room1),
        other => panic!("unknown dataset {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_push_and_sample() {
        let mut s = Split::new(4);
        s.push(&[1.0, 2.0, 3.0, 4.0], 1);
        s.push(&[5.0, 6.0, 7.0, 8.0], 0);
        assert_eq!(s.len(), 2);
        assert_eq!(s.sample(1), &[5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn batch_onehot_layout() {
        let mut s = Split::new(2);
        s.push(&[1.0, 2.0], 2);
        s.push(&[3.0, 4.0], 0);
        let (bx, by) = s.batch(&[1, 0], 3);
        assert_eq!(bx, vec![3.0, 4.0, 1.0, 2.0]);
        assert_eq!(by, vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn all_generators_produce_declared_shapes() {
        let sizes = Sizes { train: 12, val: 6, test: 6 };
        for name in ["mnist", "cifar", "kws", "widar"] {
            let ds = by_name(name, 7, sizes);
            assert_eq!(ds.train.len(), 12, "{name}");
            assert_eq!(ds.val.len(), 6);
            assert_eq!(ds.test.len(), 6);
            assert_eq!(ds.train.sample_len, ds.sample_len());
            assert!(ds.train.y.iter().all(|&y| y < ds.classes));
        }
    }

    #[test]
    fn generators_deterministic() {
        let sizes = Sizes { train: 8, val: 4, test: 4 };
        let a = by_name("mnist", 5, sizes);
        let b = by_name("mnist", 5, sizes);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.test.y, b.test.y);
    }

    #[test]
    fn seeds_change_data() {
        let sizes = Sizes { train: 8, val: 4, test: 4 };
        let a = by_name("cifar", 1, sizes);
        let b = by_name("cifar", 2, sizes);
        assert_ne!(a.train.x, b.train.x);
    }
}
