//! MNIST-like synthetic digits: 1×28×28, 10 classes.
//!
//! Each class is a fixed "skeleton" of 3–5 line strokes drawn from a
//! class-seeded RNG; each sample renders the skeleton with per-sample
//! translation, scale and amplitude jitter plus pixel noise, then
//! standardizes. The Table-1 MNIST CNN reaches high accuracy on this in
//! a few hundred SGD steps while still leaving room for pruning-induced
//! degradation — the property Fig. 5 needs.

use super::{Dataset, Sizes, Split};
use crate::data::synth::{add_noise, draw_line, standardize};
use crate::util::Rng;

/// Input height.
pub const H: usize = 28;
/// Input width.
pub const W: usize = 28;
/// Number of classes.
pub const CLASSES: usize = 10;

struct Stroke {
    x0: f32,
    y0: f32,
    x1: f32,
    y1: f32,
}

fn class_skeleton(class: usize, base_seed: u64) -> Vec<Stroke> {
    let mut rng = Rng::new(base_seed ^ (0xD16_17 + class as u64 * 7919));
    let n = 3 + rng.below(3) as usize;
    (0..n)
        .map(|_| Stroke {
            x0: rng.range(4.0, 24.0),
            y0: rng.range(4.0, 24.0),
            x1: rng.range(4.0, 24.0),
            y1: rng.range(4.0, 24.0),
        })
        .collect()
}

fn render_sample(skel: &[Stroke], rng: &mut Rng) -> Vec<f32> {
    let mut canvas = vec![0.0f32; H * W];
    let dx = rng.range(-2.0, 2.0);
    let dy = rng.range(-2.0, 2.0);
    let scale = rng.range(0.85, 1.15);
    let amp = rng.range(0.8, 1.2);
    let cx = 14.0;
    let cy = 14.0;
    for s in skel {
        let tx = |x: f32| (x - cx) * scale + cx + dx;
        let ty = |y: f32| (y - cy) * scale + cy + dy;
        draw_line(
            &mut canvas,
            H,
            W,
            tx(s.x0),
            ty(s.y0),
            tx(s.x1),
            ty(s.y1),
            rng.range(0.7, 1.1),
            amp,
        );
    }
    add_noise(&mut canvas, rng, 0.08);
    standardize(&mut canvas);
    canvas
}

fn fill_split(split: &mut Split, n: usize, skels: &[Vec<Stroke>], rng: &mut Rng) {
    for i in 0..n {
        let class = i % CLASSES;
        let sample = render_sample(&skels[class], rng);
        split.push(&sample, class);
    }
}

/// Generate the dataset (train/val/test streams are independent forks).
pub fn generate(seed: u64, sizes: Sizes) -> Dataset {
    let skels: Vec<Vec<Stroke>> = (0..CLASSES).map(|c| class_skeleton(c, seed)).collect();
    let mut root = Rng::new(seed ^ 0xB0A7);
    let mut train = Split::new(H * W);
    let mut val = Split::new(H * W);
    let mut test = Split::new(H * W);
    fill_split(&mut train, sizes.train, &skels, &mut root.fork(1));
    fill_split(&mut val, sizes.val, &skels, &mut root.fork(2));
    fill_split(&mut test, sizes.test, &skels, &mut root.fork(3));
    Dataset {
        name: "mnist".into(),
        input_shape: [1, H, W],
        classes: CLASSES,
        train,
        val,
        test,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_balanced() {
        let ds = generate(3, Sizes { train: 100, val: 20, test: 20 });
        let mut counts = [0usize; CLASSES];
        for &y in &ds.train.y {
            counts[y] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10));
    }

    #[test]
    fn samples_standardized() {
        let ds = generate(4, Sizes { train: 10, val: 2, test: 2 });
        let s = ds.train.sample(0);
        let mean: f32 = s.iter().sum::<f32>() / s.len() as f32;
        assert!(mean.abs() < 0.05);
        assert!(s.iter().all(|v| v.abs() <= 4.0));
    }

    #[test]
    fn same_class_samples_correlate_more_than_cross_class() {
        // The generator must actually encode class structure: average
        // intra-class correlation above inter-class.
        let ds = generate(5, Sizes { train: 200, val: 2, test: 2 });
        let corr = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>() / a.len() as f32
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut ni = 0;
        let mut nx = 0;
        for i in 0..40 {
            for j in (i + 1)..40 {
                let c = corr(ds.train.sample(i), ds.train.sample(j));
                if ds.train.y[i] == ds.train.y[j] {
                    intra += c;
                    ni += 1;
                } else {
                    inter += c;
                    nx += 1;
                }
            }
        }
        assert!(intra / ni as f32 > inter / nx as f32 + 0.1);
    }
}
