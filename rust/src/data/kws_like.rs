//! KWS-like synthetic spectrograms: 1×124×80, 12 classes.
//!
//! Google Speech Commands-style wake-word spectrograms: 124 time frames ×
//! 80 mel bins. Each keyword class is a set of 2–3 "formant" ridges —
//! frequency trajectories `f_k(t)` with class-specific start, slope and
//! vibrato — rendered as gaussian ridges over the time axis. Class 11 is
//! the background/noise class (no ridges, higher noise floor), mirroring
//! Speech Commands' `_unknown_`/noise buckets.

use super::{Dataset, Sizes, Split};
use crate::data::synth::{add_noise, standardize};
use crate::util::Rng;

/// Spectrogram time frames.
pub const H: usize = 124; // time frames
/// Mel bins.
pub const W: usize = 80; // mel bins
/// Number of keyword classes.
pub const CLASSES: usize = 12;

struct Formant {
    f0: f32,    // start bin
    slope: f32, // bins per frame
    vib_amp: f32,
    vib_freq: f32,
    sigma: f32,
    amp: f32,
}

fn class_formants(class: usize, base_seed: u64) -> Vec<Formant> {
    if class == CLASSES - 1 {
        return Vec::new(); // background class: pure noise
    }
    let mut rng = Rng::new(base_seed ^ (0x5EEC_0 + class as u64 * 15_485_863));
    let n = 2 + rng.below(2) as usize;
    (0..n)
        .map(|_| Formant {
            f0: rng.range(10.0, 70.0),
            slope: rng.range(-0.25, 0.25),
            vib_amp: rng.range(0.0, 4.0),
            vib_freq: rng.range(0.05, 0.3),
            sigma: rng.range(1.5, 3.0),
            amp: rng.range(0.7, 1.3),
        })
        .collect()
}

fn render_sample(formants: &[Formant], rng: &mut Rng) -> Vec<f32> {
    let mut spec = vec![0.0f32; H * W];
    let t_shift = rng.range(-8.0, 8.0);
    let f_shift = rng.range(-3.0, 3.0);
    let speed = rng.range(0.9, 1.1);
    let gain = rng.range(0.8, 1.2);
    let onset = rng.range(8.0, 30.0);
    let dur = rng.range(60.0, 90.0);
    for fm in formants {
        for t in 0..H {
            let tt = (t as f32 - onset - t_shift) * speed;
            if tt < 0.0 || tt > dur {
                continue;
            }
            let centre =
                fm.f0 + f_shift + fm.slope * tt + fm.vib_amp * (fm.vib_freq * tt).sin();
            // vertical gaussian ridge at this frame
            let lo = (centre - 3.0 * fm.sigma).floor().max(0.0) as usize;
            let hi = (centre + 3.0 * fm.sigma).ceil().min(W as f32 - 1.0) as usize;
            for f in lo..=hi {
                let d = f as f32 - centre;
                spec[t * W + f] +=
                    gain * fm.amp * (-(d * d) / (2.0 * fm.sigma * fm.sigma)).exp();
            }
        }
    }
    let noise = if formants.is_empty() { 0.35 } else { 0.12 };
    add_noise(&mut spec, rng, noise);
    standardize(&mut spec);
    spec
}

fn fill_split(split: &mut Split, n: usize, classes: &[Vec<Formant>], rng: &mut Rng) {
    for i in 0..n {
        let class = i % CLASSES;
        split.push(&render_sample(&classes[class], rng), class);
    }
}

/// Generate the dataset deterministically from `seed`.
pub fn generate(seed: u64, sizes: Sizes) -> Dataset {
    let classes: Vec<Vec<Formant>> = (0..CLASSES).map(|c| class_formants(c, seed)).collect();
    let mut root = Rng::new(seed ^ 0x5EEC_7);
    let mut train = Split::new(H * W);
    let mut val = Split::new(H * W);
    let mut test = Split::new(H * W);
    fill_split(&mut train, sizes.train, &classes, &mut root.fork(1));
    fill_split(&mut val, sizes.val, &classes, &mut root.fork(2));
    fill_split(&mut test, sizes.test, &classes, &mut root.fork(3));
    Dataset {
        name: "kws".into(),
        input_shape: [1, H, W],
        classes: CLASSES,
        train,
        val,
        test,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_table1_pipeline() {
        // (124-4)/2 = 60, (60-4)/2 = 28 ; (80-4)/2 = 38, (38-4)/2 = 17
        // => 16*28*17 = 7616, the Table-1 linear input.
        let oh = ((H - 4) / 2 - 4) / 2;
        let ow = ((W - 4) / 2 - 4) / 2;
        assert_eq!(16 * oh * ow, 7616);
    }

    #[test]
    fn background_class_is_flatter() {
        let ds = generate(3, Sizes { train: CLASSES * 4, val: CLASSES, test: CLASSES });
        // Kurtosis proxy: max value of keyword samples exceeds noise ones.
        let peak = |s: &[f32]| s.iter().cloned().fold(f32::MIN, f32::max);
        let mut kw_peaks = vec![];
        let mut bg_peaks = vec![];
        for i in 0..ds.train.len() {
            let p = peak(ds.train.sample(i));
            if ds.train.y[i] == CLASSES - 1 {
                bg_peaks.push(p);
            } else {
                kw_peaks.push(p);
            }
        }
        let avg = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        assert!(avg(&kw_peaks) > avg(&bg_peaks));
    }

    #[test]
    fn deterministic() {
        let a = generate(11, Sizes { train: 6, val: 2, test: 2 });
        let b = generate(11, Sizes { train: 6, val: 2, test: 2 });
        assert_eq!(a.train.x, b.train.x);
    }
}
