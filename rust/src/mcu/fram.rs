//! FRAM traffic model.
//!
//! The MSP430FR5994's ferroelectric RAM is non-volatile (which is what
//! makes SONIC-style intermittent computing possible) but wait-stated:
//! above 8 MHz the controller inserts wait cycles, so at the modeled
//! 16 MHz a random 16-bit access costs extra cycles. SONIC additionally
//! double-buffers task outputs (write-two-copies commit) — that, plus
//! streaming layer activations through FRAM, is why the paper's Fig. 6
//! shows *data movement dominating wall-clock time*.
//!
//! Model: `READ_CYCLES = 2`, `WRITE_CYCLES = 4` per 16-bit word
//! (cache-miss average at 16 MHz with 1 wait state; writes go through
//! the FRAM controller's read-modify-write).

/// Cycles per 16-bit FRAM read (wait-stated average at 16 MHz).
pub const READ_CYCLES: u64 = 2;
/// Cycles per 16-bit FRAM write (read-modify-write through controller).
pub const WRITE_CYCLES: u64 = 4;

/// Per-layer buffer traffic model: how many FRAM words move for a layer
/// with the given activation sizes, under SONIC-style double buffering.
#[derive(Debug, Clone)]
pub struct FramModel {
    /// Write each task output twice (commit + shadow), as SONIC does.
    pub double_buffer: bool,
}

impl Default for FramModel {
    fn default() -> Self {
        FramModel { double_buffer: true }
    }
}

impl FramModel {
    /// FRAM words written when a layer commits `out_words` of activations.
    pub fn commit_words(&self, out_words: u64) -> u64 {
        if self.double_buffer {
            2 * out_words
        } else {
            out_words
        }
    }

    /// Charge the ledger for one layer's streaming traffic:
    /// weights read once, inputs read once, outputs committed.
    pub fn charge_layer(
        &self,
        ledger: &mut super::Ledger,
        weight_words: u64,
        in_words: u64,
        out_words: u64,
    ) {
        ledger.fram_read(weight_words + in_words);
        ledger.fram_write(self.commit_words(out_words));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn double_buffer_doubles_writes() {
        let m = FramModel { double_buffer: true };
        assert_eq!(m.commit_words(100), 200);
        let s = FramModel { double_buffer: false };
        assert_eq!(s.commit_words(100), 100);
    }

    #[test]
    fn charge_layer_accounts_reads_and_writes() {
        let m = FramModel::default();
        let mut l = super::super::Ledger::new();
        m.charge_layer(&mut l, 1000, 500, 200);
        assert_eq!(l.counts.fram_reads, 1500);
        assert_eq!(l.counts.fram_writes, 400);
        assert_eq!(l.mem_cycles, 1500 * READ_CYCLES + 400 * WRITE_CYCLES);
    }

    #[test]
    fn writes_slower_than_reads() {
        assert!(WRITE_CYCLES > READ_CYCLES);
    }
}
