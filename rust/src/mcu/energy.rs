//! MSP430FR5994 energy model.
//!
//! EnergyTrace integrates supply current over time; this model integrates
//! modeled energy over executed operations — the same quantity,
//! deterministic. Constants are datasheet-order-of-magnitude:
//!
//! * Active execution: the FR5994 datasheet lists ≈ **118 µA/MHz at
//!   3.0 V** (active mode, cache hit ratio typical). Per cycle that is
//!   `118 µA · 3.0 V / 1 MHz = 354 pJ/cycle` independent of frequency.
//! * FRAM accesses burn extra energy on top of the CPU cycle:
//!   ≈ **100 pJ per 16-bit read** and ≈ **250 pJ per 16-bit write**
//!   (FRAM writes are the dominant memory cost in SONIC-class systems).
//!
//! Only *ratios* matter for reproducing the paper's Fig. 7 (UnIT vs
//! baselines); absolute mJ are reported for scale.

/// Energy model with per-cycle and per-FRAM-access costs (picojoules).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// pJ per CPU cycle (active mode).
    pub pj_per_cycle: f64,
    /// Extra pJ per 16-bit FRAM read.
    pub pj_per_fram_read: f64,
    /// Extra pJ per 16-bit FRAM write.
    pub pj_per_fram_write: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            pj_per_cycle: 354.0,
            pj_per_fram_read: 100.0,
            pj_per_fram_write: 250.0,
        }
    }
}

impl EnergyModel {
    /// Total energy in millijoules for a ledger's counts.
    pub fn millijoules(&self, cycles: u64, fram_reads: u64, fram_writes: u64) -> f64 {
        let pj = cycles as f64 * self.pj_per_cycle
            + fram_reads as f64 * self.pj_per_fram_read
            + fram_writes as f64 * self.pj_per_fram_write;
        pj * 1e-9 // pJ -> mJ
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_sanity_mnist_class_inference() {
        // Paper Fig. 7: MNIST-class inference ≈ 0.2–1.3 mJ. A dense
        // 240k-MAC model ≈ 240k * 83 cycles ≈ 20 M cycles ≈ 7 mJ; with
        // pruning + the paper's overheads the band is right.
        let m = EnergyModel::default();
        let mj = m.millijoules(20_000_000, 1_000_000, 100_000);
        assert!(mj > 1.0 && mj < 20.0, "mj={mj}");
    }

    #[test]
    fn writes_cost_more_than_reads() {
        let m = EnergyModel::default();
        assert!(m.pj_per_fram_write > m.pj_per_fram_read);
    }

    #[test]
    fn zero_is_zero() {
        assert_eq!(EnergyModel::default().millijoules(0, 0, 0), 0.0);
    }
}
