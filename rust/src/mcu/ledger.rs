//! Execution ledger: every operation the inference engine performs is
//! charged here, by instruction class, so cycles / energy / MAC counts
//! fall out exactly.
//!
//! The ledger is the hot path of the whole simulator (one `skip()` or
//! `mac()` per connection), so it is plain `u64` field bumps — no
//! branching, no allocation.

use super::cost;
use super::energy::EnergyModel;

/// Raw operation counts by class.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// Executed multiply-accumulates.
    pub macs: u64,
    /// Skipped (pruned) multiply-accumulates.
    pub skipped: u64,
    /// Threshold comparisons (one per pruning decision).
    pub compares: u64,
    /// Threshold divisions (exact or approximate), with their cycles.
    pub divs: u64,
    /// Non-MAC adds (bias, pooling, requantization).
    pub adds: u64,
    /// FRAM 16-bit word reads.
    pub fram_reads: u64,
    /// FRAM 16-bit word writes.
    pub fram_writes: u64,
}

impl OpCounts {
    /// Executed plus skipped MACs.
    pub fn total_connections(&self) -> u64 {
        self.macs + self.skipped
    }

    /// Fraction of connections skipped (0 when none ran).
    pub fn skip_fraction(&self) -> f64 {
        let total = self.total_connections();
        if total == 0 {
            0.0
        } else {
            self.skipped as f64 / total as f64
        }
    }
}

/// Accumulating execution ledger (cycles + op counts).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Ledger {
    /// Operation counts.
    pub counts: OpCounts,
    /// Compute cycles (CPU arithmetic + control).
    pub compute_cycles: u64,
    /// Memory-traffic cycles (FRAM wait/transfer; the paper's
    /// "data moving time", reported separately in Fig. 6).
    pub mem_cycles: u64,
}

impl Ledger {
    /// Fresh zeroed ledger.
    pub fn new() -> Ledger {
        Ledger::default()
    }

    /// Charge one executed MAC (multiply + accumulate).
    #[inline(always)]
    pub fn mac(&mut self) {
        self.counts.macs += 1;
        self.compute_cycles += cost::MAC;
    }

    /// Charge one skipped MAC (the pruning win: nothing but the compare,
    /// which is charged separately by `compare()`).
    #[inline(always)]
    pub fn skip(&mut self) {
        self.counts.skipped += 1;
    }

    /// Charge one threshold compare+branch.
    #[inline(always)]
    pub fn compare(&mut self) {
        self.counts.compares += 1;
        self.compute_cycles += cost::CMP_BRANCH;
    }

    /// Batched charges — the engine inner loops aggregate per weight
    /// tap / activation row and charge once (§Perf: hoisting the ledger
    /// field bumps out of the per-connection loop bought ~7 % simulator
    /// throughput with identical totals).
    #[inline(always)]
    pub fn mac_n(&mut self, n: u64) {
        self.counts.macs += n;
        self.compute_cycles += n * cost::MAC;
    }

    /// Charge `n` threshold comparisons.
    #[inline(always)]
    pub fn compare_n(&mut self, n: u64) {
        self.counts.compares += n;
        self.compute_cycles += n * cost::CMP_BRANCH;
    }

    /// Count `n` skipped MACs (no cycles — the skip is the saving).
    #[inline(always)]
    pub fn skip_n(&mut self, n: u64) {
        self.counts.skipped += n;
    }

    /// Charge one threshold division with estimator-reported cycles.
    #[inline(always)]
    pub fn div(&mut self, cycles: u64) {
        self.counts.divs += 1;
        self.compute_cycles += cycles;
    }

    /// Charge `n` divisions whose cycle costs were pre-summed — the
    /// planned engine folds a whole layer's (input-independent) conv
    /// threshold divisions into one arithmetic update with totals
    /// identical to `n` individual `div()` calls.
    #[inline(always)]
    pub fn div_n(&mut self, n: u64, total_cycles: u64) {
        self.counts.divs += n;
        self.compute_cycles += total_cycles;
    }

    /// Charge a plain addition (bias, pooling compare, requant add).
    #[inline(always)]
    pub fn add(&mut self) {
        self.counts.adds += 1;
        self.compute_cycles += cost::ADD;
    }

    /// Charge generic control cycles (loop bookkeeping).
    #[inline(always)]
    pub fn control(&mut self, cycles: u64) {
        self.compute_cycles += cycles;
    }

    /// Charge `words` 16-bit FRAM reads.
    #[inline(always)]
    pub fn fram_read(&mut self, words: u64) {
        self.counts.fram_reads += words;
        self.mem_cycles += words * super::fram::READ_CYCLES;
    }

    /// Charge `words` 16-bit FRAM writes.
    #[inline(always)]
    pub fn fram_write(&mut self, words: u64) {
        self.counts.fram_writes += words;
        self.mem_cycles += words * super::fram::WRITE_CYCLES;
    }

    /// Compute plus memory cycles.
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.mem_cycles
    }

    /// Wall-clock seconds at the modeled CPU frequency (continuous power).
    pub fn secs(&self) -> f64 {
        cost::cycles_to_secs(self.total_cycles())
    }

    /// Energy in mJ under an energy model.
    pub fn millijoules(&self, m: &EnergyModel) -> f64 {
        m.millijoules(self.total_cycles(), self.counts.fram_reads, self.counts.fram_writes)
    }

    /// Merge another ledger into this one.
    pub fn merge(&mut self, other: &Ledger) {
        self.counts.macs += other.counts.macs;
        self.counts.skipped += other.counts.skipped;
        self.counts.compares += other.counts.compares;
        self.counts.divs += other.counts.divs;
        self.counts.adds += other.counts.adds;
        self.counts.fram_reads += other.counts.fram_reads;
        self.counts.fram_writes += other.counts.fram_writes;
        self.compute_cycles += other.compute_cycles;
        self.mem_cycles += other.mem_cycles;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_vs_skip_cycle_gap() {
        // One executed MAC costs ~83 cycles; a skipped one costs only its
        // compare (3). This 27x gap is the paper's entire value
        // proposition — assert it survives the ledger plumbing.
        let mut executed = Ledger::new();
        executed.compare();
        executed.mac();
        let mut skipped = Ledger::new();
        skipped.compare();
        skipped.skip();
        assert_eq!(executed.total_cycles(), cost::CMP_BRANCH + cost::MAC);
        assert_eq!(skipped.total_cycles(), cost::CMP_BRANCH);
        assert!(executed.total_cycles() > 25 * skipped.total_cycles());
    }

    #[test]
    fn skip_fraction() {
        let mut l = Ledger::new();
        for _ in 0..30 {
            l.mac();
        }
        for _ in 0..70 {
            l.skip();
        }
        assert!((l.counts.skip_fraction() - 0.7).abs() < 1e-12);
        assert_eq!(l.counts.total_connections(), 100);
    }

    #[test]
    fn merge_adds_everything() {
        let mut a = Ledger::new();
        a.mac();
        a.fram_write(10);
        let mut b = Ledger::new();
        b.skip();
        b.compare();
        b.fram_read(5);
        a.merge(&b);
        assert_eq!(a.counts.macs, 1);
        assert_eq!(a.counts.skipped, 1);
        assert_eq!(a.counts.compares, 1);
        assert_eq!(a.counts.fram_reads, 5);
        assert_eq!(a.counts.fram_writes, 10);
    }

    #[test]
    fn mem_and_compute_cycles_separate() {
        let mut l = Ledger::new();
        l.mac();
        l.fram_read(100);
        assert_eq!(l.compute_cycles, cost::MAC);
        assert_eq!(l.mem_cycles, 100 * super::super::fram::READ_CYCLES);
    }

    /// One randomly parameterized ledger charge, replayable onto any
    /// ledger — the unit the shard-split property is built from.
    #[derive(Debug, Clone, Copy)]
    enum Op {
        Mac,
        Skip,
        Compare,
        Add,
        MacN(u64),
        SkipN(u64),
        CompareN(u64),
        Div(u64),
        DivN(u64, u64),
        Control(u64),
        FramRead(u64),
        FramWrite(u64),
    }

    impl Op {
        fn apply(self, l: &mut Ledger) {
            match self {
                Op::Mac => l.mac(),
                Op::Skip => l.skip(),
                Op::Compare => l.compare(),
                Op::Add => l.add(),
                Op::MacN(n) => l.mac_n(n),
                Op::SkipN(n) => l.skip_n(n),
                Op::CompareN(n) => l.compare_n(n),
                Op::Div(c) => l.div(c),
                Op::DivN(n, c) => l.div_n(n, c),
                Op::Control(c) => l.control(c),
                Op::FramRead(w) => l.fram_read(w),
                Op::FramWrite(w) => l.fram_write(w),
            }
        }
    }

    #[test]
    fn merge_over_arbitrary_shard_splits_equals_unsharded() {
        // The invariant evaluate_quant_parallel and the sharded serving
        // metrics rest on: charging a work sequence into K per-shard
        // ledgers and merging them (in any order) equals charging the
        // whole sequence into one ledger.
        crate::util::prop::check(0xA11CE, 300, |g| {
            let n_ops = g.usize_in(0, 120);
            let shards = g.usize_in(1, 8);
            let mut whole = Ledger::new();
            let mut parts = vec![Ledger::new(); shards];
            for _ in 0..n_ops {
                let op = match g.usize_in(0, 11) {
                    0 => Op::Mac,
                    1 => Op::Skip,
                    2 => Op::Compare,
                    3 => Op::Add,
                    4 => Op::MacN(g.usize_in(0, 1000) as u64),
                    5 => Op::SkipN(g.usize_in(0, 1000) as u64),
                    6 => Op::CompareN(g.usize_in(0, 1000) as u64),
                    7 => Op::Div(g.usize_in(0, 200) as u64),
                    8 => Op::DivN(g.usize_in(0, 50) as u64, g.usize_in(0, 5000) as u64),
                    9 => Op::Control(g.usize_in(0, 500) as u64),
                    10 => Op::FramRead(g.usize_in(0, 300) as u64),
                    _ => Op::FramWrite(g.usize_in(0, 300) as u64),
                };
                op.apply(&mut whole);
                op.apply(&mut parts[g.usize_in(0, shards - 1)]);
            }
            // Merge in a shard order the generator picks, not 0..K.
            let mut merged = Ledger::new();
            let start = g.usize_in(0, shards - 1);
            for i in 0..shards {
                merged.merge(&parts[(start + i) % shards]);
            }
            assert_eq!(merged, whole, "shards={shards} n_ops={n_ops}");
            // Derived quantities agree exactly too.
            assert_eq!(merged.total_cycles(), whole.total_cycles());
            assert_eq!(
                merged.counts.total_connections(),
                whole.counts.total_connections()
            );
        });
    }

    #[test]
    fn energy_monotone_in_work() {
        let m = EnergyModel::default();
        let mut small = Ledger::new();
        small.mac();
        let mut big = Ledger::new();
        for _ in 0..1000 {
            big.mac();
        }
        assert!(big.millijoules(&m) > small.millijoules(&m));
    }
}
