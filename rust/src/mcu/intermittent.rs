//! SONIC-like intermittent execution simulator.
//!
//! Batteryless devices run from harvested energy: a capacitor charges,
//! the MCU executes until the capacitor drains, power fails, and
//! execution resumes from the last committed task after the capacitor
//! recharges. SONIC (Gobieski et al., ASPLOS'19) decomposes DNN inference
//! into idempotent loop-continuable tasks so only bounded work is lost
//! per failure.
//!
//! This simulator replays a ledger-measured workload (a sequence of task
//! costs in cycles) under a synthetic harvesting profile and reports:
//!
//! * wall-clock time including charge (dead) intervals,
//! * re-executed cycles lost to power failures,
//! * checkpoint-commit FRAM overhead.
//!
//! Fewer compute cycles (what UnIT delivers) ⇒ fewer charge cycles per
//! inference ⇒ superlinear wall-clock wins on harvested power — the
//! qualitative effect the paper's battery-free framing relies on.

use crate::util::Rng;

/// Energy-harvesting profile: how many cycles each powered burst
/// sustains, and how long recharging takes between bursts.
#[derive(Debug, Clone)]
pub struct HarvestProfile {
    /// Mean cycles of compute per charged burst.
    pub mean_burst_cycles: f64,
    /// Burst jitter fraction (uniform ±).
    pub jitter: f64,
    /// Recharge (off) time per burst, in seconds.
    pub recharge_secs: f64,
}

impl Default for HarvestProfile {
    fn default() -> Self {
        // ~100k cycles per burst (≈6 ms at 16 MHz) and 50 ms recharge —
        // RF-harvesting scale, same regime as SONIC's evaluation.
        HarvestProfile { mean_burst_cycles: 100_000.0, jitter: 0.3, recharge_secs: 0.05 }
    }
}

/// Result of simulating one workload under intermittent power.
#[derive(Debug, Clone, Default)]
pub struct IntermittentRun {
    /// Total wall-clock seconds, charge intervals included.
    pub wall_secs: f64,
    /// Cycles re-executed because a failure hit mid-task.
    pub reexecuted_cycles: u64,
    /// Number of power failures endured.
    pub failures: u64,
    /// Extra FRAM words written for task checkpoints.
    pub checkpoint_words: u64,
}

/// Simulator: executes tasks sequentially under the harvest profile.
pub struct IntermittentSim {
    /// The energy-harvest profile driving the run.
    pub profile: HarvestProfile,
    /// FRAM words committed per task boundary (SONIC writes the loop
    /// index + dirty buffer words; we charge a fixed small state block).
    pub checkpoint_state_words: u64,
    rng: Rng,
}

impl IntermittentSim {
    /// Simulator with the default checkpoint state block.
    pub fn new(profile: HarvestProfile, seed: u64) -> Self {
        IntermittentSim { profile, checkpoint_state_words: 16, rng: Rng::new(seed) }
    }

    fn next_burst(&mut self) -> u64 {
        let j = self.profile.jitter;
        let f = self.rng.range((1.0 - j as f32).max(0.05), 1.0 + j as f32);
        (self.profile.mean_burst_cycles * f as f64).max(1.0) as u64
    }

    /// Run a sequence of task costs (cycles each, committed atomically at
    /// task end). A power failure mid-task loses that task's progress.
    pub fn run(&mut self, task_cycles: &[u64]) -> IntermittentRun {
        let mut out = IntermittentRun::default();
        let mut budget = self.next_burst();
        for &task in task_cycles {
            let commit_cost =
                self.checkpoint_state_words * super::fram::WRITE_CYCLES;
            let need = task + commit_cost;
            let mut done = false;
            while !done {
                if budget >= need {
                    budget -= need;
                    out.wall_secs += super::cost::cycles_to_secs(need);
                    out.checkpoint_words += self.checkpoint_state_words;
                    done = true;
                } else {
                    // Failure mid-task: progress lost, recharge, retry.
                    out.wall_secs += super::cost::cycles_to_secs(budget);
                    out.reexecuted_cycles += budget;
                    out.failures += 1;
                    out.wall_secs += self.profile.recharge_secs;
                    budget = self.next_burst();
                    if need > (self.profile.mean_burst_cycles * (1.0 + self.profile.jitter)) as u64
                        && budget < need
                    {
                        // Task cannot fit any burst: SONIC would subdivide;
                        // we emulate by allowing a double-length burst so
                        // the simulation always terminates.
                        budget = need;
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuous_power_limit() {
        // Huge bursts => no failures, wall time == cycle time + commits.
        let profile =
            HarvestProfile { mean_burst_cycles: 1e12, jitter: 0.0, recharge_secs: 1.0 };
        let mut sim = IntermittentSim::new(profile, 1);
        let run = sim.run(&[10_000, 20_000, 30_000]);
        assert_eq!(run.failures, 0);
        assert_eq!(run.reexecuted_cycles, 0);
        let commit = 3 * 16 * super::super::fram::WRITE_CYCLES;
        let expect = super::super::cost::cycles_to_secs(60_000 + commit);
        assert!((run.wall_secs - expect).abs() < 1e-9);
    }

    #[test]
    fn failures_add_dead_time() {
        let profile =
            HarvestProfile { mean_burst_cycles: 5_000.0, jitter: 0.2, recharge_secs: 0.05 };
        let mut sim = IntermittentSim::new(profile, 2);
        let run = sim.run(&[4_000; 20]);
        assert!(run.failures > 0);
        // Dead time must dominate: 20 tasks * ~0.25ms compute each vs
        // 50 ms per recharge.
        assert!(run.wall_secs > 0.9 * run.failures as f64 * 0.05);
    }

    #[test]
    fn fewer_cycles_less_wall_clock() {
        // UnIT's effect: a pruned workload (fewer cycles) finishes in
        // less wall-clock time under the same harvesting profile.
        let profile = HarvestProfile::default();
        let full: Vec<u64> = vec![80_000; 50];
        let pruned: Vec<u64> = vec![30_000; 50];
        let a = IntermittentSim::new(profile.clone(), 3).run(&full);
        let b = IntermittentSim::new(profile, 3).run(&pruned);
        assert!(b.wall_secs < a.wall_secs, "{} vs {}", b.wall_secs, a.wall_secs);
    }

    #[test]
    fn oversized_task_terminates() {
        let profile =
            HarvestProfile { mean_burst_cycles: 1_000.0, jitter: 0.1, recharge_secs: 0.01 };
        let mut sim = IntermittentSim::new(profile, 4);
        let run = sim.run(&[50_000]);
        assert!(run.failures >= 1);
        assert!(run.wall_secs.is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let p = HarvestProfile::default();
        let a = IntermittentSim::new(p.clone(), 9).run(&[70_000; 10]);
        let b = IntermittentSim::new(p, 9).run(&[70_000; 10]);
        assert_eq!(a.failures, b.failures);
        assert!((a.wall_secs - b.wall_secs).abs() < 1e-12);
    }
}
