//! MSP430FR5994-class MCU simulator.
//!
//! The paper's testbed is a real MSP430FR5994 running the SONIC
//! intermittent-computing runtime, measured with TI EnergyTrace. This
//! module is the simulated substitute (DESIGN.md substitution ledger):
//! a deterministic per-instruction-class **cycle cost model**
//! ([`cost`]), an **energy model** ([`energy`]), an **FRAM traffic
//! model** ([`fram`]), an execution **ledger** that the inference engine
//! charges every operation to ([`ledger`]), and a SONIC-like
//! **intermittent execution** simulator with power-failure injection
//! ([`intermittent`]).
//!
//! All of UnIT's claims are *relative* (cycles and energy saved by
//! trading 77-cycle multiplies for 2–4-cycle compares), so a faithful
//! cost model reproduces the paper's effect sizes without the physical
//! board.

pub mod cost;
pub mod energy;
pub mod fram;
pub mod intermittent;
pub mod ledger;
pub mod memmap;

pub use energy::EnergyModel;
pub use fram::FramModel;
pub use intermittent::{HarvestProfile, IntermittentSim};
pub use ledger::{Ledger, OpCounts};
