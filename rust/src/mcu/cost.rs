//! MSP430FR5994 cycle-cost constants.
//!
//! Sources (documented so every number is auditable):
//!
//! * **MUL_SW = 77** — the paper (§1) cites TI SLAA329 ("Efficient
//!   Multiplication and Division Using MSP430 MCUs"): a 16×16 software
//!   shift-and-add multiply ≈ 77 cycles. (The FR5994 does have a memory-
//!   mapped hardware multiplier, but SONIC-class batteryless deployments
//!   frequently run without it for portability, and the paper's headline
//!   arithmetic uses 77.)
//! * **ADD = 6** — paper §1: "an addition takes only 6" (register-memory
//!   addressing included).
//! * **CMP_BRANCH = 3** — paper §2: "conditional branching requires only
//!   2 to 4 clock cycles"; we take the midpoint.
//! * **DIV_SW = 140** — SLAA329's restoring 32÷16 division lands at
//!   roughly 1.8× the multiply; the paper calls division "nearly as
//!   expensive as multiplication". 140 keeps the paper's Fig. 8 ratio
//!   (approximators save 50–60 %) reachable.
//! * **SHIFT = 1** per bit (RRA/RLA on a register).
//! * **MOV = 2** register-memory move.
//!
//! Changing any constant re-prices every experiment consistently — the
//! benches print the table in effect.

/// Software 16×16→32 multiply (SLAA329 / paper §1).
pub const MUL_SW: u64 = 77;
/// 16-bit addition with a memory operand (paper §1).
pub const ADD: u64 = 6;
/// Compare + conditional branch (paper §2: 2–4 cycles; midpoint).
pub const CMP_BRANCH: u64 = 3;
/// Software 32÷16 division routine (SLAA329-class restoring divider).
pub const DIV_SW: u64 = 140;
/// Single-bit register shift.
pub const SHIFT: u64 = 1;
/// Register↔memory move (16-bit word).
pub const MOV: u64 = 2;

/// One executed MAC = multiply + accumulate-add.
pub const MAC: u64 = MUL_SW + ADD;

/// CPU frequency the wall-clock conversion uses. SONIC runs the FR5994
/// at 16 MHz (FRAM wait-stated above 8 MHz — see `fram.rs`).
pub const CPU_HZ: f64 = 16_000_000.0;

/// Convert cycles to seconds at `CPU_HZ`.
pub fn cycles_to_secs(cycles: u64) -> f64 {
    cycles as f64 / CPU_HZ
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_ratio_holds() {
        // The whole premise: a pruning *check* must be far cheaper than
        // the MAC it avoids. Paper: 77-cycle multiply vs 2-4 cycle branch.
        assert!(CMP_BRANCH * 10 < MUL_SW);
        assert!(MAC > 80);
    }

    #[test]
    fn division_near_multiplication() {
        // Paper §2.2: division "nearly as expensive" as multiplication —
        // same order of magnitude, somewhat above.
        assert!(DIV_SW >= MUL_SW);
        assert!(DIV_SW <= 3 * MUL_SW);
    }

    #[test]
    fn wallclock_conversion() {
        assert!((cycles_to_secs(16_000_000) - 1.0).abs() < 1e-12);
    }
}
