//! FRAM memory map: does a deployed model actually fit the
//! MSP430FR5994's 256 KB of FRAM?
//!
//! The paper (§3.3) chooses Table-1 architectures specifically so they
//! "run within MSP430's fixed-point FRAM limits without model swapping".
//! This planner makes that constraint executable: it lays out every
//! deployment section — quantized weights, biases, thresholds, SONIC
//! double-buffered activation arenas, checkpoint state — and reports
//! the budget.

use crate::engine::QModel;

/// Total FRAM on the MSP430FR5994.
pub const FRAM_BYTES: usize = 256 * 1024;
/// Reserved for the runtime (SONIC code, stack shadow, task state).
pub const RUNTIME_RESERVED: usize = 24 * 1024;

/// One named section of the deployment image.
#[derive(Debug, Clone)]
pub struct Section {
    /// Section label.
    pub name: String,
    /// Section size in bytes.
    pub bytes: usize,
}

/// A planned memory map.
#[derive(Debug, Clone)]
pub struct MemMap {
    /// Sections in layout order.
    pub sections: Vec<Section>,
}

impl MemMap {
    /// Plan the layout for a quantized model.
    ///
    /// * weights: int8 each;
    /// * biases: i32 accumulator-domain each;
    /// * thresholds: one u32 per layer (+ per group if present);
    /// * activations: the two largest adjacent activation buffers,
    ///   double-buffered (SONIC commit semantics), i16 each;
    /// * checkpoint state: fixed block.
    pub fn plan(q: &QModel) -> MemMap {
        let mut sections = Vec::new();
        let mut w = 0usize;
        let mut b = 0usize;
        let mut t = 0usize;
        for l in &q.layers {
            w += l.w.len();
            b += 4 * l.bias_acc.len();
            t += 4 + 4 * l.t_raw_groups.len();
        }
        sections.push(Section { name: "weights(int8)".into(), bytes: w });
        sections.push(Section { name: "biases(i32)".into(), bytes: b });
        sections.push(Section { name: "thresholds(u32)".into(), bytes: t });

        // Activation arenas: layer i reads buffer A and writes buffer B;
        // SONIC double-buffers the write side. Size by the two largest
        // activation tensors in the pipeline.
        let acts = q.def.activation_sizes();
        let mut sorted = acts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let a0 = sorted.first().copied().unwrap_or(0);
        let a1 = sorted.get(1).copied().unwrap_or(0);
        sections.push(Section { name: "activations A (i16)".into(), bytes: 2 * a0 });
        sections.push(Section {
            name: "activations B x2 (i16, double-buffered)".into(),
            bytes: 2 * 2 * a1,
        });
        sections.push(Section { name: "checkpoint state".into(), bytes: 512 });
        sections.push(Section { name: "runtime reserved".into(), bytes: RUNTIME_RESERVED });
        MemMap { sections }
    }

    /// Total planned bytes.
    pub fn total(&self) -> usize {
        self.sections.iter().map(|s| s.bytes).sum()
    }

    /// Whether the plan fits MSP430FR5994 FRAM.
    pub fn fits(&self) -> bool {
        self.total() <= FRAM_BYTES
    }

    /// FRAM bytes to spare (negative = over).
    pub fn headroom(&self) -> isize {
        FRAM_BYTES as isize - self.total() as isize
    }

    /// Human-readable report.
    pub fn report(&self) -> String {
        let mut t = crate::util::table::Table::new(vec!["section", "bytes", "KiB"]);
        for s in &self.sections {
            t.row(vec![
                s.name.clone(),
                s.bytes.to_string(),
                format!("{:.1}", s.bytes as f64 / 1024.0),
            ]);
        }
        t.row(vec![
            "TOTAL".to_string(),
            self.total().to_string(),
            format!("{:.1}", self.total() as f64 / 1024.0),
        ]);
        format!(
            "{}fits 256 KiB FRAM: {} (headroom {} bytes)\n",
            t.render(),
            if self.fits() { "yes" } else { "NO" },
            self.headroom()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Params};

    fn map_for(name: &str) -> MemMap {
        let def = zoo(name);
        let q = QModel::quantize(&def, &Params::random(&def, 1));
        MemMap::plan(&q)
    }

    #[test]
    fn mcu_models_fit_fram() {
        // Paper §3.3: mnist/cifar/kws run on the MSP430 without swapping.
        for name in ["mnist", "cifar", "kws"] {
            let m = map_for(name);
            assert!(m.fits(), "{name} does not fit: {}", m.report());
        }
    }

    #[test]
    fn widar_exceeds_fram() {
        // Paper §3.3: widar is "evaluated only on desktop-class
        // platforms" due to size — the planner must agree.
        let m = map_for("widar");
        assert!(!m.fits(), "widar unexpectedly fits: {}", m.report());
    }

    #[test]
    fn group_thresholds_increase_footprint() {
        let def = zoo("mnist");
        let q = QModel::quantize(&def, &Params::random(&def, 2));
        let base = MemMap::plan(&q).total();
        let th = crate::pruning::Thresholds {
            per_layer: vec![0.1; 3],
            groups: vec![vec![0.1; 6], vec![0.1; 16], Vec::new()],
        };
        let qg = q.with_thresholds(&th);
        let with_groups = MemMap::plan(&qg).total();
        assert_eq!(with_groups - base, 4 * (6 + 16));
    }

    #[test]
    fn report_renders_total() {
        let m = map_for("mnist");
        let r = m.report();
        assert!(r.contains("TOTAL"));
        assert!(r.contains("fits 256 KiB FRAM: yes"));
    }
}
