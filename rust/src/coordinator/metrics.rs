//! Serving metrics: request counters, latency percentiles, aggregate
//! MAC/energy statistics. Shared across workers behind a mutex (the
//! request path touches it once per request, far from contention at
//! simulator throughputs).

use std::sync::Mutex;

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default, Clone)]
struct Inner {
    served: u64,
    batches: u64,
    latencies_us: Vec<u64>,
    mac_skipped_sum: f64,
    energy_mj_sum: f64,
    mcu_secs_sum: f64,
}

/// Snapshot for reporting.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub served: u64,
    pub batches: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_batch: f64,
    pub mean_mac_skipped: f64,
    pub mean_energy_mj: f64,
    pub mean_mcu_secs: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        let _ = n;
    }

    pub fn record_request(&self, latency_us: u64, mac_skipped: f64, energy_mj: f64, mcu_secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.served += 1;
        g.latencies_us.push(latency_us);
        g.mac_skipped_sum += mac_skipped;
        g.energy_mj_sum += energy_mj;
        g.mcu_secs_sum += mcu_secs;
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((p / 100.0) * (lat.len() as f64 - 1.0)).round() as usize]
            }
        };
        let served = g.served.max(1) as f64;
        Snapshot {
            served: g.served,
            batches: g.batches,
            p50_us: pct(50.0),
            p95_us: pct(95.0),
            p99_us: pct(99.0),
            mean_batch: g.served as f64 / g.batches.max(1) as f64,
            mean_mac_skipped: g.mac_skipped_sum / served,
            mean_energy_mj: g.energy_mj_sum / served,
            mean_mcu_secs: g.mcu_secs_sum / served,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_request(i, 0.5, 0.1, 0.01);
        }
        m.record_batch(100);
        let s = m.snapshot();
        assert_eq!(s.served, 100);
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us);
        assert!((s.mean_mac_skipped - 0.5).abs() < 1e-9);
        assert_eq!(s.mean_batch, 100.0);
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.served, 0);
        assert_eq!(s.p99_us, 0);
    }
}
