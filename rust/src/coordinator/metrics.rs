//! Serving metrics: request counters, latency percentiles, aggregate
//! MAC/energy statistics. Shared across workers behind a mutex (the
//! request path touches it once per request, far from contention at
//! simulator throughputs).
//!
//! Queue wait (enqueue → dequeue) and service time (dequeue → response)
//! are recorded separately: a shard-balance regression in the
//! work-stealing pool shows up as queue percentiles growing while
//! service percentiles stay flat, which the total alone cannot reveal.
//!
//! Percentiles are computed over a bounded sliding window
//! ([`TIMING_WINDOW`] most recent requests) so a long-lived server's
//! metrics stay O(1) in memory and `snapshot` stays O(window) however
//! many requests have been served; the counters and means cover the
//! full lifetime.

use std::sync::Mutex;

/// Requests retained for percentile computation (per timing series).
pub const TIMING_WINDOW: usize = 1 << 16;

/// Fixed-capacity ring of the most recent timing samples.
#[derive(Debug, Default, Clone)]
struct TimingWindow {
    buf: Vec<u64>,
    next: usize,
}

impl TimingWindow {
    fn push(&mut self, v: u64) {
        if self.buf.len() < TIMING_WINDOW {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % TIMING_WINDOW;
        }
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default, Clone)]
struct Inner {
    served: u64,
    batches: u64,
    /// Paired rings: index i of both windows belongs to the same
    /// request (pushed together under the mutex), so total latency is
    /// derived per slot instead of stored a third time.
    queue_us: TimingWindow,
    service_us: TimingWindow,
    mac_skipped_sum: f64,
    energy_mj_sum: f64,
    mcu_secs_sum: f64,
    // Streamed-serving counters (zero when only the in-process API is
    // used): admission/lifecycle outcomes plus session accounting.
    /// Requests bounced by a full per-session in-flight window.
    rejected: u64,
    /// Requests whose deadline passed before completion.
    expired: u64,
    /// Requests cancelled by their client.
    cancelled: u64,
    /// Dead (cancelled/expired) samples dropped by workers at dequeue
    /// — work that never occupied a shard.
    dropped: u64,
    /// Window-overflow requests parked for admission on credit return
    /// (instead of rejected) — nonzero only with a park queue enabled.
    parked: u64,
    sessions_opened: u64,
    sessions_closed: u64,
    /// Requests currently admitted and not yet finished, across all
    /// sessions (gauge).
    inflight: i64,
    /// Latest per-shard queued-cost gauges (estimated MACs awaiting
    /// service per worker deque), published by
    /// `Coordinator::publish_shard_costs` — the cost-weighted
    /// placement imbalance view.
    shard_costs: Vec<u64>,
    /// Background plan compiles queued or in flight on the governor's
    /// compile thread (gauge; zero without an adaptive governor).
    bg_pending: u64,
    /// Background plan compiles completed since governor install.
    bg_compiled: u64,
    /// Background compiles that upgraded the live plan slot.
    bg_upgrades: u64,
    /// Worker panics caught by the panic supervisor.
    worker_panics: u64,
    /// Worker loops re-entered (with fresh scratch) after a caught
    /// panic.
    respawns: u64,
    /// Requests terminated with a `Failed` outcome because a worker
    /// panicked while executing one of their samples.
    failed: u64,
}

/// Snapshot for reporting.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    /// Samples completed `Ok` since start.
    pub served: u64,
    /// Worker batches executed.
    pub batches: u64,
    /// Total latency (queue + service) percentiles.
    pub p50_us: u64,
    /// 95th-percentile total latency (µs).
    pub p95_us: u64,
    /// 99th-percentile total latency (µs).
    pub p99_us: u64,
    /// Queue-wait percentiles (enqueue → worker pickup).
    pub queue_p50_us: u64,
    /// 95th-percentile queue wait (µs).
    pub queue_p95_us: u64,
    /// 99th-percentile queue wait (µs).
    pub queue_p99_us: u64,
    /// Service-time percentiles (worker pickup → response).
    pub service_p50_us: u64,
    /// 95th-percentile service time (µs).
    pub service_p95_us: u64,
    /// 99th-percentile service time (µs).
    pub service_p99_us: u64,
    /// Mean executed batch size.
    pub mean_batch: f64,
    /// Mean fraction of MACs skipped per sample.
    pub mean_mac_skipped: f64,
    /// Mean modeled energy per sample (mJ).
    pub mean_energy_mj: f64,
    /// Mean modeled MCU seconds per sample.
    pub mean_mcu_secs: f64,
    /// Streamed-serving outcomes (see the matching `Inner` fields).
    pub rejected: u64,
    /// Requests that hit their deadline.
    pub expired: u64,
    /// Requests cancelled by the client.
    pub cancelled: u64,
    /// Queued samples tombstone-dropped at dequeue.
    pub dropped: u64,
    /// Requests admitted via the park queue.
    pub parked: u64,
    /// Sessions ever opened.
    pub sessions_opened: u64,
    /// Sessions closed.
    pub sessions_closed: u64,
    /// Admitted-but-unfinished request gauge.
    pub inflight: i64,
    /// Latest per-shard queued-cost gauges (empty until published).
    pub shard_costs: Vec<u64>,
    /// Governor background-compile gauges/counters (see `Inner`).
    pub bg_pending: u64,
    /// Background compiles completed.
    pub bg_compiled: u64,
    /// Background compiles that upgraded the live plan.
    pub bg_upgrades: u64,
    /// Self-healing counters (see `Inner`).
    pub worker_panics: u64,
    /// Workers respawned after a contained panic.
    pub respawns: u64,
    /// Requests that reached the `Failed` terminal outcome.
    pub failed: u64,
}

fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        0
    } else {
        sorted[((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize]
    }
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Count one executed worker batch.
    pub fn record_batch(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        let _ = n;
    }

    /// Record one finished request: queue wait and service time in µs,
    /// plus the modeled MCU statistics.
    pub fn record_request(
        &self,
        queue_us: u64,
        service_us: u64,
        mac_skipped: f64,
        energy_mj: f64,
        mcu_secs: f64,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.served += 1;
        g.queue_us.push(queue_us);
        g.service_us.push(service_us);
        g.mac_skipped_sum += mac_skipped;
        g.energy_mj_sum += energy_mj;
        g.mcu_secs_sum += mcu_secs;
    }

    /// A request bounced by session backpressure (in-flight window full).
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// A request whose deadline expired before completion.
    pub fn record_expired(&self) {
        self.inner.lock().unwrap().expired += 1;
    }

    /// A request cancelled by its client.
    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    /// A dead sample dropped by a worker at dequeue (no inference run).
    pub fn record_dropped(&self) {
        self.inner.lock().unwrap().dropped += 1;
    }

    /// A window-overflow request parked for later admission.
    pub fn record_parked(&self) {
        self.inner.lock().unwrap().parked += 1;
    }

    /// Publish the latest per-shard queued-cost gauges (replaces the
    /// previous set; gauges, not counters).
    pub fn record_shard_costs(&self, costs: &[u64]) {
        self.inner.lock().unwrap().shard_costs = costs.to_vec();
    }

    /// Publish the governor's background-compile state (replace-style:
    /// the governor owns the true counters and mirrors them here so
    /// serve snapshots can assert misses never block the swap path).
    pub fn record_bg_compile(&self, pending: u64, compiled: u64, upgrades: u64) {
        let mut g = self.inner.lock().unwrap();
        g.bg_pending = pending;
        g.bg_compiled = compiled;
        g.bg_upgrades = upgrades;
    }

    /// A worker panic was caught by the supervisor.
    pub fn record_worker_panic(&self) {
        self.inner.lock().unwrap().worker_panics += 1;
    }

    /// The supervisor re-entered a worker loop after a caught panic.
    pub fn record_respawn(&self) {
        self.inner.lock().unwrap().respawns += 1;
    }

    /// A request reached the `Failed` terminal outcome (worker panic
    /// while one of its samples was executing).
    pub fn record_failed(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    /// Count one accepted session.
    pub fn session_opened(&self) {
        self.inner.lock().unwrap().sessions_opened += 1;
    }

    /// Count one closed session.
    pub fn session_closed(&self) {
        self.inner.lock().unwrap().sessions_closed += 1;
    }

    /// Adjust the admitted-but-unfinished request gauge (`+1` on
    /// admission, `-1` on completion/cancel/expiry).
    pub fn inflight_delta(&self, d: i64) {
        self.inner.lock().unwrap().inflight += d;
    }

    /// Consistent copy of all counters and percentile estimates.
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut que = g.queue_us.buf.clone();
        let mut svc = g.service_us.buf.clone();
        // Same slot of both rings = same request, so per-request total
        // latency is the element-wise sum.
        let mut lat: Vec<u64> =
            que.iter().zip(svc.iter()).map(|(a, b)| a + b).collect();
        lat.sort_unstable();
        que.sort_unstable();
        svc.sort_unstable();
        let served = g.served.max(1) as f64;
        Snapshot {
            served: g.served,
            batches: g.batches,
            p50_us: percentile(&lat, 50.0),
            p95_us: percentile(&lat, 95.0),
            p99_us: percentile(&lat, 99.0),
            queue_p50_us: percentile(&que, 50.0),
            queue_p95_us: percentile(&que, 95.0),
            queue_p99_us: percentile(&que, 99.0),
            service_p50_us: percentile(&svc, 50.0),
            service_p95_us: percentile(&svc, 95.0),
            service_p99_us: percentile(&svc, 99.0),
            mean_batch: g.served as f64 / g.batches.max(1) as f64,
            mean_mac_skipped: g.mac_skipped_sum / served,
            mean_energy_mj: g.energy_mj_sum / served,
            mean_mcu_secs: g.mcu_secs_sum / served,
            rejected: g.rejected,
            expired: g.expired,
            cancelled: g.cancelled,
            dropped: g.dropped,
            parked: g.parked,
            sessions_opened: g.sessions_opened,
            sessions_closed: g.sessions_closed,
            inflight: g.inflight,
            shard_costs: g.shard_costs.clone(),
            bg_pending: g.bg_pending,
            bg_compiled: g.bg_compiled,
            bg_upgrades: g.bg_upgrades,
            worker_panics: g.worker_panics,
            respawns: g.respawns,
            failed: g.failed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_request(i, 2 * i, 0.5, 0.1, 0.01);
        }
        m.record_batch(100);
        let s = m.snapshot();
        assert_eq!(s.served, 100);
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us);
        assert!(s.queue_p50_us <= s.queue_p99_us);
        assert!(s.service_p50_us <= s.service_p99_us);
        assert!((s.mean_mac_skipped - 0.5).abs() < 1e-9);
        assert_eq!(s.mean_batch, 100.0);
    }

    #[test]
    fn queue_and_service_split_total() {
        let m = Metrics::new();
        m.record_request(10, 30, 0.0, 0.0, 0.0);
        let s = m.snapshot();
        assert_eq!(s.queue_p50_us, 10);
        assert_eq!(s.service_p50_us, 30);
        assert_eq!(s.p50_us, 40);
    }

    #[test]
    fn timing_window_is_bounded_and_keeps_recent_samples() {
        let mut w = TimingWindow::default();
        for i in 0..(TIMING_WINDOW as u64 + 100) {
            w.push(i);
        }
        assert_eq!(w.buf.len(), TIMING_WINDOW);
        // the 100 oldest samples were overwritten by the newest 100
        assert!(w.buf.contains(&(TIMING_WINDOW as u64 + 99)));
        assert!(!w.buf.contains(&0));
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.served, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.queue_p99_us, 0);
        assert_eq!(s.service_p99_us, 0);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.inflight, 0);
    }

    #[test]
    fn session_counters_roundtrip() {
        let m = Metrics::new();
        m.session_opened();
        m.inflight_delta(2);
        m.record_rejected();
        m.record_expired();
        m.record_cancelled();
        m.record_dropped();
        m.record_dropped();
        m.record_parked();
        m.inflight_delta(-1);
        m.session_closed();
        let s = m.snapshot();
        assert_eq!(
            (s.rejected, s.expired, s.cancelled, s.dropped, s.parked),
            (1, 1, 1, 2, 1)
        );
        assert_eq!((s.sessions_opened, s.sessions_closed), (1, 1));
        assert_eq!(s.inflight, 1);
    }

    #[test]
    fn bg_compile_gauges_replace_not_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.bg_pending, s.bg_compiled, s.bg_upgrades), (0, 0, 0));
        m.record_bg_compile(2, 5, 3);
        let s = m.snapshot();
        assert_eq!((s.bg_pending, s.bg_compiled, s.bg_upgrades), (2, 5, 3));
        m.record_bg_compile(0, 6, 4);
        let s = m.snapshot();
        assert_eq!((s.bg_pending, s.bg_compiled, s.bg_upgrades), (0, 6, 4), "must replace");
    }

    #[test]
    fn self_healing_counters_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.worker_panics, s.respawns, s.failed), (0, 0, 0));
        m.record_worker_panic();
        m.record_failed();
        m.record_respawn();
        m.record_worker_panic();
        m.record_respawn();
        let s = m.snapshot();
        assert_eq!((s.worker_panics, s.respawns, s.failed), (2, 2, 1));
    }

    #[test]
    fn shard_cost_gauges_replace_not_accumulate() {
        let m = Metrics::new();
        assert!(m.snapshot().shard_costs.is_empty());
        m.record_shard_costs(&[10, 20, 30]);
        assert_eq!(m.snapshot().shard_costs, vec![10, 20, 30]);
        m.record_shard_costs(&[5, 0, 7]);
        assert_eq!(m.snapshot().shard_costs, vec![5, 0, 7], "gauges must replace");
    }
}
