//! Serving metrics: request counters, latency percentiles, aggregate
//! MAC/energy statistics.
//!
//! Queue wait (enqueue → dequeue) and service time (dequeue → response)
//! are recorded separately: a shard-balance regression in the
//! work-stealing pool shows up as queue percentiles growing while
//! service percentiles stay flat, which the total alone cannot reveal.
//!
//! Percentiles come from fixed-size log-bucketed histograms
//! ([`crate::obs::hist`]): constant memory however long the server
//! lives, O(buckets) snapshots, and shard-local recording merged at
//! snapshot time — the raw-sample `TimingWindow` rings this replaced
//! were O(window) memory per series and sorted on every snapshot.
//!
//! # Consistency guarantee
//!
//! All **counters, sums, and gauges** live under one mutex and are
//! copied in a single critical section, so any snapshot is a mutually
//! consistent cut of them (`served` can never lag `batches`, panic and
//! respawn counts move together, and so on). The **histograms**
//! (latency/keep-ratio/MAC percentiles) are recorded *outside* that
//! mutex on sharded locks for concurrency; their sample populations
//! may therefore lead or lag the counter cut by the handful of
//! requests mid-record at snapshot time. Percentiles are statistical
//! summaries, so this skew is harmless — but it is the guarantee
//! actually provided, hence documented.

use std::sync::Mutex;

use crate::obs::hist::{Histogram, ShardedHistogram, RATIO_SCALE};

/// Lock shards per histogram series (worker-count scale).
const HIST_SHARDS: usize = 4;

/// Aggregated serving metrics.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// Queue-wait histogram (enqueue → worker pickup), µs.
    queue_us: ShardedHistogram,
    /// Service-time histogram (worker pickup → response), µs.
    service_us: ShardedHistogram,
    /// Total latency histogram, µs. Recorded as `queue + service` of
    /// the same request at completion, so its percentiles reflect true
    /// per-request totals (not an after-the-fact convolution).
    total_us: ShardedHistogram,
    /// Keep-ratio histogram, fixed point at [`RATIO_SCALE`].
    keep_ratio: ShardedHistogram,
    /// Executed-MACs-per-request histogram.
    macs: ShardedHistogram,
    /// Per-model, per-layer (executed, skipped) MAC accumulators,
    /// populated by workers only when observability is on.
    layers: Mutex<Vec<Vec<(u64, u64)>>>,
    /// Per-model (tenant) serving statistics, grown on first sight of
    /// a model id. The SLO engine takes monotone cuts of these to
    /// compute burn rates, so everything here is cumulative.
    tenants: Mutex<Vec<TenantMetrics>>,
}

/// Cumulative per-model (tenant) serving statistics: the inputs to
/// per-tenant SLO burn-rate tracking and per-tenant exposition. All
/// fields grow monotonically except the `inflight` gauge.
#[derive(Debug, Default, Clone)]
pub struct TenantMetrics {
    /// Total (queue + service) latency histogram for this tenant, µs.
    pub latency_us: Histogram,
    /// Keep-ratio histogram, fixed point at [`RATIO_SCALE`].
    pub keep: Histogram,
    /// Requests completed `Ok` for this tenant.
    pub served: u64,
    /// Requests ending in `Error`/`Failed` for this tenant.
    pub errors: u64,
    /// Requests refused with `Throttled` by the tenant's admission
    /// policy.
    pub throttled: u64,
    /// Admitted-but-unfinished requests for this tenant (gauge).
    pub inflight: i64,
}

/// One monotone cut of a tenant's objective-violation counters, taken
/// under the tenant lock at SLO-tick time. Two cuts subtract to give
/// exact windowed violation counts without storing histograms per
/// window.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TenantCut {
    /// Requests completed `Ok` so far.
    pub served: u64,
    /// Requests ended in `Error`/`Failed` so far.
    pub errors: u64,
    /// Completed requests whose total latency exceeded the objective.
    pub lat_violations: u64,
    /// Completed requests whose keep ratio fell below the floor.
    pub keep_violations: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            queue_us: ShardedHistogram::new(HIST_SHARDS),
            service_us: ShardedHistogram::new(HIST_SHARDS),
            total_us: ShardedHistogram::new(HIST_SHARDS),
            keep_ratio: ShardedHistogram::new(HIST_SHARDS),
            macs: ShardedHistogram::new(HIST_SHARDS),
            layers: Mutex::new(Vec::new()),
            tenants: Mutex::new(Vec::new()),
        }
    }
}

#[derive(Debug, Default, Clone)]
struct Inner {
    served: u64,
    batches: u64,
    mac_skipped_sum: f64,
    energy_mj_sum: f64,
    mcu_secs_sum: f64,
    // Streamed-serving counters (zero when only the in-process API is
    // used): admission/lifecycle outcomes plus session accounting.
    /// Requests bounced by a full per-session in-flight window.
    rejected: u64,
    /// Requests whose deadline passed before completion.
    expired: u64,
    /// Requests cancelled by their client.
    cancelled: u64,
    /// Dead (cancelled/expired) samples dropped by workers at dequeue
    /// — work that never occupied a shard.
    dropped: u64,
    /// Window-overflow requests parked for admission on credit return
    /// (instead of rejected) — nonzero only with a park queue enabled.
    parked: u64,
    sessions_opened: u64,
    sessions_closed: u64,
    /// Requests currently admitted and not yet finished, across all
    /// sessions (gauge).
    inflight: i64,
    /// Latest per-shard queued-cost gauges (estimated MACs awaiting
    /// service per worker deque), published by
    /// `Coordinator::publish_shard_costs` — the cost-weighted
    /// placement imbalance view.
    shard_costs: Vec<u64>,
    /// Background plan compiles queued or in flight on the governor's
    /// compile thread (gauge; zero without an adaptive governor).
    bg_pending: u64,
    /// Background plan compiles completed since governor install.
    bg_compiled: u64,
    /// Background compiles that upgraded the live plan slot.
    bg_upgrades: u64,
    /// Worker panics caught by the panic supervisor.
    worker_panics: u64,
    /// Worker loops re-entered (with fresh scratch) after a caught
    /// panic.
    respawns: u64,
    /// Requests terminated with a `Failed` outcome because a worker
    /// panicked while executing one of their samples.
    failed: u64,
}

/// Snapshot for reporting.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    /// Samples completed `Ok` since start.
    pub served: u64,
    /// Worker batches executed.
    pub batches: u64,
    /// Total latency (queue + service) percentiles.
    pub p50_us: u64,
    /// 95th-percentile total latency (µs).
    pub p95_us: u64,
    /// 99th-percentile total latency (µs).
    pub p99_us: u64,
    /// Queue-wait percentiles (enqueue → worker pickup).
    pub queue_p50_us: u64,
    /// 95th-percentile queue wait (µs).
    pub queue_p95_us: u64,
    /// 99th-percentile queue wait (µs).
    pub queue_p99_us: u64,
    /// Service-time percentiles (worker pickup → response).
    pub service_p50_us: u64,
    /// 95th-percentile service time (µs).
    pub service_p95_us: u64,
    /// 99th-percentile service time (µs).
    pub service_p99_us: u64,
    /// Keep-ratio percentiles (fraction of MACs executed, 0..=1).
    pub keep_p50: f64,
    /// 95th-percentile keep ratio.
    pub keep_p95: f64,
    /// Executed-MACs-per-request percentiles.
    pub mac_p50: u64,
    /// 99th-percentile executed MACs per request.
    pub mac_p99: u64,
    /// Mean executed batch size.
    pub mean_batch: f64,
    /// Mean fraction of MACs skipped per sample.
    pub mean_mac_skipped: f64,
    /// Mean modeled energy per sample (mJ).
    pub mean_energy_mj: f64,
    /// Mean modeled MCU seconds per sample.
    pub mean_mcu_secs: f64,
    /// Streamed-serving outcomes (see the matching `Inner` fields).
    pub rejected: u64,
    /// Requests that hit their deadline.
    pub expired: u64,
    /// Requests cancelled by the client.
    pub cancelled: u64,
    /// Queued samples tombstone-dropped at dequeue.
    pub dropped: u64,
    /// Requests admitted via the park queue.
    pub parked: u64,
    /// Sessions ever opened.
    pub sessions_opened: u64,
    /// Sessions closed.
    pub sessions_closed: u64,
    /// Admitted-but-unfinished request gauge.
    pub inflight: i64,
    /// Latest per-shard queued-cost gauges (empty until published).
    pub shard_costs: Vec<u64>,
    /// Governor background-compile gauges/counters (see `Inner`).
    pub bg_pending: u64,
    /// Background compiles completed.
    pub bg_compiled: u64,
    /// Background compiles that upgraded the live plan.
    pub bg_upgrades: u64,
    /// Self-healing counters (see `Inner`).
    pub worker_panics: u64,
    /// Workers respawned after a contained panic.
    pub respawns: u64,
    /// Requests that reached the `Failed` terminal outcome.
    pub failed: u64,
}

/// Grow-on-first-sight accessor for a model's tenant row (mirrors the
/// `layers` table's growth discipline).
fn tenant_entry(rows: &mut Vec<TenantMetrics>, model: usize) -> &mut TenantMetrics {
    if rows.len() <= model {
        rows.resize_with(model + 1, TenantMetrics::default);
    }
    &mut rows[model]
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Count one executed worker batch.
    pub fn record_batch(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        let _ = n;
    }

    /// Record one finished request for model `model`: queue wait and
    /// service time in µs, the modeled MCU statistics, and the
    /// executed MAC count. Also lands the latency/keep samples in the
    /// per-tenant tables the SLO engine reads.
    pub fn record_request(
        &self,
        model: usize,
        queue_us: u64,
        service_us: u64,
        mac_skipped: f64,
        energy_mj: f64,
        mcu_secs: f64,
        macs: u64,
    ) {
        {
            let mut g = self.inner.lock().unwrap();
            g.served += 1;
            g.mac_skipped_sum += mac_skipped;
            g.energy_mj_sum += energy_mj;
            g.mcu_secs_sum += mcu_secs;
        }
        // Histograms record outside the counter mutex (see the module
        // docs' consistency note).
        let total = queue_us + service_us;
        self.queue_us.record(queue_us);
        self.service_us.record(service_us);
        self.total_us.record(total);
        let keep = ((1.0 - mac_skipped).clamp(0.0, 1.0) * RATIO_SCALE as f64).round() as u64;
        self.keep_ratio.record(keep);
        self.macs.record(macs);
        {
            let mut g = self.tenants.lock().unwrap();
            let t = tenant_entry(&mut g, model);
            t.served += 1;
            t.latency_us.record(total);
            t.keep.record(keep);
        }
    }

    /// Accumulate one request's per-layer (executed, skipped) MAC
    /// counts for model `model`. Called by workers only when
    /// observability is enabled; grows the tables on first sight of a
    /// model/layer.
    pub fn record_layers(&self, model: usize, kept: &[u64], skipped: &[u64]) {
        let mut g = self.layers.lock().unwrap();
        if g.len() <= model {
            g.resize(model + 1, Vec::new());
        }
        let rows = &mut g[model];
        if rows.len() < kept.len() {
            rows.resize(kept.len(), (0, 0));
        }
        for (i, row) in rows.iter_mut().enumerate().take(kept.len()) {
            row.0 += kept[i];
            row.1 += skipped.get(i).copied().unwrap_or(0);
        }
    }

    /// Per-model, per-layer cumulative (executed, skipped) MAC totals.
    /// Empty until a worker with observability enabled has served a
    /// request.
    pub fn layer_totals(&self) -> Vec<Vec<(u64, u64)>> {
        self.layers.lock().unwrap().clone()
    }

    /// A request bounced by session backpressure (in-flight window full).
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// A request whose deadline expired before completion.
    pub fn record_expired(&self) {
        self.inner.lock().unwrap().expired += 1;
    }

    /// A request cancelled by its client.
    pub fn record_cancelled(&self) {
        self.inner.lock().unwrap().cancelled += 1;
    }

    /// A dead sample dropped by a worker at dequeue (no inference run).
    pub fn record_dropped(&self) {
        self.inner.lock().unwrap().dropped += 1;
    }

    /// A window-overflow request parked for later admission.
    pub fn record_parked(&self) {
        self.inner.lock().unwrap().parked += 1;
    }

    /// Publish the latest per-shard queued-cost gauges (replaces the
    /// previous set; gauges, not counters).
    pub fn record_shard_costs(&self, costs: &[u64]) {
        self.inner.lock().unwrap().shard_costs = costs.to_vec();
    }

    /// Publish the governor's background-compile state (replace-style:
    /// the governor owns the true counters and mirrors them here so
    /// serve snapshots can assert misses never block the swap path).
    pub fn record_bg_compile(&self, pending: u64, compiled: u64, upgrades: u64) {
        let mut g = self.inner.lock().unwrap();
        g.bg_pending = pending;
        g.bg_compiled = compiled;
        g.bg_upgrades = upgrades;
    }

    /// A worker panic was caught by the supervisor.
    pub fn record_worker_panic(&self) {
        self.inner.lock().unwrap().worker_panics += 1;
    }

    /// The supervisor re-entered a worker loop after a caught panic.
    pub fn record_respawn(&self) {
        self.inner.lock().unwrap().respawns += 1;
    }

    /// A request reached the `Failed` terminal outcome (worker panic
    /// while one of its samples was executing).
    pub fn record_failed(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    /// Count one accepted session.
    pub fn session_opened(&self) {
        self.inner.lock().unwrap().sessions_opened += 1;
    }

    /// Count one closed session.
    pub fn session_closed(&self) {
        self.inner.lock().unwrap().sessions_closed += 1;
    }

    /// Adjust the admitted-but-unfinished request gauge (`+1` on
    /// admission, `-1` on completion/cancel/expiry).
    pub fn inflight_delta(&self, d: i64) {
        self.inner.lock().unwrap().inflight += d;
    }

    /// Count one request for model `model` ending in an error outcome
    /// (`Error`/`Failed`) — feeds the tenant's error-rate burn.
    pub fn record_tenant_error(&self, model: usize) {
        let mut g = self.tenants.lock().unwrap();
        tenant_entry(&mut g, model).errors += 1;
    }

    /// Count one request refused with `Throttled` by model `model`'s
    /// admission policy.
    pub fn record_tenant_throttled(&self, model: usize) {
        let mut g = self.tenants.lock().unwrap();
        tenant_entry(&mut g, model).throttled += 1;
    }

    /// Adjust model `model`'s admitted-but-unfinished request gauge
    /// (the value the tenant's inflight admission quota is enforced
    /// against).
    pub fn tenant_inflight_delta(&self, model: usize, d: i64) {
        let mut g = self.tenants.lock().unwrap();
        tenant_entry(&mut g, model).inflight += d;
    }

    /// Current inflight gauge for model `model` (0 if never seen).
    pub fn tenant_inflight(&self, model: usize) -> i64 {
        self.tenants.lock().unwrap().get(model).map_or(0, |t| t.inflight)
    }

    /// Clone of every tenant's cumulative statistics (index = model
    /// id; empty until a request completes or a tenant counter fires).
    pub fn tenant_snapshot(&self) -> Vec<TenantMetrics> {
        self.tenants.lock().unwrap().clone()
    }

    /// One monotone cut of model `model`'s objective-violation
    /// counters against the given objectives: latency objective in µs
    /// (`u64::MAX` disables) and keep floor in [`RATIO_SCALE`] fixed
    /// point (`0` disables). Computed under the tenant lock without
    /// cloning the histograms; `None` if the model has never been
    /// seen.
    pub fn tenant_cut(&self, model: usize, lat_obj_us: u64, keep_floor: u64) -> Option<TenantCut> {
        let g = self.tenants.lock().unwrap();
        let t = g.get(model)?;
        let lat_violations = t.latency_us.count() - t.latency_us.count_le(lat_obj_us);
        let keep_violations = if keep_floor == 0 {
            0
        } else {
            t.keep.count_le(keep_floor.saturating_sub(1))
        };
        Some(TenantCut { served: t.served, errors: t.errors, lat_violations, keep_violations })
    }

    /// Merged view of the global total-latency histogram (µs), for the
    /// native `le`-bucket exposition.
    pub fn latency_hist(&self) -> Histogram {
        self.total_us.merged()
    }

    /// Merged view of the global keep-ratio histogram ([`RATIO_SCALE`]
    /// fixed point), for the native `le`-bucket exposition.
    pub fn keep_hist(&self) -> Histogram {
        self.keep_ratio.merged()
    }

    /// Snapshot of all counters and percentile estimates. Counters,
    /// sums, and gauges are one consistent cut (copied under a single
    /// lock); histogram percentiles may lead or lag that cut by
    /// requests mid-record (see the module docs).
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap().clone();
        let lat = self.total_us.merged();
        let que = self.queue_us.merged();
        let svc = self.service_us.merged();
        let keep = self.keep_ratio.merged();
        let macs = self.macs.merged();
        let served = g.served.max(1) as f64;
        Snapshot {
            served: g.served,
            batches: g.batches,
            p50_us: lat.percentile(50.0),
            p95_us: lat.percentile(95.0),
            p99_us: lat.percentile(99.0),
            queue_p50_us: que.percentile(50.0),
            queue_p95_us: que.percentile(95.0),
            queue_p99_us: que.percentile(99.0),
            service_p50_us: svc.percentile(50.0),
            service_p95_us: svc.percentile(95.0),
            service_p99_us: svc.percentile(99.0),
            keep_p50: keep.percentile(50.0) as f64 / RATIO_SCALE as f64,
            keep_p95: keep.percentile(95.0) as f64 / RATIO_SCALE as f64,
            mac_p50: macs.percentile(50.0),
            mac_p99: macs.percentile(99.0),
            mean_batch: g.served as f64 / g.batches.max(1) as f64,
            mean_mac_skipped: g.mac_skipped_sum / served,
            mean_energy_mj: g.energy_mj_sum / served,
            mean_mcu_secs: g.mcu_secs_sum / served,
            rejected: g.rejected,
            expired: g.expired,
            cancelled: g.cancelled,
            dropped: g.dropped,
            parked: g.parked,
            sessions_opened: g.sessions_opened,
            sessions_closed: g.sessions_closed,
            inflight: g.inflight,
            shard_costs: g.shard_costs,
            bg_pending: g.bg_pending,
            bg_compiled: g.bg_compiled,
            bg_upgrades: g.bg_upgrades,
            worker_panics: g.worker_panics,
            respawns: g.respawns,
            failed: g.failed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_request(0, i, 2 * i, 0.5, 0.1, 0.01, 1024);
        }
        m.record_batch(100);
        let s = m.snapshot();
        assert_eq!(s.served, 100);
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us);
        assert!(s.queue_p50_us <= s.queue_p99_us);
        assert!(s.service_p50_us <= s.service_p99_us);
        assert!((s.mean_mac_skipped - 0.5).abs() < 1e-9);
        assert_eq!(s.mean_batch, 100.0);
        assert!((s.keep_p50 - 0.5).abs() < 1e-3, "keep_p50 = {}", s.keep_p50);
        assert_eq!(s.mac_p50, 1024, "powers of two are exactly representable");
    }

    #[test]
    fn queue_and_service_split_total() {
        let m = Metrics::new();
        m.record_request(0, 10, 30, 0.0, 0.0, 0.0, 0);
        let s = m.snapshot();
        assert_eq!(s.queue_p50_us, 10);
        assert_eq!(s.service_p50_us, 30);
        assert_eq!(s.p50_us, 40);
        assert!((s.keep_p50 - 1.0).abs() < 1e-9, "0 skipped = keep ratio 1");
    }

    #[test]
    fn histogram_memory_is_bounded() {
        // The raw-sample windows this replaced held 1<<16 u64s per
        // series; the histograms are constant-size however many
        // requests are recorded. Record far past the old window and
        // check snapshots still see the full population.
        let m = Metrics::new();
        let n = (1u64 << 17) + 100;
        for i in 0..n {
            m.record_request(0, i % 1000, 50, 0.0, 0.0, 0.0, 0);
        }
        let s = m.snapshot();
        assert_eq!(s.served, n);
        assert_eq!(s.service_p50_us, 50);
        assert!(s.queue_p99_us <= 1000);
    }

    #[test]
    fn empty_snapshot_safe() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.served, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.queue_p99_us, 0);
        assert_eq!(s.service_p99_us, 0);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.inflight, 0);
        assert_eq!(s.mac_p99, 0);
    }

    #[test]
    fn session_counters_roundtrip() {
        let m = Metrics::new();
        m.session_opened();
        m.inflight_delta(2);
        m.record_rejected();
        m.record_expired();
        m.record_cancelled();
        m.record_dropped();
        m.record_dropped();
        m.record_parked();
        m.inflight_delta(-1);
        m.session_closed();
        let s = m.snapshot();
        assert_eq!(
            (s.rejected, s.expired, s.cancelled, s.dropped, s.parked),
            (1, 1, 1, 2, 1)
        );
        assert_eq!((s.sessions_opened, s.sessions_closed), (1, 1));
        assert_eq!(s.inflight, 1);
    }

    #[test]
    fn bg_compile_gauges_replace_not_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.bg_pending, s.bg_compiled, s.bg_upgrades), (0, 0, 0));
        m.record_bg_compile(2, 5, 3);
        let s = m.snapshot();
        assert_eq!((s.bg_pending, s.bg_compiled, s.bg_upgrades), (2, 5, 3));
        m.record_bg_compile(0, 6, 4);
        let s = m.snapshot();
        assert_eq!((s.bg_pending, s.bg_compiled, s.bg_upgrades), (0, 6, 4), "must replace");
    }

    #[test]
    fn self_healing_counters_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.worker_panics, s.respawns, s.failed), (0, 0, 0));
        m.record_worker_panic();
        m.record_failed();
        m.record_respawn();
        m.record_worker_panic();
        m.record_respawn();
        let s = m.snapshot();
        assert_eq!((s.worker_panics, s.respawns, s.failed), (2, 2, 1));
    }

    #[test]
    fn shard_cost_gauges_replace_not_accumulate() {
        let m = Metrics::new();
        assert!(m.snapshot().shard_costs.is_empty());
        m.record_shard_costs(&[10, 20, 30]);
        assert_eq!(m.snapshot().shard_costs, vec![10, 20, 30]);
        m.record_shard_costs(&[5, 0, 7]);
        assert_eq!(m.snapshot().shard_costs, vec![5, 0, 7], "gauges must replace");
    }

    #[test]
    fn tenant_tables_accumulate_outcomes_and_inflight() {
        let m = Metrics::new();
        assert!(m.tenant_snapshot().is_empty());
        m.record_request(1, 10, 30, 0.0, 0.0, 0.0, 0);
        m.record_request(1, 10, 30, 0.5, 0.0, 0.0, 0);
        m.record_tenant_error(1);
        m.record_tenant_throttled(1);
        m.record_tenant_throttled(1);
        m.tenant_inflight_delta(1, 3);
        m.tenant_inflight_delta(1, -1);
        let snap = m.tenant_snapshot();
        assert_eq!(snap.len(), 2, "model 1 grows the table through index 1");
        assert_eq!(snap[0].served, 0, "unseen model 0 stays zeroed");
        let t = &snap[1];
        assert_eq!((t.served, t.errors, t.throttled, t.inflight), (2, 1, 2, 2));
        assert_eq!(m.tenant_inflight(1), 2);
        assert_eq!(m.tenant_inflight(7), 0, "never-seen model reads 0");
        assert_eq!(t.latency_us.count(), 2);
        assert_eq!(t.keep.count(), 2);
    }

    #[test]
    fn tenant_cut_counts_objective_violations_exactly() {
        let m = Metrics::new();
        assert!(m.tenant_cut(0, u64::MAX, 0).is_none(), "unseen model has no cut");
        // Latencies 40 and 4 µs against a 31 µs objective: 31 is in
        // the linear bucket region, so count_le is exact there.
        m.record_request(0, 10, 30, 0.5, 0.0, 0.0, 0); // total 40, keep 5000
        m.record_request(0, 1, 3, 0.0, 0.0, 0.0, 0); // total 4, keep 10000
        m.record_tenant_error(0);
        let cut = m.tenant_cut(0, 31, 6000).expect("cut");
        assert_eq!(cut.served, 2);
        assert_eq!(cut.errors, 1);
        assert_eq!(cut.lat_violations, 1, "only the 40 µs request exceeds 31 µs");
        assert_eq!(cut.keep_violations, 1, "only keep 0.5 sits below the 0.6 floor");
        // Disabled objectives count nothing.
        let cut = m.tenant_cut(0, u64::MAX, 0).expect("cut");
        assert_eq!((cut.lat_violations, cut.keep_violations), (0, 0));
        // Cuts are monotone: later cuts dominate earlier ones.
        m.record_request(0, 50, 50, 0.9, 0.0, 0.0, 0);
        let later = m.tenant_cut(0, 31, 6000).expect("cut");
        assert!(later.served >= 2 && later.lat_violations >= 1 && later.keep_violations >= 2);
    }

    #[test]
    fn layer_totals_accumulate_per_model_and_layer() {
        let m = Metrics::new();
        assert!(m.layer_totals().is_empty());
        m.record_layers(0, &[100, 200], &[50, 0]);
        m.record_layers(0, &[10, 20], &[5, 5]);
        m.record_layers(2, &[7], &[3]);
        let t = m.layer_totals();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0], vec![(110, 55), (220, 5)]);
        assert!(t[1].is_empty(), "unseen model stays empty");
        assert_eq!(t[2], vec![(7, 3)]);
    }
}
