//! Dynamic batching policy: group queued requests into batches of at
//! most `max_batch`, waiting at most `max_wait` for stragglers once the
//! first request of a batch has arrived.
//!
//! Split into a pure, property-tested policy ([`BatchPolicy::plan`]) and
//! a thin channel pump ([`Batcher::collect`]).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::request::InferRequest;

/// Pure batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max samples per planned batch.
    pub max_batch: usize,
    /// Max time to wait filling a batch before dispatching.
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// Plan batch sizes for `pending` queued requests: FIFO chunks of at
    /// most `max_batch`, never empty, covering every request exactly once.
    pub fn plan(&self, pending: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut left = pending;
        while left > 0 {
            let take = left.min(self.max_batch);
            out.push(take);
            left -= take;
        }
        out
    }
}

/// Channel-driven batch collector.
pub struct Batcher {
    /// The batching policy this collector applies.
    pub policy: BatchPolicy,
}

impl Batcher {
    /// Block for the next batch: waits indefinitely for the first
    /// request, then gathers more until `max_batch` or `max_wait`.
    /// Returns `None` when the channel is closed and drained.
    pub fn collect(&self, rx: &Receiver<InferRequest>) -> Option<Vec<InferRequest>> {
        let first = rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    #[test]
    fn plan_covers_all_requests_exactly_once() {
        crate::util::prop::check(31, 500, |g| {
            let p = BatchPolicy {
                max_batch: g.usize_in(1, 64),
                max_wait: Duration::from_millis(1),
            };
            let pending = g.usize_in(0, 500);
            let plan = p.plan(pending);
            assert_eq!(plan.iter().sum::<usize>(), pending);
            assert!(plan.iter().all(|&b| b > 0 && b <= p.max_batch));
            // only the last batch may be partial
            for &b in plan.iter().rev().skip(1) {
                assert_eq!(b, p.max_batch);
            }
        });
    }

    fn req(id: u64, tx: &std::sync::mpsc::Sender<super::super::InferResponse>) -> InferRequest {
        InferRequest {
            id,
            x: vec![],
            xi: None,
            slot: 0,
            t_enqueue: Instant::now(),
            reply: super::super::ReplyTo::Single(tx.clone()),
            ctl: None,
        }
    }

    #[test]
    fn collect_respects_max_batch() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        for i in 0..10 {
            tx.send(req(i, &rtx)).unwrap();
        }
        let b = Batcher {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) },
        };
        let batch = b.collect(&rx).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].id, 0); // FIFO
        let batch2 = b.collect(&rx).unwrap();
        assert_eq!(batch2[0].id, 4);
    }

    #[test]
    fn collect_returns_partial_after_timeout() {
        let (tx, rx) = channel();
        let (rtx, _rrx) = channel();
        tx.send(req(0, &rtx)).unwrap();
        let b = Batcher {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) },
        };
        let batch = b.collect(&rx).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn collect_none_on_closed_channel() {
        let (tx, rx) = channel::<InferRequest>();
        drop(tx);
        let b = Batcher {
            policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
        };
        assert!(b.collect(&rx).is_none());
    }
}
