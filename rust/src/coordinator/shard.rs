//! Work-stealing sharded queues for the McuSim worker pool.
//!
//! PR 1 left the coordinator with one `Arc<Mutex<Receiver>>` shared by
//! every worker: each dequeue serialized on a single lock, so request
//! throughput stopped scaling past a couple of workers. This module
//! replaces it with the classic sharded design:
//!
//! * each worker owns a **local deque** (FIFO from the owner's side);
//! * [`ShardPool::push`] places new work round-robin with a
//!   two-choice least-loaded refinement, so shards stay balanced
//!   without a global lock;
//! * an idle worker first drains its own shard, then **steals the
//!   oldest item from the longest queue** (both ends sit under the
//!   same shard mutex, so front-stealing costs the same as the
//!   classic Chase-Lev back-steal while preserving request fairness —
//!   the oldest waiter is served first, keeping queue-wait percentiles
//!   honest under imbalance), then sweeps every shard before deciding
//!   the pool is empty;
//! * blocking pops park on one condvar; every push notifies one
//!   sleeper under the same gate, so wakeups cannot be lost (a 50 ms
//!   timed re-check is kept as belt-and-braces).
//!
//! The pool is deliberately generic over the item type: the serving
//! path pushes [`crate::coordinator::InferRequest`]s, the tests push
//! integers.
//!
//! Shutdown contract: after [`ShardPool::close`], `push` panics,
//! blocked `pop`s drain whatever is still queued and then return
//! `None`. Nothing is dropped: the closed flag is checked *inside*
//! the target shard's lock on push, and a worker returns `None` only
//! after a full sweep that began *after* it observed the closed flag —
//! any successful racing push either lands where that sweep looks, or
//! its shard critical section is mutex-ordered after the sweep's and
//! is then forced to observe `closed` and panic instead of inserting.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// How [`ShardPool::push_with_cost`] picks a target shard.
///
/// `TwoChoice` is the original count-based policy; `CostWeighted` is
/// the latency-aware one: UnIT's per-sample MACs vary with activation
/// sparsity, so two queues of equal *length* can hold very different
/// amounts of *work*. Weighting placement by the queued cost gauge
/// (estimated remaining MACs) balances mixed dense/pruned traffic by
/// work; queue length only breaks ties.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Round-robin with a power-of-two-choices length refinement (the
    /// pre-cost-gauge policy, kept for A/B comparison and benches).
    TwoChoice,
    /// Least queued cost across all shards; shorter queue, then
    /// round-robin order, break ties.
    #[default]
    CostWeighted,
}

/// Per-worker queues with round-robin submission and work stealing.
#[derive(Debug)]
pub struct ShardPool<T> {
    shards: Vec<Mutex<VecDeque<(T, u64)>>>,
    /// Approximate per-shard lengths (maintained under each shard's
    /// lock, read without it) — used to pick push targets and steal
    /// victims; correctness never depends on them being exact.
    lens: Vec<AtomicUsize>,
    /// Per-shard queued-cost gauges (sum of the cost attached to each
    /// queued item), same maintenance discipline as `lens`.
    costs: Vec<AtomicU64>,
    rr: AtomicUsize,
    closed: AtomicBool,
    /// Workers currently parked on (or entering) the condvar. Pushes
    /// skip the gate lock entirely while this is zero, so a saturated
    /// pool has no global lock on the submit path.
    parked: AtomicUsize,
    /// Number of successful non-local pops (observability + tests).
    steals: AtomicU64,
    gate: Mutex<()>,
    cv: Condvar,
}

impl<T> ShardPool<T> {
    /// A pool with `n` shards (one per worker; `n == 0` is rounded up).
    pub fn new(n: usize) -> ShardPool<T> {
        let n = n.max(1);
        ShardPool {
            shards: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            lens: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            costs: (0..n).map(|_| AtomicU64::new(0)).collect(),
            rr: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            parked: AtomicUsize::new(0),
            steals: AtomicU64::new(0),
            gate: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Number of shards in the pool.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total queued items (approximate while producers/consumers run).
    pub fn queue_len(&self) -> usize {
        self.lens.iter().map(|l| l.load(Ordering::Relaxed)).sum()
    }

    /// Total queued cost (approximate while producers/consumers run).
    pub fn queue_cost(&self) -> u64 {
        self.costs.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Per-shard queued-cost gauges, one entry per shard (approximate
    /// while producers/consumers run) — the observability view behind
    /// `Metrics::record_shard_costs`, so cost-weighted placement
    /// imbalance is visible without poking individual shards.
    pub fn per_shard_costs(&self) -> Vec<u64> {
        self.costs.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Successful steals so far (a shard-imbalance observability knob).
    pub fn steal_count(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Enqueue with unit cost under the legacy two-choice policy (the
    /// in-process front door; streamed serving uses
    /// [`ShardPool::push_with_cost`] with real MAC estimates).
    pub fn push(&self, item: T) {
        self.push_with_cost(item, 1, Placement::TwoChoice);
    }

    fn pick_shard(&self, placement: Placement) -> usize {
        let n = self.shards.len();
        match placement {
            Placement::TwoChoice => {
                let a = self.rr.fetch_add(1, Ordering::Relaxed) % n;
                let b = (a + 1) % n;
                if self.lens[b].load(Ordering::Relaxed) < self.lens[a].load(Ordering::Relaxed)
                {
                    b
                } else {
                    a
                }
            }
            Placement::CostWeighted => {
                // Full scan of the cost gauges (n_shards = worker count,
                // single digits): least queued work wins, queue length
                // then round-robin origin break ties so equal-cost
                // (e.g. empty) shards still spread.
                let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
                let mut best = start;
                let mut best_key = (
                    self.costs[start].load(Ordering::Relaxed),
                    self.lens[start].load(Ordering::Relaxed),
                );
                for off in 1..n {
                    let i = (start + off) % n;
                    let key = (
                        self.costs[i].load(Ordering::Relaxed),
                        self.lens[i].load(Ordering::Relaxed),
                    );
                    if key < best_key {
                        best = i;
                        best_key = key;
                    }
                }
                best
            }
        }
    }

    /// Enqueue `item` carrying `cost` units of estimated service work
    /// on the shard `placement` picks. Returns the chosen shard index.
    ///
    /// Panics if the pool is closed (same contract as
    /// [`ShardPool::push`]); use [`ShardPool::try_push_with_cost`] from
    /// paths that must survive a racing shutdown.
    pub fn push_with_cost(&self, item: T, cost: u64, placement: Placement) -> usize {
        let idx = self.pick_shard(placement);
        if self.enqueue_at(idx, item, cost).is_some() {
            panic!("push on closed ShardPool");
        }
        idx
    }

    /// Non-panicking [`ShardPool::push_with_cost`]: hands the item back
    /// instead when the pool is closed, so a session racing shutdown
    /// can turn it into an error reply rather than a worker panic.
    pub fn try_push_with_cost(
        &self,
        item: T,
        cost: u64,
        placement: Placement,
    ) -> Result<usize, T> {
        let idx = self.pick_shard(placement);
        match self.enqueue_at(idx, item, cost) {
            None => Ok(idx),
            Some(item) => Err(item),
        }
    }

    /// Enqueue on a specific shard (callers that manage placement
    /// themselves; [`ShardPool::push`] is the balanced front door).
    ///
    /// Panics if the pool is closed — the check happens inside the
    /// shard lock, so a push cannot race `close` into a drained shard
    /// and silently lose the item.
    pub fn push_to(&self, idx: usize, item: T) {
        if self.enqueue_at(idx, item, 1).is_some() {
            panic!("push on closed ShardPool");
        }
    }

    /// The one true insert: returns the item back (instead of
    /// inserting) when the pool is closed.
    fn enqueue_at(&self, idx: usize, item: T, cost: u64) -> Option<T> {
        {
            let mut q = self.shards[idx].lock().unwrap();
            if self.closed.load(Ordering::Acquire) {
                return Some(item);
            }
            q.push_back((item, cost));
            self.lens[idx].store(q.len(), Ordering::Release);
            self.costs[idx].fetch_add(cost, Ordering::Release);
        }
        // Wake a sleeper only if one exists (SeqCst pairs with the
        // parked increment in `pop`: if the load sees 0, the worker's
        // increment — and therefore its pre-park re-check — is ordered
        // after our insert, so it finds the item instead of sleeping).
        // One item needs one worker: notify_one, under the gate so the
        // wakeup cannot slip between a sleeper's re-check and its wait.
        if self.parked.load(Ordering::SeqCst) > 0 {
            let _g = self.gate.lock().unwrap();
            self.cv.notify_one();
        }
        None
    }

    fn pop_front_at(&self, idx: usize) -> Option<T> {
        let mut q = self.shards[idx].lock().unwrap();
        let popped = q.pop_front();
        self.lens[idx].store(q.len(), Ordering::Release);
        match popped {
            Some((item, cost)) => {
                self.costs[idx].fetch_sub(cost, Ordering::Release);
                Some(item)
            }
            None => None,
        }
    }

    fn steal_at(&self, idx: usize) -> Option<T> {
        let item = self.pop_front_at(idx);
        if item.is_some() {
            self.steals.fetch_add(1, Ordering::Relaxed);
        }
        item
    }

    /// Non-blocking pop for `worker`: local shard first, then steal the
    /// oldest item from the (approximately) longest other shard, then a
    /// full sweep so `None` is an exact "nothing queued anywhere"
    /// answer.
    pub fn try_pop(&self, worker: usize) -> Option<T> {
        let n = self.shards.len();
        let local = worker % n;
        if let Some(item) = self.pop_front_at(local) {
            return Some(item);
        }
        let mut victim = None;
        let mut victim_len = 0usize;
        for (i, l) in self.lens.iter().enumerate() {
            let len = l.load(Ordering::Relaxed);
            if i != local && len > victim_len {
                victim = Some(i);
                victim_len = len;
            }
        }
        if let Some(i) = victim {
            if let Some(item) = self.steal_at(i) {
                return Some(item);
            }
        }
        for i in 0..n {
            if i == local {
                continue;
            }
            if let Some(item) = self.steal_at(i) {
                return Some(item);
            }
        }
        None
    }

    /// Blocking pop for `worker`. Returns `None` only once the pool is
    /// closed *and* every shard has been drained.
    pub fn pop(&self, worker: usize) -> Option<T> {
        loop {
            // Order matters: observe `closed` BEFORE the sweep. `None`
            // is returned only when a full sweep that *started after*
            // closed was seen comes up empty — a racing push either
            // completed its shard critical section before the sweep
            // visited that shard (the sweep finds the item) or entered
            // it after (the mutex chain forces it to see `closed` and
            // panic), so an item can never be stranded.
            let closed = self.closed.load(Ordering::Acquire);
            if let Some(item) = self.try_pop(worker) {
                return Some(item);
            }
            if closed {
                return None;
            }
            let guard = self.gate.lock().unwrap();
            // Announce intent to park *before* the final re-check: any
            // push after this sees parked > 0 and takes the notify
            // path; any push before it is caught by the re-check.
            self.parked.fetch_add(1, Ordering::SeqCst);
            if let Some(item) = self.try_pop(worker) {
                self.parked.fetch_sub(1, Ordering::SeqCst);
                return Some(item);
            }
            if self.closed.load(Ordering::Acquire) {
                // Close raced in after the pre-sweep load: go around
                // for a final observe-closed-then-sweep pass instead of
                // concluding emptiness from a pre-close sweep.
                self.parked.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            // The 50 ms timeout is a belt-and-braces backstop: a missed
            // wakeup (impossible per the protocol above) would cost
            // latency, never lose an item.
            let _unused = self.cv.wait_timeout(guard, Duration::from_millis(50)).unwrap();
            self.parked.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Close the intake and wake every parked worker.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        let _g = self.gate.lock().unwrap();
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn every_item_popped_exactly_once_under_contention() {
        let pool: Arc<ShardPool<usize>> = Arc::new(ShardPool::new(4));
        let n_items = 2000usize;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    for i in 0..n_items / 4 {
                        pool.push(p * (n_items / 4) + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4)
            .map(|w| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = pool.pop(w) {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        pool.close();
        let mut all: Vec<usize> =
            consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let expect: Vec<usize> = (0..n_items).collect();
        assert_eq!(all, expect, "items lost or duplicated");
        assert_eq!(pool.queue_len(), 0);
    }

    #[test]
    fn idle_workers_steal_from_loaded_shard() {
        let pool: Arc<ShardPool<u32>> = Arc::new(ShardPool::new(4));
        // Pile everything onto shard 0; workers 1..3 can only make
        // progress by stealing.
        for i in 0..600u32 {
            pool.push_to(0, i);
        }
        pool.close();
        let consumers: Vec<_> = (1..4)
            .map(|w| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let mut n = 0usize;
                    while pool.pop(w).is_some() {
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        let total: usize = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(total, 600);
        assert!(pool.steal_count() > 0, "no steals despite a fully skewed load");
    }

    #[test]
    fn local_pops_are_fifo() {
        let pool: ShardPool<u32> = ShardPool::new(2);
        for i in 0..8u32 {
            pool.push_to(1, i);
        }
        for i in 0..8u32 {
            assert_eq!(pool.try_pop(1), Some(i));
        }
        assert_eq!(pool.try_pop(1), None);
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let pool: Arc<ShardPool<u32>> = Arc::new(ShardPool::new(2));
        let workers: Vec<_> = (0..2)
            .map(|w| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || pool.pop(w))
            })
            .collect();
        std::thread::sleep(Duration::from_millis(20));
        pool.close();
        for h in workers {
            assert_eq!(h.join().unwrap(), None);
        }
    }

    #[test]
    fn drain_completes_after_close() {
        let pool: ShardPool<u32> = ShardPool::new(3);
        for i in 0..30u32 {
            pool.push(i);
        }
        pool.close();
        let mut seen = std::collections::HashSet::new();
        for w in 0..3 {
            while let Some(v) = pool.pop(w) {
                assert!(seen.insert(v));
            }
        }
        assert_eq!(seen.len(), 30);
    }

    #[test]
    #[should_panic(expected = "push on closed ShardPool")]
    fn push_after_close_panics() {
        let pool: ShardPool<u32> = ShardPool::new(1);
        pool.close();
        pool.push(1);
    }

    #[test]
    fn try_push_returns_item_after_close_instead_of_panicking() {
        let pool: ShardPool<u32> = ShardPool::new(2);
        assert!(pool.try_push_with_cost(7, 10, Placement::CostWeighted).is_ok());
        pool.close();
        assert_eq!(pool.try_push_with_cost(8, 10, Placement::CostWeighted), Err(8));
        // the pre-close item still drains
        assert_eq!(pool.pop(0), Some(7));
        assert_eq!(pool.pop(0), None);
    }

    #[test]
    fn cost_weighted_placement_balances_work_not_count() {
        let pool: ShardPool<u32> = ShardPool::new(2);
        // One huge item, then many small ones: count-blind cost
        // weighting must route all the small work away from the loaded
        // shard (two-choice would alternate by length).
        let big = pool.push_with_cost(0, 1_000_000, Placement::CostWeighted);
        for i in 1..10u32 {
            let idx = pool.push_with_cost(i, 100, Placement::CostWeighted);
            assert_ne!(idx, big, "small item {i} landed on the loaded shard");
        }
        assert_eq!(pool.queue_cost(), 1_000_000 + 900);
        assert_eq!(pool.queue_len(), 10);
        let per = pool.per_shard_costs();
        assert_eq!(per.len(), 2);
        assert_eq!(per.iter().sum::<u64>(), pool.queue_cost());
        assert!(per.contains(&1_000_000), "loaded shard gauge missing: {per:?}");
    }

    /// Satellite property: under BOTH placement policies, any push
    /// sequence drains to exactly the pushed multiset, per-shard FIFO
    /// order survives (front-steals included), and the cost gauges
    /// return to zero.
    #[test]
    fn placement_policies_never_lose_or_reorder_items() {
        crate::util::prop::check(0xC057, 60, |g| {
            let n_shards = g.usize_in(1, 5);
            let n_items = g.usize_in(1, 120);
            let policy = *g.choice(&[Placement::TwoChoice, Placement::CostWeighted]);
            let pool: ShardPool<usize> = ShardPool::new(n_shards);
            let mut shard_of = Vec::with_capacity(n_items);
            let mut total_cost = 0u64;
            for item in 0..n_items {
                let cost = g.u32_in(0, 1_000_000) as u64;
                total_cost += cost;
                shard_of.push(pool.push_with_cost(item, cost, policy));
            }
            assert_eq!(pool.queue_len(), n_items);
            assert_eq!(pool.queue_cost(), total_cost);
            // Drain from random workers: mixes local pops with steals.
            let mut popped = Vec::new();
            while let Some(v) = pool.try_pop(g.usize_in(0, n_shards.max(1) - 1)) {
                popped.push(v);
            }
            assert_eq!(popped.len(), n_items, "items lost or duplicated");
            let mut sorted = popped.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..n_items).collect::<Vec<_>>());
            // FIFO per shard: the pop subsequence belonging to one
            // shard must be in push order (pops always take the front).
            for s in 0..n_shards {
                let pushed: Vec<usize> =
                    (0..n_items).filter(|&i| shard_of[i] == s).collect();
                let drained: Vec<usize> =
                    popped.iter().copied().filter(|&i| shard_of[i] == s).collect();
                assert_eq!(drained, pushed, "shard {s} reordered under {policy:?}");
            }
            assert_eq!(pool.queue_cost(), 0, "cost gauge leaked");
            assert_eq!(pool.queue_len(), 0);
        });
    }
}
