//! The serving runtime: request intake, dynamic batching, worker pool.
//!
//! Thread topology:
//!
//! * **McuSim backend** — N worker threads, each owning one shard of a
//!   work-stealing [`ShardPool`] (see [`super::shard`]): `submit`
//!   spreads load round-robin/least-loaded across the per-worker
//!   deques, idle workers steal from the longest queue, and
//!   [`Coordinator::submit_batch`] splits one request's samples across
//!   shards and reassembles them in input order. Each worker runs the
//!   fixed-point engine on one sample at a time, exactly as the target
//!   MCU would, and reports the modeled cycles/energy with the
//!   prediction. The engine runs on a shared prepacked
//!   [`PlannedModel`] (compiled once at start-up) with a per-worker
//!   scratch arena — bit-identical to the naive engine, several times
//!   faster on the host, zero allocation per request.
//! * **Pjrt backend** — a single executor thread *owns* the PJRT client
//!   (the `xla` crate's client is `Rc`-based and not `Send`, so it is
//!   created inside the thread), batches requests up to the artifact's
//!   batch size (8), zero-pads partial batches, and fans results back
//!   out.
//!
//! One McuSim coordinator can host **several models** at once
//! ([`Coordinator::start_multi`]): each model gets its own
//! [`PlanSlot`] + [`CostEstimatorSlot`] row in an immutable model
//! table, every [`InferRequest`] carries the index of its target
//! model, and workers pick up the right plan per dequeue (with one
//! cached `(generation, plan, scratch)` triple per model, so the
//! single-model fast path — one relaxed atomic load per dequeue — is
//! unchanged). The fleet scheduler
//! ([`crate::control::FleetScheduler`]) retargets the per-model slots;
//! [`Coordinator::start`] is the single-model special case.
//!
//! Every response carries queue wait and service time separately (and
//! [`Metrics`] aggregates both), so a shard-balance regression shows up
//! as a queue-percentile blowup even when service time is flat.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{BatchSink, InferRequest, InferResponse, ReplyTo, RequestCtl, StreamSink};
use super::shard::{Placement, ShardPool};
use crate::approx::DivKind;
use crate::engine::{PlanConfig, PlannedModel, PruneMode, QModel, Scratch};
use crate::mcu::EnergyModel;
use crate::models::Params;
use crate::obs::{EventKind, FlightRecorder, LayerSink, ObsConfig, TraceRing, TraceSampler};
use crate::util::stats::argmax;
use crate::util::{lock_recover, read_recover, write_recover, FaultPlan};

/// Which execution backend serves requests.
#[derive(Debug, Clone)]
pub enum BackendChoice {
    /// Fixed-point MCU simulator with the given pruning setup.
    McuSim {
        /// Quantized model to serve.
        q: QModel,
        /// Pruning mode (dense / UnIT / fat-neuron).
        mode: PruneMode,
        /// Division strategy for the threshold comparisons.
        div: DivKind,
    },
    /// Float AOT artifact at batch 8 through PJRT.
    Pjrt {
        /// Zoo model name (selects the AOT artifact).
        model: String,
        /// Float parameters fed to the artifact.
        params: Params,
        /// Per-layer UnIT thresholds fed to the artifact.
        t_vec: Vec<f32>,
        /// Fat-neuron threshold fed to the artifact.
        fat_t: f32,
    },
}

/// One model hosted by a multi-model McuSim coordinator: the zoo name
/// clients address it by, its quantized weights, and its pruning
/// setup. The position in the `Vec` passed to
/// [`Coordinator::start_multi`] becomes the model's wire id.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Tenant name (`unit serve --models` order decides the id).
    pub name: String,
    /// Quantized model to serve under this id.
    pub q: QModel,
    /// Pruning mode (dense / UnIT / fat-neuron).
    pub mode: PruneMode,
    /// Division strategy for the threshold comparisons.
    pub div: DivKind,
}

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// McuSim worker-thread count (one shard each); clamped to ≥ 1.
    pub workers: usize,
    /// Pjrt dynamic-batch cap (clamped to the artifact's batch size).
    pub max_batch: usize,
    /// Pjrt dynamic-batch linger: how long the executor waits to fill
    /// a partial batch before running it.
    pub max_wait: Duration,
    /// Shard placement policy (McuSim): cost-weighted by the plan's
    /// per-sample MAC estimate by default; `Placement::TwoChoice` is
    /// the legacy count-based policy.
    pub placement: Placement,
    /// Deterministic fault-injection plan (worker panics, for the
    /// chaos harness); `None` — no probes taken — in production.
    pub fault: Option<Arc<FaultPlan>>,
    /// Observability wiring. [`ObsConfig::off`] (the default) takes no
    /// timestamps and emits no events — the request hot path is
    /// bit-identical to a build without the subsystem.
    pub obs: ObsConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            placement: Placement::default(),
            fault: None,
            obs: ObsConfig::off(),
        }
    }
}

/// Submission failure (streamed paths only — the in-process `submit`
/// APIs keep their infallible signatures).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The coordinator's intake is closed (shutdown in progress).
    Closed,
    /// The target model id is not in this coordinator's model table.
    UnknownModel,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Closed => write!(f, "coordinator intake closed"),
            SubmitError::UnknownModel => write!(f, "unknown model id"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// The atomically swappable active plan: workers check it at every
/// dequeue, so the control plane's governor can retarget the whole
/// pool to a different threshold scale **between requests** — no
/// worker restart, no in-flight request ever sees a torn plan (each
/// request runs start-to-finish on the `Arc` it picked up). Swaps come
/// from two places: the governor's inline path (resident plans) and
/// its background compile thread's **upgrades** — workers observe both
/// the same way. Multi-model coordinators hold one slot per model.
///
/// `RwLock<Arc<…>>` rather than a lock-free pointer because the write
/// path is rare and std has no atomic `Arc` swap. The read path is
/// cheaper still: a monotone **generation counter** bumps on every
/// swap, so a worker's per-dequeue check is one relaxed atomic load —
/// it takes the lock only when the generation actually moved (plan
/// swaps are orders of magnitude rarer than dequeues).
#[derive(Debug)]
pub struct PlanSlot {
    cur: RwLock<Arc<PlannedModel>>,
    generation: AtomicU64,
}

impl PlanSlot {
    /// A slot initially holding `plan`, at generation 0.
    pub fn new(plan: Arc<PlannedModel>) -> PlanSlot {
        PlanSlot { cur: RwLock::new(plan), generation: AtomicU64::new(0) }
    }

    /// The currently active plan. Poison-tolerant: a worker that
    /// panicked while reading can never invalidate the slot — the last
    /// published plan stays valid (see [`crate::util::lock`]).
    pub fn get(&self) -> Arc<PlannedModel> {
        Arc::clone(&read_recover(&self.cur))
    }

    /// Monotone swap counter: unchanged generation ⇒ `get` would
    /// return the same plan the caller already holds.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Install `plan`; returns the one it replaced.
    pub fn swap(&self, plan: Arc<PlannedModel>) -> Arc<PlannedModel> {
        let mut cur = write_recover(&self.cur);
        // Bump under the write lock so a reader that sees the new
        // generation is guaranteed to read the new plan.
        self.generation.fetch_add(1, Ordering::Release);
        std::mem::replace(&mut *cur, plan)
    }
}

/// Placement cost oracle the control plane can install over the
/// built-in layer-0 extrapolation ([`PlannedModel::estimate_macs`]):
/// given the active plan and a quantized sample, price its service
/// cost in estimated MACs.
pub trait CostEstimator: Send + Sync {
    /// Estimated MACs to serve the quantized sample `x_raw` on `plan`.
    fn estimate(&self, plan: &PlannedModel, x_raw: &[i16]) -> u64;
}

/// The shared, swappable cost-oracle slot (`None` = use the plan's own
/// estimate). The governor holds a clone and retargets it per plan
/// swap; multi-model coordinators keep one slot per model, so each
/// tenant's queue cost is priced by its own calibrated profile.
pub type CostEstimatorSlot = Arc<RwLock<Option<Arc<dyn CostEstimator>>>>;

/// Per-request energy observer: workers report each McuSim inference's
/// modeled ledger energy here (when installed). This is the control
/// plane's feedback input — implemented by `control::Governor`
/// (single-model) and `control::FleetScheduler` (multi-model), which
/// close the budget loop by swapping [`PlanSlot`]s.
pub trait EnergyTap: Send + Sync {
    /// Report one inference's modeled ledger energy in millijoules.
    fn observe(&self, energy_mj: f64);

    /// Observed model-level keep ratio of one inference (kept MACs
    /// over total MAC positions) — the drift detector's feedback
    /// signal. Default no-op so plain energy observers are unaffected.
    fn observe_keep(&self, _ratio: f64) {}

    /// Offer a served sample's raw input to the observer's
    /// recalibration reservoir. Default no-op.
    fn sample_input(&self, _x: &[f32]) {}

    /// Model-attributed energy report. Workers always call this
    /// variant; the default forwards to [`EnergyTap::observe`], so a
    /// single-model observer never sees the id. Multi-model observers
    /// override it to route feedback per tenant.
    fn observe_model(&self, _model: u32, energy_mj: f64) {
        self.observe(energy_mj);
    }

    /// Model-attributed keep-ratio report (see
    /// [`EnergyTap::observe_keep`]).
    fn observe_keep_model(&self, _model: u32, ratio: f64) {
        self.observe_keep(ratio);
    }

    /// Model-attributed reservoir offer (see
    /// [`EnergyTap::sample_input`]).
    fn sample_input_model(&self, _model: u32, x: &[f32]) {
        self.sample_input(x);
    }
}

/// The shared, swappable energy-observer slot workers read per request.
type EnergyTapSlot = Arc<RwLock<Option<Arc<dyn EnergyTap>>>>;

/// One row of the coordinator's immutable model table: everything the
/// submit paths and workers need to serve (and price) one tenant.
struct ModelEntry {
    /// Tenant name (zoo model name on real deployments).
    name: String,
    /// Active-plan slot; `None` on the Pjrt backend, whose executor
    /// owns its artifact.
    plan: Option<Arc<PlanSlot>>,
    /// Per-model placement cost oracle.
    cost_est: CostEstimatorSlot,
    /// Flat `C·H·W` sample length this model expects.
    input_len: usize,
}

/// Request intake: the sharded pool (McuSim) or the executor channel
/// (Pjrt, whose single thread batches dynamically). The channel sender
/// sits behind a mutex so `close` works through `&self` — the serve
/// listener shuts the stack down in close-listener → drain-sessions →
/// close-pool order while sessions still hold the coordinator.
enum Intake {
    Pool(Arc<ShardPool<InferRequest>>),
    Chan(Mutex<Option<Sender<InferRequest>>>),
}

/// Handle to a running coordinator.
pub struct Coordinator {
    intake: Intake,
    handles: Mutex<Vec<JoinHandle<()>>>,
    next_id: AtomicU64,
    /// Immutable model table: index = model id. Single-backend
    /// coordinators have exactly one row; every mutable per-model
    /// state (plan, cost oracle) lives behind its row's shared slots,
    /// so the table itself is never written after start.
    models: Arc<Vec<ModelEntry>>,
    /// Optional per-request energy observer (the control plane's
    /// feedback input), read by every McuSim worker after each
    /// inference.
    energy_tap: EnergyTapSlot,
    placement: Placement,
    /// Observability wiring; the "intake" ring (when on) records one
    /// `Enqueue` event per submitted sample.
    obs: ObsConfig,
    intake_ring: Option<Arc<TraceRing>>,
    /// Shared serving metrics (latency, batches, panics, drops).
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start serving with the chosen backend (single model — the
    /// common case; see [`Coordinator::start_multi`] for multi-tenant
    /// McuSim serving).
    pub fn start(backend: BackendChoice, cfg: ServeConfig) -> Coordinator {
        match backend {
            BackendChoice::McuSim { q, mode, div } => Coordinator::start_multi(
                vec![ModelSpec { name: "default".to_string(), q, mode, div }],
                cfg,
            ),
            BackendChoice::Pjrt { model, params, t_vec, fat_t } => {
                let metrics = Arc::new(Metrics::new());
                let input_len = crate::models::zoo(&model).input_len();
                let (tx, rx) = channel::<InferRequest>();
                let policy =
                    BatchPolicy { max_batch: cfg.max_batch.min(8), max_wait: cfg.max_wait };
                let exec_metrics = Arc::clone(&metrics);
                let name = model.clone();
                let handles = vec![std::thread::spawn(move || {
                    pjrt_executor(rx, model, params, t_vec, fat_t, policy, exec_metrics)
                })];
                let intake_ring = cfg.obs.recorder.as_ref().map(|r| r.ring("intake"));
                Coordinator {
                    intake: Intake::Chan(Mutex::new(Some(tx))),
                    handles: Mutex::new(handles),
                    next_id: AtomicU64::new(0),
                    models: Arc::new(vec![ModelEntry {
                        name,
                        plan: None,
                        cost_est: Arc::new(RwLock::new(None)),
                        input_len,
                    }]),
                    energy_tap: Arc::new(RwLock::new(None)),
                    placement: cfg.placement,
                    obs: cfg.obs,
                    intake_ring,
                    metrics,
                }
            }
        }
    }

    /// Start a multi-model McuSim coordinator: one shared
    /// work-stealing pool serves every model in `specs`, and the
    /// position of a spec in the `Vec` is its model id (what wire v4
    /// `Request.model` addresses). Each model gets its own
    /// [`PlanSlot`] and [`CostEstimatorSlot`]; workers look the plan
    /// up per dequeue, so the control plane retargets tenants
    /// independently. Panics if `specs` is empty.
    pub fn start_multi(specs: Vec<ModelSpec>, cfg: ServeConfig) -> Coordinator {
        assert!(!specs.is_empty(), "start_multi needs at least one model");
        let metrics = Arc::new(Metrics::new());
        let placement = cfg.placement;
        let energy_tap: EnergyTapSlot = Arc::new(RwLock::new(None));
        // Compile each model's execution plan once; workers share the
        // packed tables (read-only) and own their scratch. The slots
        // let the control plane swap any model's plan at runtime
        // (workers re-read them per dequeue).
        let entries: Vec<ModelEntry> = specs
            .into_iter()
            .map(|spec| {
                let input_len = spec.q.def.input_len();
                let plan = Arc::new(PlanSlot::new(Arc::new(PlannedModel::compile(
                    &spec.q,
                    PlanConfig::for_mode(spec.mode, spec.div),
                ))));
                ModelEntry {
                    name: spec.name,
                    plan: Some(plan),
                    cost_est: Arc::new(RwLock::new(None)),
                    input_len,
                }
            })
            .collect();
        let models = Arc::new(entries);
        let workers = cfg.workers.max(1);
        let pool = Arc::new(ShardPool::new(workers));
        let obs = cfg.obs.clone();
        let intake_ring = obs.recorder.as_ref().map(|r| r.ring("intake"));
        let handles = (0..workers)
            .map(|w| {
                let pool = Arc::clone(&pool);
                let models = Arc::clone(&models);
                let metrics = Arc::clone(&metrics);
                let tap = Arc::clone(&energy_tap);
                let fault = cfg.fault.clone();
                // One flight-recorder ring per worker: per-worker
                // writers never contend, and the Chrome export maps
                // each ring to its own synthetic thread lane.
                let ring = obs.recorder.as_ref().map(|r| r.ring(&format!("worker{w}")));
                // The head-sampling decision rides in by value: one
                // hash per dequeue decides whether this request's
                // spans are recorded at all.
                let sampler = obs.sampler;
                // Panic supervisor: a worker panic (engine bug or
                // injected chaos) fails the stranded request through
                // its ctl and re-enters the loop with fresh scratch,
                // instead of silently shrinking the pool. Unwind
                // safety is by construction: shared state is atomics
                // and recover-on-poison locks, and the one value a
                // panic can strand — the in-flight request — is
                // reconciled from the stash right here.
                std::thread::spawn(move || {
                    let inflight: Mutex<Option<InFlight>> = Mutex::new(None);
                    loop {
                        let run = catch_unwind(AssertUnwindSafe(|| {
                            mcu_worker(
                                w,
                                &pool,
                                &models,
                                &metrics,
                                &tap,
                                fault.as_deref(),
                                ring.as_deref(),
                                sampler,
                                &inflight,
                            )
                        }));
                        match run {
                            // Pool closed and drained: clean exit.
                            Ok(()) => break,
                            Err(_) => {
                                metrics.record_worker_panic();
                                if let Some(r) = &ring {
                                    r.emit(EventKind::WorkerPanic, 0, w as u64, 0, 0);
                                }
                                if let Some(fl) = lock_recover(&inflight).take() {
                                    fail_inflight(fl, &metrics);
                                }
                                metrics.record_respawn();
                                if let Some(r) = &ring {
                                    r.emit(EventKind::WorkerRespawn, 0, w as u64, 0, 0);
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        Coordinator {
            intake: Intake::Pool(pool),
            handles: Mutex::new(handles),
            next_id: AtomicU64::new(0),
            models,
            energy_tap,
            placement,
            obs,
            intake_ring,
            metrics,
        }
    }

    /// The attached flight recorder, when observability is on
    /// (`None` with [`ObsConfig::off`] — the default).
    pub fn recorder(&self) -> Option<Arc<FlightRecorder>> {
        self.obs.recorder.clone()
    }

    /// Price one sample for placement: the owning model's active-plan
    /// MAC estimate under cost-weighted placement (via that model's
    /// installed [`CostEstimator`] when the control plane calibrated
    /// one), unit cost otherwise (the Pjrt backend batches
    /// dynamically; its queue is one channel). The quantized buffer
    /// the estimate needed rides along in the request so the McuSim
    /// worker does not quantize again.
    fn price(&self, model: u32, x: &[f32]) -> (u64, Option<Vec<i16>>) {
        let Some(entry) = self.models.get(model as usize) else {
            return (1, None);
        };
        match (&entry.plan, self.placement) {
            (Some(slot), Placement::CostWeighted) => {
                let plan = slot.get();
                let xi = plan.quantize_input(x);
                let est = read_recover(&entry.cost_est).clone();
                let cost = match est {
                    Some(e) => e.estimate(&plan, &xi),
                    None => plan.estimate_macs(&xi),
                };
                (cost, Some(xi))
            }
            _ => (1, None),
        }
    }

    /// Model 0's active-plan slot (McuSim backend): the single-model
    /// control plane's swap point. `None` on the Pjrt backend.
    pub fn plan_slot(&self) -> Option<Arc<PlanSlot>> {
        self.plan_slot_of(0)
    }

    /// The active-plan slot of `model`; `None` for an unknown id or on
    /// the Pjrt backend.
    pub fn plan_slot_of(&self, model: u32) -> Option<Arc<PlanSlot>> {
        self.models.get(model as usize).and_then(|e| e.plan.as_ref().map(Arc::clone))
    }

    /// Shared handle to model 0's placement cost-oracle slot; the
    /// governor retargets it on every plan swap.
    pub fn cost_estimator_slot(&self) -> CostEstimatorSlot {
        Arc::clone(&self.models[0].cost_est)
    }

    /// Shared handle to the placement cost-oracle slot of `model`;
    /// `None` for an unknown id.
    pub fn cost_estimator_slot_of(&self, model: u32) -> Option<CostEstimatorSlot> {
        self.models.get(model as usize).map(|e| Arc::clone(&e.cost_est))
    }

    /// Install (or clear) the per-request energy observer the McuSim
    /// workers report to.
    pub fn set_energy_tap(&self, tap: Option<Arc<dyn EnergyTap>>) {
        *write_recover(&self.energy_tap) = tap;
    }

    /// Per-shard queued-cost gauges (estimated MACs awaiting service
    /// per worker deque) — empty on the Pjrt backend. The observability
    /// feed for cost-weighted placement imbalance.
    pub fn shard_costs(&self) -> Vec<u64> {
        match &self.intake {
            Intake::Pool(pool) => pool.per_shard_costs(),
            Intake::Chan(_) => Vec::new(),
        }
    }

    /// Copy the current per-shard cost gauges into [`Metrics`] so they
    /// appear in snapshots (called by reporting paths, not per
    /// request).
    pub fn publish_shard_costs(&self) {
        self.metrics.record_shard_costs(&self.shard_costs());
    }

    /// Estimated service cost of one model-0 sample (see `price`).
    pub fn estimate_cost(&self, x: &[f32]) -> u64 {
        self.price(0, x).0
    }

    /// Expected flat sample length (`C·H·W`) of model 0, for
    /// session-side request validation on single-model servers.
    pub fn input_len(&self) -> usize {
        self.models[0].input_len
    }

    /// Expected flat sample length of `model`; `None` for an unknown
    /// id — sessions turn that into an `Error` reply instead of
    /// queueing the request.
    pub fn input_len_of(&self, model: u32) -> Option<usize> {
        self.models.get(model as usize).map(|e| e.input_len)
    }

    /// Number of models in the table (≥ 1).
    pub fn model_count(&self) -> usize {
        self.models.len()
    }

    /// The tenant name serving under `model`, if the id is known.
    pub fn model_name(&self, model: u32) -> Option<&str> {
        self.models.get(model as usize).map(|e| e.name.as_str())
    }

    /// The model id registered under `name` (first match), if any.
    pub fn model_id_of(&self, name: &str) -> Option<u32> {
        self.models.iter().position(|e| e.name == name).map(|i| i as u32)
    }

    /// Dispatch on the infallible in-process paths. A closed intake
    /// (shutdown racing a submit) drops the request, which the caller
    /// observes as its reply channel disconnecting — this used to
    /// panic inside the shard pool, taking the *submitting* thread
    /// down with it.
    fn dispatch(&self, mut req: InferRequest) {
        let (cost, xi) = self.price(req.model, &req.x);
        req.xi = xi;
        match &self.intake {
            Intake::Pool(pool) => {
                let _ = pool.try_push_with_cost(req, cost, self.placement);
            }
            Intake::Chan(tx) => {
                if let Some(tx) = lock_recover(tx).as_ref() {
                    let _ = tx.send(req);
                }
            }
        }
    }

    /// Fallible dispatch for streamed sessions racing shutdown.
    fn try_dispatch(&self, mut req: InferRequest) -> Result<(), SubmitError> {
        let (cost, xi) = self.price(req.model, &req.x);
        req.xi = xi;
        match &self.intake {
            Intake::Pool(pool) => pool
                .try_push_with_cost(req, cost, self.placement)
                .map(|_| ())
                .map_err(|_| SubmitError::Closed),
            Intake::Chan(tx) => match lock_recover(tx).as_ref() {
                Some(tx) => tx.send(req).map_err(|_| SubmitError::Closed),
                None => Err(SubmitError::Closed),
            },
        }
    }

    /// Submit one request to model 0; returns the response channel.
    pub fn submit(&self, x: Vec<f32>) -> Receiver<InferResponse> {
        self.submit_to(0, x).expect("model 0 always exists")
    }

    /// Submit one request to `model`; returns the response channel, or
    /// [`SubmitError::UnknownModel`] for an id outside the table.
    pub fn submit_to(
        &self,
        model: u32,
        x: Vec<f32>,
    ) -> Result<Receiver<InferResponse>, SubmitError> {
        if (model as usize) >= self.models.len() {
            return Err(SubmitError::UnknownModel);
        }
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if let Some(r) = &self.intake_ring {
            if self.obs.sampler.sampled(id) {
                r.emit(EventKind::Enqueue, id, model as u64, 0, 0);
            }
        }
        let req = InferRequest {
            id,
            model,
            x,
            xi: None,
            slot: 0,
            t_enqueue: Instant::now(),
            reply: ReplyTo::Single(rtx),
            ctl: None,
        };
        self.dispatch(req);
        Ok(rrx)
    }

    /// Submit a streamed request on behalf of a socket session: all
    /// samples target `model`, share `id` and `ctl`, and every reply
    /// flows through `sink` (which handles ordering and suppression).
    /// Samples are placed cost-weighted across shards like any other
    /// submission.
    ///
    /// On `Err`, `ctl` has been cancelled: any samples already queued
    /// before the intake closed are tombstoned, so no replies flow and
    /// the caller owns the error answer to its client.
    pub fn submit_streamed(
        &self,
        id: u64,
        model: u32,
        xs: Vec<Vec<f32>>,
        ctl: Arc<RequestCtl>,
        sink: Arc<dyn StreamSink>,
    ) -> Result<(), SubmitError> {
        if (model as usize) >= self.models.len() {
            ctl.cancel();
            return Err(SubmitError::UnknownModel);
        }
        if matches!(self.intake, Intake::Pool(_)) {
            self.metrics.record_batch(xs.len().max(1));
        }
        // One Enqueue per streamed request (its samples share the wire
        // id): the trace tracks request lifecycles, not per-sample
        // queue membership. Head-sampled like every lifecycle event.
        if let Some(r) = &self.intake_ring {
            if self.obs.sampler.sampled(id) {
                r.emit(EventKind::Enqueue, id, model as u64, 0, 0);
            }
        }
        let t_enqueue = Instant::now();
        for (slot, x) in xs.into_iter().enumerate() {
            let req = InferRequest {
                id,
                model,
                x,
                xi: None,
                slot,
                t_enqueue,
                reply: ReplyTo::Stream(Arc::clone(&sink)),
                ctl: Some(Arc::clone(&ctl)),
            };
            if let Err(e) = self.try_dispatch(req) {
                ctl.cancel();
                return Err(e);
            }
        }
        Ok(())
    }

    /// Submit one *batched* request to model 0: its samples are split
    /// across the worker shards (so a large batch executes in
    /// parallel) and the responses arrive as a single `Vec` in input
    /// order.
    pub fn submit_batch(&self, xs: Vec<Vec<f32>>) -> Receiver<Vec<InferResponse>> {
        self.submit_batch_to(0, xs).expect("model 0 always exists")
    }

    /// Submit one batched request to `model` (see
    /// [`Coordinator::submit_batch`]).
    pub fn submit_batch_to(
        &self,
        model: u32,
        xs: Vec<Vec<f32>>,
    ) -> Result<Receiver<Vec<InferResponse>>, SubmitError> {
        if (model as usize) >= self.models.len() {
            return Err(SubmitError::UnknownModel);
        }
        let (rtx, rrx) = channel();
        if xs.is_empty() {
            let _ = rtx.send(Vec::new());
            return Ok(rrx);
        }
        // The Pjrt executor re-batches dynamically and records its own
        // batch sizes; for the sharded pool the split request *is* the
        // batch, recorded here.
        if matches!(self.intake, Intake::Pool(_)) {
            self.metrics.record_batch(xs.len());
        }
        let sink = Arc::new(BatchSink::new(xs.len(), rtx));
        let t_enqueue = Instant::now();
        for (slot, x) in xs.into_iter().enumerate() {
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            if let Some(r) = &self.intake_ring {
                if self.obs.sampler.sampled(id) {
                    r.emit(EventKind::Enqueue, id, model as u64, 0, 0);
                }
            }
            self.dispatch(InferRequest {
                id,
                model,
                x,
                xi: None,
                slot,
                t_enqueue,
                reply: ReplyTo::Batch(Arc::clone(&sink)),
                ctl: None,
            });
        }
        Ok(rrx)
    }

    /// Close the intake through a shared handle: queued requests still
    /// drain, later submissions fail ([`Coordinator::submit_streamed`]
    /// returns `Err`; the infallible in-process paths panic). Safe to
    /// call more than once. This is the serve listener's half of the
    /// close-listener → drain-sessions → close-pool shutdown order.
    pub fn close(&self) {
        match &self.intake {
            Intake::Pool(pool) => pool.close(),
            Intake::Chan(tx) => drop(lock_recover(tx).take()),
        }
    }

    /// Join all workers (after [`Coordinator::close`]): returns once
    /// every queued request has drained and the threads exited. Safe to
    /// call more than once (later calls are no-ops).
    pub fn join_workers(&self) {
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *lock_recover(&self.handles));
        for h in handles {
            // The supervisor absorbs worker panics; a panic escaping it
            // (catastrophic) must not cascade into the joining thread.
            let _ = h.join();
        }
    }

    /// Close the intake and join all workers (queued requests drain
    /// first — nothing is dropped).
    pub fn shutdown(self) {
        self.close();
        self.join_workers();
    }
}

/// Dropping the handle without [`Coordinator::shutdown`] (early
/// return, panic unwind) must not leak spinning worker threads: close
/// the intake so workers drain and exit on their own. `shutdown` is
/// still the graceful path — it additionally joins them.
impl Drop for Coordinator {
    fn drop(&mut self) {
        self.close();
    }
}

/// What a worker holds when it might die: enough to route the
/// `Failed` terminal outcome to the waiting client instead of losing
/// the request. Stashed at dequeue, taken back on the normal reply
/// path, reconciled by the panic supervisor otherwise.
struct InFlight {
    ctl: Option<Arc<RequestCtl>>,
    reply: ReplyTo,
}

/// Terminal-fail a request stranded by a worker panic. A streamed
/// request gets exactly one `Failed` status through its sink iff the
/// supervisor wins the ctl's terminal CAS (a concurrent cancel/expiry
/// may beat it — then that outcome already answered the client).
/// In-process callers have no ctl: dropping the stashed reply
/// disconnects their channel, which is their failure signal.
fn fail_inflight(fl: InFlight, metrics: &Metrics) {
    let won = match &fl.ctl {
        Some(ctl) => ctl.fail(),
        None => true,
    };
    if won {
        metrics.record_failed();
        if let ReplyTo::Stream(sink) = fl.reply {
            sink.fail();
        }
    }
}

/// [`LayerSink`] adapter: forwards per-layer engine spans into the
/// owning worker's flight-recorder ring. The span start is
/// reconstructed from "now minus duration" so the engine itself needs
/// no handle on the ring's clock.
struct RingSink<'a> {
    ring: &'a TraceRing,
    id: u64,
}

impl LayerSink for RingSink<'_> {
    fn layer(&self, index: usize, elapsed_ns: u64, kept: u64, skipped: u64) {
        let dur_us = elapsed_ns / 1000;
        let t_us = self.ring.now_us().saturating_sub(dur_us);
        self.ring.span(EventKind::Layer, self.id, t_us, dur_us, index as u64, kept, skipped);
    }
}

#[allow(clippy::too_many_arguments)]
fn mcu_worker(
    worker: usize,
    pool: &ShardPool<InferRequest>,
    models: &[ModelEntry],
    metrics: &Metrics,
    tap: &EnergyTapSlot,
    fault: Option<&FaultPlan>,
    ring: Option<&TraceRing>,
    sampler: TraceSampler,
    inflight: &Mutex<Option<InFlight>>,
) {
    let energy = EnergyModel::default();
    // Per-worker, per-model `(generation, plan, scratch)` cache: no
    // allocation on the request path once a model has served. The
    // scratch arena is re-sized only when that model's plan is swapped
    // (same model ⇒ same sizes in practice, but a realloc per swap is
    // cheap insurance against a differently shaped plan). With one
    // model loaded this is exactly the old single-slot fast path.
    let mut cached: Vec<Option<(u64, Arc<PlannedModel>, Scratch)>> =
        models.iter().map(|_| None).collect();
    while let Some(mut req) = pool.pop(worker) {
        // Tombstone drop: a cancelled/expired request is discarded at
        // dequeue — no inference, no reply, no shard occupancy beyond
        // this O(1) check.
        if req.ctl.as_ref().is_some_and(|c| c.is_dead()) {
            metrics.record_dropped();
            continue;
        }
        // Pick up the owning model's active plan for this request: the
        // control plane swaps slots between requests, never under one.
        // The generation probe makes inline swaps *and*
        // background-compile upgrades visible for one atomic load; the
        // slot lock is touched only when a swap actually happened.
        // Submit paths validate the model id, so a missing row here is
        // a bug — degrade to a tombstone drop, never a panic.
        let m = req.model as usize;
        let Some(slot) = models.get(m).and_then(|e| e.plan.as_ref()) else {
            if let Some(ctl) = &req.ctl {
                ctl.cancel();
            }
            metrics.record_dropped();
            continue;
        };
        // Generation is read BEFORE the plan: a swap landing in
        // between then pairs the new plan with a stale generation,
        // which only costs one redundant re-read at the next dequeue.
        // (The other order would pair the OLD plan with the NEW
        // generation and pin the worker on a stale plan until the next
        // swap.)
        let gen = slot.generation();
        let stale = match &cached[m] {
            Some((g, _, _)) => *g != gen,
            None => true,
        };
        if stale {
            let cur = slot.get();
            match &mut cached[m] {
                Some((g, plan, scratch)) => {
                    *g = gen;
                    if !Arc::ptr_eq(&cur, plan) {
                        *scratch = cur.new_scratch();
                        *plan = cur;
                    }
                }
                entry @ None => {
                    let scratch = cur.new_scratch();
                    *entry = Some((gen, cur, scratch));
                }
            }
        }
        let (_, plan, scratch) = cached[m].as_mut().expect("model cache filled above");
        // Stash what we are about to execute: if this iteration
        // panics, the supervisor fails the request from the stash
        // instead of losing it. The reply handle moves into the stash
        // (it is not Clone) and moves back out on the normal path.
        let is_single = matches!(req.reply, ReplyTo::Single(_));
        *lock_recover(inflight) = Some(InFlight { ctl: req.ctl.clone(), reply: req.reply });
        if fault.is_some_and(|f| f.inject_panic()) {
            panic!("injected worker panic (chaos plan, seed {})", fault.unwrap().seed());
        }
        let t_deq = Instant::now();
        let queue_us = t_deq.duration_since(req.t_enqueue).as_micros() as u64;
        // Head-based sampling: one hash of the request id decides
        // whether this request records its spans. Unsampled requests
        // take the exact unobserved path below — same as no ring.
        let traced = ring.filter(|_| sampler.sampled(req.id));
        if let Some(r) = traced {
            r.emit(EventKind::Dequeue, req.id, worker as u64, 0, 0);
        }
        // Cost-weighted dispatch already quantized the input; reuse it.
        let xi = match req.xi.take() {
            Some(xi) => xi,
            None => plan.quantize_input(&req.x),
        };
        // The observed path and the plain one run the same kernels on
        // the same plan; with no ring (or an unsampled request) the
        // sink is `None` and the engine takes no timestamps at all
        // (bit-identical output).
        let out = match traced {
            Some(r) => {
                let sink = RingSink { ring: r, id: req.id };
                plan.infer_observed(&xi, scratch, Some(&sink))
            }
            None => plan.infer(&xi, scratch),
        };
        let service_us = t_deq.elapsed().as_micros() as u64;
        if let Some(r) = traced {
            let t_us = r.now_us().saturating_sub(service_us);
            r.span(
                EventKind::Service,
                req.id,
                t_us,
                service_us,
                worker as u64,
                req.model as u64,
                0,
            );
            metrics.record_layers(req.model, &out.kept, &out.skipped);
        }
        let macs = out.ledger.counts.macs;
        let resp = InferResponse {
            id: req.id,
            predicted: out.argmax(),
            mac_skipped: out.skip_fraction(),
            energy_mj: out.ledger.millijoules(&energy),
            mcu_secs: out.ledger.secs(),
            logits: out.logits,
            queue_us,
            service_us,
            latency_us: queue_us + service_us,
        };
        if is_single {
            metrics.record_batch(1);
        }
        metrics.record_request(
            m,
            queue_us,
            service_us,
            resp.mac_skipped,
            resp.energy_mj,
            resp.mcu_secs,
            macs,
        );
        let energy_mj = resp.energy_mj;
        // Model-level keep ratio of this inference: the drift
        // detector's feedback signal, complementary to the skip
        // fraction already on the response.
        let keep_ratio = 1.0 - resp.mac_skipped;
        // Normal path: take the reply back out of the stash — from
        // here on a panic has nothing to reconcile.
        let fl = lock_recover(inflight).take().expect("in-flight stash present");
        fl.reply.deliver(req.slot, resp);
        // Feed the control plane AFTER delivering the reply: a scale
        // change (and a possible cache-miss compile) never sits
        // between a finished inference and its client. Clone the Arc
        // out of the lock so a slow observe holds no lock. The
        // model-attributed variants default to the plain ones, so a
        // single-model governor is oblivious to the id.
        let observer = read_recover(tap).clone();
        if let Some(observer) = observer {
            observer.observe_model(req.model, energy_mj);
            observer.observe_keep_model(req.model, keep_ratio);
            observer.sample_input_model(req.model, &req.x);
        }
    }
}

fn pjrt_executor(
    rx: Receiver<InferRequest>,
    model: String,
    params: Params,
    t_vec: Vec<f32>,
    fat_t: f32,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    // The PJRT client must be created inside the owning thread (Rc-based).
    let rt = crate::runtime::Runtime::cpu().expect("PJRT client");
    let store = crate::runtime::ArtifactStore::discover();
    let batch = policy.max_batch;
    let exe = store.load_fwd(&rt, &model, batch).expect("fwd artifact");
    let manifest = store.manifest(&model).expect("manifest");
    let sample_len: usize = {
        let [c, h, w] = manifest.input_shape;
        c * h * w
    };
    // Sessions validate wire requests against the zoo definition
    // (`Coordinator::input_len`); the executor packs against the
    // artifact manifest. They must be the same model — disagree loudly
    // at startup rather than silently dropping admitted requests.
    assert_eq!(
        sample_len,
        crate::models::zoo(&model).input_len(),
        "artifact manifest input shape disagrees with the model zoo for {model}"
    );
    let classes = manifest.classes;
    let flat: Vec<Vec<f32>> = params.flat_order().into_iter().map(|s| s.to_vec()).collect();
    let fat = [fat_t];

    let batcher = Batcher { policy };
    while let Some(reqs) = batcher.collect(&rx) {
        let t_svc = Instant::now();
        // Same tombstone contract as mcu_worker: cancelled/expired
        // streamed requests are dropped at dequeue, not executed with
        // the reply thrown away. And defense in depth: sessions
        // validate wire sample lengths, but a malformed request must
        // degrade to a dropped sample, never a panic that kills the
        // only executor thread.
        let mut reqs = reqs;
        reqs.retain(|r| {
            let dead = r.ctl.as_ref().is_some_and(|c| c.is_dead());
            if dead || r.x.len() != sample_len {
                // Tombstone a streamed request we are discarding so its
                // suppression semantics (and any session bookkeeping
                // keyed to the ctl leaving Active) still engage.
                if let Some(ctl) = &r.ctl {
                    ctl.cancel();
                }
                metrics.record_dropped();
                return false;
            }
            true
        });
        if reqs.is_empty() {
            continue;
        }
        let mut bx = vec![0.0f32; batch * sample_len];
        for (i, r) in reqs.iter().enumerate() {
            bx[i * sample_len..(i + 1) * sample_len].copy_from_slice(&r.x);
        }
        let mut args: Vec<&[f32]> = flat.iter().map(|t| t.as_slice()).collect();
        args.push(&bx);
        args.push(&t_vec);
        args.push(&fat);
        let out = exe.run_f32(&args).expect("pjrt execute");
        let logits_all = &out[0];
        metrics.record_batch(reqs.len());
        let service_us = t_svc.elapsed().as_micros() as u64;
        for (i, req) in reqs.into_iter().enumerate() {
            let logits = logits_all[i * classes..(i + 1) * classes].to_vec();
            let queue_us = t_svc.duration_since(req.t_enqueue).as_micros() as u64;
            let resp = InferResponse {
                id: req.id,
                predicted: argmax(&logits),
                logits,
                mac_skipped: 0.0,
                energy_mj: 0.0,
                mcu_secs: 0.0,
                queue_us,
                service_us,
                latency_us: queue_us + service_us,
            };
            metrics.record_request(0, queue_us, service_us, 0.0, 0.0, 0.0, 0);
            req.reply.deliver(req.slot, resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Params};

    #[test]
    fn mcu_backend_serves_and_shuts_down() {
        let def = zoo("mnist");
        let params = Params::random(&def, 1);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 2, ..Default::default() },
        );
        let rxs: Vec<_> =
            (0..6).map(|i| coord.submit(vec![0.1 * i as f32; def.input_len()])).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.logits.len(), 10);
            assert!(resp.mcu_secs > 0.0);
            assert_eq!(resp.latency_us, resp.queue_us + resp.service_us);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.served, 6);
        coord.shutdown();
    }

    #[test]
    fn no_request_lost_under_load() {
        let def = zoo("mnist");
        let params = Params::random(&def, 2);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Tree },
            ServeConfig { workers: 3, ..Default::default() },
        );
        let n = 24;
        let rxs: Vec<_> = (0..n).map(|_| coord.submit(vec![0.2; def.input_len()])).collect();
        let mut got = 0;
        for rx in rxs {
            rx.recv().unwrap();
            got += 1;
        }
        assert_eq!(got, n);
        assert_eq!(coord.metrics.snapshot().served, n as u64);
        coord.shutdown();
    }

    #[test]
    fn batch_submission_splits_and_reassembles_in_order() {
        let def = zoo("mnist");
        let params = Params::random(&def, 3);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 3, ..Default::default() },
        );
        let n = 17usize; // larger than the worker count: forces a split
        let xs: Vec<Vec<f32>> =
            (0..n).map(|i| vec![0.05 * i as f32; def.input_len()]).collect();
        let rx = coord.submit_batch(xs);
        let out = rx.recv().unwrap();
        assert_eq!(out.len(), n);
        // Ids are assigned sequentially at submit; input order must
        // survive the cross-worker split.
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id - out[0].id, i as u64, "batch slot {i} reordered");
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.served, n as u64);
        assert_eq!(snap.batches, 1);
        coord.shutdown();
    }

    #[test]
    fn dropping_without_shutdown_drains_and_stops_workers() {
        let def = zoo("mnist");
        let params = Params::random(&def, 5);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 2, ..Default::default() },
        );
        let rxs: Vec<_> =
            (0..4).map(|i| coord.submit(vec![0.1 * i as f32; def.input_len()])).collect();
        drop(coord); // no shutdown(): Drop must close the pool, workers drain
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert_eq!(resp.logits.len(), 10);
        }
    }

    #[test]
    fn placement_policies_serve_identical_results() {
        let def = zoo("mnist");
        let params = Params::random(&def, 6);
        let q = QModel::quantize(&def, &params);
        let xs: Vec<Vec<f32>> =
            (0..8).map(|i| vec![0.07 * i as f32; def.input_len()]).collect();
        let mut by_policy = Vec::new();
        for placement in [Placement::TwoChoice, Placement::CostWeighted] {
            let coord = Coordinator::start(
                BackendChoice::McuSim {
                    q: q.clone(),
                    mode: PruneMode::Unit,
                    div: DivKind::Shift,
                },
                ServeConfig { workers: 3, placement, ..Default::default() },
            );
            let out = coord.submit_batch(xs.clone()).recv().unwrap();
            by_policy.push(out.iter().map(|r| r.logits.clone()).collect::<Vec<_>>());
            coord.shutdown();
        }
        assert_eq!(by_policy[0], by_policy[1], "placement changed results");
    }

    #[test]
    fn multi_model_routes_to_the_right_plan_bit_identically() {
        let def = zoo("mnist");
        let qa = QModel::quantize(&def, &Params::random(&def, 21));
        let qb = QModel::quantize(&def, &Params::random(&def, 22));
        let x: Vec<f32> =
            (0..def.input_len()).map(|i| ((i % 13) as f32 - 6.0) / 5.0).collect();
        // Reference: each model served alone.
        let mut solo = Vec::new();
        for q in [&qa, &qb] {
            let coord = Coordinator::start(
                BackendChoice::McuSim {
                    q: q.clone(),
                    mode: PruneMode::Unit,
                    div: DivKind::Shift,
                },
                ServeConfig { workers: 2, ..Default::default() },
            );
            solo.push(coord.submit(x.clone()).recv().unwrap().logits);
            coord.shutdown();
        }
        assert_ne!(solo[0], solo[1], "distinct params must disagree");
        let coord = Coordinator::start_multi(
            vec![
                ModelSpec { name: "a".into(), q: qa, mode: PruneMode::Unit, div: DivKind::Shift },
                ModelSpec { name: "b".into(), q: qb, mode: PruneMode::Unit, div: DivKind::Shift },
            ],
            ServeConfig { workers: 2, ..Default::default() },
        );
        assert_eq!(coord.model_count(), 2);
        assert_eq!(coord.model_id_of("b"), Some(1));
        assert_eq!(coord.model_name(1), Some("b"));
        assert_eq!(coord.input_len_of(1), Some(def.input_len()));
        assert_eq!(coord.input_len_of(2), None, "unknown id must not resolve");
        // Interleave the tenants: every reply must come from the
        // request's own model, bit-identical to solo serving.
        for _ in 0..3 {
            let ra = coord.submit_to(0, x.clone()).unwrap();
            let rb = coord.submit_to(1, x.clone()).unwrap();
            assert_eq!(ra.recv().unwrap().logits, solo[0], "model a diverged from solo run");
            assert_eq!(rb.recv().unwrap().logits, solo[1], "model b diverged from solo run");
        }
        assert_eq!(coord.submit_to(7, x.clone()).err(), Some(SubmitError::UnknownModel));
        assert_eq!(coord.submit_batch_to(7, vec![x]).err(), Some(SubmitError::UnknownModel));
        coord.shutdown();
    }

    #[test]
    fn streamed_submit_to_unknown_model_tombstones() {
        struct Devnull;
        impl StreamSink for Devnull {
            fn put(&self, _slot: usize, _resp: InferResponse) {}
        }
        let def = zoo("mnist");
        let q = QModel::quantize(&def, &Params::random(&def, 23));
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 1, ..Default::default() },
        );
        let ctl = RequestCtl::shared();
        let err = coord.submit_streamed(
            1,
            5,
            vec![vec![0.0; def.input_len()]],
            Arc::clone(&ctl),
            Arc::new(Devnull),
        );
        assert_eq!(err, Err(SubmitError::UnknownModel));
        assert!(ctl.is_dead(), "failed submit must tombstone the request");
        coord.shutdown();
    }

    #[test]
    fn streamed_submit_after_close_errors_instead_of_panicking() {
        use crate::coordinator::request::{InferResponse, RequestCtl, StreamSink};
        struct Devnull;
        impl StreamSink for Devnull {
            fn put(&self, _slot: usize, _resp: InferResponse) {}
        }
        let def = zoo("mnist");
        let params = Params::random(&def, 7);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 1, ..Default::default() },
        );
        coord.close();
        let ctl = RequestCtl::shared();
        let err = coord.submit_streamed(
            1,
            0,
            vec![vec![0.0; def.input_len()]],
            Arc::clone(&ctl),
            Arc::new(Devnull),
        );
        assert_eq!(err, Err(SubmitError::Closed));
        assert!(ctl.is_dead(), "failed submit must tombstone the request");
        coord.join_workers();
    }

    #[test]
    fn plan_slot_generation_tracks_swaps() {
        let def = zoo("mnist");
        let params = Params::random(&def, 8);
        let q = QModel::quantize(&def, &params);
        let cfg = PlanConfig::for_mode(PruneMode::Dense, DivKind::Shift);
        let a = Arc::new(PlannedModel::compile(&q, cfg));
        let b = Arc::new(PlannedModel::compile(&q, PlanConfig { t_scale_q8: 512, ..cfg }));
        let slot = PlanSlot::new(Arc::clone(&a));
        let g0 = slot.generation();
        assert!(Arc::ptr_eq(&slot.get(), &a));
        let old = slot.swap(Arc::clone(&b));
        assert!(Arc::ptr_eq(&old, &a), "swap must return the replaced plan");
        assert!(slot.generation() > g0, "generation must move on swap");
        assert!(Arc::ptr_eq(&slot.get(), &b));
        let g1 = slot.generation();
        slot.swap(a);
        assert!(slot.generation() > g1);
    }

    #[test]
    fn submit_after_close_disconnects_instead_of_panicking() {
        let def = zoo("mnist");
        let params = Params::random(&def, 10);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 1, ..Default::default() },
        );
        coord.close();
        // Regression: these in-process paths used to panic inside the
        // shard pool when racing shutdown; they must now degrade to a
        // disconnected reply channel.
        let rx = coord.submit(vec![0.0; def.input_len()]);
        assert!(rx.recv().is_err(), "closed intake must disconnect, not serve");
        let brx = coord.submit_batch(vec![vec![0.0; def.input_len()]; 2]);
        assert!(brx.recv().is_err());
        coord.join_workers();
    }

    #[test]
    fn worker_panics_are_contained_and_requests_fail_terminally() {
        use crate::util::FaultRates;
        let def = zoo("mnist");
        let params = Params::random(&def, 11);
        let q = QModel::quantize(&def, &params);
        let fault = Arc::new(FaultPlan::with_rates(
            7,
            FaultRates { panic_rate: 1.0, ..FaultRates::default() },
        ));
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 2, fault: Some(fault), ..Default::default() },
        );
        // Every dequeue panics: each request must end disconnected
        // (failed), never hang, and the pool must keep accepting work
        // (respawns) rather than bleed workers.
        let n = 6u64;
        for i in 0..n {
            let rx = coord.submit(vec![0.01 * i as f32; def.input_len()]);
            assert!(
                rx.recv_timeout(Duration::from_secs(30)).is_err(),
                "request {i} should fail via disconnect"
            );
        }
        coord.close();
        coord.join_workers();
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.worker_panics, n, "one caught panic per request");
        assert_eq!(snap.respawns, n, "every caught panic must respawn");
        assert_eq!(snap.failed, n, "every stranded request must fail terminally");
    }

    #[test]
    fn panic_fails_streamed_request_exactly_once() {
        use crate::coordinator::request::CtlState;
        use crate::util::FaultRates;
        struct FailCounter {
            fails: AtomicU64,
        }
        impl StreamSink for FailCounter {
            fn put(&self, _slot: usize, _resp: InferResponse) {}
            fn fail(&self) {
                self.fails.fetch_add(1, Ordering::SeqCst);
            }
        }
        let def = zoo("mnist");
        let params = Params::random(&def, 12);
        let q = QModel::quantize(&def, &params);
        let fault = Arc::new(FaultPlan::with_rates(
            3,
            FaultRates { panic_rate: 1.0, ..FaultRates::default() },
        ));
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 1, fault: Some(fault), ..Default::default() },
        );
        let sink = Arc::new(FailCounter { fails: AtomicU64::new(0) });
        let ctl = RequestCtl::shared();
        // Three samples, one worker: the first dequeue panics and wins
        // the fail CAS; the remaining samples are tombstone-dropped at
        // dequeue — the client hears `Failed` exactly once.
        coord
            .submit_streamed(
                1,
                0,
                vec![vec![0.2; def.input_len()]; 3],
                Arc::clone(&ctl),
                Arc::clone(&sink) as Arc<dyn StreamSink>,
            )
            .unwrap();
        let t0 = Instant::now();
        while !ctl.is_dead() && t0.elapsed() < Duration::from_secs(30) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(ctl.state(), CtlState::Failed);
        coord.close();
        coord.join_workers();
        assert_eq!(sink.fails.load(Ordering::SeqCst), 1, "exactly one Failed notification");
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.failed, 1);
        assert!(snap.worker_panics >= 1);
        assert_eq!(snap.dropped, 2, "surviving samples tombstone-dropped");
    }

    #[test]
    fn flight_recorder_captures_request_lifecycle_bit_identically() {
        let def = zoo("mnist");
        let q = QModel::quantize(&def, &Params::random(&def, 31));
        let xs: Vec<Vec<f32>> =
            (0..4).map(|i| vec![0.09 * i as f32; def.input_len()]).collect();
        // Reference run with observability off.
        let coord = Coordinator::start(
            BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Shift },
            ServeConfig { workers: 2, ..Default::default() },
        );
        assert!(coord.recorder().is_none(), "obs off by default");
        let baseline: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| coord.submit(x.clone()).recv().unwrap().logits)
            .collect();
        coord.shutdown();
        // Observed run: same logits, full event lifecycle on the rings.
        let obs = ObsConfig::enabled();
        let rec = obs.recorder.clone().unwrap();
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
            ServeConfig { workers: 2, obs, ..Default::default() },
        );
        assert!(coord.recorder().is_some());
        for (i, x) in xs.iter().enumerate() {
            let got = coord.submit(x.clone()).recv().unwrap().logits;
            assert_eq!(got, baseline[i], "observed serving changed sample {i}");
        }
        coord.shutdown();
        let events: Vec<crate::obs::Event> =
            rec.rings().iter().flat_map(|r| r.snapshot()).collect();
        let count = |k: EventKind| events.iter().filter(|e| e.kind == k).count();
        assert_eq!(count(EventKind::Enqueue), 4);
        assert_eq!(count(EventKind::Dequeue), 4);
        assert_eq!(count(EventKind::Service), 4);
        // mnist has >1 layers: at least one Layer span per request,
        // and the spans' executed/skipped MACs aggregate into the
        // per-layer table exactly.
        assert!(count(EventKind::Layer) >= 4, "per-layer spans missing");
        let span_kept: u64 = events
            .iter()
            .filter(|e| e.kind == EventKind::Layer)
            .map(|e| e.b)
            .sum();
        let table: u64 = coord.metrics.layer_totals()[0].iter().map(|&(k, _)| k).sum();
        assert_eq!(span_kept, table, "Layer spans and aggregate table disagree");
    }

    #[test]
    fn sample_rate_zero_is_bit_identical_and_records_no_spans() {
        // The head-sampling acceptance property: observability ON with
        // --trace-sample-rate 0 must produce bit-identical logits and
        // MAC counters to the fully unobserved path, and zero
        // request-lifecycle events. Random inputs across prune modes.
        crate::util::prop::check(0x5A0B, 6, |g| {
            let def = zoo("mnist");
            let q = QModel::quantize(&def, &Params::random(&def, g.usize_in(0, 1 << 20) as u64));
            let xs: Vec<Vec<f32>> = (0..3)
                .map(|_| {
                    (0..def.input_len())
                        .map(|i| ((g.usize_in(0, 200) as f32) / 100.0 - 1.0) * (1.0 + i as f32 % 3.0))
                        .collect()
                })
                .collect();
            let coord = Coordinator::start(
                BackendChoice::McuSim { q: q.clone(), mode: PruneMode::Unit, div: DivKind::Shift },
                ServeConfig { workers: 2, ..Default::default() },
            );
            let baseline: Vec<_> = xs
                .iter()
                .map(|x| {
                    let r = coord.submit(x.clone()).recv().unwrap();
                    (r.logits, r.mac_skipped)
                })
                .collect();
            coord.shutdown();
            let obs = ObsConfig::enabled_sampled(0.0);
            let rec = obs.recorder.clone().unwrap();
            let coord = Coordinator::start(
                BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Shift },
                ServeConfig { workers: 2, obs, ..Default::default() },
            );
            for (i, x) in xs.iter().enumerate() {
                let r = coord.submit(x.clone()).recv().unwrap();
                assert_eq!(r.logits, baseline[i].0, "rate-0 sampling changed logits {i}");
                assert_eq!(r.mac_skipped, baseline[i].1, "rate-0 sampling changed MACs {i}");
            }
            coord.shutdown();
            let events: Vec<crate::obs::Event> =
                rec.rings().iter().flat_map(|r| r.snapshot()).collect();
            let lifecycle = events
                .iter()
                .filter(|e| {
                    matches!(
                        e.kind,
                        EventKind::Enqueue
                            | EventKind::Dequeue
                            | EventKind::Service
                            | EventKind::Layer
                    )
                })
                .count();
            assert_eq!(lifecycle, 0, "rate 0 must record no request events");
            assert!(coord.metrics.layer_totals().iter().all(|m| m.is_empty()));
        });
    }

    #[test]
    fn empty_batch_resolves_immediately() {
        let def = zoo("mnist");
        let params = Params::random(&def, 4);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig::default(),
        );
        let out = coord.submit_batch(Vec::new()).recv().unwrap();
        assert!(out.is_empty());
        coord.shutdown();
    }
}
