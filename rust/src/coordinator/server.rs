//! The serving runtime: request intake, dynamic batching, worker pool.
//!
//! Thread topology:
//!
//! * **McuSim backend** — N worker threads share the request queue
//!   (`Arc<Mutex<Receiver>>`); each runs the fixed-point engine on one
//!   sample at a time, exactly as the target MCU would, and reports the
//!   modeled cycles/energy with the prediction. The engine runs on a
//!   shared prepacked [`PlannedModel`] (compiled once at start-up) with
//!   a per-worker scratch arena — bit-identical to the naive engine,
//!   several times faster on the host, zero allocation per request.
//! * **Pjrt backend** — a single executor thread *owns* the PJRT client
//!   (the `xla` crate's client is `Rc`-based and not `Send`, so it is
//!   created inside the thread), batches requests up to the artifact's
//!   batch size (8), zero-pads partial batches, and fans results back
//!   out.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{InferRequest, InferResponse};
use crate::approx::DivKind;
use crate::engine::{PlanConfig, PlannedModel, PruneMode, QModel};
use crate::mcu::EnergyModel;
use crate::models::Params;
use crate::util::stats::argmax;

/// Which execution backend serves requests.
#[derive(Debug, Clone)]
pub enum BackendChoice {
    /// Fixed-point MCU simulator with the given pruning setup.
    McuSim { q: QModel, mode: PruneMode, div: DivKind },
    /// Float AOT artifact at batch 8 through PJRT.
    Pjrt {
        model: String,
        params: Params,
        /// Per-layer UnIT thresholds fed to the artifact.
        t_vec: Vec<f32>,
        fat_t: f32,
    },
}

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<Sender<InferRequest>>,
    handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start serving with the chosen backend.
    pub fn start(backend: BackendChoice, cfg: ServeConfig) -> Coordinator {
        let (tx, rx) = channel::<InferRequest>();
        let metrics = Arc::new(Metrics::new());
        let handles = match backend {
            BackendChoice::McuSim { q, mode, div } => {
                let shared = Arc::new(Mutex::new(rx));
                // Compile the execution plan once; workers share the
                // packed tables (read-only) and own their scratch.
                let plan = Arc::new(PlannedModel::compile(&q, PlanConfig::for_mode(mode, div)));
                (0..cfg.workers.max(1))
                    .map(|_| {
                        let rx = Arc::clone(&shared);
                        let plan = Arc::clone(&plan);
                        let metrics = Arc::clone(&metrics);
                        std::thread::spawn(move || mcu_worker(rx, plan, metrics))
                    })
                    .collect()
            }
            BackendChoice::Pjrt { model, params, t_vec, fat_t } => {
                let metrics = Arc::clone(&metrics);
                let policy = BatchPolicy { max_batch: cfg.max_batch.min(8), max_wait: cfg.max_wait };
                vec![std::thread::spawn(move || {
                    pjrt_executor(rx, model, params, t_vec, fat_t, policy, metrics)
                })]
            }
        };
        Coordinator { tx: Some(tx), handles, next_id: AtomicU64::new(0), metrics }
    }

    /// Submit one request; returns the response channel.
    pub fn submit(&self, x: Vec<f32>) -> Receiver<InferResponse> {
        let (rtx, rrx) = channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            x,
            t_enqueue: Instant::now(),
            reply: rtx,
        };
        self.tx.as_ref().expect("coordinator closed").send(req).expect("queue closed");
        rrx
    }

    /// Close the intake and join all workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close channel
        for h in self.handles.drain(..) {
            h.join().expect("worker panicked");
        }
    }
}

fn mcu_worker(
    rx: Arc<Mutex<Receiver<InferRequest>>>,
    plan: Arc<PlannedModel>,
    metrics: Arc<Metrics>,
) {
    let energy = EnergyModel::default();
    // Per-worker scratch arena: no allocation on the request path.
    let mut scratch = plan.new_scratch();
    loop {
        let req = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(req) = req else { break };
        let xi = plan.quantize_input(&req.x);
        let out = plan.infer(&xi, &mut scratch);
        let latency_us = req.t_enqueue.elapsed().as_micros() as u64;
        let resp = InferResponse {
            id: req.id,
            predicted: out.argmax(),
            mac_skipped: out.skip_fraction(),
            energy_mj: out.ledger.millijoules(&energy),
            mcu_secs: out.ledger.secs(),
            logits: out.logits,
            latency_us,
        };
        metrics.record_batch(1);
        metrics.record_request(latency_us, resp.mac_skipped, resp.energy_mj, resp.mcu_secs);
        let _ = req.reply.send(resp); // receiver may have gone away
    }
}

fn pjrt_executor(
    rx: Receiver<InferRequest>,
    model: String,
    params: Params,
    t_vec: Vec<f32>,
    fat_t: f32,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    // The PJRT client must be created inside the owning thread (Rc-based).
    let rt = crate::runtime::Runtime::cpu().expect("PJRT client");
    let store = crate::runtime::ArtifactStore::discover();
    let batch = policy.max_batch;
    let exe = store.load_fwd(&rt, &model, batch).expect("fwd artifact");
    let manifest = store.manifest(&model).expect("manifest");
    let sample_len: usize = {
        let [c, h, w] = manifest.input_shape;
        c * h * w
    };
    let classes = manifest.classes;
    let flat: Vec<Vec<f32>> = params.flat_order().into_iter().map(|s| s.to_vec()).collect();
    let fat = [fat_t];

    let batcher = Batcher { policy };
    while let Some(reqs) = batcher.collect(&rx) {
        let mut bx = vec![0.0f32; batch * sample_len];
        for (i, r) in reqs.iter().enumerate() {
            bx[i * sample_len..(i + 1) * sample_len].copy_from_slice(&r.x);
        }
        let mut args: Vec<&[f32]> = flat.iter().map(|t| t.as_slice()).collect();
        args.push(&bx);
        args.push(&t_vec);
        args.push(&fat);
        let out = exe.run_f32(&args).expect("pjrt execute");
        let logits_all = &out[0];
        metrics.record_batch(reqs.len());
        for (i, req) in reqs.into_iter().enumerate() {
            let logits = logits_all[i * classes..(i + 1) * classes].to_vec();
            let latency_us = req.t_enqueue.elapsed().as_micros() as u64;
            let resp = InferResponse {
                id: req.id,
                predicted: argmax(&logits),
                logits,
                mac_skipped: 0.0,
                energy_mj: 0.0,
                mcu_secs: 0.0,
                latency_us,
            };
            metrics.record_request(latency_us, 0.0, 0.0, 0.0);
            let _ = req.reply.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Params};

    #[test]
    fn mcu_backend_serves_and_shuts_down() {
        let def = zoo("mnist");
        let params = Params::random(&def, 1);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 2, ..Default::default() },
        );
        let rxs: Vec<_> =
            (0..6).map(|i| coord.submit(vec![0.1 * i as f32; def.input_len()])).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.logits.len(), 10);
            assert!(resp.mcu_secs > 0.0);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.served, 6);
        coord.shutdown();
    }

    #[test]
    fn no_request_lost_under_load() {
        let def = zoo("mnist");
        let params = Params::random(&def, 2);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Tree },
            ServeConfig { workers: 3, ..Default::default() },
        );
        let n = 24;
        let rxs: Vec<_> = (0..n).map(|_| coord.submit(vec![0.2; def.input_len()])).collect();
        let mut got = 0;
        for rx in rxs {
            rx.recv().unwrap();
            got += 1;
        }
        assert_eq!(got, n);
        assert_eq!(coord.metrics.snapshot().served, n as u64);
        coord.shutdown();
    }
}
