//! The serving runtime: request intake, dynamic batching, worker pool.
//!
//! Thread topology:
//!
//! * **McuSim backend** — N worker threads, each owning one shard of a
//!   work-stealing [`ShardPool`] (see [`super::shard`]): `submit`
//!   spreads load round-robin/least-loaded across the per-worker
//!   deques, idle workers steal from the longest queue, and
//!   [`Coordinator::submit_batch`] splits one request's samples across
//!   shards and reassembles them in input order. Each worker runs the
//!   fixed-point engine on one sample at a time, exactly as the target
//!   MCU would, and reports the modeled cycles/energy with the
//!   prediction. The engine runs on a shared prepacked
//!   [`PlannedModel`] (compiled once at start-up) with a per-worker
//!   scratch arena — bit-identical to the naive engine, several times
//!   faster on the host, zero allocation per request.
//! * **Pjrt backend** — a single executor thread *owns* the PJRT client
//!   (the `xla` crate's client is `Rc`-based and not `Send`, so it is
//!   created inside the thread), batches requests up to the artifact's
//!   batch size (8), zero-pads partial batches, and fans results back
//!   out.
//!
//! Every response carries queue wait and service time separately (and
//! [`Metrics`] aggregates both), so a shard-balance regression shows up
//! as a queue-percentile blowup even when service time is flat.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{BatchSink, InferRequest, InferResponse, ReplyTo};
use super::shard::ShardPool;
use crate::approx::DivKind;
use crate::engine::{PlanConfig, PlannedModel, PruneMode, QModel};
use crate::mcu::EnergyModel;
use crate::models::Params;
use crate::util::stats::argmax;

/// Which execution backend serves requests.
#[derive(Debug, Clone)]
pub enum BackendChoice {
    /// Fixed-point MCU simulator with the given pruning setup.
    McuSim { q: QModel, mode: PruneMode, div: DivKind },
    /// Float AOT artifact at batch 8 through PJRT.
    Pjrt {
        model: String,
        params: Params,
        /// Per-layer UnIT thresholds fed to the artifact.
        t_vec: Vec<f32>,
        fat_t: f32,
    },
}

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Request intake: the sharded pool (McuSim) or the executor channel
/// (Pjrt, whose single thread batches dynamically).
enum Intake {
    Pool(Arc<ShardPool<InferRequest>>),
    Chan(Sender<InferRequest>),
}

/// Handle to a running coordinator.
pub struct Coordinator {
    intake: Option<Intake>,
    handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start serving with the chosen backend.
    pub fn start(backend: BackendChoice, cfg: ServeConfig) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        let (intake, handles) = match backend {
            BackendChoice::McuSim { q, mode, div } => {
                let workers = cfg.workers.max(1);
                let pool = Arc::new(ShardPool::new(workers));
                // Compile the execution plan once; workers share the
                // packed tables (read-only) and own their scratch.
                let plan = Arc::new(PlannedModel::compile(&q, PlanConfig::for_mode(mode, div)));
                let handles = (0..workers)
                    .map(|w| {
                        let pool = Arc::clone(&pool);
                        let plan = Arc::clone(&plan);
                        let metrics = Arc::clone(&metrics);
                        std::thread::spawn(move || mcu_worker(w, pool, plan, metrics))
                    })
                    .collect();
                (Intake::Pool(pool), handles)
            }
            BackendChoice::Pjrt { model, params, t_vec, fat_t } => {
                let (tx, rx) = channel::<InferRequest>();
                let metrics = Arc::clone(&metrics);
                let policy = BatchPolicy { max_batch: cfg.max_batch.min(8), max_wait: cfg.max_wait };
                let handles = vec![std::thread::spawn(move || {
                    pjrt_executor(rx, model, params, t_vec, fat_t, policy, metrics)
                })];
                (Intake::Chan(tx), handles)
            }
        };
        Coordinator { intake: Some(intake), handles, next_id: AtomicU64::new(0), metrics }
    }

    fn dispatch(&self, req: InferRequest) {
        match self.intake.as_ref().expect("coordinator closed") {
            Intake::Pool(pool) => pool.push(req),
            Intake::Chan(tx) => tx.send(req).expect("queue closed"),
        }
    }

    /// Submit one request; returns the response channel.
    pub fn submit(&self, x: Vec<f32>) -> Receiver<InferResponse> {
        let (rtx, rrx) = channel();
        let req = InferRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            x,
            slot: 0,
            t_enqueue: Instant::now(),
            reply: ReplyTo::Single(rtx),
        };
        self.dispatch(req);
        rrx
    }

    /// Submit one *batched* request: its samples are split across the
    /// worker shards (so a large batch executes in parallel) and the
    /// responses arrive as a single `Vec` in input order.
    pub fn submit_batch(&self, xs: Vec<Vec<f32>>) -> Receiver<Vec<InferResponse>> {
        let (rtx, rrx) = channel();
        if xs.is_empty() {
            let _ = rtx.send(Vec::new());
            return rrx;
        }
        // The Pjrt executor re-batches dynamically and records its own
        // batch sizes; for the sharded pool the split request *is* the
        // batch, recorded here.
        if matches!(self.intake, Some(Intake::Pool(_))) {
            self.metrics.record_batch(xs.len());
        }
        let sink = Arc::new(BatchSink::new(xs.len(), rtx));
        let t_enqueue = Instant::now();
        for (slot, x) in xs.into_iter().enumerate() {
            self.dispatch(InferRequest {
                id: self.next_id.fetch_add(1, Ordering::Relaxed),
                x,
                slot,
                t_enqueue,
                reply: ReplyTo::Batch(Arc::clone(&sink)),
            });
        }
        rrx
    }

    /// Close the intake and join all workers (queued requests drain
    /// first — nothing is dropped).
    pub fn shutdown(mut self) {
        self.close_intake();
        for h in self.handles.drain(..) {
            h.join().expect("worker panicked");
        }
    }

    fn close_intake(&mut self) {
        match self.intake.take() {
            Some(Intake::Pool(pool)) => pool.close(),
            Some(Intake::Chan(tx)) => drop(tx),
            None => {}
        }
    }
}

/// Dropping the handle without [`Coordinator::shutdown`] (early
/// return, panic unwind) must not leak spinning worker threads: close
/// the intake so workers drain and exit on their own. `shutdown` is
/// still the graceful path — it additionally joins them.
impl Drop for Coordinator {
    fn drop(&mut self) {
        self.close_intake();
    }
}

fn mcu_worker(
    worker: usize,
    pool: Arc<ShardPool<InferRequest>>,
    plan: Arc<PlannedModel>,
    metrics: Arc<Metrics>,
) {
    let energy = EnergyModel::default();
    // Per-worker scratch arena: no allocation on the request path.
    let mut scratch = plan.new_scratch();
    while let Some(req) = pool.pop(worker) {
        let t_deq = Instant::now();
        let queue_us = t_deq.duration_since(req.t_enqueue).as_micros() as u64;
        let xi = plan.quantize_input(&req.x);
        let out = plan.infer(&xi, &mut scratch);
        let service_us = t_deq.elapsed().as_micros() as u64;
        let resp = InferResponse {
            id: req.id,
            predicted: out.argmax(),
            mac_skipped: out.skip_fraction(),
            energy_mj: out.ledger.millijoules(&energy),
            mcu_secs: out.ledger.secs(),
            logits: out.logits,
            queue_us,
            service_us,
            latency_us: queue_us + service_us,
        };
        if matches!(req.reply, ReplyTo::Single(_)) {
            metrics.record_batch(1);
        }
        metrics.record_request(
            queue_us,
            service_us,
            resp.mac_skipped,
            resp.energy_mj,
            resp.mcu_secs,
        );
        req.reply.deliver(req.slot, resp);
    }
}

fn pjrt_executor(
    rx: Receiver<InferRequest>,
    model: String,
    params: Params,
    t_vec: Vec<f32>,
    fat_t: f32,
    policy: BatchPolicy,
    metrics: Arc<Metrics>,
) {
    // The PJRT client must be created inside the owning thread (Rc-based).
    let rt = crate::runtime::Runtime::cpu().expect("PJRT client");
    let store = crate::runtime::ArtifactStore::discover();
    let batch = policy.max_batch;
    let exe = store.load_fwd(&rt, &model, batch).expect("fwd artifact");
    let manifest = store.manifest(&model).expect("manifest");
    let sample_len: usize = {
        let [c, h, w] = manifest.input_shape;
        c * h * w
    };
    let classes = manifest.classes;
    let flat: Vec<Vec<f32>> = params.flat_order().into_iter().map(|s| s.to_vec()).collect();
    let fat = [fat_t];

    let batcher = Batcher { policy };
    while let Some(reqs) = batcher.collect(&rx) {
        let t_svc = Instant::now();
        let mut bx = vec![0.0f32; batch * sample_len];
        for (i, r) in reqs.iter().enumerate() {
            bx[i * sample_len..(i + 1) * sample_len].copy_from_slice(&r.x);
        }
        let mut args: Vec<&[f32]> = flat.iter().map(|t| t.as_slice()).collect();
        args.push(&bx);
        args.push(&t_vec);
        args.push(&fat);
        let out = exe.run_f32(&args).expect("pjrt execute");
        let logits_all = &out[0];
        metrics.record_batch(reqs.len());
        let service_us = t_svc.elapsed().as_micros() as u64;
        for (i, req) in reqs.into_iter().enumerate() {
            let logits = logits_all[i * classes..(i + 1) * classes].to_vec();
            let queue_us = t_svc.duration_since(req.t_enqueue).as_micros() as u64;
            let resp = InferResponse {
                id: req.id,
                predicted: argmax(&logits),
                logits,
                mac_skipped: 0.0,
                energy_mj: 0.0,
                mcu_secs: 0.0,
                queue_us,
                service_us,
                latency_us: queue_us + service_us,
            };
            metrics.record_request(queue_us, service_us, 0.0, 0.0, 0.0);
            req.reply.deliver(req.slot, resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Params};

    #[test]
    fn mcu_backend_serves_and_shuts_down() {
        let def = zoo("mnist");
        let params = Params::random(&def, 1);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 2, ..Default::default() },
        );
        let rxs: Vec<_> =
            (0..6).map(|i| coord.submit(vec![0.1 * i as f32; def.input_len()])).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.logits.len(), 10);
            assert!(resp.mcu_secs > 0.0);
            assert_eq!(resp.latency_us, resp.queue_us + resp.service_us);
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.served, 6);
        coord.shutdown();
    }

    #[test]
    fn no_request_lost_under_load() {
        let def = zoo("mnist");
        let params = Params::random(&def, 2);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Unit, div: DivKind::Tree },
            ServeConfig { workers: 3, ..Default::default() },
        );
        let n = 24;
        let rxs: Vec<_> = (0..n).map(|_| coord.submit(vec![0.2; def.input_len()])).collect();
        let mut got = 0;
        for rx in rxs {
            rx.recv().unwrap();
            got += 1;
        }
        assert_eq!(got, n);
        assert_eq!(coord.metrics.snapshot().served, n as u64);
        coord.shutdown();
    }

    #[test]
    fn batch_submission_splits_and_reassembles_in_order() {
        let def = zoo("mnist");
        let params = Params::random(&def, 3);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 3, ..Default::default() },
        );
        let n = 17usize; // larger than the worker count: forces a split
        let xs: Vec<Vec<f32>> =
            (0..n).map(|i| vec![0.05 * i as f32; def.input_len()]).collect();
        let rx = coord.submit_batch(xs);
        let out = rx.recv().unwrap();
        assert_eq!(out.len(), n);
        // Ids are assigned sequentially at submit; input order must
        // survive the cross-worker split.
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.id - out[0].id, i as u64, "batch slot {i} reordered");
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.served, n as u64);
        assert_eq!(snap.batches, 1);
        coord.shutdown();
    }

    #[test]
    fn dropping_without_shutdown_drains_and_stops_workers() {
        let def = zoo("mnist");
        let params = Params::random(&def, 5);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig { workers: 2, ..Default::default() },
        );
        let rxs: Vec<_> =
            (0..4).map(|i| coord.submit(vec![0.1 * i as f32; def.input_len()])).collect();
        drop(coord); // no shutdown(): Drop must close the pool, workers drain
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert_eq!(resp.logits.len(), 10);
        }
    }

    #[test]
    fn empty_batch_resolves_immediately() {
        let def = zoo("mnist");
        let params = Params::random(&def, 4);
        let q = QModel::quantize(&def, &params);
        let coord = Coordinator::start(
            BackendChoice::McuSim { q, mode: PruneMode::Dense, div: DivKind::Shift },
            ServeConfig::default(),
        );
        let out = coord.submit_batch(Vec::new()).recv().unwrap();
        assert!(out.is_empty());
        coord.shutdown();
    }
}
