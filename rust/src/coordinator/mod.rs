//! Serving coordinator (Layer 3's runtime contribution).
//!
//! UnIT itself is a per-inference technique; this module is the system
//! around it: a request router + dynamic batcher + worker pool that
//! serves inference over two backends, with Python never on the path:
//!
//! * **McuSim** — the fixed-point engine ([`crate::engine`]) with UnIT
//!   pruning and the full MSP430 cycle/energy ledger (one sample at a
//!   time, as the real MCU would), on a work-stealing sharded worker
//!   pool ([`shard`]): per-worker deques, round-robin/least-loaded
//!   submission, idle workers stealing from the longest queue, and
//!   batched requests split across workers with in-order reassembly;
//! * **Pjrt** — the AOT float artifact at batch 8 via the PJRT runtime
//!   (the paper's desktop-class deployment), with dynamic batching and
//!   zero-padding of partial batches.
//!
//! Everything is std::thread + mpsc (no tokio in the vendored set); the
//! batcher is a pure, property-tested policy ([`batcher`]).

pub mod adaptive;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod shard;

pub use adaptive::EnergyController;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use request::{
    BatchSink, CtlState, InferRequest, InferResponse, ReplyTo, RequestCtl, StreamSink,
};
pub use server::{
    BackendChoice, Coordinator, CostEstimator, CostEstimatorSlot, EnergyTap, ModelSpec,
    PlanSlot, ServeConfig, SubmitError,
};
pub use shard::{Placement, ShardPool};
