//! Energy-adaptive threshold control (paper §6.1: UnIT's "flexibility is
//! especially beneficial in environments where computational and energy
//! resources fluctuate").
//!
//! Because UnIT keeps the full network resident and decides per input,
//! its aggressiveness is a *runtime knob*: scaling every layer threshold
//! by `s` trades accuracy for energy instantly, with no re-deployment.
//! This controller closes the loop for harvested-power targets:
//!
//! * the harvester reports an **energy budget** per inference (mJ);
//! * after each inference the controller compares the ledger's measured
//!   energy against the budget and nudges the threshold scale
//!   multiplicatively (AIMD-flavored: gentle increase, gentle decrease,
//!   clamped to a calibrated range);
//! * the scale is exposed in Q8.8 for [`crate::engine::EngineConfig::t_scale_q8`].
//!
//! The controller is deliberately model-free (no energy→scale curve
//! fitting): UnIT's monotonicity — larger scale ⇒ more skips ⇒ less
//! energy — makes a first-order feedback loop sufficient, and the same
//! loop keeps working under domain shift where a fitted curve would go
//! stale.

/// AIMD-style threshold-scale controller.
#[derive(Debug, Clone)]
pub struct EnergyController {
    /// Target energy per inference (mJ).
    pub budget_mj: f64,
    /// Current scale (1.0 = calibrated thresholds).
    scale: f64,
    /// Clamp range for the scale.
    pub min_scale: f64,
    /// Upper end of the clamp range.
    pub max_scale: f64,
    /// Multiplicative step per update.
    pub step: f64,
    /// EWMA of measured energy (smoothing).
    ewma_mj: f64,
    ewma_alpha: f64,
    updates: u64,
}

impl EnergyController {
    /// Controller at scale 1.0 with the default clamp, step, and EWMA settings.
    pub fn new(budget_mj: f64) -> EnergyController {
        EnergyController {
            budget_mj,
            scale: 1.0,
            min_scale: 0.25,
            max_scale: 8.0,
            step: 1.08,
            ewma_mj: 0.0,
            ewma_alpha: 0.3,
            updates: 0,
        }
    }

    /// Current scale as the engine's Q8.8 knob, clamped to
    /// `[min_scale, max_scale]`. `observe` already clamps its updates,
    /// but the *initial* scale (or one set before a `snap_to_grid`
    /// re-bound) could sit outside the range — clamping at the read
    /// guarantees the knob and the clamp bounds can never disagree,
    /// which is what lets the plan cache treat this value as a key.
    pub fn t_scale_q8(&self) -> u32 {
        let s = self.scale.clamp(self.min_scale, self.max_scale);
        (s * 256.0).round().max(1.0) as u32
    }

    /// Bind the controller to a quantized scale grid: `min_scale` /
    /// `max_scale` become the grid's exact end steps and the current
    /// scale is snapped onto a step, so from here on
    /// `grid.snap_q8(self.t_scale_q8())` is always a valid step and
    /// round-trips exactly at the bounds — controller output and
    /// plan-cache keys cannot disagree.
    pub fn snap_to_grid(&mut self, grid: &crate::control::ScaleGrid) {
        self.min_scale = grid.min_scale();
        self.max_scale = grid.max_scale();
        let step = grid.snap_q8(self.t_scale_q8());
        self.scale = grid.scale(step);
    }

    /// Force the scale to an exact value (clamped to the controller's
    /// range) — the governor's feed-forward seeding path.
    pub fn set_scale(&mut self, scale: f64) {
        self.scale = scale.clamp(self.min_scale, self.max_scale);
    }

    /// Current threshold scale (1.0 = calibrated).
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// EWMA of observed per-inference energy (mJ).
    pub fn ewma_mj(&self) -> f64 {
        self.ewma_mj
    }

    /// Report one inference's measured energy; returns the new scale.
    pub fn observe(&mut self, measured_mj: f64) -> f64 {
        self.updates += 1;
        self.ewma_mj = if self.updates == 1 {
            measured_mj
        } else {
            self.ewma_alpha * measured_mj + (1.0 - self.ewma_alpha) * self.ewma_mj
        };
        if self.ewma_mj > self.budget_mj {
            // over budget: prune harder
            self.scale = (self.scale * self.step).min(self.max_scale);
        } else if self.ewma_mj < 0.85 * self.budget_mj {
            // comfortably under budget: relax toward accuracy
            self.scale = (self.scale / self.step).max(self.min_scale);
        }
        self.scale
    }

    /// Change the budget (harvester forecast update).
    pub fn set_budget(&mut self, budget_mj: f64) {
        self.budget_mj = budget_mj;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn over_budget_raises_scale() {
        let mut c = EnergyController::new(1.0);
        for _ in 0..20 {
            c.observe(2.0);
        }
        assert!(c.scale() > 1.5, "scale={}", c.scale());
        assert!(c.t_scale_q8() > 256);
    }

    #[test]
    fn under_budget_relaxes_scale() {
        let mut c = EnergyController::new(1.0);
        for _ in 0..20 {
            c.observe(2.0);
        }
        let high = c.scale();
        for _ in 0..60 {
            c.observe(0.2);
        }
        assert!(c.scale() < high);
    }

    #[test]
    fn scale_clamped() {
        let mut c = EnergyController::new(0.001);
        for _ in 0..500 {
            c.observe(10.0);
        }
        assert!(c.scale() <= c.max_scale);
        let mut c = EnergyController::new(1e9);
        for _ in 0..500 {
            c.observe(0.0001);
        }
        assert!(c.scale() >= c.min_scale);
    }

    #[test]
    fn deadband_holds_scale() {
        // Within [0.85, 1.0]×budget nothing changes (no oscillation).
        let mut c = EnergyController::new(1.0);
        c.observe(0.95);
        let s = c.scale();
        for _ in 0..10 {
            c.observe(0.95);
        }
        assert_eq!(c.scale(), s);
    }

    /// Satellite: after `snap_to_grid`, controller output and
    /// plan-cache keys can never disagree — every `t_scale_q8` the
    /// controller emits snaps to a step whose Q8.8 value snaps back to
    /// the same step, and the bounds round-trip exactly.
    #[test]
    fn grid_snapped_controller_round_trips_through_the_grid() {
        use crate::control::ScaleGrid;
        let grid = ScaleGrid::default_grid();
        let mut c = EnergyController::new(1.0);
        c.snap_to_grid(&grid);
        // Bounds are exact grid steps.
        assert_eq!(grid.snap_q8((c.min_scale * 256.0).round() as u32), 0);
        assert_eq!(
            grid.snap_q8((c.max_scale * 256.0).round() as u32),
            grid.len() - 1
        );
        // Drive the controller hard in both directions; every reading
        // must stay within the grid span and snap to a stable step.
        let mut drive = |mj: f64, n: usize, c: &mut EnergyController| {
            for _ in 0..n {
                c.observe(mj);
                let q8 = c.t_scale_q8();
                assert!(q8 >= grid.q8(0) && q8 <= grid.q8(grid.len() - 1), "q8 {q8} off-grid");
                let step = grid.snap_q8(q8);
                assert_eq!(grid.snap_q8(grid.q8(step)), step, "snap not idempotent");
            }
        };
        drive(100.0, 200, &mut c); // saturate high
        assert_eq!(grid.snap_q8(c.t_scale_q8()), grid.len() - 1);
        drive(1e-6, 400, &mut c); // saturate low
        assert_eq!(grid.snap_q8(c.t_scale_q8()), 0);
        // An out-of-range forced scale is clamped at the read.
        c.set_scale(1e9);
        assert!(c.t_scale_q8() <= grid.q8(grid.len() - 1));
    }

    #[test]
    fn closed_loop_with_engine_converges_to_budget() {
        // End-to-end: drive the real engine with the controller on a
        // model whose dense energy exceeds the budget; the loop must cut
        // measured energy to (near) the budget by raising the scale.
        use crate::approx::DivShift;
        use crate::engine::{infer, EngineConfig, PruneMode, QModel};
        use crate::mcu::EnergyModel;
        use crate::models::{zoo, Params};
        use crate::pruning::Thresholds;

        let def = zoo("mnist");
        let params = Params::random(&def, 31);
        let q = QModel::quantize(&def, &params)
            .with_thresholds(&Thresholds::uniform(3, 0.05));
        let energy = EnergyModel::default();
        let x: Vec<f32> = (0..def.input_len()).map(|i| ((i % 13) as f32 - 6.0) / 5.0).collect();
        let xi = q.quantize_input(&x);

        // dense ≈ 8.7 mJ; at scale 1 this model lands ≈ 4.5 mJ, so a
        // 3.5 mJ budget forces the controller above scale 1.
        let mut ctrl = EnergyController::new(3.5);
        let mut last = 0.0;
        for _ in 0..120 {
            let cfg = EngineConfig {
                mode: PruneMode::Unit,
                div: &DivShift,
                sonic_accumulators: true,
                precomputed_conv_thresholds: false,
                t_scale_q8: ctrl.t_scale_q8(),
            };
            let out = infer(&q, &xi, &cfg);
            last = out.ledger.millijoules(&energy);
            ctrl.observe(last);
        }
        assert!(last <= 4.2, "did not converge toward budget: {last} mJ");
        assert!(ctrl.scale() > 1.0, "scale {} never rose above 1", ctrl.scale());
    }
}
