//! Request/response types flowing through the coordinator.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// One inference request.
pub struct InferRequest {
    pub id: u64,
    /// Flat `C·H·W` f32 input.
    pub x: Vec<f32>,
    /// Enqueue timestamp (latency accounting).
    pub t_enqueue: Instant,
    /// Response channel.
    pub reply: Sender<InferResponse>,
}

/// The coordinator's answer.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// Fraction of MACs skipped (MCU backend; 0 for PJRT).
    pub mac_skipped: f64,
    /// Modeled MCU energy in mJ (MCU backend; 0 for PJRT).
    pub energy_mj: f64,
    /// Modeled MCU wall-clock seconds (MCU backend; 0 for PJRT).
    pub mcu_secs: f64,
    /// Host-side service latency (queue + compute).
    pub latency_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn reply_roundtrip() {
        let (tx, rx) = channel();
        let req = InferRequest { id: 9, x: vec![0.0; 4], t_enqueue: Instant::now(), reply: tx };
        req.reply
            .send(InferResponse {
                id: req.id,
                logits: vec![1.0],
                predicted: 0,
                mac_skipped: 0.5,
                energy_mj: 0.1,
                mcu_secs: 0.2,
                latency_us: 3,
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 9);
        assert_eq!(resp.predicted, 0);
    }
}
