//! Request/response types flowing through the coordinator, the
//! in-order reassembly sink for split batches, and the per-request
//! lifecycle control block streamed sessions use for cancellation and
//! deadlines.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One inference request (a single submission, or one sample of a
/// split batch).
pub struct InferRequest {
    /// Request id (wire id for streamed requests, coordinator-assigned
    /// for in-process submissions).
    pub id: u64,
    /// Index of the target model in the coordinator's model table
    /// (always `0` on single-model coordinators). Workers look up the
    /// plan slot for this model per dequeue; the submit paths validate
    /// it, so by the time a request is queued the index is in range.
    pub model: u32,
    /// Flat `C·H·W` f32 input.
    pub x: Vec<f32>,
    /// Q8.8-quantized `x`, populated when cost-weighted dispatch
    /// already quantized it for the MAC estimate — the McuSim worker
    /// reuses it instead of quantizing a second time. `None` on the
    /// Pjrt path (which consumes the f32s) and under count placement.
    pub xi: Option<Vec<i16>>,
    /// Position of this sample inside its batch (0 for singles).
    pub slot: usize,
    /// Enqueue timestamp (latency accounting).
    pub t_enqueue: Instant,
    /// Response route.
    pub reply: ReplyTo,
    /// Lifecycle control for streamed requests: a worker that dequeues
    /// a dead (cancelled/expired) request drops it without running
    /// inference — the tombstone that makes "drop not-yet-started work
    /// from the shard deques" O(1) instead of a deque scan. `None` for
    /// the in-process submit paths, which cannot be cancelled.
    pub ctl: Option<Arc<RequestCtl>>,
}

/// Lifecycle state of a streamed request (see [`RequestCtl`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CtlState {
    /// Queued or executing; replies flow.
    Active = 0,
    /// Client cancelled: queued samples are dropped at dequeue, replies
    /// for in-flight samples are suppressed.
    Cancelled = 1,
    /// Deadline passed before completion: same suppression as cancel,
    /// plus a single `Expired` status frame from the reaper.
    Expired = 2,
    /// Every sample's reply was delivered; terminal.
    Done = 3,
    /// A worker panicked while executing a sample of this request: same
    /// suppression as cancel (remaining queued samples tombstone-drop,
    /// in-flight replies are suppressed), plus a single `Failed` status
    /// frame from the panic supervisor — the request is terminal, never
    /// silently lost.
    Failed = 4,
}

/// Shared control block for one streamed request (all samples of a
/// batch share it). The state machine is a single atomic: exactly one
/// of `cancel` / `expire` / `complete` wins the transition out of
/// `Active`, so a deadline firing concurrently with the last reply (or
/// with a client cancel) resolves race-free — whoever CASes first
/// decides the request's outcome, everyone else observes it.
#[derive(Debug, Default)]
pub struct RequestCtl {
    state: AtomicU8,
}

impl RequestCtl {
    /// A fresh shared control block in the `Active` state.
    pub fn shared() -> Arc<RequestCtl> {
        Arc::new(RequestCtl::default())
    }

    /// Current lifecycle state (racy by nature; terminal states are
    /// stable once observed).
    pub fn state(&self) -> CtlState {
        match self.state.load(Ordering::Acquire) {
            0 => CtlState::Active,
            1 => CtlState::Cancelled,
            2 => CtlState::Expired,
            4 => CtlState::Failed,
            _ => CtlState::Done,
        }
    }

    fn transition(&self, to: CtlState) -> bool {
        self.state
            .compare_exchange(0, to as u8, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Client cancel. Returns `false` if the request already finished,
    /// expired, or was cancelled before.
    pub fn cancel(&self) -> bool {
        self.transition(CtlState::Cancelled)
    }

    /// Deadline expiry (reaper). Returns `false` when the request beat
    /// the deadline (already `Done`) or was cancelled first.
    pub fn expire(&self) -> bool {
        self.transition(CtlState::Expired)
    }

    /// All replies delivered. Returns `false` if cancel/expire won.
    pub fn complete(&self) -> bool {
        self.transition(CtlState::Done)
    }

    /// Worker panic (supervisor). Returns `false` when the request was
    /// already terminal — exactly one `Failed` outcome can win, so the
    /// supervisor emits at most one `Failed` frame per request.
    pub fn fail(&self) -> bool {
        self.transition(CtlState::Failed)
    }

    /// True when a worker should drop this request instead of running
    /// it (and a sink should suppress its reply).
    pub fn is_dead(&self) -> bool {
        matches!(self.state(), CtlState::Cancelled | CtlState::Expired | CtlState::Failed)
    }
}

/// Streamed reply consumer: one sample's response at a time, in the
/// order the sink chooses to release them. Implemented by the serve
/// layer's session sink (which re-orders slots and writes wire frames).
pub trait StreamSink: Send + Sync {
    /// Deliver the finished response for batch position `slot`.
    fn put(&self, slot: usize, resp: InferResponse);

    /// The request failed terminally (worker panic). Called by the
    /// panic supervisor *after* it wins the [`RequestCtl::fail`] CAS,
    /// so an implementation is invoked at most once per request and
    /// should emit its request-level failure notification (the serve
    /// layer's session sink sends one `Failed` status frame). Default:
    /// no-op — in-process callers learn of the failure from their
    /// reply channel disconnecting.
    fn fail(&self) {}
}

/// Where a worker delivers the finished response.
pub enum ReplyTo {
    /// A plain single-request reply channel.
    Single(Sender<InferResponse>),
    /// One slot of a split batch; the sink reassembles input order.
    Batch(Arc<BatchSink>),
    /// One slot of a streamed request (socket sessions): delivered
    /// per-sample, suppression and ordering handled by the sink.
    Stream(Arc<dyn StreamSink>),
}

impl ReplyTo {
    /// Route `resp` to its requester. `slot` indexes the batch sink
    /// (ignored for singles). Dropped receivers are fine — serving
    /// never fails because a client went away.
    pub fn deliver(self, slot: usize, resp: InferResponse) {
        match self {
            ReplyTo::Single(tx) => {
                let _ = tx.send(resp);
            }
            ReplyTo::Batch(sink) => sink.put(slot, resp),
            ReplyTo::Stream(sink) => sink.put(slot, resp),
        }
    }
}

/// Collects the responses of one split batch and releases them as a
/// single in-order `Vec` once every slot has arrived. Samples of one
/// batch execute on different workers in any order; the sink is what
/// guarantees the caller still sees input order.
pub struct BatchSink {
    state: Mutex<BatchState>,
}

struct BatchState {
    slots: Vec<Option<InferResponse>>,
    filled: usize,
    tx: Option<Sender<Vec<InferResponse>>>,
}

impl BatchSink {
    /// A sink expecting `n` slots, replying on `tx` when complete.
    pub fn new(n: usize, tx: Sender<Vec<InferResponse>>) -> BatchSink {
        BatchSink {
            state: Mutex::new(BatchState {
                slots: (0..n).map(|_| None).collect(),
                filled: 0,
                tx: Some(tx),
            }),
        }
    }

    /// Deposit the response for `slot`; the last deposit sends the
    /// assembled batch.
    pub fn put(&self, slot: usize, resp: InferResponse) {
        let mut g = self.state.lock().unwrap();
        debug_assert!(g.slots[slot].is_none(), "batch slot {slot} filled twice");
        g.slots[slot] = Some(resp);
        g.filled += 1;
        if g.filled == g.slots.len() {
            let tx = g.tx.take().expect("batch sink completed twice");
            let out: Vec<InferResponse> =
                g.slots.drain(..).map(|s| s.expect("missing batch slot")).collect();
            let _ = tx.send(out);
        }
    }
}

/// The coordinator's answer.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// Echo of the request id this response answers.
    pub id: u64,
    /// Raw output logits, one per class.
    pub logits: Vec<f32>,
    /// Argmax of `logits`.
    pub predicted: usize,
    /// Fraction of MACs skipped (MCU backend; 0 for PJRT).
    pub mac_skipped: f64,
    /// Modeled MCU energy in mJ (MCU backend; 0 for PJRT).
    pub energy_mj: f64,
    /// Modeled MCU wall-clock seconds (MCU backend; 0 for PJRT).
    pub mcu_secs: f64,
    /// Host-side queue wait: enqueue → a worker picked it up.
    pub queue_us: u64,
    /// Host-side service time: dequeue → response ready.
    pub service_us: u64,
    /// Total host-side latency (`queue_us + service_us`).
    pub latency_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn resp(id: u64) -> InferResponse {
        InferResponse {
            id,
            logits: vec![1.0],
            predicted: 0,
            mac_skipped: 0.5,
            energy_mj: 0.1,
            mcu_secs: 0.2,
            queue_us: 1,
            service_us: 2,
            latency_us: 3,
        }
    }

    #[test]
    fn reply_roundtrip() {
        let (tx, rx) = channel();
        let req = InferRequest {
            id: 9,
            model: 0,
            x: vec![0.0; 4],
            xi: None,
            slot: 0,
            t_enqueue: Instant::now(),
            reply: ReplyTo::Single(tx),
            ctl: None,
        };
        let (id, slot) = (req.id, req.slot);
        req.reply.deliver(slot, resp(id));
        let got = rx.recv().unwrap();
        assert_eq!(got.id, 9);
        assert_eq!(got.latency_us, got.queue_us + got.service_us);
    }

    #[test]
    fn batch_sink_reassembles_input_order() {
        let (tx, rx) = channel();
        let sink = Arc::new(BatchSink::new(4, tx));
        // Deliver out of order, as stealing workers would.
        for slot in [2usize, 0, 3, 1] {
            assert!(rx.try_recv().is_err(), "sent before all slots arrived");
            sink.put(slot, resp(100 + slot as u64));
        }
        let out = rx.recv().unwrap();
        assert_eq!(out.len(), 4);
        for (slot, r) in out.iter().enumerate() {
            assert_eq!(r.id, 100 + slot as u64, "slot {slot} out of order");
        }
    }

    #[test]
    fn batch_sink_survives_dropped_receiver() {
        let (tx, rx) = channel();
        let sink = BatchSink::new(1, tx);
        drop(rx);
        sink.put(0, resp(1)); // must not panic
    }

    #[test]
    fn ctl_first_transition_wins() {
        let ctl = RequestCtl::shared();
        assert_eq!(ctl.state(), CtlState::Active);
        assert!(!ctl.is_dead());
        assert!(ctl.cancel());
        assert_eq!(ctl.state(), CtlState::Cancelled);
        assert!(ctl.is_dead());
        // losers observe, don't overwrite
        assert!(!ctl.expire());
        assert!(!ctl.complete());
        assert!(!ctl.cancel());
        assert_eq!(ctl.state(), CtlState::Cancelled);
    }

    #[test]
    fn ctl_complete_beats_late_expiry() {
        let ctl = RequestCtl::shared();
        assert!(ctl.complete());
        // The reaper firing after the last reply must be a no-op.
        assert!(!ctl.expire());
        assert_eq!(ctl.state(), CtlState::Done);
        assert!(!ctl.is_dead());
    }

    #[test]
    fn ctl_fail_is_terminal_and_dead() {
        let ctl = RequestCtl::shared();
        assert!(ctl.fail());
        assert_eq!(ctl.state(), CtlState::Failed);
        assert!(ctl.is_dead(), "failed requests must tombstone queued siblings");
        // Late completion / expiry / a second panic are no-ops.
        assert!(!ctl.complete());
        assert!(!ctl.expire());
        assert!(!ctl.fail());
        assert_eq!(ctl.state(), CtlState::Failed);
    }

    #[test]
    fn ctl_complete_beats_late_fail() {
        let ctl = RequestCtl::shared();
        assert!(ctl.complete());
        assert!(!ctl.fail(), "a delivered request cannot be failed after the fact");
        assert_eq!(ctl.state(), CtlState::Done);
    }

    #[test]
    fn ctl_race_has_exactly_one_winner() {
        for _ in 0..200 {
            let ctl = RequestCtl::shared();
            let c2 = Arc::clone(&ctl);
            let c3 = Arc::clone(&ctl);
            let a = std::thread::spawn(move || c2.cancel());
            let b = std::thread::spawn(move || c3.expire());
            let (wa, wb) = (a.join().unwrap(), b.join().unwrap());
            assert!(wa ^ wb, "exactly one transition must win");
            let st = ctl.state();
            assert_eq!(st == CtlState::Cancelled, wa);
            assert_eq!(st == CtlState::Expired, wb);
        }
    }
}
