//! Request/response types flowing through the coordinator, plus the
//! in-order reassembly sink for split batches.

use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One inference request (a single submission, or one sample of a
/// split batch).
pub struct InferRequest {
    pub id: u64,
    /// Flat `C·H·W` f32 input.
    pub x: Vec<f32>,
    /// Position of this sample inside its batch (0 for singles).
    pub slot: usize,
    /// Enqueue timestamp (latency accounting).
    pub t_enqueue: Instant,
    /// Response route.
    pub reply: ReplyTo,
}

/// Where a worker delivers the finished response.
pub enum ReplyTo {
    /// A plain single-request reply channel.
    Single(Sender<InferResponse>),
    /// One slot of a split batch; the sink reassembles input order.
    Batch(Arc<BatchSink>),
}

impl ReplyTo {
    /// Route `resp` to its requester. `slot` indexes the batch sink
    /// (ignored for singles). Dropped receivers are fine — serving
    /// never fails because a client went away.
    pub fn deliver(self, slot: usize, resp: InferResponse) {
        match self {
            ReplyTo::Single(tx) => {
                let _ = tx.send(resp);
            }
            ReplyTo::Batch(sink) => sink.put(slot, resp),
        }
    }
}

/// Collects the responses of one split batch and releases them as a
/// single in-order `Vec` once every slot has arrived. Samples of one
/// batch execute on different workers in any order; the sink is what
/// guarantees the caller still sees input order.
pub struct BatchSink {
    state: Mutex<BatchState>,
}

struct BatchState {
    slots: Vec<Option<InferResponse>>,
    filled: usize,
    tx: Option<Sender<Vec<InferResponse>>>,
}

impl BatchSink {
    /// A sink expecting `n` slots, replying on `tx` when complete.
    pub fn new(n: usize, tx: Sender<Vec<InferResponse>>) -> BatchSink {
        BatchSink {
            state: Mutex::new(BatchState {
                slots: (0..n).map(|_| None).collect(),
                filled: 0,
                tx: Some(tx),
            }),
        }
    }

    /// Deposit the response for `slot`; the last deposit sends the
    /// assembled batch.
    pub fn put(&self, slot: usize, resp: InferResponse) {
        let mut g = self.state.lock().unwrap();
        debug_assert!(g.slots[slot].is_none(), "batch slot {slot} filled twice");
        g.slots[slot] = Some(resp);
        g.filled += 1;
        if g.filled == g.slots.len() {
            let tx = g.tx.take().expect("batch sink completed twice");
            let out: Vec<InferResponse> =
                g.slots.drain(..).map(|s| s.expect("missing batch slot")).collect();
            let _ = tx.send(out);
        }
    }
}

/// The coordinator's answer.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// Fraction of MACs skipped (MCU backend; 0 for PJRT).
    pub mac_skipped: f64,
    /// Modeled MCU energy in mJ (MCU backend; 0 for PJRT).
    pub energy_mj: f64,
    /// Modeled MCU wall-clock seconds (MCU backend; 0 for PJRT).
    pub mcu_secs: f64,
    /// Host-side queue wait: enqueue → a worker picked it up.
    pub queue_us: u64,
    /// Host-side service time: dequeue → response ready.
    pub service_us: u64,
    /// Total host-side latency (`queue_us + service_us`).
    pub latency_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn resp(id: u64) -> InferResponse {
        InferResponse {
            id,
            logits: vec![1.0],
            predicted: 0,
            mac_skipped: 0.5,
            energy_mj: 0.1,
            mcu_secs: 0.2,
            queue_us: 1,
            service_us: 2,
            latency_us: 3,
        }
    }

    #[test]
    fn reply_roundtrip() {
        let (tx, rx) = channel();
        let req = InferRequest {
            id: 9,
            x: vec![0.0; 4],
            slot: 0,
            t_enqueue: Instant::now(),
            reply: ReplyTo::Single(tx),
        };
        let (id, slot) = (req.id, req.slot);
        req.reply.deliver(slot, resp(id));
        let got = rx.recv().unwrap();
        assert_eq!(got.id, 9);
        assert_eq!(got.latency_us, got.queue_us + got.service_us);
    }

    #[test]
    fn batch_sink_reassembles_input_order() {
        let (tx, rx) = channel();
        let sink = Arc::new(BatchSink::new(4, tx));
        // Deliver out of order, as stealing workers would.
        for slot in [2usize, 0, 3, 1] {
            assert!(rx.try_recv().is_err(), "sent before all slots arrived");
            sink.put(slot, resp(100 + slot as u64));
        }
        let out = rx.recv().unwrap();
        assert_eq!(out.len(), 4);
        for (slot, r) in out.iter().enumerate() {
            assert_eq!(r.id, 100 + slot as u64, "slot {slot} out of order");
        }
    }

    #[test]
    fn batch_sink_survives_dropped_receiver() {
        let (tx, rx) = channel();
        let sink = BatchSink::new(1, tx);
        drop(rx);
        sink.put(0, resp(1)); // must not panic
    }
}
