//! Imprecise BLAS (paper §6.8): UnIT's threshold machinery applied to
//! plain linear algebra, where "the two matrix values are entirely
//! unknown" ahead of time and thresholds must be derived *dynamically*
//! from the operands themselves.
//!
//! [`unit_gemv`] / [`unit_gemm`] compute `y = A·x` / `C = A·B` while
//! skipping products whose magnitude falls below a dynamically chosen
//! threshold: `T = quantile_p(|a_ij|) · quantile_p(|x_j|)` — the same
//! rank-1 separability as Eq. 1, picked per call with no calibration
//! data. The skip test reuses the row/column reciprocal exactly like the
//! linear-layer engine (one division per x_j, reused across a column of
//! A).
//!
//! The result is an *approximate* product with a tunable error/FLOP
//! trade-off — useful on MCUs for non-ML workloads (filters, projections)
//! that tolerate bounded error.

use crate::util::stats::percentile;

/// Result of an imprecise BLAS call.
#[derive(Debug, Clone)]
pub struct BlasStats {
    /// Products actually multiplied.
    pub kept: u64,
    /// Products skipped by the dynamic threshold.
    pub skipped: u64,
}

impl BlasStats {
    /// Fraction of products skipped (0 when none ran).
    pub fn skip_fraction(&self) -> f64 {
        let t = self.kept + self.skipped;
        if t == 0 {
            0.0
        } else {
            self.skipped as f64 / t as f64
        }
    }
}

/// Dynamic threshold from operand magnitude quantiles: products of two
/// sub-`p`-quantile magnitudes are dropped.
fn dynamic_threshold(a: &[f32], x: &[f32], drop_pct: f64) -> f32 {
    if drop_pct <= 0.0 || a.is_empty() || x.is_empty() {
        return 0.0;
    }
    // Subsample |a| for large matrices — the threshold is a statistic,
    // not an exact order statistic.
    let stride = (a.len() / 4096).max(1);
    let sa: Vec<f32> = a.iter().step_by(stride).map(|v| v.abs()).collect();
    let sx: Vec<f32> = x.iter().map(|v| v.abs()).collect();
    percentile(&sa, drop_pct) * percentile(&sx, drop_pct)
}

/// Imprecise `y = A·x` (A row-major `m×n`). `drop_pct = 0` is exact.
pub fn unit_gemv(a: &[f32], m: usize, n: usize, x: &[f32], drop_pct: f64) -> (Vec<f32>, BlasStats) {
    assert_eq!(a.len(), m * n, "A shape");
    assert_eq!(x.len(), n, "x shape");
    let t = dynamic_threshold(a, x, drop_pct);
    let mut y = vec![0.0f32; m];
    let mut stats = BlasStats { kept: 0, skipped: 0 };
    // Column-major walk: one reciprocal per x_j, reused down the column
    // (the Eq. 2 reuse pattern).
    for j in 0..n {
        let xv = x[j];
        let ax = xv.abs();
        if ax == 0.0 {
            stats.skipped += m as u64;
            continue;
        }
        let tbar = if t > 0.0 { t / ax } else { 0.0 };
        for i in 0..m {
            let av = a[i * n + j];
            if av.abs() > tbar {
                y[i] += av * xv;
                stats.kept += 1;
            } else {
                stats.skipped += 1;
            }
        }
    }
    (y, stats)
}

/// Imprecise `C = A·B` (row-major, `m×k · k×n`). `drop_pct = 0` is exact.
pub fn unit_gemm(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    drop_pct: f64,
) -> (Vec<f32>, BlasStats) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    let t = dynamic_threshold(a, b, drop_pct);
    let mut c = vec![0.0f32; m * n];
    let mut stats = BlasStats { kept: 0, skipped: 0 };
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            let aa = av.abs();
            if aa == 0.0 {
                stats.skipped += n as u64;
                continue;
            }
            // One reciprocal per A element, reused across the B row
            // (weight-reuse pattern of Eq. 3).
            let tbar = if t > 0.0 { t / aa } else { 0.0 };
            let brow = &b[kk * n..(kk + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (j, &bv) in brow.iter().enumerate() {
                if bv.abs() > tbar {
                    crow[j] += av * bv;
                    stats.kept += 1;
                } else {
                    stats.skipped += 1;
                }
            }
        }
    }
    (c, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn dense_gemv(a: &[f32], m: usize, n: usize, x: &[f32]) -> Vec<f32> {
        (0..m).map(|i| (0..n).map(|j| a[i * n + j] * x[j]).sum()).collect()
    }

    #[test]
    fn zero_drop_is_exact() {
        prop::check(61, 100, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(1, 12);
            let a = g.vec_normal(m * n);
            let x = g.vec_normal(n);
            let (y, stats) = unit_gemv(&a, m, n, &x, 0.0);
            let want = dense_gemv(&a, m, n, &x);
            for (u, v) in y.iter().zip(&want) {
                assert!((u - v).abs() < 1e-4);
            }
            assert_eq!(stats.skipped, 0);
        });
    }

    #[test]
    fn skips_grow_with_drop_pct() {
        prop::check(62, 50, |g| {
            let a = g.vec_normal(40 * 30);
            let x = g.vec_normal(30);
            let mut last = 0u64;
            for p in [0.0, 10.0, 30.0, 60.0] {
                let (_y, s) = unit_gemv(&a, 40, 30, &x, p);
                assert!(s.skipped >= last, "p={p}");
                last = s.skipped;
            }
        });
    }

    #[test]
    fn error_bounded_by_dropped_mass() {
        // The absolute error of y_i is at most (number of dropped
        // products) * T, since every dropped |a*x| <= T.
        prop::check(63, 100, |g| {
            let m = g.usize_in(2, 10);
            let n = g.usize_in(2, 20);
            let a = g.vec_normal(m * n);
            let x = g.vec_normal(n);
            let p = g.f32_in(5.0, 50.0) as f64;
            let t = super::dynamic_threshold(&a, &x, p);
            let (y, _s) = unit_gemv(&a, m, n, &x, p);
            let want = dense_gemv(&a, m, n, &x);
            for (u, v) in y.iter().zip(&want) {
                assert!(
                    (u - v).abs() <= n as f32 * t + 1e-4,
                    "err {} > bound {}",
                    (u - v).abs(),
                    n as f32 * t
                );
            }
        });
    }

    #[test]
    fn gemm_matches_gemv_per_column() {
        prop::check(64, 40, |g| {
            let (m, k, n) = (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 8));
            let a = g.vec_normal(m * k);
            let b = g.vec_normal(k * n);
            let (c, _s) = unit_gemm(&a, &b, m, k, n, 0.0);
            // check column j of C equals A * column j of B
            for j in 0..n {
                let xj: Vec<f32> = (0..k).map(|kk| b[kk * n + j]).collect();
                let want = dense_gemv(&a, m, k, &xj);
                for i in 0..m {
                    assert!((c[i * n + j] - want[i]).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn gemm_conservation() {
        let a = vec![1.0f32; 6 * 5];
        let b = vec![0.5f32; 5 * 4];
        let (_c, s) = unit_gemm(&a, &b, 6, 5, 4, 25.0);
        assert_eq!(s.kept + s.skipped, (6 * 5 * 4) as u64);
    }
}
