//! Quantiles, means and accuracy helpers used by calibration and metrics.

/// Percentile (0..=100) by nearest-rank on a copy of the data.
/// Used by the calibration pass: the paper computes per-layer thresholds
/// as a fixed percentile (e.g. 20th) of |activation·weight| products.
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p));
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Streaming reservoir of up to `cap` samples for quantile estimation
/// without unbounded memory (calibration over large activation sets).
pub struct Reservoir {
    cap: usize,
    seen: u64,
    buf: Vec<f32>,
    rng: crate::util::Rng,
}

impl Reservoir {
    /// Empty reservoir holding at most `cap` samples.
    pub fn new(cap: usize, seed: u64) -> Self {
        Reservoir { cap, seen: 0, buf: Vec::with_capacity(cap), rng: crate::util::Rng::new(seed) }
    }

    /// Offer one sample (reservoir-replaces once full).
    pub fn push(&mut self, x: f32) {
        self.seen += 1;
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.buf[j as usize] = x;
            }
        }
    }

    /// The `p`-th percentile of the held samples (0 when empty).
    pub fn percentile(&self, p: f64) -> f32 {
        percentile(&self.buf, p)
    }

    /// Samples currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether no samples are held.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Arithmetic mean (0 for an empty slice).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Classification accuracy from (prediction, label) pairs.
pub fn accuracy(pred: &[usize], label: &[usize]) -> f64 {
    assert_eq!(pred.len(), label.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(label).filter(|(p, l)| p == l).count();
    hits as f64 / pred.len() as f64
}

/// Macro-averaged F1 over `k` classes (Table 2 metric).
pub fn macro_f1(pred: &[usize], label: &[usize], k: usize) -> f64 {
    assert_eq!(pred.len(), label.len());
    let mut tp = vec![0f64; k];
    let mut fp = vec![0f64; k];
    let mut fnn = vec![0f64; k];
    for (&p, &l) in pred.iter().zip(label) {
        if p == l {
            tp[p] += 1.0;
        } else {
            fp[p] += 1.0;
            fnn[l] += 1.0;
        }
    }
    let mut f1 = 0.0;
    for c in 0..k {
        let prec = if tp[c] + fp[c] > 0.0 { tp[c] / (tp[c] + fp[c]) } else { 0.0 };
        let rec = if tp[c] + fnn[c] > 0.0 { tp[c] / (tp[c] + fnn[c]) } else { 0.0 };
        f1 += if prec + rec > 0.0 { 2.0 * prec * rec / (prec + rec) } else { 0.0 };
    }
    f1 / k as f64
}

/// argmax with deterministic tie-break (lowest index).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn percentile_20th_of_uniform() {
        let xs: Vec<f32> = (0..101).map(|i| i as f32).collect();
        assert_eq!(percentile(&xs, 20.0), 20.0);
    }

    #[test]
    fn reservoir_exact_when_under_cap() {
        let mut r = Reservoir::new(100, 1);
        for i in 0..50 {
            r.push(i as f32);
        }
        assert_eq!(r.len(), 50);
        assert_eq!(r.percentile(100.0), 49.0);
    }

    #[test]
    fn reservoir_approximates_quantile() {
        let mut r = Reservoir::new(2000, 2);
        for i in 0..100_000 {
            r.push((i % 1000) as f32);
        }
        let p50 = r.percentile(50.0);
        assert!((p50 - 500.0).abs() < 60.0, "p50={p50}");
    }

    #[test]
    fn f1_perfect_and_worst() {
        let a = [0usize, 1, 2, 0, 1, 2];
        assert!((macro_f1(&a, &a, 3) - 1.0).abs() < 1e-9);
        let b = [1usize, 2, 0, 1, 2, 0];
        assert!(macro_f1(&a, &b, 3) < 1e-9);
    }

    #[test]
    fn accuracy_half() {
        assert_eq!(accuracy(&[0, 1, 0, 1], &[0, 1, 1, 0]), 0.5);
    }

    #[test]
    fn argmax_tiebreak_low_index() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }
}
