//! Poison-tolerant lock acquisition: recover the guard instead of
//! propagating a `PoisonError`.
//!
//! A panicking thread poisons every `Mutex`/`RwLock` it holds, and the
//! default `.lock().unwrap()` idiom then re-panics every *subsequent*
//! locker — one contained worker panic would cascade through the plan
//! slot, the governor's controller, and the energy tap until the whole
//! process is down. That is exactly backwards for the values this crate
//! keeps behind shared locks: they are **last-published snapshots**
//! (the active plan `Arc`, the cost-estimator `Arc`, AIMD controller
//! state, background-compile bookkeeping), written atomically from the
//! caller's point of view — a swap either happened or it did not, so
//! the value observed after recovering a poisoned guard is always a
//! consistent previously-published one ("last published value wins").
//!
//! These helpers clear the poison flag on recovery so later lockers do
//! not pay the `Err` branch again. They are **not** appropriate for
//! locks guarding multi-step invariants that a mid-flight panic could
//! tear (none of the call sites below are: see each site's comment).

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering (and clearing) poison from a previous
/// holder's panic. The returned guard sees the last value published
/// before the panic.
pub fn lock_recover<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            m.clear_poison();
            poisoned.into_inner()
        }
    }
}

/// [`lock_recover`] for `RwLock` readers.
pub fn read_recover<T: ?Sized>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => {
            l.clear_poison();
            poisoned.into_inner()
        }
    }
}

/// [`lock_recover`] for `RwLock` writers.
pub fn write_recover<T: ?Sized>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    match l.write() {
        Ok(g) => g,
        Err(poisoned) => {
            l.clear_poison();
            poisoned.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn mutex_recovers_last_published_value() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let mut g = m2.lock().unwrap();
            *g = 42; // published before the panic below
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned(), "test setup: lock must start poisoned");
        assert_eq!(*lock_recover(&m), 42, "last published value lost");
        // Poison is cleared: the plain idiom works again afterwards.
        assert!(!m.is_poisoned());
        assert_eq!(*m.lock().unwrap(), 42);
    }

    #[test]
    fn rwlock_recovers_for_readers_and_writers() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(read_recover(&l).len(), 3);
        write_recover(&l).push(4);
        assert!(!l.is_poisoned());
        assert_eq!(l.read().unwrap().len(), 4);
    }

    #[test]
    fn unpoisoned_locks_pass_straight_through() {
        let m = Mutex::new(1);
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 2);
        let l = RwLock::new(1);
        *write_recover(&l) += 1;
        assert_eq!(*read_recover(&l), 2);
    }
}
