//! Deterministic xorshift64* PRNG.
//!
//! Every stochastic component in the repo (datasets, property tests,
//! failure injection) derives from this generator with an explicit seed so
//! all experiments are exactly reproducible.

/// xorshift64* — tiny, fast, and good enough for synthetic data and
/// property-test case generation (not cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has a zero fixed point).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Derive an independent stream, e.g. per dataset split or per worker.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(s)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
