//! Minimal command-line parser (the vendored crate set has no clap).
//!
//! Supports `--key value`, `--key=value`, bare `--flag`, and positional
//! arguments, which is all the binaries in this repo need.

use std::collections::HashMap;

/// Parsed command line: positionals plus a key→value map.
#[derive(Debug, Default)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the current process's arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// The value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// The value of `--key`, or `default`.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Whether `--key` was passed at all.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// `--key` parsed as `usize`, or `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().expect(key)).unwrap_or(default)
    }

    /// `--key` parsed as `u64`, or `default`.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().expect(key)).unwrap_or(default)
    }

    /// `--key` parsed as `f64`, or `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().expect(key)).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--model", "mnist", "--steps=300", "run"]);
        assert_eq!(a.get("model"), Some("mnist"));
        assert_eq!(a.usize_or("steps", 0), 300);
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn bare_flag() {
        let a = parse(&["--verbose", "--out", "x.txt"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("x.txt"));
    }

    #[test]
    fn trailing_bare_flag() {
        let a = parse(&["cmd", "--debug"]);
        assert!(a.flag("debug"));
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("model", "mnist"), "mnist");
        assert_eq!(a.f64_or("lr", 0.05), 0.05);
        assert!(!a.flag("missing"));
    }
}
