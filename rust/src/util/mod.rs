//! Small self-contained utilities (the vendored crate set has no clap /
//! serde / proptest / criterion, so these are hand-rolled).

pub mod cli;
pub mod fault;
pub mod lock;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use fault::{FaultPlan, FaultRates};
pub use lock::{lock_recover, read_recover, write_recover};
pub use rng::Rng;
