//! Small self-contained utilities (the vendored crate set has no clap /
//! serde / proptest / criterion, so these are hand-rolled).

pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
