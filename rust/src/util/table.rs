//! ASCII table rendering for experiment reports (paper-style rows).

/// Simple column-aligned table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:w$} |", c, w = widths[i]));
            }
            s
        };
        let sep = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals (helper for table cells).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Format a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["longer", "22"]);
        let s = t.render();
        let lines: Vec<&str> = s.trim_end().split('\n').collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("longer"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.8421), "84.21%");
    }
}
