//! Deterministic fault-injection plan — the seed of the chaos harness.
//!
//! A [`FaultPlan`] is a pure function from `(seed, site, draw index)`
//! to an injection decision: each site (worker panic, outbound frame
//! corruption, delayed reply, stalled read) keeps its own atomic draw
//! counter, and every decision hashes `(seed, site, n)` through a
//! splitmix64 finalizer. Two consequences:
//!
//! * **reproducible** — the k-th decision at a given site is the same
//!   for a given seed, every run, with no shared RNG lock on any hot
//!   path (one relaxed `fetch_add` per probe);
//! * **independent streams** — sites never perturb each other's
//!   sequences, so adding a probe at one site does not reshuffle the
//!   faults injected at another.
//!
//! The plan is threaded behind `ServeOpts::fault` /
//! `ServeConfig::fault` (built by `unit serve --chaos-seed N`) and is
//! entirely absent — a `None`, zero branches taken — in production
//! builds of the serve path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::obs::{EventKind, TraceRing};

/// Injection probabilities and magnitudes. Rates are per-probe
/// Bernoulli probabilities in `[0, 1]`.
#[derive(Debug, Clone, Copy)]
pub struct FaultRates {
    /// Worker panics per dequeued request.
    pub panic_rate: f64,
    /// Outbound frame corruptions per sent frame.
    pub corrupt_rate: f64,
    /// Delayed replies per sent frame.
    pub delay_rate: f64,
    /// Upper bound on an injected reply delay.
    pub delay_max_ms: u64,
    /// Stalled reads per inbound frame.
    pub stall_rate: f64,
    /// Upper bound on an injected read stall.
    pub stall_max_ms: u64,
}

impl Default for FaultRates {
    fn default() -> FaultRates {
        FaultRates {
            panic_rate: 0.04,
            corrupt_rate: 0.01,
            delay_rate: 0.05,
            delay_max_ms: 3,
            stall_rate: 0.02,
            stall_max_ms: 5,
        }
    }
}

/// Injection site index: worker panic on a dequeued request.
pub const SITE_PANIC: usize = 0;
/// Injection site index: outbound frame corruption.
pub const SITE_CORRUPT: usize = 1;
/// Injection site index: delayed reply write.
pub const SITE_DELAY: usize = 2;
/// Injection site index: stalled inbound read.
pub const SITE_STALL: usize = 3;
/// Number of injection sites.
pub const SITES: usize = 4;

/// Seeded, lock-free fault injector (see module docs).
pub struct FaultPlan {
    seed: u64,
    rates: FaultRates,
    counters: [AtomicU64; SITES],
    /// Injections that actually FIRED per site (the draw counters
    /// above advance on every probe; these only on a hit).
    injected: [AtomicU64; SITES],
    /// Optional flight-recorder ring: when attached, every fired
    /// injection emits a [`EventKind::Fault`] event with the site in
    /// `a`. Attaching never perturbs the decision streams — emission
    /// happens after the draw, outside [`FaultPlan::draw`].
    ring: OnceLock<Arc<TraceRing>>,
}

/// splitmix64 finalizer: a high-quality 64-bit mix, used here as a
/// stateless hash of `(seed, site, n)`.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// A plan with the default chaos rates.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan::with_rates(seed, FaultRates::default())
    }

    /// A plan with explicit rates.
    pub fn with_rates(seed: u64, rates: FaultRates) -> FaultPlan {
        FaultPlan {
            seed,
            rates,
            counters: Default::default(),
            injected: Default::default(),
            ring: OnceLock::new(),
        }
    }

    /// Attach a flight-recorder ring: every injection that fires from
    /// now on also emits a [`EventKind::Fault`] event (site in `a`).
    /// First attachment wins; decision streams are unaffected.
    pub fn attach_ring(&self, ring: Arc<TraceRing>) {
        let _ = self.ring.set(ring);
    }

    /// Injections that actually fired at `site` (one of the `SITE_*`
    /// constants) since construction.
    pub fn injected(&self, site: usize) -> u64 {
        self.injected[site].load(Ordering::Relaxed)
    }

    /// Record a fired injection: bump the per-site counter and emit a
    /// trace event when a ring is attached.
    fn fired(&self, site: usize) {
        self.injected[site].fetch_add(1, Ordering::Relaxed);
        if let Some(r) = self.ring.get() {
            r.emit(EventKind::Fault, 0, site as u64, 0, 0);
        }
    }

    /// The plan's seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The plan's rates.
    pub fn rates(&self) -> FaultRates {
        self.rates
    }

    /// The n-th raw draw at `site` (advances the site counter).
    fn draw(&self, site: usize) -> u64 {
        let n = self.counters[site].fetch_add(1, Ordering::Relaxed);
        let stream = (site as u64).wrapping_mul(0xA24B_AED4_963E_E407);
        mix(self.seed ^ stream ^ n.wrapping_mul(0x2545_F491_4F6C_DD1D))
    }

    /// Uniform in `[0, 1)` from a raw draw.
    fn unit(raw: u64) -> f64 {
        (raw >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Should the worker panic on this dequeued request?
    pub fn inject_panic(&self) -> bool {
        let hit = Self::unit(self.draw(SITE_PANIC)) < self.rates.panic_rate;
        if hit {
            self.fired(SITE_PANIC);
        }
        hit
    }

    /// Maybe corrupt an encoded outbound frame in place (one byte
    /// XOR-flipped at a seed-chosen offset — enough to fail the CRC or
    /// the header checks, never enough to resize the buffer). Returns
    /// whether a corruption was injected.
    pub fn corrupt_frame(&self, frame: &mut [u8]) -> bool {
        let raw = self.draw(SITE_CORRUPT);
        if frame.is_empty() || Self::unit(raw) >= self.rates.corrupt_rate {
            return false;
        }
        let off = (mix(raw) as usize) % frame.len();
        frame[off] ^= 0xA5;
        self.fired(SITE_CORRUPT);
        true
    }

    /// An injected delay to apply before writing a reply frame.
    pub fn reply_delay(&self) -> Option<Duration> {
        let raw = self.draw(SITE_DELAY);
        if self.rates.delay_max_ms == 0 || Self::unit(raw) >= self.rates.delay_rate {
            return None;
        }
        self.fired(SITE_DELAY);
        Some(Duration::from_millis(mix(raw) % self.rates.delay_max_ms + 1))
    }

    /// An injected stall to apply before servicing an inbound frame.
    pub fn read_stall(&self) -> Option<Duration> {
        let raw = self.draw(SITE_STALL);
        if self.rates.stall_max_ms == 0 || Self::unit(raw) >= self.rates.stall_rate {
            return None;
        }
        self.fired(SITE_STALL);
        Some(Duration::from_millis(mix(raw) % self.rates.stall_max_ms + 1))
    }
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan").field("seed", &self.seed).field("rates", &self.rates).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_sequences_are_reproducible_per_seed() {
        let always = FaultRates {
            panic_rate: 0.5,
            corrupt_rate: 0.5,
            delay_rate: 0.5,
            stall_rate: 0.5,
            ..FaultRates::default()
        };
        let a = FaultPlan::with_rates(7, always);
        let b = FaultPlan::with_rates(7, always);
        let seq = |p: &FaultPlan| -> Vec<bool> { (0..256).map(|_| p.inject_panic()).collect() };
        assert_eq!(seq(&a), seq(&b), "same seed must replay the same panics");
        let c = FaultPlan::with_rates(8, always);
        assert_ne!(seq(&a), seq(&c), "different seeds must diverge");
    }

    #[test]
    fn sites_are_independent_streams() {
        // Interleaving probes at another site must not reshuffle the
        // panic stream.
        let a = FaultPlan::new(11);
        let b = FaultPlan::new(11);
        let mut seq_a = Vec::new();
        let mut seq_b = Vec::new();
        for _ in 0..128 {
            seq_a.push(a.inject_panic());
            let _ = a.reply_delay();
        }
        for _ in 0..128 {
            seq_b.push(b.inject_panic());
        }
        assert_eq!(seq_a, seq_b);
    }

    #[test]
    fn rates_are_respected_in_the_large() {
        let tenth = FaultRates { panic_rate: 0.1, ..FaultRates::default() };
        let p = FaultPlan::with_rates(3, tenth);
        let n = 20_000;
        let hits = (0..n).filter(|_| p.inject_panic()).count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.1).abs() < 0.02, "panic rate off: {frac}");
        let silent = FaultRates {
            panic_rate: 0.0,
            corrupt_rate: 0.0,
            delay_rate: 0.0,
            stall_rate: 0.0,
            ..FaultRates::default()
        };
        let zero = FaultPlan::with_rates(3, silent);
        assert!((0..1000).all(|_| !zero.inject_panic()));
        let mut buf = vec![0u8; 64];
        assert!((0..1000).all(|_| !zero.corrupt_frame(&mut buf)));
        assert!(buf.iter().all(|&b| b == 0), "zero-rate corrupt touched the buffer");
    }

    #[test]
    fn corruption_flips_exactly_one_byte_in_bounds() {
        let always = FaultRates { corrupt_rate: 1.0, ..FaultRates::default() };
        let p = FaultPlan::with_rates(5, always);
        for len in [1usize, 2, 16, 1024] {
            let mut buf = vec![0u8; len];
            assert!(p.corrupt_frame(&mut buf));
            let flipped: Vec<usize> = (0..len).filter(|&i| buf[i] != 0).collect();
            assert_eq!(flipped.len(), 1, "len {len}: expected exactly one flipped byte");
            assert_eq!(buf[flipped[0]], 0xA5);
        }
        let mut empty: [u8; 0] = [];
        assert!(!p.corrupt_frame(&mut empty), "empty frames cannot be corrupted");
    }

    #[test]
    fn injected_counters_and_ring_events_track_fired_injections_only() {
        use std::time::Instant;
        let half = FaultRates {
            panic_rate: 0.5,
            corrupt_rate: 0.5,
            delay_rate: 0.5,
            stall_rate: 0.5,
            ..FaultRates::default()
        };
        // Reference plan (no ring): the decision stream to compare to.
        let bare = FaultPlan::with_rates(21, half);
        let wired = FaultPlan::with_rates(21, half);
        let ring = Arc::new(TraceRing::new("faults", Instant::now(), 4096));
        wired.attach_ring(Arc::clone(&ring));
        let mut buf = vec![0u8; 32];
        let mut want = [0u64; SITES];
        for _ in 0..200 {
            assert_eq!(bare.inject_panic(), wired.inject_panic(), "ring perturbed the stream");
            let mut b2 = vec![0u8; 32];
            assert_eq!(bare.corrupt_frame(&mut buf), wired.corrupt_frame(&mut b2));
            assert_eq!(bare.reply_delay(), wired.reply_delay());
            assert_eq!(bare.read_stall(), wired.read_stall());
            buf.fill(0);
        }
        for site in 0..SITES {
            want[site] = wired.injected(site);
            assert!(want[site] > 0, "site {site} never fired at rate 0.5 over 200 probes");
            assert_eq!(bare.injected(site), want[site]);
        }
        // Every fired injection is on the ring, sites attributed in `a`.
        let events = ring.snapshot();
        assert_eq!(ring.dropped(), 0);
        let mut got = [0u64; SITES];
        for e in &events {
            assert_eq!(e.kind, EventKind::Fault);
            got[e.a as usize] += 1;
        }
        assert_eq!(got, want, "ring event counts must equal fired-injection counts");
    }

    #[test]
    fn delays_and_stalls_are_bounded() {
        let slow = FaultRates {
            delay_rate: 1.0,
            stall_rate: 1.0,
            delay_max_ms: 3,
            stall_max_ms: 5,
            ..FaultRates::default()
        };
        let p = FaultPlan::with_rates(9, slow);
        for _ in 0..500 {
            let d = p.reply_delay().expect("rate 1.0 must always delay");
            assert!(d >= Duration::from_millis(1) && d <= Duration::from_millis(3));
            let s = p.read_stall().expect("rate 1.0 must always stall");
            assert!(s >= Duration::from_millis(1) && s <= Duration::from_millis(5));
        }
    }
}
