//! Mini property-based testing harness (proptest is not in the vendored
//! crate set).
//!
//! `check(seed, cases, |g| { ... })` runs a closure over `cases` generated
//! inputs drawn from a seeded [`Gen`]; on failure the failing case index
//! and seed are reported so the case can be replayed deterministically.

use crate::util::Rng;

/// Case generator handed to property closures.
pub struct Gen {
    rng: Rng,
    /// Zero-based index of the current case.
    pub case: usize,
}

impl Gen {
    /// Uniform `usize` in `lo..=hi`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform `i32` in `lo..=hi`.
    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.rng.below((hi - lo + 1) as u64) as i32
    }

    /// Uniform `u32` in `lo..=hi`.
    pub fn u32_in(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.rng.below((hi - lo + 1) as u64) as u32
    }

    /// Uniform `f32` in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo, hi)
    }

    /// Standard-normal `f32`.
    pub fn normal(&mut self) -> f32 {
        self.rng.normal()
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// `len` uniform `f32`s in `[lo, hi)`.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// `len` standard-normal `f32`s.
    pub fn vec_normal(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.normal()).collect()
    }

    /// Pick one element uniformly (for enum-ish choices: models, modes,
    /// estimators).
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "choice over empty slice");
        &items[self.usize_in(0, items.len() - 1)]
    }

    /// Input vector with a controllable fraction of exact zeros — the
    /// zero-skip paths are the interesting edge for the engine
    /// equivalence properties.
    pub fn vec_sparse_normal(&mut self, len: usize, zero_frac: f64) -> Vec<f32> {
        (0..len)
            .map(|_| if self.rng.chance(zero_frac) { 0.0 } else { self.rng.normal() })
            .collect()
    }
}

/// Run `cases` property checks. The closure should panic (e.g. via
/// `assert!`) on a violated property.
pub fn check<F: FnMut(&mut Gen)>(seed: u64, cases: usize, mut prop: F) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let rng = root.fork(case as u64);
        let mut g = Gen { rng, case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(1, 50, |g| {
            let x = g.i32_in(-100, 100);
            assert!(x >= -100 && x <= 100);
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn reports_failing_case() {
        check(2, 50, |g| {
            let x = g.usize_in(0, 10);
            assert!(x < 10, "x was {x}");
        });
    }

    #[test]
    fn generator_ranges() {
        check(3, 100, |g| {
            let v = g.f32_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&v));
            let u = g.u32_in(5, 9);
            assert!((5..=9).contains(&u));
        });
    }
}
