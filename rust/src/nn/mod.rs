//! Float reference network layers (Layer-3 side).
//!
//! This is the f32 ground-truth implementation of the Table-1 models,
//! used to (a) cross-check the PJRT-loaded AOT artifacts, (b) validate
//! the fixed-point MCU engine within quantization tolerance, and (c)
//! run the paper's *float-platform* evaluation (Widar / Table 2, which
//! the paper runs on desktop-class hardware rather than the MSP430).
//!
//! [`forward`] additionally implements UnIT pruning *in the float
//! domain* (Eqs. 2 and 3 verbatim) with exact kept/skipped-MAC counting,
//! mirroring the paper's "debug build" that reports skip statistics.

pub mod forward;
pub mod layers;

pub use forward::{forward, ForwardOpts, ForwardStats};
pub use layers::{conv2d_shape, Layer};
