//! Float reference network layers (Layer-3 side).
//!
//! This is the f32 ground-truth implementation of the Table-1 models,
//! used to (a) cross-check the PJRT-loaded AOT artifacts, (b) validate
//! the fixed-point MCU engine within quantization tolerance, and (c)
//! run the paper's *float-platform* evaluation (Widar / Table 2, which
//! the paper runs on desktop-class hardware rather than the MSP430).
//!
//! [`forward`] additionally implements UnIT pruning *in the float
//! domain* (Eqs. 2 and 3 verbatim) with exact kept/skipped-MAC counting,
//! mirroring the paper's "debug build" that reports skip statistics.
//!
//! [`planned`] is the prepacked fast path: conv `w̄` tables hoisted out
//! of the per-call loop, magnitude-sorted linear rows with binary-search
//! early exit, and reusable scratch buffers — bit-identical outputs at a
//! fraction of the host cost. Batched evaluation runs on it.

pub mod forward;
pub mod layers;
pub mod planned;

pub use forward::{forward, ForwardOpts, ForwardStats};
pub use layers::{conv2d_shape, Layer};
pub use planned::{FloatPlan, FloatScratch};
