//! Layer descriptors and shape inference for the Table-1 architectures.

/// One layer of a sequential Table-1 model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layer {
    /// Valid 2-D convolution (+ReLU) with optional 2×2 max pool.
    Conv { out_ch: usize, in_ch: usize, kh: usize, kw: usize, pool: bool },
    /// Fully connected; `relu` marks hidden linears (Widar's L1).
    Linear { n_in: usize, n_out: usize, relu: bool },
}

impl Layer {
    /// Parameter element counts `(weights, biases)`.
    pub fn param_counts(&self) -> (usize, usize) {
        match *self {
            Layer::Conv { out_ch, in_ch, kh, kw, .. } => (out_ch * in_ch * kh * kw, out_ch),
            Layer::Linear { n_in, n_out, .. } => (n_in * n_out, n_out),
        }
    }

    /// Dense MACs given the input spatial shape; returns (macs, out_shape).
    pub fn dense_macs(&self, in_shape: [usize; 3]) -> (u64, [usize; 3]) {
        match *self {
            Layer::Conv { out_ch, in_ch, kh, kw, pool } => {
                let [c, h, w] = in_shape;
                assert_eq!(c, in_ch, "conv input channels");
                let (oh, ow) = conv2d_shape(h, w, kh, kw);
                let macs = (out_ch * in_ch * kh * kw * oh * ow) as u64;
                let (oh, ow) = if pool { (oh / 2, ow / 2) } else { (oh, ow) };
                (macs, [out_ch, oh, ow])
            }
            Layer::Linear { n_in, n_out, .. } => {
                assert_eq!(in_shape.iter().product::<usize>(), n_in, "linear input size");
                ((n_in * n_out) as u64, [n_out, 1, 1])
            }
        }
    }

    /// Whether this layer is a convolution.
    pub fn is_conv(&self) -> bool {
        matches!(self, Layer::Conv { .. })
    }
}

/// Valid-convolution output spatial shape.
pub fn conv2d_shape(h: usize, w: usize, kh: usize, kw: usize) -> (usize, usize) {
    assert!(h >= kh && w >= kw, "kernel larger than input");
    (h - kh + 1, w - kw + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_valid() {
        assert_eq!(conv2d_shape(28, 28, 5, 5), (24, 24));
        assert_eq!(conv2d_shape(13, 13, 6, 6), (8, 8));
    }

    #[test]
    fn mnist_pipeline_shapes() {
        let l1 = Layer::Conv { out_ch: 6, in_ch: 1, kh: 5, kw: 5, pool: true };
        let (m1, s1) = l1.dense_macs([1, 28, 28]);
        assert_eq!(m1, 6 * 25 * 24 * 24);
        assert_eq!(s1, [6, 12, 12]);
        let l2 = Layer::Conv { out_ch: 16, in_ch: 6, kh: 5, kw: 5, pool: true };
        let (m2, s2) = l2.dense_macs(s1);
        assert_eq!(m2, 16 * 6 * 25 * 8 * 8);
        assert_eq!(s2, [16, 4, 4]);
        let l3 = Layer::Linear { n_in: 256, n_out: 10, relu: false };
        let (m3, s3) = l3.dense_macs(s2);
        assert_eq!(m3, 2560);
        assert_eq!(s3, [10, 1, 1]);
    }

    #[test]
    fn param_counts() {
        let c = Layer::Conv { out_ch: 6, in_ch: 3, kh: 5, kw: 5, pool: false };
        assert_eq!(c.param_counts(), (450, 6));
        let l = Layer::Linear { n_in: 256, n_out: 10, relu: false };
        assert_eq!(l.param_counts(), (2560, 10));
    }

    #[test]
    #[should_panic(expected = "linear input size")]
    fn shape_mismatch_panics() {
        let l = Layer::Linear { n_in: 100, n_out: 10, relu: false };
        l.dense_macs([16, 4, 4]);
    }
}
