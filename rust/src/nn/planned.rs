//! Prepacked float forward: the host-fast twin of [`super::forward`].
//!
//! [`forward`](super::forward::forward) recomputes the conv layers'
//! input-independent Eq. 3 thresholds `w̄ = T/|w|` on *every call* and
//! allocates fresh activation buffers per layer. [`FloatPlan::compile`]
//! hoists all of that into a one-time compile step:
//!
//! * conv `w̄` tables are computed once and reused across calls;
//! * each linear weight row is magnitude-sorted so Eq. 2's keep-set
//!   `|w| > T/|x|` is a prefix found by binary search — skipped MACs
//!   cost O(log n_out) amortized instead of one compare each;
//! * [`FloatScratch`] ping-pong buffers remove per-call allocation.
//!
//! The float path shares the quant plan's **scale-indexed layout
//! contract** ([`crate::engine::plan`]): everything that depends only
//! on the *weights* — conv weight/bias buffers and the linear
//! magnitude-sorted tables — lives behind `Arc`s, and everything that
//! depends on the *thresholds* (conv `w̄` tables, linear `t`, the
//! FATReLU cut) is a thin stamped residue. [`FloatPlan::restamp`]
//! rebuilds only the residue for a new [`ForwardOpts`]: a threshold
//! sweep (Fig. 5 percentile curves, the Table-2 mechanism comparison)
//! pays the O(weights · log) sort once, then O(weights) per setting.
//!
//! Results are **bit-identical** to the reference pass: per output
//! element, contributions are applied in the same order (ascending
//! input index, taps in declaration order), and the same f32 predicate
//! decides every keep/skip, so logits and per-layer kept/skipped
//! counts match exactly. (This is also why the float conv does *not*
//! reorder taps the way the quant plan does, and why the linear
//! kernel's blocked row tiles batch only the *threshold lookups* while
//! the MAC sweeps stay row-major: f32 accumulation is order-sensitive,
//! so the hoisted `w̄` table keeps declaration order and row
//! contributions keep ascending-index order.) `evaluate_float` and the
//! parallel batched eval in [`crate::train::eval`] run on this path.

use std::sync::Arc;

use super::forward::{ForwardOpts, ForwardStats};
use super::layers::{conv2d_shape, Layer};
use crate::models::{ModelDef, Params};

/// The weight-only (threshold-invariant) tables of one linear layer,
/// shared across every [`FloatPlan::restamp`] of the same model.
#[derive(Debug)]
struct FloatLinTables {
    /// Per input row: weights sorted by descending `|w|`.
    sorted_w: Vec<f32>,
    /// `|w|` of `sorted_w` (binary-search key).
    sorted_abs: Vec<f32>,
    /// Original output index per sorted tap.
    sorted_idx: Vec<u32>,
}

#[derive(Debug, Clone)]
enum FLayer {
    Conv {
        out_ch: usize,
        in_ch: usize,
        kh: usize,
        kw: usize,
        h: usize,
        wd: usize,
        oh: usize,
        ow: usize,
        pool: bool,
        /// Weight-invariant buffers, shared across restamps.
        w: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
        /// Hoisted Eq. 3 thresholds `T/|w|` (∞ for zero weights), same
        /// layout as `w` — the threshold-dependent stamped residue.
        wbar: Vec<f32>,
    },
    Linear {
        n_in: usize,
        n_out: usize,
        relu: bool,
        b: Arc<Vec<f32>>,
        /// Layer threshold `T` (the stamped residue).
        t: f32,
        /// Magnitude-sorted rows, shared across restamps.
        tables: Arc<FloatLinTables>,
    },
}

/// Reusable ping-pong activation buffers for [`FloatPlan::forward`].
#[derive(Debug, Clone)]
pub struct FloatScratch {
    act_a: Vec<f32>,
    act_b: Vec<f32>,
}

/// A `ModelDef + Params + ForwardOpts` triple compiled for fast host
/// execution (thresholds and FATReLU cut-off are baked in; see
/// [`FloatPlan::restamp`] for re-baking them cheaply).
#[derive(Debug, Clone)]
pub struct FloatPlan {
    layers: Vec<FLayer>,
    fat_t: f32,
    input_len: usize,
    n_layers: usize,
    max_act: usize,
}

/// Row-tile width of the blocked linear lookup, mirroring the quant
/// plan's `LIN_BLOCK`.
const LIN_BLOCK: usize = 4;

/// Drain a gathered tile of live linear rows `(k, xv, cut)` —
/// **row-major, ascending `k`, taps in sorted order**, exactly the
/// order the unblocked loop used. Only the Eq. 2 prefix *lookups* were
/// batched by the caller; f32 accumulation is order-sensitive, so the
/// MAC sweeps must not interleave rows the way the quant plan's
/// register-blocked kernel does (i64 there is order-independent).
/// Every bit of the logits is therefore unchanged.
#[inline]
fn flush_float_rows(
    tables: &FloatLinTables,
    n_out: usize,
    tile: &[(usize, f32, usize)],
    dst: &mut [f32],
) {
    for &(k, xv, cut) in tile {
        let ws = &tables.sorted_w[k * n_out..k * n_out + cut];
        let idx = &tables.sorted_idx[k * n_out..k * n_out + cut];
        for (wv, &j) in ws.iter().zip(idx) {
            dst[j as usize] += xv * *wv;
        }
    }
}

/// Hoisted Eq. 3 threshold table for one conv weight buffer
/// (identical formula to the reference pass — the whole point is
/// computing it once, not per call).
fn conv_wbar(w: &[f32], t: f32) -> Vec<f32> {
    w.iter()
        .map(|&wv| {
            let a = wv.abs();
            if a > 0.0 {
                t / a
            } else {
                f32::INFINITY
            }
        })
        .collect()
}

impl FloatPlan {
    /// Compile per-layer magnitude-sorted tables for prefix keep-set lookup.
    pub fn compile(def: &ModelDef, params: &Params, opts: &ForwardOpts) -> FloatPlan {
        assert_eq!(opts.t_vec.len(), def.layers.len(), "t_vec arity");
        let input_len = def.input_len();
        let mut shape = def.input_shape;
        let mut max_act = input_len;
        let mut layers = Vec::with_capacity(def.layers.len());
        for (li, layer) in def.layers.iter().enumerate() {
            let t = opts.t_vec[li];
            let w = &params.weights[li];
            let b = &params.biases[li];
            match *layer {
                Layer::Conv { out_ch, in_ch, kh, kw, pool } => {
                    let [c, h, wd] = shape;
                    debug_assert_eq!(c, in_ch, "conv input channels");
                    let (oh, ow) = conv2d_shape(h, wd, kh, kw);
                    let wbar = conv_wbar(w, t);
                    max_act = max_act.max(out_ch * oh * ow);
                    shape = if pool { [out_ch, oh / 2, ow / 2] } else { [out_ch, oh, ow] };
                    layers.push(FLayer::Conv {
                        out_ch,
                        in_ch,
                        kh,
                        kw,
                        h,
                        wd,
                        oh,
                        ow,
                        pool,
                        w: Arc::new(w.clone()),
                        b: Arc::new(b.clone()),
                        wbar,
                    });
                }
                Layer::Linear { n_in, n_out, relu } => {
                    debug_assert_eq!(shape.iter().product::<usize>(), n_in, "linear input");
                    let mut sorted_w = Vec::with_capacity(n_in * n_out);
                    let mut sorted_abs = Vec::with_capacity(n_in * n_out);
                    let mut sorted_idx = Vec::with_capacity(n_in * n_out);
                    let mut order: Vec<u32> = Vec::with_capacity(n_out);
                    for k in 0..n_in {
                        let row = &w[k * n_out..(k + 1) * n_out];
                        order.clear();
                        order.extend(0..n_out as u32);
                        order.sort_by(|&a, &b| {
                            row[b as usize].abs().total_cmp(&row[a as usize].abs())
                        });
                        for &j in &order {
                            let wv = row[j as usize];
                            sorted_w.push(wv);
                            sorted_abs.push(wv.abs());
                            sorted_idx.push(j);
                        }
                    }
                    max_act = max_act.max(n_out);
                    shape = [n_out, 1, 1];
                    layers.push(FLayer::Linear {
                        n_in,
                        n_out,
                        relu,
                        b: Arc::new(b.clone()),
                        t,
                        tables: Arc::new(FloatLinTables { sorted_w, sorted_abs, sorted_idx }),
                    });
                }
            }
        }
        FloatPlan {
            n_layers: layers.len(),
            layers,
            fat_t: opts.fat_t,
            input_len,
            max_act,
        }
    }

    /// Re-bake this plan for new thresholds / FATReLU cut, **sharing**
    /// every weight-derived table with `self` (conv weight/bias
    /// buffers, linear sorted rows — behind `Arc`s, no copy, no
    /// re-sort). Only the conv `w̄` tables and the linear `t` scalars
    /// are recomputed: the float twin of the quant plan's cut-table
    /// stamp. The result is bit-identical to a fresh
    /// [`FloatPlan::compile`] of the same model under `opts`
    /// (property-tested below).
    pub fn restamp(&self, opts: &ForwardOpts) -> FloatPlan {
        assert_eq!(opts.t_vec.len(), self.layers.len(), "t_vec arity");
        let layers = self
            .layers
            .iter()
            .zip(&opts.t_vec)
            .map(|(layer, &t)| match layer {
                // Constructed field by field (not cloned-then-patched)
                // so the outgoing wbar Vec is never copied — only the
                // Arcs are cloned and the new wbar is computed.
                FLayer::Conv {
                    out_ch,
                    in_ch,
                    kh,
                    kw,
                    h,
                    wd,
                    oh,
                    ow,
                    pool,
                    w,
                    b,
                    wbar: _,
                } => FLayer::Conv {
                    out_ch: *out_ch,
                    in_ch: *in_ch,
                    kh: *kh,
                    kw: *kw,
                    h: *h,
                    wd: *wd,
                    oh: *oh,
                    ow: *ow,
                    pool: *pool,
                    w: Arc::clone(w),
                    b: Arc::clone(b),
                    wbar: conv_wbar(w, t),
                },
                FLayer::Linear { n_in, n_out, relu, b, t: _, tables } => FLayer::Linear {
                    n_in: *n_in,
                    n_out: *n_out,
                    relu: *relu,
                    b: Arc::clone(b),
                    t,
                    tables: Arc::clone(tables),
                },
            })
            .collect();
        FloatPlan {
            layers,
            fat_t: opts.fat_t,
            input_len: self.input_len,
            n_layers: self.n_layers,
            max_act: self.max_act,
        }
    }

    /// Allocate a scratch for this plan (one per thread).
    pub fn new_scratch(&self) -> FloatScratch {
        FloatScratch {
            act_a: vec![0.0f32; self.max_act],
            act_b: vec![0.0f32; self.max_act],
        }
    }

    /// Planned forward pass: identical `(logits, stats)` to
    /// [`super::forward::forward`] under the compiled opts.
    pub fn forward(&self, x: &[f32], s: &mut FloatScratch) -> (Vec<f32>, ForwardStats) {
        self.forward_observed(x, s, None)
    }

    /// [`FloatPlan::forward`] with an optional per-layer observability
    /// sink (same contract as
    /// [`PlannedModel::infer_observed`](crate::engine::PlannedModel::infer_observed):
    /// `None` takes no timestamps and is bit-identical to the plain
    /// forward).
    pub fn forward_observed(
        &self,
        x: &[f32],
        s: &mut FloatScratch,
        sink: Option<&dyn crate::obs::LayerSink>,
    ) -> (Vec<f32>, ForwardStats) {
        assert_eq!(x.len(), self.input_len, "input length");
        let mut stats = ForwardStats {
            kept: vec![0; self.n_layers],
            skipped: vec![0; self.n_layers],
        };
        s.act_a[..x.len()].copy_from_slice(x);
        let mut in_a = true;
        let mut cur_len = x.len();
        for (li, layer) in self.layers.iter().enumerate() {
            let t_layer = sink.map(|_| std::time::Instant::now());
            let (src_buf, dst_buf) = if in_a {
                (&mut s.act_a, &mut s.act_b)
            } else {
                (&mut s.act_b, &mut s.act_a)
            };
            let src: &[f32] = &src_buf[..cur_len];
            match layer {
                FLayer::Conv {
                    out_ch,
                    in_ch,
                    kh,
                    kw,
                    h,
                    wd,
                    oh,
                    ow,
                    pool,
                    w,
                    b,
                    wbar,
                } => {
                    let (out_ch, in_ch, kh, kw, h, wd, oh, ow) =
                        (*out_ch, *in_ch, *kh, *kw, *h, *wd, *oh, *ow);
                    let ickk = in_ch * kh * kw;
                    let mut kept = 0u64;
                    let mut skipped = 0u64;
                    for o in 0..out_ch {
                        let wrow = &w[o * ickk..(o + 1) * ickk];
                        let brow = &wbar[o * ickk..(o + 1) * ickk];
                        for p in 0..oh {
                            for q in 0..ow {
                                let mut acc = b[o];
                                let mut ti = 0usize;
                                for ci in 0..in_ch {
                                    for u in 0..kh {
                                        let row = &src[(ci * h + p + u) * wd + q..];
                                        for v in 0..kw {
                                            let xv = row[v];
                                            // Eq. 3: keep iff |x| > T/|w|
                                            if xv.abs() > brow[ti] {
                                                acc += xv * wrow[ti];
                                                kept += 1;
                                            } else {
                                                skipped += 1;
                                            }
                                            ti += 1;
                                        }
                                    }
                                }
                                dst_buf[(o * oh + p) * ow + q] = acc;
                            }
                        }
                    }
                    stats.kept[li] = kept;
                    stats.skipped[li] = skipped;
                    // FATReLU (fat_t = 0 ⇒ ReLU)
                    for v in dst_buf[..out_ch * oh * ow].iter_mut() {
                        if *v <= self.fat_t {
                            *v = 0.0;
                        }
                    }
                    cur_len = out_ch * oh * ow;
                    if *pool {
                        let (ph, pw) = (oh / 2, ow / 2);
                        // In place: each write lands at index w while its
                        // four reads sit at ≥ 4w, so no unread input is
                        // clobbered.
                        for o in 0..out_ch {
                            for p in 0..ph {
                                for q in 0..pw {
                                    let mut m = f32::NEG_INFINITY;
                                    for du in 0..2 {
                                        for dv in 0..2 {
                                            m = m.max(
                                                dst_buf
                                                    [(o * oh + 2 * p + du) * ow + 2 * q + dv],
                                            );
                                        }
                                    }
                                    dst_buf[(o * ph + p) * pw + q] = m;
                                }
                            }
                        }
                        cur_len = out_ch * ph * pw;
                    }
                }
                FLayer::Linear { n_in, n_out, relu, b, t, tables } => {
                    let (n_in, n_out) = (*n_in, *n_out);
                    dst_buf[..n_out].copy_from_slice(b);
                    let mut kept = 0u64;
                    let mut skipped = 0u64;
                    // Blocked lookups, ordered sweeps: up to LIN_BLOCK
                    // live rows' Eq. 2 prefix cuts are found together
                    // (the float side of the quant plan's blocked
                    // linear kernel), then flush_float_rows drains them
                    // in the original row-major order.
                    let mut tile = [(0usize, 0.0f32, 0usize); LIN_BLOCK];
                    let mut fill = 0usize;
                    for k in 0..n_in {
                        let xv = src[k];
                        let a = xv.abs();
                        if a > 0.0 {
                            let tbar = *t / a;
                            let abs_row = &tables.sorted_abs[k * n_out..(k + 1) * n_out];
                            // Eq. 2 keep-set = the sorted-row prefix with
                            // |w| > T/|x|.
                            let cut = abs_row.partition_point(|&ab| ab > tbar);
                            kept += cut as u64;
                            skipped += (n_out - cut) as u64;
                            if cut > 0 {
                                tile[fill] = (k, xv, cut);
                                fill += 1;
                                if fill == LIN_BLOCK {
                                    flush_float_rows(tables, n_out, &tile[..fill], dst_buf);
                                    fill = 0;
                                }
                            }
                        } else {
                            // zero activation: whole row skipped
                            skipped += n_out as u64;
                        }
                    }
                    if fill > 0 {
                        flush_float_rows(tables, n_out, &tile[..fill], dst_buf);
                    }
                    stats.kept[li] = kept;
                    stats.skipped[li] = skipped;
                    if *relu {
                        for v in dst_buf[..n_out].iter_mut() {
                            if *v <= self.fat_t {
                                *v = 0.0;
                            }
                        }
                    }
                    cur_len = n_out;
                }
            }
            if let Some(sk) = sink {
                let ns = t_layer.map(|t| t.elapsed().as_nanos() as u64).unwrap_or(0);
                sk.layer(li, ns, stats.kept[li], stats.skipped[li]);
            }
            in_a = !in_a;
        }
        let act = if in_a { &s.act_a } else { &s.act_b };
        (act[..cur_len].to_vec(), stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{zoo, Params, MODEL_NAMES};
    use crate::nn::forward;

    fn bit_identical(def: &ModelDef, params: &Params, x: &[f32], opts: &ForwardOpts) {
        let (want, wstats) = forward(def, params, x, opts);
        let plan = FloatPlan::compile(def, params, opts);
        let mut s = plan.new_scratch();
        let (got, gstats) = plan.forward(x, &mut s);
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{}: logit {i} differs: {a} vs {b}",
                def.name
            );
        }
        assert_eq!(gstats.kept, wstats.kept, "{} kept", def.name);
        assert_eq!(gstats.skipped, wstats.skipped, "{} skipped", def.name);
    }

    #[test]
    fn planned_bit_identical_across_zoo() {
        for name in MODEL_NAMES {
            let def = zoo(name);
            let params = Params::random(&def, 31);
            let x: Vec<f32> = (0..def.input_len())
                .map(|i| (((i * 19) % 41) as f32 - 20.0) / 11.0)
                .collect();
            for t in [0.0f32, 0.08, 0.4] {
                let opts = ForwardOpts { t_vec: vec![t; def.layers.len()], fat_t: 0.0 };
                bit_identical(&def, &params, &x, &opts);
            }
        }
    }

    #[test]
    fn planned_bit_identical_with_fatrelu() {
        let def = zoo("widar");
        let params = Params::random(&def, 33);
        let x: Vec<f32> =
            (0..def.input_len()).map(|i| ((i % 27) as f32 - 13.0) / 8.0).collect();
        let opts = ForwardOpts { t_vec: vec![0.15; def.layers.len()], fat_t: 0.3 };
        bit_identical(&def, &params, &x, &opts);
    }

    /// The float twin of the quant plan's cut-table stamp: a restamp
    /// at new thresholds is bit-identical to a fresh compile AND
    /// actually shares the weight-derived tables (Arc pointer
    /// equality — no re-sort, no copy).
    #[test]
    fn restamp_bit_identical_and_shares_weight_tables() {
        let def = zoo("mnist");
        let params = Params::random(&def, 37);
        let base_opts = ForwardOpts { t_vec: vec![0.0; def.layers.len()], fat_t: 0.0 };
        let base = FloatPlan::compile(&def, &params, &base_opts);
        let x: Vec<f32> = (0..def.input_len())
            .map(|i| (((i * 23) % 31) as f32 - 15.0) / 9.0)
            .collect();
        for (t, fat) in [(0.0f32, 0.0f32), (0.07, 0.0), (0.3, 0.25), (0.6, 0.1)] {
            let opts = ForwardOpts { t_vec: vec![t; def.layers.len()], fat_t: fat };
            let stamped = base.restamp(&opts);
            let fresh = FloatPlan::compile(&def, &params, &opts);
            let (mut ss, mut sf) = (stamped.new_scratch(), fresh.new_scratch());
            let (ls, stats_s) = stamped.forward(&x, &mut ss);
            let (lf, stats_f) = fresh.forward(&x, &mut sf);
            for (a, b) in ls.iter().zip(&lf) {
                assert_eq!(a.to_bits(), b.to_bits(), "t={t} fat={fat}: logits differ");
            }
            assert_eq!(stats_s.kept, stats_f.kept, "t={t}: kept differ");
            assert_eq!(stats_s.skipped, stats_f.skipped, "t={t}: skipped differ");
            for (a, b) in stamped.layers.iter().zip(&base.layers) {
                match (a, b) {
                    (
                        FLayer::Conv { w: wa, b: ba, .. },
                        FLayer::Conv { w: wb, b: bb, .. },
                    ) => {
                        assert!(Arc::ptr_eq(wa, wb), "conv weights copied, not shared");
                        assert!(Arc::ptr_eq(ba, bb), "conv bias copied, not shared");
                    }
                    (
                        FLayer::Linear { tables: ta, b: ba, .. },
                        FLayer::Linear { tables: tb, b: bb, .. },
                    ) => {
                        assert!(Arc::ptr_eq(ta, tb), "sorted rows copied, not shared");
                        assert!(Arc::ptr_eq(ba, bb), "linear bias copied, not shared");
                    }
                    _ => panic!("layer kinds diverged across restamp"),
                }
            }
        }
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        let def = zoo("mnist");
        let params = Params::random(&def, 35);
        let opts = ForwardOpts { t_vec: vec![0.1; 3], fat_t: 0.0 };
        let plan = FloatPlan::compile(&def, &params, &opts);
        let mut s = plan.new_scratch();
        let xa = vec![0.4f32; def.input_len()];
        let xb: Vec<f32> = (0..def.input_len()).map(|i| ((i % 7) as f32 - 3.0) / 4.0).collect();
        let (la, _) = plan.forward(&xa, &mut s);
        let _ = plan.forward(&xb, &mut s);
        let (la2, _) = plan.forward(&xa, &mut s);
        assert_eq!(la, la2);
    }

    #[test]
    fn prop_planned_equivalence_random() {
        crate::util::prop::check(55, 12, |g| {
            let def = zoo("mnist");
            let params = Params::random(&def, g.case as u64 + 101);
            let x = g.vec_normal(def.input_len());
            let t_vec: Vec<f32> = (0..3).map(|_| g.f32_in(0.0, 0.6)).collect();
            let fat_t = if g.bool() { g.f32_in(0.0, 0.5) } else { 0.0 };
            let opts = ForwardOpts { t_vec, fat_t };
            bit_identical(&def, &params, &x, &opts);
        });
    }
}
