//! Float-domain forward pass with UnIT pruning and exact MAC accounting.
//!
//! Implements the paper's Eqs. 2/3 verbatim in f32 — the same semantics
//! as the Layer-1 Pallas kernels (`python/compile/kernels/`) and the
//! fixed-point engine ([`crate::engine`]); integration tests pin all
//! three together.
//!
//! Reuse-aware structure is preserved even in the float path: for convs
//! the per-weight thresholds `w̄ = T/|w|` are computed once per layer
//! (they are input-independent); for linears the per-activation
//! thresholds `x̄ = T/|x|` are computed once per input element and reused
//! across the whole weight row.
//!
//! **Skip accounting**: a connection counts as *skipped* when the
//! threshold comparison rejects it — which, at `T = 0`, happens exactly
//! for zero operands. This matches the paper's Table 2, where even the
//! "Unpruned" row reports ~16 % MACs skipped (post-ReLU zero
//! activations).

use super::layers::{conv2d_shape, Layer};
use crate::models::{ModelDef, Params};

/// Pruning configuration for one forward pass.
#[derive(Debug, Clone)]
pub struct ForwardOpts {
    /// Per-layer UnIT thresholds `T` (empty or zeros ⇒ dense numerics).
    pub t_vec: Vec<f32>,
    /// FATReLU cut-off applied at every ReLU site (0 ⇒ plain ReLU).
    pub fat_t: f32,
}

impl ForwardOpts {
    /// Dense numerics: zero thresholds, plain ReLU.
    pub fn dense(n_layers: usize) -> ForwardOpts {
        ForwardOpts { t_vec: vec![0.0; n_layers], fat_t: 0.0 }
    }

    /// UnIT thresholds, plain ReLU.
    pub fn unit(t_vec: Vec<f32>) -> ForwardOpts {
        ForwardOpts { t_vec, fat_t: 0.0 }
    }
}

/// Per-layer kept/skipped MAC counts for one forward pass.
#[derive(Debug, Clone, Default)]
pub struct ForwardStats {
    /// Kept MACs per layer.
    pub kept: Vec<u64>,
    /// Skipped MACs per layer.
    pub skipped: Vec<u64>,
}

impl ForwardStats {
    /// Kept MACs summed over layers.
    pub fn total_kept(&self) -> u64 {
        self.kept.iter().sum()
    }

    /// Skipped MACs summed over layers.
    pub fn total_skipped(&self) -> u64 {
        self.skipped.iter().sum()
    }

    /// Fraction of all MACs skipped (0 when nothing ran).
    pub fn skip_fraction(&self) -> f64 {
        let total = self.total_kept() + self.total_skipped();
        if total == 0 {
            0.0
        } else {
            self.total_skipped() as f64 / total as f64
        }
    }

    /// Accumulate another pass's counts into this one.
    pub fn merge(&mut self, other: &ForwardStats) {
        if self.kept.is_empty() {
            self.kept = vec![0; other.kept.len()];
            self.skipped = vec![0; other.skipped.len()];
        }
        for (a, b) in self.kept.iter_mut().zip(&other.kept) {
            *a += b;
        }
        for (a, b) in self.skipped.iter_mut().zip(&other.skipped) {
            *a += b;
        }
    }
}

/// UnIT-pruned forward pass for a single sample.
///
/// Returns `(logits, stats)`. `x` is the flat `C·H·W` input.
pub fn forward(def: &ModelDef, params: &Params, x: &[f32], opts: &ForwardOpts) -> (Vec<f32>, ForwardStats) {
    assert_eq!(x.len(), def.input_len(), "input length");
    assert_eq!(opts.t_vec.len(), def.layers.len(), "t_vec arity");
    let mut stats = ForwardStats {
        kept: vec![0; def.layers.len()],
        skipped: vec![0; def.layers.len()],
    };
    let mut act = x.to_vec();
    let mut shape = def.input_shape;
    for (li, layer) in def.layers.iter().enumerate() {
        let t = opts.t_vec[li];
        let w = &params.weights[li];
        let b = &params.biases[li];
        match *layer {
            Layer::Conv { out_ch, in_ch, kh, kw, pool } => {
                let [c, h, wd] = shape;
                debug_assert_eq!(c, in_ch);
                let (oh, ow) = conv2d_shape(h, wd, kh, kw);
                let mut out = vec![0.0f32; out_ch * oh * ow];
                // Reuse-aware: one division per weight tap (Eq. 3),
                // amortized across all OH*OW positions.
                let wbar: Vec<f32> = w
                    .iter()
                    .map(|&wv| {
                        let a = wv.abs();
                        if a > 0.0 {
                            t / a
                        } else {
                            f32::INFINITY
                        }
                    })
                    .collect();
                let mut kept = 0u64;
                let mut skipped = 0u64;
                for o in 0..out_ch {
                    let wrow = &w[o * in_ch * kh * kw..(o + 1) * in_ch * kh * kw];
                    let brow = &wbar[o * in_ch * kh * kw..(o + 1) * in_ch * kh * kw];
                    for p in 0..oh {
                        for q in 0..ow {
                            let mut acc = b[o];
                            let mut ti = 0usize;
                            for ci in 0..in_ch {
                                for u in 0..kh {
                                    let row = &act[(ci * h + p + u) * wd + q..];
                                    for v in 0..kw {
                                        let xv = row[v];
                                        // Eq. 3: keep iff |x| > T/|w|
                                        if xv.abs() > brow[ti] {
                                            acc += xv * wrow[ti];
                                            kept += 1;
                                        } else {
                                            skipped += 1;
                                        }
                                        ti += 1;
                                    }
                                }
                            }
                            out[(o * oh + p) * ow + q] = acc;
                        }
                    }
                }
                stats.kept[li] = kept;
                stats.skipped[li] = skipped;
                // activation: FATReLU (fat_t = 0 ⇒ ReLU)
                for v in out.iter_mut() {
                    if *v <= opts.fat_t {
                        *v = 0.0;
                    }
                }
                shape = [out_ch, oh, ow];
                act = out;
                if pool {
                    let (ph, pw) = (oh / 2, ow / 2);
                    let mut pooled = vec![0.0f32; out_ch * ph * pw];
                    for o in 0..out_ch {
                        for p in 0..ph {
                            for q in 0..pw {
                                // NEG_INFINITY, not f32::MIN: windows of
                                // deeply negative (pre-clamp) activations
                                // must still pool to their true max.
                                let mut m = f32::NEG_INFINITY;
                                for du in 0..2 {
                                    for dv in 0..2 {
                                        m = m.max(act[(o * oh + 2 * p + du) * ow + 2 * q + dv]);
                                    }
                                }
                                pooled[(o * ph + p) * pw + q] = m;
                            }
                        }
                    }
                    shape = [out_ch, ph, pw];
                    act = pooled;
                }
            }
            Layer::Linear { n_in, n_out, relu } => {
                debug_assert_eq!(shape.iter().product::<usize>(), n_in);
                let mut out = b.clone();
                let mut kept = 0u64;
                let mut skipped = 0u64;
                // Reuse-aware: one division per input activation (Eq. 2),
                // reused across the whole weight row.
                for k in 0..n_in {
                    let xv = act[k];
                    let row = &w[k * n_out..(k + 1) * n_out];
                    let a = xv.abs();
                    if a > 0.0 {
                        let tbar = t / a;
                        for (j, &wv) in row.iter().enumerate() {
                            // Eq. 2: keep iff |w| > T/|x|
                            if wv.abs() > tbar {
                                out[j] += xv * wv;
                                kept += 1;
                            } else {
                                skipped += 1;
                            }
                        }
                    } else {
                        // zero activation: every MAC in the row is skipped
                        skipped += n_out as u64;
                    }
                }
                stats.kept[li] = kept;
                stats.skipped[li] = skipped;
                if relu {
                    for v in out.iter_mut() {
                        if *v <= opts.fat_t {
                            *v = 0.0;
                        }
                    }
                }
                shape = [n_out, 1, 1];
                act = out;
            }
        }
    }
    (act, stats)
}

/// Convenience: dense forward (T = 0, plain ReLU), logits only.
pub fn forward_dense(def: &ModelDef, params: &Params, x: &[f32]) -> Vec<f32> {
    forward(def, params, x, &ForwardOpts::dense(def.layers.len())).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    fn tiny_model() -> (ModelDef, Params) {
        let def = ModelDef {
            name: "tiny".into(),
            input_shape: [1, 6, 6],
            classes: 3,
            layers: vec![
                Layer::Conv { out_ch: 2, in_ch: 1, kh: 3, kw: 3, pool: true },
                Layer::Linear { n_in: 8, n_out: 3, relu: false },
            ],
        };
        let params = Params::random(&def, 5);
        (def, params)
    }

    #[test]
    fn dense_counts_cover_all_connections() {
        let (def, params) = tiny_model();
        let x: Vec<f32> = (0..36).map(|i| (i as f32 / 36.0) - 0.5).collect();
        let (_logits, stats) = forward(&def, &params, &x, &ForwardOpts::dense(2));
        let dense = def.dense_macs();
        for (li, &d) in dense.iter().enumerate() {
            assert_eq!(stats.kept[li] + stats.skipped[li], d, "layer {li}");
        }
    }

    #[test]
    fn t0_skips_only_zero_operands() {
        let (def, params) = tiny_model();
        // strictly positive input + random weights: conv layer skips only
        // where a weight is exactly zero (none, generically)
        let x: Vec<f32> = (0..36).map(|i| 0.1 + i as f32 * 0.01).collect();
        let (_l, stats) = forward(&def, &params, &x, &ForwardOpts::dense(2));
        assert_eq!(stats.skipped[0], 0);
        // linear layer skips only rows of post-ReLU zero activations
        let zeros_after_relu = stats.skipped[1] % 3;
        assert_eq!(zeros_after_relu, 0); // whole rows of 3
    }

    #[test]
    fn raising_t_monotonically_increases_skips() {
        let (def, params) = tiny_model();
        let x: Vec<f32> = (0..36).map(|i| ((i * 7 % 13) as f32 - 6.0) / 6.0).collect();
        let mut last = 0u64;
        for t in [0.0f32, 0.05, 0.1, 0.3, 1.0] {
            let (_l, s) = forward(&def, &params, &x, &ForwardOpts::unit(vec![t, t]));
            let sk = s.total_skipped();
            assert!(sk >= last, "t={t}: {sk} < {last}");
            last = sk;
        }
    }

    #[test]
    fn huge_t_prunes_all_and_outputs_bias() {
        let (def, params) = tiny_model();
        let x = vec![0.5f32; 36];
        let (logits, s) = forward(&def, &params, &x, &ForwardOpts::unit(vec![1e9, 1e9]));
        assert_eq!(s.total_kept(), 0);
        // final layer output = bias (biases are zero in random init)
        assert!(logits.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn fatrelu_increases_downstream_skips() {
        let (def, params) = tiny_model();
        let x: Vec<f32> = (0..36).map(|i| ((i % 5) as f32 - 2.0) / 2.0).collect();
        let base = forward(&def, &params, &x, &ForwardOpts { t_vec: vec![0.0; 2], fat_t: 0.0 });
        let fat = forward(&def, &params, &x, &ForwardOpts { t_vec: vec![0.0; 2], fat_t: 0.4 });
        // more zeros entering the linear layer => more skips there
        assert!(fat.1.skipped[1] >= base.1.skipped[1]);
    }

    #[test]
    fn full_zoo_models_run() {
        for name in crate::models::MODEL_NAMES {
            let def = zoo(name);
            let params = Params::random(&def, 2);
            let x = vec![0.3f32; def.input_len()];
            let (logits, stats) =
                forward(&def, &params, &x, &ForwardOpts::dense(def.layers.len()));
            assert_eq!(logits.len(), def.classes, "{name}");
            assert_eq!(
                stats.total_kept() + stats.total_skipped(),
                def.total_dense_macs(),
                "{name}"
            );
        }
    }

    #[test]
    fn prop_pruned_equals_dense_with_masked_contributions() {
        // Property: the pruned output equals a dense pass over weights
        // where each contribution failing Eq. 2/3 is zeroed.
        crate::util::prop::check(77, 20, |g| {
            let def = ModelDef {
                name: "p".into(),
                input_shape: [1, 5, 5],
                classes: 2,
                layers: vec![Layer::Linear { n_in: 25, n_out: 2, relu: false }],
            };
            let params = Params::random(&def, g.case as u64 + 1);
            let x = g.vec_normal(25);
            let t = g.f32_in(0.0, 0.5);
            let (got, _) = forward(&def, &params, &x, &ForwardOpts::unit(vec![t]));
            // manual masked computation
            let w = &params.weights[0];
            let mut want = vec![0.0f32; 2];
            for k in 0..25 {
                let xa = x[k].abs();
                for j in 0..2 {
                    let wv = w[k * 2 + j];
                    let keep = xa > 0.0 && wv.abs() > t / xa;
                    if keep {
                        want[j] += x[k] * wv;
                    }
                }
            }
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        });
    }
}
