//! `unit` — the leader binary: train, calibrate, evaluate and serve the
//! UnIT-pruned Table-1 models.
//!
//! ```text
//! unit info                             # model zoo + cost model summary
//! unit train  --model mnist --steps 400 # train via the AOT step artifact
//! unit eval   --model mnist --div shift --percentile 20
//! unit eval   --model mnist --adaptive --budget-mj 3.5   # budget sweep on the plan cache
//! unit serve  --model mnist --requests 64 --workers 2 [--backend pjrt]
//! unit serve  --listen 127.0.0.1:0 --workers 4   # streamed TCP serving
//! unit serve  --listen 127.0.0.1:0 --budget-mj 4.0 --park 16  # adaptive + parked admission
//! unit serve  --listen 127.0.0.1:0 --chaos-seed 7   # deterministic fault injection (chaos)
//! unit serve  --listen 127.0.0.1:0 --models mnist,kws --fleet-budget-mj 8  # multi-model fleet
//! unit serve  --listen 127.0.0.1:0 --metrics-addr 127.0.0.1:0  # flight recorder + /metrics HTTP
//! unit serve  --listen 127.0.0.1:0 --slo mnist=5:0.5:0.01  # per-tenant SLOs + burn admission
//! unit trace  --addr HOST:PORT --out trace.json   # dump the flight recorder (Chrome trace JSON)
//! unit top    --addr HOST:PORT [--iters N] [--json]  # live scrape-and-print of the key gauges
//! unit slo    --addr HOST:PORT --model N --p99-ms X  # declare a tenant's SLOs at runtime (SetSlo)
//! unit bench diff OLD.json NEW.json     # perf gate: exit 1 on >10% regression
//! ```

use anyhow::Result;
use std::sync::Arc;
use std::time::Duration;

use unit_pruner::approx::DivKind;
use unit_pruner::control::{calibrated_cache, FleetScheduler, Governor, ScaleGrid};
use unit_pruner::coordinator::{
    BackendChoice, Coordinator, EnergyController, ModelSpec, Placement, ServeConfig,
};
use unit_pruner::data::{by_name, Sizes};
use unit_pruner::obs::{
    spawn_http, AdmissionPolicy, MetricsHub, ObsConfig, SloEngine, SloSpec, SloWindows,
};
use unit_pruner::serve::{Client, ServeOpts, Server, SessionCfg};
use unit_pruner::engine::{KernelBackend, PlanBacked, PlanConfig, PruneMode, QModel};
use unit_pruner::mcu::{cost, EnergyModel};
use unit_pruner::models::{zoo, MODEL_NAMES};
use unit_pruner::pruning::{calibrate, CalibConfig};
use unit_pruner::report::diff;
use unit_pruner::runtime::{ArtifactStore, Runtime};
use unit_pruner::train::{ensure_trained, evaluate_float, TrainConfig};
use unit_pruner::util::cli::Args;
use unit_pruner::util::table::Table;
use unit_pruner::util::FaultPlan;

fn main() -> Result<()> {
    let args = Args::from_env();
    // `--kernel auto|scalar|lanes|simd` (or `$UNIT_KERNEL`) pins the
    // engine's kernel backend process-wide before any plan compiles:
    // every Auto-configured `PlanConfig` in eval/serve/bench resolves
    // to it. `simd` degrades to `scalar` on hosts without a usable
    // SIMD level, so forcing it is always safe.
    if let Some(s) = args.get("kernel") {
        match KernelBackend::parse(s) {
            Some(k) => KernelBackend::set_process_default(k),
            None => {
                eprintln!("unknown --kernel `{s}` (expected auto|scalar|lanes|simd)");
                std::process::exit(2);
            }
        }
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") | None => info(),
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("memmap") => cmd_memmap(&args),
        Some("bench") => cmd_bench(&args),
        Some("trace") => cmd_trace(&args),
        Some("top") => cmd_top(&args),
        Some("slo") => cmd_slo(&args),
        Some(other) => {
            eprintln!(
                "unknown command {other}; try: info | train | eval | serve | memmap | bench | \
                 trace | top | slo"
            );
            std::process::exit(2);
        }
    }
}

/// `unit bench diff OLD NEW [--tolerance 10] [--ratios-only] [--warn-only]`
///
/// Compares two `BENCH_perf.json` snapshots and exits non-zero when any
/// gated engine/coordinator/eval row regresses beyond the tolerance —
/// the CI perf gate. `--ratios-only` gates only the machine-portable
/// planned-vs-naive speedup ratios (for CI runners whose absolute
/// throughput varies); `--warn-only` prints the delta table but always
/// exits 0 (informational runs).
fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("diff") => {}
        _ => {
            eprintln!(
                "usage: unit bench diff OLD.json NEW.json \
                 [--tolerance PCT] [--ratios-only] [--warn-only]"
            );
            std::process::exit(2);
        }
    }
    let old_path = args
        .get("old")
        .map(str::to_string)
        .or_else(|| args.positional.get(2).cloned())
        .unwrap_or_else(|| {
            eprintln!("bench diff: missing OLD snapshot path");
            std::process::exit(2);
        });
    let new_path = args
        .get("new")
        .map(str::to_string)
        .or_else(|| args.positional.get(3).cloned())
        .unwrap_or_else(|| {
            eprintln!("bench diff: missing NEW snapshot path");
            std::process::exit(2);
        });
    // The shared parser greedily reads `--flag value`; a boolean flag
    // placed before the paths would swallow one. Catch that instead of
    // mis-reporting a missing path.
    for flag in ["ratios-only", "warn-only"] {
        if let Some(v) = args.get(flag) {
            if !matches!(v, "true" | "1" | "yes") {
                eprintln!(
                    "bench diff: --{flag} takes no value (got {v:?}); \
                     place flags after the snapshot paths"
                );
                std::process::exit(2);
            }
        }
    }
    let tolerance = args.f64_or("tolerance", 10.0);
    let ratios_only = args.flag("ratios-only");
    let warn_only = args.flag("warn-only");

    let old = diff::load_snapshot(std::path::Path::new(&old_path))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let new = diff::load_snapshot(std::path::Path::new(&new_path))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let report = diff::diff_snapshots(&old, &new, tolerance, ratios_only);
    println!(
        "bench diff: {old_path} -> {new_path} (tolerance {tolerance}%{})",
        if ratios_only { ", ratios only" } else { "" }
    );
    println!("{}", report.render());
    let regs = report.regressions();
    if regs.is_empty() {
        println!("perf gate: OK ({} rows compared)", report.rows.len());
        return Ok(());
    }
    eprintln!("perf gate: {} row(s) regressed > {tolerance}%:", regs.len());
    for r in &regs {
        eprintln!(
            "  {} {} {}: {:.2} -> {:.2} ({:+.1}%)",
            r.section, r.key, r.metric, r.old, r.new, r.delta_pct
        );
    }
    if warn_only {
        eprintln!("(--warn-only: not failing the build)");
        return Ok(());
    }
    std::process::exit(1);
}

/// FRAM memory-map report for a (randomly initialized) model — the
/// deployment-fit check of paper §3.3.
fn cmd_memmap(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mnist").to_string();
    let def = zoo(&model);
    let q = QModel::quantize(&def, &unit_pruner::models::Params::random(&def, 1));
    println!("FRAM memory map for {model}:\n");
    println!("{}", unit_pruner::mcu::memmap::MemMap::plan(&q).report());
    Ok(())
}

fn info() -> Result<()> {
    println!("UnIT reproduction — model zoo (paper Table 1)\n");
    let mut t = Table::new(vec!["model", "input", "classes", "layers", "dense MACs", "params"]);
    for name in MODEL_NAMES {
        let def = zoo(name);
        let params: usize = def.layers.iter().map(|l| l.param_counts().0 + l.param_counts().1).sum();
        t.row(vec![
            name.to_string(),
            format!("{:?}", def.input_shape),
            def.classes.to_string(),
            def.layers.len().to_string(),
            def.total_dense_macs().to_string(),
            params.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "MSP430 cost model: MAC={} cyc (mul {} + add {}), compare={} cyc, div={} cyc @ {} MHz",
        cost::MAC,
        cost::MUL_SW,
        cost::ADD,
        cost::CMP_BRANCH,
        cost::DIV_SW,
        cost::CPU_HZ / 1e6
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mnist").to_string();
    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let ds = by_name(&model, args.u64_or("seed", 42), Sizes::default());
    let defaults = TrainConfig::for_model(&model);
    let cfg = TrainConfig {
        steps: args.usize_or("steps", defaults.steps),
        lr: args.f64_or("lr", defaults.lr as f64) as f32,
        seed: args.u64_or("seed", 7),
        log_every: args.usize_or("log-every", 50),
        lr_decay: true,
    };
    let params = ensure_trained(&rt, &store, &model, &ds, &cfg)?;
    let def = zoo(&model);
    let r = evaluate_float(
        &def,
        &params,
        &ds.test,
        &unit_pruner::nn::ForwardOpts::dense(def.layers.len()),
        200,
    );
    println!("trained {model}: test accuracy {:.2}% (n={})", 100.0 * r.accuracy, r.n);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mnist").to_string();
    let div = DivKind::parse(args.get_or("div", "shift")).expect("div kind");
    let pct = args.f64_or("percentile", 20.0);
    let n_eval = args.usize_or("samples", 200);

    let rt = Runtime::cpu()?;
    let store = ArtifactStore::discover();
    let ds = by_name(&model, args.u64_or("seed", 42), Sizes::default());
    let params = ensure_trained(&rt, &store, &model, &ds, &TrainConfig::default())?;
    let def = zoo(&model);

    let th = calibrate(&def, &params, &ds.val, &CalibConfig { percentile: pct, ..Default::default() });
    println!("calibrated thresholds (p{pct}): {:?}", th.per_layer);

    let q = QModel::quantize(&def, &params);
    let qp = q.clone().with_thresholds(&th);
    let energy = EnergyModel::default();

    if args.flag("adaptive") {
        return cmd_eval_adaptive(args, &qp, &ds, div);
    }

    let mut rows = Table::new(vec!["config", "accuracy", "MAC skipped", "mcu secs", "energy mJ"]);
    for (label, qm, mode) in [
        ("dense", &q, PruneMode::Dense),
        ("unit", &qp, PruneMode::Unit),
    ] {
        let n = ds.test.len().min(n_eval);
        let mut hits = 0usize;
        let mut skipped = 0f64;
        let mut secs = 0f64;
        let mut mj = 0f64;
        // Planned fast path: compile once, zero allocation per sample;
        // ledger/logits identical to the naive engine.
        let mut pb = PlanBacked::new(qm, PlanConfig::for_mode(mode, div));
        for i in 0..n {
            let xi = pb.quantize_input(ds.test.sample(i));
            let out = pb.infer(&xi);
            if out.argmax() == ds.test.y[i] {
                hits += 1;
            }
            skipped += out.skip_fraction();
            secs += out.ledger.secs();
            mj += out.ledger.millijoules(&energy);
        }
        let nf = n as f64;
        rows.row(vec![
            label.to_string(),
            format!("{:.2}%", 100.0 * hits as f64 / nf),
            format!("{:.2}%", 100.0 * skipped / nf),
            format!("{:.3}", secs / nf),
            format!("{:.3}", mj / nf),
        ]);
    }
    println!("{}", rows.render());
    Ok(())
}

/// `unit eval --adaptive [--budget-mj B] [--calib-samples N]`:
/// budget-driven evaluation on the plan cache. Sweeps a set of budget
/// phases (fractions of the measured dense energy, or a fixed
/// `--budget-mj`), running the AIMD controller snapped to the default
/// scale grid with every plan served from the cache — the in-process
/// twin of `unit serve --budget-mj`.
fn cmd_eval_adaptive(
    args: &Args,
    qp: &QModel,
    ds: &unit_pruner::data::Dataset,
    div: DivKind,
) -> Result<()> {
    let n_cal = ds.val.len().min(args.usize_or("calib-samples", 8));
    let cal: Vec<Vec<f32>> = (0..n_cal).map(|i| ds.val.sample(i).to_vec()).collect();
    let (cache, profile) = calibrated_cache(
        qp.clone(),
        PlanConfig::for_mode(PruneMode::Unit, div),
        ScaleGrid::default_grid(),
        &cal,
    );
    let energy = EnergyModel::default();

    // Budget phases: fractions of the measured scale-1.0 energy, or
    // one fixed budget when --budget-mj is given.
    let base_step = cache.grid().snap_q8(256);
    let base_mj = profile.mean_mj(base_step);
    let fixed = args.f64_or("budget-mj", 0.0);
    let phases: Vec<(String, f64)> = if fixed > 0.0 {
        vec![(format!("{fixed} mJ"), fixed)]
    } else {
        [2.0, 1.0, 0.6, 0.35, 1.2]
            .iter()
            .map(|m| (format!("{m}x base"), base_mj * m))
            .collect()
    };

    let mut ctrl = EnergyController::new(phases[0].1);
    ctrl.snap_to_grid(cache.grid());
    let steps_per_phase = args.usize_or("samples", 60);
    let mut t = Table::new(vec![
        "phase", "budget mJ", "mean mJ", "scale", "step", "mean skip %", "accuracy",
    ]);
    let mut idx = 0usize;
    for (name, budget) in &phases {
        ctrl.set_budget(*budget);
        let (mut mj_sum, mut skip_sum, mut hits) = (0.0f64, 0.0f64, 0usize);
        for _ in 0..steps_per_phase {
            let i = idx % ds.test.len();
            idx += 1;
            let step = cache.grid().snap_q8(ctrl.t_scale_q8());
            let plan = cache.plan_at(step);
            let mut scratch = plan.new_scratch();
            let out = plan.infer(&plan.quantize_input(ds.test.sample(i)), &mut scratch);
            let mj = out.ledger.millijoules(&energy);
            ctrl.observe(mj);
            mj_sum += mj;
            skip_sum += out.skip_fraction();
            hits += (out.argmax() == ds.test.y[i]) as usize;
        }
        let n = steps_per_phase as f64;
        t.row(vec![
            name.clone(),
            format!("{budget:.3}"),
            format!("{:.3}", mj_sum / n),
            format!("{:.2}x", ctrl.scale()),
            format!("{}/{}", cache.grid().snap_q8(ctrl.t_scale_q8()), cache.grid().len()),
            format!("{:.1}%", 100.0 * skip_sum / n),
            format!("{:.1}%", 100.0 * hits as f64 / n),
        ]);
    }
    println!("{}", t.render());
    println!(
        "plan cache: {} hits, {} misses over {} grid steps (calibration warmed the grid; \
         every phase transition was cache-served)",
        cache.hits(),
        cache.misses(),
        cache.grid().len()
    );
    Ok(())
}

/// `unit serve`: burst mode (`--requests N`, the in-process demo) or
/// streamed TCP mode (`--listen ADDR`, the production front door).
fn cmd_serve(args: &Args) -> Result<()> {
    // `--models A,B` switches to the multi-model fleet path (its own
    // bootstrap: one plan cache + keep profile per model, a fleet
    // scheduler instead of a governor).
    if args.get("models").is_some() {
        return cmd_serve_multi(args);
    }
    let model = args.get_or("model", "mnist").to_string();
    let n_req = args.usize_or("requests", 64);
    let backend = args.get_or("backend", "mcu").to_string();

    let ds = by_name(&model, args.u64_or("seed", 42), Sizes::default());
    let def = zoo(&model);
    // Trained weights need the PJRT runtime (the trainer runs on AOT
    // step artifacts). Without it — the default offline build — serve
    // still works: randomly initialized weights exercise the identical
    // pruning/serving machinery, which is what the protocol smoke
    // tests need.
    let params = match Runtime::cpu().and_then(|rt| {
        let store = ArtifactStore::discover();
        ensure_trained(&rt, &store, &model, &ds, &TrainConfig::default())
    }) {
        Ok(p) => p,
        Err(e) => {
            if backend == "pjrt" {
                eprintln!("serve: the pjrt backend needs the `xla` feature + artifacts: {e}");
                std::process::exit(2);
            }
            eprintln!("[serve] trained weights unavailable ({e}); using random init");
            unit_pruner::models::Params::random(&def, args.u64_or("seed", 42))
        }
    };
    let th = calibrate(&def, &params, &ds.val, &CalibConfig::default());

    // `--budget-mj B` (> 0) turns on budget-driven adaptive serving.
    let budget_mj = args.f64_or("budget-mj", 0.0);
    // Kept aside for the adaptive control plane: the governor's plan
    // cache compiles from the same quantized model + mode/div. Cloned
    // only when a governor will actually be installed.
    let mut adaptive_src: Option<(QModel, PruneMode, DivKind)> = None;
    let choice = if backend == "pjrt" {
        BackendChoice::Pjrt {
            model: model.clone(),
            params,
            t_vec: th.per_layer.clone(),
            fat_t: 0.0,
        }
    } else {
        let q = QModel::quantize(&def, &params).with_thresholds(&th);
        let div = DivKind::parse(args.get_or("div", "shift")).expect("div kind");
        if budget_mj > 0.0 {
            adaptive_src = Some((q.clone(), PruneMode::Unit, div));
        }
        BackendChoice::McuSim { q, mode: PruneMode::Unit, div }
    };
    let placement = match args.get_or("placement", "cost") {
        "two-choice" | "count" => Placement::TwoChoice,
        _ => Placement::CostWeighted,
    };
    // `--chaos-seed S` (non-zero) arms the deterministic fault plan:
    // injected worker panics coordinator-side plus reply corruption,
    // delays, and read stalls session-side — the self-healing paths
    // under test in CI's chaos-smoke job.
    let chaos_seed = args.u64_or("chaos-seed", 0);
    let fault = (chaos_seed != 0).then(|| Arc::new(FaultPlan::new(chaos_seed)));
    if let Some(f) = &fault {
        eprintln!("[serve] chaos plan armed (seed {})", f.seed());
    }
    // `--metrics-addr ADDR` turns the observability layer on: a
    // flight recorder on every worker plus the /metrics + /trace HTTP
    // side listener (bound in cmd_serve_listen).
    let obs = obs_from_args(args);
    let coord = Coordinator::start(
        choice,
        ServeConfig {
            workers: args.usize_or("workers", 2),
            max_batch: args.usize_or("max-batch", 8),
            max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 2)),
            placement,
            fault: fault.clone(),
            obs,
        },
    );

    // Adaptive serving: a plan cache over the default scale grid,
    // per-layer keep-ratio curves calibrated on the validation split
    // (which warms the cache), and the governor installed as the
    // coordinator's energy tap.
    let governor = if budget_mj > 0.0 {
        match adaptive_src {
            Some((q, mode, div)) => {
                let n_cal = ds.val.len().min(args.usize_or("calib-samples", 8));
                let cal: Vec<Vec<f32>> =
                    (0..n_cal).map(|i| ds.val.sample(i).to_vec()).collect();
                eprintln!(
                    "[serve] calibrating keep-ratio curves over the scale grid \
                     ({} samples)…",
                    cal.len()
                );
                let (cache, profile) = calibrated_cache(
                    q,
                    PlanConfig::for_mode(mode, div),
                    ScaleGrid::default_grid(),
                    &cal,
                );
                match Governor::install(&coord, cache, Some(profile), budget_mj) {
                    Ok(g) => {
                        let s = g.status();
                        println!(
                            "[serve] adaptive governor on: budget {budget_mj} mJ, seeded at \
                             scale {:.2}x (step {}/{})",
                            s.scale_q8 as f64 / 256.0,
                            s.step,
                            s.steps_total
                        );
                        Some(g)
                    }
                    Err(e) => {
                        eprintln!("[serve] adaptive governor unavailable: {e}");
                        None
                    }
                }
            }
            None => {
                eprintln!("[serve] --budget-mj needs the mcu backend; ignoring");
                None
            }
        }
    } else {
        None
    };

    if let Some(addr) = args.get("listen") {
        return cmd_serve_listen(args, coord, governor, None, fault, addr);
    }
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_req)
        .map(|i| coord.submit(ds.test.sample(i % ds.test.len()).to_vec()))
        .collect();
    let mut correct = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv()?;
        if resp.predicted == ds.test.y[i % ds.test.len()] {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = coord.metrics.snapshot();
    coord.shutdown();
    println!(
        "served {} requests on {backend} in {:.3}s ({:.1} req/s), accuracy {:.2}%",
        snap.served,
        wall,
        n_req as f64 / wall,
        100.0 * correct as f64 / n_req as f64
    );
    println!(
        "latency p50/p95/p99 = {}/{}/{} us, mean batch {:.2}, mean MAC skipped {:.2}%, mean MCU energy {:.3} mJ",
        snap.p50_us,
        snap.p95_us,
        snap.p99_us,
        snap.mean_batch,
        100.0 * snap.mean_mac_skipped,
        snap.mean_energy_mj
    );
    println!(
        "queue wait p50/p99 = {}/{} us, service p50/p99 = {}/{} us",
        snap.queue_p50_us, snap.queue_p99_us, snap.service_p50_us, snap.service_p99_us
    );
    if let Some(g) = &governor {
        let s = g.status();
        println!(
            "adaptive: scale {:.2}x (step {}/{}), ewma {:.3} mJ vs budget {:.3} mJ, \
             {} swaps, plan cache {} hits / {} misses",
            s.scale_q8 as f64 / 256.0,
            s.step,
            s.steps_total,
            s.ewma_mj,
            s.budget_mj,
            s.swaps,
            s.cache_hits,
            s.cache_misses
        );
    }
    Ok(())
}

/// `unit serve --models A,B[,C…] [--fleet-budget-mj N] --listen ADDR`:
/// one process hosting several zoo models behind a fleet-wide energy
/// budget. Each model gets its own plan cache and calibrated keep
/// profile; the [`FleetScheduler`] divides the budget across them by
/// marginal keep-per-millijoule (see `control::scheduler`) and answers
/// the per-tenant `SetBudget` caps and per-model `Stats` admin frames.
/// Without `--fleet-budget-mj` the budget defaults to every model's
/// 1.0x-scale energy summed — roomy, so the scheduler only bites once
/// an admin tightens it.
fn cmd_serve_multi(args: &Args) -> Result<()> {
    let names: Vec<String> = args
        .get("models")
        .unwrap_or_default()
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        eprintln!("serve: --models needs a comma-separated list (e.g. --models mnist,kws)");
        std::process::exit(2);
    }
    let div = DivKind::parse(args.get_or("div", "shift")).expect("div kind");
    let n_cal = args.usize_or("calib-samples", 8);
    let mut specs = Vec::new();
    let mut tenants = Vec::new();
    for name in &names {
        let def = zoo(name);
        let ds = by_name(name, args.u64_or("seed", 42), Sizes::default());
        // Same trained-weights-or-random-init fallback as single-model
        // serve: the scheduling machinery is identical either way.
        let params = match Runtime::cpu().and_then(|rt| {
            let store = ArtifactStore::discover();
            ensure_trained(&rt, &store, name, &ds, &TrainConfig::default())
        }) {
            Ok(p) => p,
            Err(e) => {
                eprintln!(
                    "[serve] {name}: trained weights unavailable ({e}); using random init"
                );
                unit_pruner::models::Params::random(&def, args.u64_or("seed", 42))
            }
        };
        let th = calibrate(&def, &params, &ds.val, &CalibConfig::default());
        let q = QModel::quantize(&def, &params).with_thresholds(&th);
        let cal: Vec<Vec<f32>> =
            (0..ds.val.len().min(n_cal)).map(|i| ds.val.sample(i).to_vec()).collect();
        eprintln!(
            "[serve] {name}: calibrating keep-ratio curves over the scale grid \
             ({} samples)…",
            cal.len()
        );
        let (cache, profile) = calibrated_cache(
            q.clone(),
            PlanConfig::for_mode(PruneMode::Unit, div),
            ScaleGrid::default_grid(),
            &cal,
        );
        specs.push(ModelSpec { name: name.clone(), q, mode: PruneMode::Unit, div });
        tenants.push((cache, profile));
    }
    let default_budget: f64 =
        tenants.iter().map(|(c, p)| p.mean_mj(c.grid().snap_q8(256))).sum();
    let flag_budget = args.f64_or("fleet-budget-mj", 0.0);
    let fleet_budget = if flag_budget > 0.0 { flag_budget } else { default_budget };

    let placement = match args.get_or("placement", "cost") {
        "two-choice" | "count" => Placement::TwoChoice,
        _ => Placement::CostWeighted,
    };
    let chaos_seed = args.u64_or("chaos-seed", 0);
    let fault = (chaos_seed != 0).then(|| Arc::new(FaultPlan::new(chaos_seed)));
    if let Some(f) = &fault {
        eprintln!("[serve] chaos plan armed (seed {})", f.seed());
    }
    let obs = obs_from_args(args);
    let coord = Coordinator::start_multi(
        specs,
        ServeConfig {
            workers: args.usize_or("workers", 2),
            max_batch: args.usize_or("max-batch", 8),
            max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 2)),
            placement,
            fault: fault.clone(),
            obs,
        },
    );
    let sched = FleetScheduler::install(&coord, tenants, fleet_budget)
        .map_err(|e| anyhow::anyhow!("fleet scheduler: {e}"))?;
    for (i, name) in names.iter().enumerate() {
        let st = sched.status(i as u32).expect("tenant status");
        println!(
            "[serve] model {i} ({name}): seeded at scale {:.2}x (step {}/{})",
            st.scale_q8 as f64 / 256.0,
            st.step,
            st.steps_total
        );
    }
    println!(
        "[serve] fleet scheduler on: {} models, fleet budget {fleet_budget:.3} mJ{}",
        names.len(),
        if flag_budget > 0.0 { "" } else { " (defaulted: sum of 1.0x-scale energies)" }
    );
    let Some(addr) = args.get("listen") else {
        eprintln!("serve: --models requires --listen (multi-model serving is TCP-only)");
        std::process::exit(2);
    };
    cmd_serve_listen(args, coord, None, Some(sched), fault, addr)
}

/// Observability switch shared by both serve paths: `--metrics-addr`
/// turns the flight recorder on; `--trace-sample-rate R` (default 1.0)
/// then decides head-based, per request id, which requests carry their
/// spans onto the rings. Rate 0 keeps the recorder reachable for
/// fleet/fault events while recording no per-request spans at all.
fn obs_from_args(args: &Args) -> ObsConfig {
    if args.get("metrics-addr").is_some() {
        ObsConfig::enabled_sampled(args.f64_or("trace-sample-rate", 1.0))
    } else {
        ObsConfig::off()
    }
}

/// `unit serve --listen ADDR [--window N] [--park P] [--park-bytes B]
/// [--deadline-ms D] [--max-conns C] [--serve-secs S] [--stats-secs T]
/// [--budget-mj B] [--chaos-seed S] [--models A,B --fleet-budget-mj N]
/// [--slo name=lat_ms:kr:err,…] [--trace-sample-rate R]`
///
/// Streamed TCP serving: sessions with credit-window backpressure
/// (window-overflow frames parked for credit-return admission when
/// `--park` > 0, with `--park-bytes` optionally capping the decoded
/// bytes the queue may pin), deadlines, and cancellation over the
/// framed wire protocol (see README "Streaming serving" / "Adaptive
/// serving").
/// `--listen 127.0.0.1:0` binds an ephemeral port; the bound address
/// is printed on one line so scripts/CI can scrape it. `--serve-secs
/// 0` (default) serves until killed.
fn cmd_serve_listen(
    args: &Args,
    coord: Coordinator,
    governor: Option<Arc<Governor>>,
    scheduler: Option<Arc<FleetScheduler>>,
    fault: Option<Arc<FaultPlan>>,
    addr: &str,
) -> Result<()> {
    // Chaos + observability together: every fired injection also
    // lands on the flight recorder's "faults" ring.
    if let (Some(f), Some(rec)) = (&fault, coord.recorder()) {
        f.attach_ring(rec.ring("faults"));
    }
    // Per-tenant SLO engine: always on for a listening server so the
    // wire `SetSlo` admin frame works even without a `--slo` flag;
    // without declared objectives it never trips and admission stays
    // free. Declared objectives become multi-window burn rates over
    // the per-tenant metrics; a latched trip throttles the tenant's
    // admission and (under a fleet scheduler) pins its allocation to
    // the cheapest grid step until the burn clears.
    let slo_names: Vec<String> = (0..coord.model_count())
        .map(|i| coord.model_name(i as u32).unwrap_or_default().to_string())
        .collect();
    let slo = SloEngine::new(
        slo_names,
        Arc::clone(&coord.metrics),
        SloWindows::default(),
        AdmissionPolicy::default(),
    );
    if let Some(list) = args.get("slo") {
        match SloSpec::parse_list(list) {
            Ok(entries) => {
                for (name, spec) in entries {
                    match slo.model_id_of(&name) {
                        Some(m) => {
                            slo.set_slo(m, spec);
                            println!(
                                "[serve] slo {name}: p99<={}ms keep>={} err<={}",
                                spec.p99_ms, spec.keep_floor, spec.err_ceiling
                            );
                        }
                        None => {
                            eprintln!("serve: --slo names unknown model `{name}`");
                            std::process::exit(2);
                        }
                    }
                }
            }
            Err(e) => {
                eprintln!("serve: {e}");
                std::process::exit(2);
            }
        }
    }
    if let Some(sched) = &scheduler {
        let weak = Arc::downgrade(sched);
        slo.set_on_trip(move |model, tripped| {
            if let Some(s) = weak.upgrade() {
                let _ = s.set_tenant_throttled(model, tripped);
            }
        });
    }
    slo.start_ticker();
    let opts = ServeOpts {
        max_conns: args.usize_or("max-conns", 64),
        session: SessionCfg {
            max_inflight: args.usize_or("window", 64),
            park: args.usize_or("park", 0),
            park_bytes: args.usize_or("park-bytes", 0),
            default_deadline: match args.u64_or("deadline-ms", 0) {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            },
            drain_timeout: Duration::from_secs(args.u64_or("drain-secs", 10)),
            ..Default::default()
        },
        governor: governor.clone(),
        scheduler: scheduler.clone(),
        fault,
        slo: Some(Arc::clone(&slo)),
    };
    let metrics = std::sync::Arc::clone(&coord.metrics);
    let server = Server::start(coord, addr, opts).map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
    // Single greppable line, flushed immediately: CI scrapes the
    // ephemeral port from it.
    println!("unit serve: listening on {}", server.local_addr());
    use std::io::Write as _;
    std::io::stdout().flush().ok();

    // `--metrics-addr ADDR` (":0" for an ephemeral port) binds the
    // HTTP exposition side listener: GET /metrics (Prometheus text)
    // and GET /trace (Chrome trace-event JSON).
    if let Some(maddr) = args.get("metrics-addr") {
        let coord_ref = server.coordinator();
        let model_names = (0..coord_ref.model_count())
            .map(|i| coord_ref.model_name(i as u32).unwrap_or_default().to_string())
            .collect();
        let hub = Arc::new(MetricsHub {
            metrics: Arc::clone(&metrics),
            governor: governor.clone(),
            scheduler: scheduler.clone(),
            recorder: coord_ref.recorder(),
            slo: Some(Arc::clone(&slo)),
            model_names,
            kernel_backend: KernelBackend::active_label(),
        });
        match spawn_http(maddr, hub) {
            Ok(bound) => {
                // Same greppable single-line contract as the serve
                // address above: CI scrapes the ephemeral port.
                println!("unit serve: metrics on {bound}");
                std::io::stdout().flush().ok();
            }
            Err(e) => eprintln!("[serve] metrics listener failed to bind {maddr}: {e}"),
        }
    }

    let serve_secs = args.u64_or("serve-secs", 0);
    let stats_secs = args.u64_or("stats-secs", 10);
    let t0 = std::time::Instant::now();
    let mut last_stats = std::time::Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(200));
        if serve_secs > 0 && t0.elapsed() >= Duration::from_secs(serve_secs) {
            break;
        }
        if stats_secs > 0 && last_stats.elapsed() >= Duration::from_secs(stats_secs) {
            last_stats = std::time::Instant::now();
            // Refresh the per-shard queued-cost gauges so placement
            // imbalance is visible in the snapshot.
            server.coordinator().publish_shard_costs();
            let s = metrics.snapshot();
            let shard_cost_str = if s.shard_costs.is_empty() {
                String::new()
            } else {
                let strs: Vec<String> =
                    s.shard_costs.iter().map(|c| c.to_string()).collect();
                format!(" shard-cost=[{}]", strs.join(","))
            };
            let adaptive_str = match &governor {
                Some(g) => {
                    let gs = g.status();
                    format!(
                        " scale={:.2}x step={}/{} ewma={:.3}mJ budget={:.3}mJ swaps={} \
                         bg={}p/{}c/{}u drift={}t/{}r",
                        gs.scale_q8 as f64 / 256.0,
                        gs.step,
                        gs.steps_total,
                        gs.ewma_mj,
                        gs.budget_mj,
                        gs.swaps,
                        gs.bg_pending,
                        gs.bg_compiled,
                        gs.bg_upgrades,
                        gs.drift_trips,
                        gs.recalibrations
                    )
                }
                None => String::new(),
            };
            let fleet_str = match &scheduler {
                Some(sched) => {
                    let fs = sched.fleet_status();
                    let parts: Vec<String> = (0..fs.models as u32)
                        .filter_map(|i| sched.status(i))
                        .map(|m| {
                            format!(
                                "{}:{}/{}@{:.2}x",
                                m.name,
                                m.step,
                                m.steps_total,
                                m.scale_q8 as f64 / 256.0
                            )
                        })
                        .collect();
                    format!(
                        " fleet={:.3}mJ resolves={} models=[{}]",
                        fs.fleet_budget_mj,
                        fs.resolves,
                        parts.join(",")
                    )
                }
                None => String::new(),
            };
            // Per-tenant burn rates, only for tenants with declared
            // objectives: name:fast/slow, "!" while the trip is
            // latched (admission throttled).
            let slo_str = {
                let rows: Vec<String> = slo
                    .status()
                    .into_iter()
                    .filter(|t| t.spec.is_some())
                    .map(|t| {
                        format!(
                            "{}:{:.2}/{:.2}{}",
                            t.name,
                            t.burn_fast,
                            t.burn_slow,
                            if t.tripped { "!" } else { "" }
                        )
                    })
                    .collect();
                if rows.is_empty() {
                    String::new()
                } else {
                    format!(" slo-burn=[{}]", rows.join(","))
                }
            };
            println!(
                "[stats] served={} inflight={} rejected={} expired={} cancelled={} dropped={} \
                 failed={} panics={} respawns={} parked={} sessions={}/{} \
                 p50/p99={}/{}us kernel={}{shard_cost_str}{adaptive_str}{fleet_str}{slo_str}",
                s.served,
                s.inflight,
                s.rejected,
                s.expired,
                s.cancelled,
                s.dropped,
                s.failed,
                s.worker_panics,
                s.respawns,
                s.parked,
                s.sessions_opened - s.sessions_closed,
                s.sessions_opened,
                s.p50_us,
                s.p99_us,
                KernelBackend::active_label(),
            );
            std::io::stdout().flush().ok();
        }
    }
    // Snapshot after the drain so work completed during graceful
    // shutdown is counted in the summary.
    server.shutdown();
    let s = metrics.snapshot();
    println!(
        "unit serve: done — served {} ({} rejected, {} expired, {} cancelled, {} dropped, \
         {} failed, {} parked; {} panics contained, {} respawns) over {} sessions",
        s.served,
        s.rejected,
        s.expired,
        s.cancelled,
        s.dropped,
        s.failed,
        s.parked,
        s.worker_panics,
        s.respawns,
        s.sessions_opened
    );
    Ok(())
}

/// `unit trace --addr HOST:PORT [--out trace.json]`: pull the serving
/// flight recorder over the wire (`TraceDump`, v5) and write it as
/// Chrome trace-event JSON — load the file in `chrome://tracing` or
/// Perfetto to see queue→service→per-layer timelines per worker. An
/// empty `traceEvents` array means the server runs with observability
/// off (start it with `--metrics-addr`).
fn cmd_trace(args: &Args) -> Result<()> {
    let Some(addr) = args.get("addr") else {
        eprintln!("trace: --addr HOST:PORT is required (the serve listener address)");
        std::process::exit(2);
    };
    let out = args.get_or("out", "trace.json").to_string();
    let client = Client::connect(addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let body = client.trace_dump(Duration::from_secs(args.u64_or("timeout-secs", 10)))?;
    std::fs::write(&out, &body)?;
    println!("unit trace: wrote {} bytes to {out}", body.len());
    Ok(())
}

/// `unit slo --addr HOST:PORT --model N [--p99-ms X] [--keep-floor Y]
/// [--err-ceiling Z]`: declare (or replace) one tenant's service-level
/// objectives on a live server over the wire (`SetSlo`, v6) — the
/// runtime equivalent of the `--slo` serve flag. Omitted or `<= 0`
/// components disable that objective; all-zero removes the tenant's
/// objectives and clears any latched burn trip. The server's `Stats`
/// reply is printed as confirmation.
fn cmd_slo(args: &Args) -> Result<()> {
    let Some(addr) = args.get("addr") else {
        eprintln!("slo: --addr HOST:PORT is required (the serve listener address)");
        std::process::exit(2);
    };
    let model = args.u64_or("model", 0) as u32;
    let p99_ms = args.f64_or("p99-ms", 0.0);
    let keep_floor = args.f64_or("keep-floor", 0.0) as f32;
    let err_ceiling = args.f64_or("err-ceiling", 0.0) as f32;
    let client = Client::connect(addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let stats = client.set_slo(
        model,
        p99_ms,
        keep_floor,
        err_ceiling,
        Duration::from_secs(args.u64_or("timeout-secs", 10)),
    )?;
    println!(
        "unit slo: model {model} p99<={p99_ms}ms keep>={keep_floor} err<={err_ceiling} \
         (server reports model {} of {}, step {}/{})",
        stats.model, stats.models_loaded, stats.step, stats.steps_total,
    );
    Ok(())
}

/// Sum of every sample of `name` in a Prometheus text body. `name` may
/// include a label set (`unit_latency_us{quantile="0.5"}`) for an
/// exact series, or be a bare family name to sum across labels
/// (`unit_trace_dropped_total` over all rings).
fn scrape_sum(text: &str, name: &str) -> f64 {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .filter_map(|l| {
            let rest = l.strip_prefix(name)?;
            if !(rest.starts_with(' ') || rest.starts_with('{')) {
                return None;
            }
            l.rsplit(' ').next()?.parse::<f64>().ok()
        })
        .sum()
}

/// `unit top --addr HOST:PORT [--iters N] [--interval-ms M] [--json]`:
/// scrape the server over the wire (`Scrape`, v5) every interval and
/// print a one-line live view of the key gauges — including, when SLOs
/// are declared, the summed burn-trip state and throttled-request
/// count. `--iters 0` (default) runs until killed; a positive count
/// bounds the loop (scripts, CI). `--json` emits one JSON object per
/// iteration instead of the human line (machine consumers, no extra
/// dependency: the fields are a flat map of numbers).
fn cmd_top(args: &Args) -> Result<()> {
    let Some(addr) = args.get("addr") else {
        eprintln!("top: --addr HOST:PORT is required (the serve listener address)");
        std::process::exit(2);
    };
    let iters = args.usize_or("iters", 0);
    let every = Duration::from_millis(args.u64_or("interval-ms", 1000));
    let json = args.flag("json");
    let client = Client::connect(addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let mut n = 0usize;
    loop {
        let text = client.scrape(Duration::from_secs(5))?;
        let g = |name: &str| scrape_sum(&text, name);
        // Info gauge, not a number: the backend name rides in the
        // `backend` label of `unit_kernel_backend`.
        let kernel = text
            .lines()
            .find_map(|l| l.strip_prefix("unit_kernel_backend{backend=\""))
            .and_then(|rest| rest.split('"').next())
            .unwrap_or("?");
        if json {
            // Hand-rolled: every value except the kernel label is a
            // finite f64, so plain Display is valid JSON; the label is
            // a fixed lowercase identifier needing no escaping.
            println!(
                "{{\"served\":{},\"inflight\":{},\"rejected\":{},\"failed\":{},\"parked\":{},\
                 \"throttled\":{},\"p50_us\":{},\"p99_us\":{},\"keep_p50\":{},\
                 \"mac_skipped_ratio\":{},\"scale\":{},\"slo_tripped\":{},\"slo_trips\":{},\
                 \"trace_events\":{},\"trace_dropped\":{},\"kernel\":\"{kernel}\"}}",
                g("unit_requests_served_total"),
                g("unit_inflight"),
                g("unit_rejected_total"),
                g("unit_requests_failed_total"),
                g("unit_parked_total"),
                g("unit_tenant_throttled_total"),
                g("unit_latency_us{quantile=\"0.5\"}"),
                g("unit_latency_us{quantile=\"0.99\"}"),
                g("unit_keep_ratio{quantile=\"0.5\"}"),
                g("unit_mac_skipped_ratio"),
                g("unit_governor_scale_q8") / 256.0,
                g("unit_slo_tripped"),
                g("unit_slo_trips_total"),
                g("unit_trace_events_total"),
                g("unit_trace_dropped_total"),
            );
        } else {
            println!(
                "[top] served={:.0} inflight={:.0} rejected={:.0} failed={:.0} parked={:.0} \
                 throttled={:.0} p50/p99={:.0}/{:.0}us keep_p50={:.3} skip={:.2}% scale={:.2}x \
                 slo_tripped={:.0} trips={:.0} events={:.0} dropped={:.0} kernel={kernel}",
                g("unit_requests_served_total"),
                g("unit_inflight"),
                g("unit_rejected_total"),
                g("unit_requests_failed_total"),
                g("unit_parked_total"),
                g("unit_tenant_throttled_total"),
                g("unit_latency_us{quantile=\"0.5\"}"),
                g("unit_latency_us{quantile=\"0.99\"}"),
                g("unit_keep_ratio{quantile=\"0.5\"}"),
                100.0 * g("unit_mac_skipped_ratio"),
                g("unit_governor_scale_q8") / 256.0,
                g("unit_slo_tripped"),
                g("unit_slo_trips_total"),
                g("unit_trace_events_total"),
                g("unit_trace_dropped_total"),
            );
        }
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        n += 1;
        if iters > 0 && n >= iters {
            break;
        }
        std::thread::sleep(every);
    }
    Ok(())
}
