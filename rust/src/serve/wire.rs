//! Framed wire protocol for streamed serving: a length-prefixed binary
//! codec with CRC-checked payloads.
//!
//! The codec layer is **pure** — it maps [`Frame`]s to bytes and back
//! with no sockets, threads, or clocks involved, so the whole protocol
//! is property-testable in memory (`tests/serve_wire.rs` round-trips
//! random frames and fuzzes truncation/corruption). [`FrameReader`] is
//! the incremental decoder sessions and clients feed raw socket reads
//! into.
//!
//! ## Frame layout
//!
//! Every frame on the stream is `[u32 len][body…]` where `len` counts
//! the bytes after the prefix. The body is:
//!
//! ```text
//! offset  size  field
//! 0       4     magic   "UnIT"
//! 4       2     version (little-endian, currently 6; 3, 4, and 5
//!               still accepted)
//! 6       1     frame type (1=Request 2=Response 3=Cancel 4=Ping 5=Pong
//!               6=Goodbye 7=SetBudget 8=Stats 9=Scrape 10=TraceDump
//!               11=SetSlo)
//! 7       1     dtype   (Request only: 0=f32-LE 1=i8; 0 elsewhere)
//! 8       8     request id (u64 LE; client-chosen, echoed on replies)
//! 16      …     type-specific payload (see below)
//! end-4   4     crc32 (IEEE) over body[0 .. end-4]
//! ```
//!
//! Payloads (v4 layout; the v3 differences are noted inline):
//!
//! * **Request** — `deadline_ms:u32` (0 = none), `n_samples:u32`,
//!   `sample_len:u32`, `model:u32` (v4; a v3 frame has no model field
//!   and decodes as model `0`), then `n_samples * sample_len` values
//!   (f32 LE or i8 per `dtype`; i8 is normalized fixed-point,
//!   dequantized as `v / 127.0`). `n_samples > 1` is a batch: the
//!   server splits it across shards and streams one Response per
//!   sample, in slot order.
//! * **Response** — `status:u8`, `slot:u32` ([`WHOLE_REQUEST`] for
//!   request-level statuses like Rejected/Expired), `predicted:u16`,
//!   `queue_us:u32`, `service_us:u32`, `mac_skipped:f32`,
//!   `n_logits:u32`, then the f32 logits.
//! * **SetBudget** — `budget_mj:f64`, `model:u32` (v4; a v3 frame has
//!   no model field and decodes as [`FLEET_MODEL`] — "the whole
//!   fleet"). A budget `<= 0.0` changes nothing (pure stats query).
//!   The server answers with a `Stats` frame echoing the id; when the
//!   server has no adaptive control attached, the answered `Stats`
//!   carries `scale_q8 == 0`.
//! * **Stats** — `scale_q8:u32` (0 ⇒ adaptive control disabled),
//!   `step:u32`, `steps_total:u32`, `budget_mj:f64`, `ewma_mj:f64`,
//!   `keep_ratio:f32`, `cache_hits:u64`, `cache_misses:u64`,
//!   `swaps:u64`, `bg_pending:u64`, `bg_compiled:u64`,
//!   `bg_upgrades:u64`, `worker_panics:u64`, `respawns:u64`,
//!   `drift_trips:u64`, `recalibrations:u64`, then the v4 tail
//!   `model:u32`, `models_loaded:u32`, `fleet_budget_mj:f64` — the
//!   control plane's scale/keep-ratio/budget state for one model, its
//!   background-compile-thread health, the self-healing counters, and
//!   the fleet shape (server → client, answering a `SetBudget`). The
//!   three `bg_*` fields were added in protocol version 2; the
//!   panic/respawn and drift/recalibration counters in version 3
//!   (panic counters are served even without a governor); the
//!   model/fleet tail in version 4. **Stats decoding is
//!   forward-tolerant**: a missing v4 tail decodes to defaults and
//!   extra trailing bytes after the known fields are ignored, so a v3
//!   parser of this codec reads a v4 `Stats` (and a v4 parser will
//!   read a v5 one) without a `Malformed` error.
//! * **Scrape** (v5) — `body_len:u32`, then `body_len` bytes of UTF-8
//!   text. A client sends an empty body to request a metrics scrape;
//!   the server replies with the same frame type, same id, and the
//!   Prometheus text exposition as the body. Like `Stats`, decoding is
//!   forward-tolerant: trailing bytes after the body are ignored.
//! * **TraceDump** (v5) — same shape as `Scrape`; the reply body is
//!   the flight recorder's Chrome trace-event JSON (an empty
//!   `traceEvents` document when no recorder is attached). Also
//!   forward-tolerant.
//! * **SetSlo** (v6) — `model:u32`, `p99_ms:f64`, `keep_floor:f32`,
//!   `err_ceiling:f32`: declare (or replace) one tenant's service
//!   objectives. A component `<= 0` disables that objective. The
//!   server answers with a `Stats` frame echoing the id (the
//!   `SetBudget` admin idiom). Forward-tolerant decoding.
//! * **Cancel / Ping / Pong / Goodbye** — empty (the header id is the
//!   operand; Goodbye ignores it).
//!
//! Decoding is otherwise strict: wrong magic/version/type/status, a
//! length that disagrees with the payload's own arithmetic, or a CRC
//! mismatch all return a [`WireError`] — never a panic — so a
//! malicious or corrupt peer cannot take a session thread down. An
//! unsupported version is reported as [`WireError::BadVersion`], which
//! sessions answer with a clean `Goodbye` rather than treating the
//! peer as unframed.

/// Frame magic: the protocol's first four bytes.
pub const MAGIC: [u8; 4] = *b"UnIT";
/// Protocol version carried by every encoded frame. Version 2 extended
/// the `Stats` payload with the governor's background-compile counters;
/// version 3 added the `Failed` response status and the `Stats`
/// self-healing counters (worker panics/respawns, drift
/// trips/recalibrations); version 4 added multi-tenant model identity
/// (`model` on `Request`/`SetBudget`, the model/fleet `Stats` tail);
/// version 5 added the observability admin frames (`Scrape`,
/// `TraceDump`); version 6 added the per-tenant SLO engine's `SetSlo`
/// admin frame and the `Throttled` response status. Decoding accepts
/// [`MIN_VERSION`]..=`VERSION`; anything else is refused with
/// [`WireError::BadVersion`] rather than mis-framed.
pub const VERSION: u16 = 6;
/// Oldest protocol version the decoder still accepts. v3 frames carry
/// no model identity: their requests decode as model `0` and their
/// `SetBudget` as [`FLEET_MODEL`].
pub const MIN_VERSION: u16 = 3;
/// Fixed header bytes before the type-specific payload.
pub const HEADER_LEN: usize = 16;
/// Hard cap on one frame's post-prefix length: a corrupt length prefix
/// must not make the reader buffer gigabytes.
pub const MAX_FRAME_LEN: usize = 1 << 24;
/// `slot` value meaning "this status applies to the whole request"
/// (backpressure rejection, deadline expiry, protocol errors).
pub const WHOLE_REQUEST: u32 = u32::MAX;
/// `model` value meaning "the whole fleet" on a `SetBudget` frame: the
/// budget applies to the global scheduler (or the single governor)
/// rather than one tenant. Also what a v3 `SetBudget` — which predates
/// model identity — decodes to.
pub const FLEET_MODEL: u32 = u32::MAX;

/// Sample payload of a request: little-endian f32, or normalized i8
/// (dequantized as `v / 127.0` server-side — the compact transport for
/// sensor-style clients).
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Little-endian f32 samples (the engine's native dtype).
    F32(Vec<f32>),
    /// Normalized i8 samples, dequantized server-side as `v / 127.0`.
    I8(Vec<i8>),
}

impl Payload {
    /// Number of scalar values carried.
    pub fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I8(v) => v.len(),
        }
    }

    /// True when no scalar values are carried.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dequantize to the f32 samples the engine consumes (consuming:
    /// the f32 case hands its vector over without a copy — the request
    /// hot path owns its payload).
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Payload::F32(v) => v,
            Payload::I8(v) => v.iter().map(|&b| b as f32 / 127.0).collect(),
        }
    }

    /// Serialized size of the sample data in bytes.
    pub fn byte_len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len() * 4,
            Payload::I8(v) => v.len(),
        }
    }

    fn dtype(&self) -> u8 {
        match self {
            Payload::F32(_) => 0,
            Payload::I8(_) => 1,
        }
    }
}

/// Response status. `Ok` carries a real result; the rest are
/// request-level outcomes (sent with `slot == WHOLE_REQUEST` except for
/// per-slot suppression, which sends nothing at all).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Successful inference result.
    Ok = 0,
    /// Backpressure: the session's in-flight window was full.
    Rejected = 1,
    /// The request's deadline passed before a shard picked it up.
    Expired = 2,
    /// The request was cancelled by the client.
    Cancelled = 3,
    /// Server-side error (malformed sample length, closed pool, …).
    Error = 4,
    /// A worker panicked while executing the request (v3). The request
    /// is terminal: remaining queued samples were dropped and no
    /// further replies follow. Safe to resubmit — the panic supervisor
    /// has already respawned the worker.
    Failed = 5,
    /// The tenant's admission policy refused the request (v6): its SLO
    /// burn rate is tripped and the throttle quota is exhausted. The
    /// refusal is tenant-scoped — other models on the same connection
    /// are unaffected — and safe to retry after backoff.
    Throttled = 6,
}

impl Status {
    fn from_u8(v: u8) -> Result<Status, WireError> {
        Ok(match v {
            0 => Status::Ok,
            1 => Status::Rejected,
            2 => Status::Expired,
            3 => Status::Cancelled,
            4 => Status::Error,
            5 => Status::Failed,
            6 => Status::Throttled,
            other => return Err(WireError::BadStatus(other)),
        })
    }
}

/// One protocol frame (see module docs for the byte layout).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server: run inference on `data` (a batch when
    /// `data.len() > sample_len`).
    Request {
        /// Client-chosen request id, echoed on every reply.
        id: u64,
        /// Milliseconds from receipt until the request expires (0 = no
        /// deadline beyond the session default).
        deadline_ms: u32,
        /// Values per sample; `data.len()` must be a multiple of it.
        sample_len: u32,
        /// Target model id (v4). Single-model servers (and every v3
        /// client) use `0`; an unknown id is answered `Error`.
        model: u32,
        /// The samples themselves.
        data: Payload,
    },
    /// Server → client: one sample's result, or a request-level status.
    Response {
        /// The request id this reply answers.
        id: u64,
        /// Sample index inside the request, or [`WHOLE_REQUEST`].
        slot: u32,
        /// Outcome for this slot (or the whole request).
        status: Status,
        /// Argmax class of the logits (0 on non-`Ok` statuses).
        predicted: u16,
        /// Microseconds the sample waited in a shard queue.
        queue_us: u32,
        /// Microseconds the worker spent computing the sample.
        service_us: u32,
        /// Fraction of MACs the pruned plan skipped for this sample.
        mac_skipped: f32,
        /// The raw logits (empty on non-`Ok` statuses).
        logits: Vec<f32>,
    },
    /// Client → server: drop not-yet-started work for `id`, suppress
    /// all of its remaining replies.
    Cancel {
        /// Id of the request to cancel.
        id: u64,
    },
    /// Liveness probe; the server echoes a `Pong` with the same id.
    Ping {
        /// Probe id, echoed on the `Pong`.
        id: u64,
    },
    /// Server → client: answer to a `Ping`.
    Pong {
        /// The probed id, echoed back.
        id: u64,
    },
    /// Either side: graceful drain-then-close. The server answers a
    /// client Goodbye with its own once in-flight work has drained.
    Goodbye,
    /// Client → server (admin): change an energy budget
    /// (mJ/inference); `budget_mj <= 0.0` is a pure stats query. The
    /// server always answers with a [`Frame::Stats`] echoing `id`.
    SetBudget {
        /// Admin exchange id, echoed on the `Stats` reply.
        id: u64,
        /// New budget in mJ/inference; `<= 0.0` queries without
        /// changing anything.
        budget_mj: f64,
        /// Scope: a model id for one tenant's cap, or [`FLEET_MODEL`]
        /// for the fleet-wide budget (what a v3 frame decodes to).
        model: u32,
    },
    /// Server → client (admin): the adaptive control plane's state.
    /// `scale_q8 == 0` means no governor/scheduler is attached (every
    /// other control field is then meaningless and zero).
    Stats {
        /// The admin exchange id this reply answers.
        id: u64,
        /// Active threshold scale in Q8.8 (256 = 1.0).
        scale_q8: u32,
        /// Active grid step for the reported model.
        step: u32,
        /// The scale grid's total step count.
        steps_total: u32,
        /// The reported model's energy budget (mJ/inference).
        budget_mj: f64,
        /// EWMA of observed per-request energy (mJ).
        ewma_mj: f64,
        /// Calibrated whole-model keep ratio at the active step (0
        /// when no keep-ratio profile is attached).
        keep_ratio: f32,
        /// Plan-cache hits since install.
        cache_hits: u64,
        /// Plan-cache misses (inline compiles) since install.
        cache_misses: u64,
        /// Plan swaps since the governor was installed (inline +
        /// background upgrades).
        swaps: u64,
        /// Background compiles queued or in flight (gauge).
        bg_pending: u64,
        /// Background compiles completed since install.
        bg_compiled: u64,
        /// Background compiles that upgraded the live plan slot.
        bg_upgrades: u64,
        /// Worker panics caught by the supervisor (v3; served even
        /// without a governor).
        worker_panics: u64,
        /// Worker loops respawned after a caught panic (v3).
        respawns: u64,
        /// Drift-detector trips since install (v3; 0 without a
        /// governor).
        drift_trips: u64,
        /// Completed live recalibrations since install (v3; 0 without a
        /// governor).
        recalibrations: u64,
        /// Which model this frame reports (v4). `0` for a v3 peer or a
        /// single-model server.
        model: u32,
        /// Number of models the server is hosting (v4; 0 from a v3
        /// peer).
        models_loaded: u32,
        /// The fleet-wide energy budget the scheduler is dividing (v4;
        /// 0 from a v3 peer or when no scheduler is attached).
        fleet_budget_mj: f64,
    },
    /// Admin metrics scrape (v5). A client sends this with an empty
    /// `body` to request the server's full Prometheus text exposition;
    /// the server replies with the same frame type and id, `body`
    /// filled. Decoding is forward-tolerant like `Stats`: trailing
    /// payload bytes are ignored.
    Scrape {
        /// Admin exchange id, echoed on the reply.
        id: u64,
        /// UTF-8 text: empty on the query, the Prometheus exposition
        /// on the reply.
        body: String,
    },
    /// Admin flight-recorder dump (v5). Same request/reply shape as
    /// [`Frame::Scrape`]; the reply `body` is Chrome trace-event JSON
    /// (an empty `traceEvents` document when the server has no flight
    /// recorder attached). Forward-tolerant decoding.
    TraceDump {
        /// Admin exchange id, echoed on the reply.
        id: u64,
        /// UTF-8 text: empty on the query, the Chrome trace JSON on
        /// the reply.
        body: String,
    },
    /// Client → server (admin, v6): declare one tenant's service
    /// objectives for the SLO engine. Any component `<= 0` disables
    /// that objective. The server always answers with a
    /// [`Frame::Stats`] echoing `id`, the `SetBudget` idiom.
    /// Forward-tolerant decoding.
    SetSlo {
        /// Admin exchange id, echoed on the `Stats` reply.
        id: u64,
        /// Target model id.
        model: u32,
        /// p99 total-latency objective in milliseconds.
        p99_ms: f64,
        /// Keep-ratio floor in `[0, 1]`.
        keep_floor: f32,
        /// Error-rate ceiling in `[0, 1]`.
        err_ceiling: f32,
    },
}

impl Frame {
    fn type_byte(&self) -> u8 {
        match self {
            Frame::Request { .. } => 1,
            Frame::Response { .. } => 2,
            Frame::Cancel { .. } => 3,
            Frame::Ping { .. } => 4,
            Frame::Pong { .. } => 5,
            Frame::Goodbye => 6,
            Frame::SetBudget { .. } => 7,
            Frame::Stats { .. } => 8,
            Frame::Scrape { .. } => 9,
            Frame::TraceDump { .. } => 10,
            Frame::SetSlo { .. } => 11,
        }
    }

    fn id(&self) -> u64 {
        match self {
            Frame::Request { id, .. }
            | Frame::Response { id, .. }
            | Frame::Cancel { id }
            | Frame::Ping { id }
            | Frame::Pong { id }
            | Frame::SetBudget { id, .. }
            | Frame::Stats { id, .. }
            | Frame::Scrape { id, .. }
            | Frame::TraceDump { id, .. }
            | Frame::SetSlo { id, .. } => *id,
            Frame::Goodbye => 0,
        }
    }
}

/// Decode failure. Every variant is a protocol error: the connection
/// that produced it cannot be trusted to stay framed and should close.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The frame's first four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Version outside [`MIN_VERSION`]`..=`[`VERSION`]. Sessions answer
    /// this one with a clean `Goodbye` (refused, not unframed).
    BadVersion(u16),
    /// Unknown frame-type byte.
    BadType(u8),
    /// Unknown response-status byte.
    BadStatus(u8),
    /// Unknown request-payload dtype byte.
    BadDtype(u8),
    /// CRC mismatch: `(stored, computed)`.
    Crc(u32, u32),
    /// Frame length prefix exceeds [`MAX_FRAME_LEN`] or is shorter than
    /// a header + CRC can be.
    BadLength(usize),
    /// The payload's internal arithmetic (sample counts, logit counts)
    /// disagrees with the frame length.
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::BadMagic(m) => write!(f, "bad magic {m:?}"),
            WireError::BadVersion(v) => write!(f, "unsupported version {v}"),
            WireError::BadType(t) => write!(f, "unknown frame type {t}"),
            WireError::BadStatus(s) => write!(f, "unknown status {s}"),
            WireError::BadDtype(d) => write!(f, "unknown dtype {d}"),
            WireError::Crc(a, b) => write!(f, "crc mismatch: stored {a:#010x}, computed {b:#010x}"),
            WireError::BadLength(n) => write!(f, "bad frame length {n}"),
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, the zlib polynomial) — table built at compile time.

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC32 of `data` (matches zlib's `crc32(0, …)`).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------------
// Encoding

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Encode `frame` including its length prefix — the exact bytes to put
/// on the stream.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut body = Vec::with_capacity(64);
    body.extend_from_slice(&MAGIC);
    put_u16(&mut body, VERSION);
    body.push(frame.type_byte());
    let dtype = match frame {
        Frame::Request { data, .. } => data.dtype(),
        _ => 0,
    };
    body.push(dtype);
    put_u64(&mut body, frame.id());
    match frame {
        Frame::Request { deadline_ms, sample_len, model, data, .. } => {
            put_u32(&mut body, *deadline_ms);
            let n_samples =
                if *sample_len == 0 { 0 } else { (data.len() as u32) / *sample_len };
            put_u32(&mut body, n_samples);
            put_u32(&mut body, *sample_len);
            put_u32(&mut body, *model);
            // Serialize exactly n_samples * sample_len values: a ragged
            // payload (caller bug) is truncated to whole samples so the
            // frame stays self-consistent instead of becoming a
            // session-killing protocol error at the peer.
            let n_vals = (n_samples * *sample_len) as usize;
            match data {
                Payload::F32(v) => {
                    for &x in &v[..n_vals] {
                        put_f32(&mut body, x);
                    }
                }
                Payload::I8(v) => {
                    body.extend(v[..n_vals].iter().map(|&b| b as u8));
                }
            }
        }
        Frame::Response {
            slot,
            status,
            predicted,
            queue_us,
            service_us,
            mac_skipped,
            logits,
            ..
        } => {
            body.push(*status as u8);
            put_u32(&mut body, *slot);
            put_u16(&mut body, *predicted);
            put_u32(&mut body, *queue_us);
            put_u32(&mut body, *service_us);
            put_f32(&mut body, *mac_skipped);
            put_u32(&mut body, logits.len() as u32);
            for &l in logits {
                put_f32(&mut body, l);
            }
        }
        Frame::SetBudget { budget_mj, model, .. } => {
            put_f64(&mut body, *budget_mj);
            put_u32(&mut body, *model);
        }
        Frame::Stats {
            scale_q8,
            step,
            steps_total,
            budget_mj,
            ewma_mj,
            keep_ratio,
            cache_hits,
            cache_misses,
            swaps,
            bg_pending,
            bg_compiled,
            bg_upgrades,
            worker_panics,
            respawns,
            drift_trips,
            recalibrations,
            model,
            models_loaded,
            fleet_budget_mj,
            ..
        } => {
            put_u32(&mut body, *scale_q8);
            put_u32(&mut body, *step);
            put_u32(&mut body, *steps_total);
            put_f64(&mut body, *budget_mj);
            put_f64(&mut body, *ewma_mj);
            put_f32(&mut body, *keep_ratio);
            put_u64(&mut body, *cache_hits);
            put_u64(&mut body, *cache_misses);
            put_u64(&mut body, *swaps);
            put_u64(&mut body, *bg_pending);
            put_u64(&mut body, *bg_compiled);
            put_u64(&mut body, *bg_upgrades);
            put_u64(&mut body, *worker_panics);
            put_u64(&mut body, *respawns);
            put_u64(&mut body, *drift_trips);
            put_u64(&mut body, *recalibrations);
            put_u32(&mut body, *model);
            put_u32(&mut body, *models_loaded);
            put_f64(&mut body, *fleet_budget_mj);
        }
        Frame::Scrape { body: text, .. } | Frame::TraceDump { body: text, .. } => {
            put_u32(&mut body, text.len() as u32);
            body.extend_from_slice(text.as_bytes());
        }
        Frame::SetSlo { model, p99_ms, keep_floor, err_ceiling, .. } => {
            put_u32(&mut body, *model);
            put_f64(&mut body, *p99_ms);
            put_f32(&mut body, *keep_floor);
            put_f32(&mut body, *err_ceiling);
        }
        Frame::Cancel { .. } | Frame::Ping { .. } | Frame::Pong { .. } | Frame::Goodbye => {}
    }
    let crc = crc32(&body);
    put_u32(&mut body, crc);
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

// ---------------------------------------------------------------------------
// Decoding

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], WireError> {
        // checked_add: crafted sample/logit counts can make `n` large
        // enough that `pos + n` would wrap and sneak past the bounds
        // check — overflow is just another malformed frame.
        let end = self.pos.checked_add(n).ok_or(WireError::Malformed(what))?;
        if end > self.buf.len() {
            return Err(WireError::Malformed(what));
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self, what: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }
    fn u16(&mut self, what: &'static str) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }
    fn u32(&mut self, what: &'static str) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }
    fn u64(&mut self, what: &'static str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
    fn f32(&mut self, what: &'static str) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }
    fn f64(&mut self, what: &'static str) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }
}

/// Decode one frame from the front of `buf`.
///
/// Returns `Ok(None)` when `buf` holds an incomplete frame (read more
/// bytes), `Ok(Some((frame, consumed)))` on success, and `Err` on any
/// protocol violation. Never panics on arbitrary input.
pub fn decode(buf: &[u8]) -> Result<Option<(Frame, usize)>, WireError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
    if len > MAX_FRAME_LEN || len < HEADER_LEN + 4 {
        return Err(WireError::BadLength(len));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    let body = &buf[4..4 + len];
    let (payload, crc_bytes) = body.split_at(len - 4);
    let stored = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    let computed = crc32(payload);
    if stored != computed {
        return Err(WireError::Crc(stored, computed));
    }
    let mut c = Cursor { buf: payload, pos: 0 };
    let magic: [u8; 4] = c.take(4, "magic")?.try_into().unwrap();
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = c.u16("version")?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(WireError::BadVersion(version));
    }
    let ftype = c.u8("type")?;
    let dtype = c.u8("dtype")?;
    let id = c.u64("id")?;
    let frame = match ftype {
        1 => {
            let deadline_ms = c.u32("deadline")?;
            let n_samples = c.u32("n_samples")?;
            let sample_len = c.u32("sample_len")?;
            // v3 requests predate model identity: model 0.
            let model = if version >= 4 { c.u32("model")? } else { 0 };
            let n_vals = (n_samples as usize)
                .checked_mul(sample_len as usize)
                .filter(|n| n.checked_mul(4).is_some())
                .ok_or(WireError::Malformed("sample count overflow"))?;
            let data = match dtype {
                0 => {
                    let raw = c.take(n_vals * 4, "f32 samples")?;
                    Payload::F32(
                        raw.chunks_exact(4)
                            .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
                            .collect(),
                    )
                }
                1 => {
                    let raw = c.take(n_vals, "i8 samples")?;
                    Payload::I8(raw.iter().map(|&b| b as i8).collect())
                }
                other => return Err(WireError::BadDtype(other)),
            };
            Frame::Request { id, deadline_ms, sample_len, model, data }
        }
        2 => {
            let status = Status::from_u8(c.u8("status")?)?;
            let slot = c.u32("slot")?;
            let predicted = c.u16("predicted")?;
            let queue_us = c.u32("queue_us")?;
            let service_us = c.u32("service_us")?;
            let mac_skipped = c.f32("mac_skipped")?;
            let n_logits = c.u32("n_logits")? as usize;
            let raw = c.take(
                n_logits.checked_mul(4).ok_or(WireError::Malformed("logit count overflow"))?,
                "logits",
            )?;
            let logits = raw
                .chunks_exact(4)
                .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
                .collect();
            Frame::Response {
                id,
                slot,
                status,
                predicted,
                queue_us,
                service_us,
                mac_skipped,
                logits,
            }
        }
        3 => Frame::Cancel { id },
        4 => Frame::Ping { id },
        5 => Frame::Pong { id },
        6 => Frame::Goodbye,
        7 => {
            let budget_mj = c.f64("budget_mj")?;
            // v3 SetBudget predates per-tenant scoping: fleet-wide.
            let model = if version >= 4 { c.u32("model")? } else { FLEET_MODEL };
            Frame::SetBudget { id, budget_mj, model }
        }
        8 => {
            let scale_q8 = c.u32("scale_q8")?;
            let step = c.u32("step")?;
            let steps_total = c.u32("steps_total")?;
            let budget_mj = c.f64("budget_mj")?;
            let ewma_mj = c.f64("ewma_mj")?;
            let keep_ratio = c.f32("keep_ratio")?;
            let cache_hits = c.u64("cache_hits")?;
            let cache_misses = c.u64("cache_misses")?;
            let swaps = c.u64("swaps")?;
            let bg_pending = c.u64("bg_pending")?;
            let bg_compiled = c.u64("bg_compiled")?;
            let bg_upgrades = c.u64("bg_upgrades")?;
            let worker_panics = c.u64("worker_panics")?;
            let respawns = c.u64("respawns")?;
            let drift_trips = c.u64("drift_trips")?;
            let recalibrations = c.u64("recalibrations")?;
            // Forward-tolerant tail: a v3 frame stops here (defaults),
            // and any bytes past the fields we know are ignored so a
            // future extension does not break this parser.
            let (model, models_loaded, fleet_budget_mj) =
                if payload.len().saturating_sub(c.pos) >= 16 {
                    (c.u32("model")?, c.u32("models_loaded")?, c.f64("fleet_budget_mj")?)
                } else {
                    (0, 0, 0.0)
                };
            Frame::Stats {
                id,
                scale_q8,
                step,
                steps_total,
                budget_mj,
                ewma_mj,
                keep_ratio,
                cache_hits,
                cache_misses,
                swaps,
                bg_pending,
                bg_compiled,
                bg_upgrades,
                worker_panics,
                respawns,
                drift_trips,
                recalibrations,
                model,
                models_loaded,
                fleet_budget_mj,
            }
        }
        9 | 10 => {
            let n = c.u32("body_len")? as usize;
            let raw = c.take(n, "body")?;
            let body = String::from_utf8(raw.to_vec())
                .map_err(|_| WireError::Malformed("body not UTF-8"))?;
            if ftype == 9 {
                Frame::Scrape { id, body }
            } else {
                Frame::TraceDump { id, body }
            }
        }
        11 => {
            let model = c.u32("model")?;
            let p99_ms = c.f64("p99_ms")?;
            let keep_floor = c.f32("keep_floor")?;
            let err_ceiling = c.f32("err_ceiling")?;
            Frame::SetSlo { id, model, p99_ms, keep_floor, err_ceiling }
        }
        other => return Err(WireError::BadType(other)),
    };
    // Stats/Scrape/TraceDump/SetSlo are forward-tolerant (see above);
    // every other frame type is strict about consuming its payload
    // exactly.
    if !matches!(ftype, 8 | 9 | 10 | 11) && c.pos != payload.len() {
        return Err(WireError::Malformed("trailing bytes"));
    }
    Ok(Some((frame, 4 + len)))
}

/// Incremental decoder: feed it raw socket reads, pop whole frames.
///
/// Bytes may arrive in any chunking — a frame split across reads stays
/// buffered until it completes:
///
/// ```
/// use unit_pruner::serve::wire::{encode, Frame, FrameReader};
///
/// let bytes = encode(&Frame::Ping { id: 7 });
/// let (head, tail) = bytes.split_at(5); // mid-frame split
///
/// let mut reader = FrameReader::new();
/// reader.feed(head);
/// assert_eq!(reader.next().unwrap(), None); // incomplete: need more
/// reader.feed(tail);
/// assert_eq!(reader.next().unwrap(), Some(Frame::Ping { id: 7 }));
/// assert_eq!(reader.pending(), 0);
/// ```
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Bytes of `buf` already consumed (compacted lazily).
    start: usize,
}

impl FrameReader {
    /// An empty reader.
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Append newly received bytes.
    pub fn feed(&mut self, bytes: &[u8]) {
        // Compact before growing so the buffer stays bounded by the
        // largest in-flight frame, not the session lifetime.
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are
    /// needed, or the first protocol error encountered (after which the
    /// stream is unframed and the connection should close).
    pub fn next(&mut self) -> Result<Option<Frame>, WireError> {
        match decode(&self.buf[self.start..])? {
            Some((frame, consumed)) => {
                self.start += consumed;
                Ok(Some(frame))
            }
            None => Ok(None),
        }
    }

    /// Bytes currently buffered and not yet decoded.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let bytes = encode(&f);
        let (got, used) = decode(&bytes).unwrap().unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(got, f);
    }

    #[test]
    fn crc32_known_vectors() {
        // zlib reference values.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"hello"), 0x3610_A686);
    }

    #[test]
    fn all_frame_kinds_roundtrip() {
        roundtrip(Frame::Request {
            id: 42,
            deadline_ms: 250,
            sample_len: 4,
            model: 0,
            data: Payload::F32(vec![1.0, -2.5, 0.0, 3.25, 9.0, 8.0, 7.0, 6.0]),
        });
        roundtrip(Frame::Request {
            id: 7,
            deadline_ms: 0,
            sample_len: 3,
            model: 2, // v4 multi-tenant addressing
            data: Payload::I8(vec![-128, 0, 127]),
        });
        roundtrip(Frame::Response {
            id: 42,
            slot: 1,
            status: Status::Ok,
            predicted: 9,
            queue_us: 120,
            service_us: 480,
            mac_skipped: 0.82,
            logits: vec![0.5, -1.5, 2.0],
        });
        roundtrip(Frame::Response {
            id: 9,
            slot: WHOLE_REQUEST,
            status: Status::Rejected,
            predicted: 0,
            queue_us: 0,
            service_us: 0,
            mac_skipped: 0.0,
            logits: vec![],
        });
        // v3 terminal failure shape (worker panic).
        roundtrip(Frame::Response {
            id: 10,
            slot: WHOLE_REQUEST,
            status: Status::Failed,
            predicted: 0,
            queue_us: 0,
            service_us: 0,
            mac_skipped: 0.0,
            logits: vec![],
        });
        roundtrip(Frame::Cancel { id: 3 });
        roundtrip(Frame::Ping { id: 1 });
        roundtrip(Frame::Pong { id: 1 });
        roundtrip(Frame::Goodbye);
        roundtrip(Frame::SetBudget { id: 5, budget_mj: 3.25, model: FLEET_MODEL });
        roundtrip(Frame::SetBudget { id: 6, budget_mj: 0.0, model: 1 }); // per-tenant query
        roundtrip(Frame::Stats {
            id: 5,
            scale_q8: 712,
            step: 11,
            steps_total: 20,
            budget_mj: 3.25,
            ewma_mj: 3.31,
            keep_ratio: 0.41,
            cache_hits: 190,
            cache_misses: 12,
            swaps: 17,
            bg_pending: 1,
            bg_compiled: 9,
            bg_upgrades: 7,
            worker_panics: 2,
            respawns: 2,
            drift_trips: 1,
            recalibrations: 1,
            model: 1,
            models_loaded: 2,
            fleet_budget_mj: 6.5,
        });
        // "no governor" shape (panic counters still served)
        roundtrip(Frame::Stats {
            id: 9,
            scale_q8: 0,
            step: 0,
            steps_total: 0,
            budget_mj: 0.0,
            ewma_mj: 0.0,
            keep_ratio: 0.0,
            cache_hits: 0,
            cache_misses: 0,
            swaps: 0,
            bg_pending: 0,
            bg_compiled: 0,
            bg_upgrades: 0,
            worker_panics: 3,
            respawns: 3,
            drift_trips: 0,
            recalibrations: 0,
            model: 0,
            models_loaded: 0,
            fleet_budget_mj: 0.0,
        });
        // v5 observability admin frames: empty query + filled reply.
        roundtrip(Frame::Scrape { id: 12, body: String::new() });
        roundtrip(Frame::Scrape {
            id: 12,
            body: "# TYPE unit_inflight gauge\nunit_inflight 0\n".to_string(),
        });
        roundtrip(Frame::TraceDump { id: 13, body: String::new() });
        roundtrip(Frame::TraceDump {
            id: 13,
            body: r#"{"traceEvents":[],"displayTimeUnit":"ms"}"#.to_string(),
        });
        // v6 SLO admin frame and tenant-scoped throttle status.
        roundtrip(Frame::SetSlo {
            id: 14,
            model: 1,
            p99_ms: 50.0,
            keep_floor: 0.3,
            err_ceiling: 0.01,
        });
        roundtrip(Frame::SetSlo {
            id: 15,
            model: 0,
            p99_ms: 0.0, // disabled component
            keep_floor: 0.0,
            err_ceiling: 0.0,
        });
        roundtrip(Frame::Response {
            id: 16,
            slot: WHOLE_REQUEST,
            status: Status::Throttled,
            predicted: 0,
            queue_us: 0,
            service_us: 0,
            mac_skipped: 0.0,
            logits: vec![],
        });
    }

    #[test]
    fn incomplete_prefix_is_none_not_error() {
        let bytes = encode(&Frame::Ping { id: 5 });
        for cut in 0..bytes.len() {
            assert_eq!(decode(&bytes[..cut]).unwrap(), None, "cut at {cut}");
        }
    }

    #[test]
    fn corrupt_byte_errors_never_panics() {
        let bytes = encode(&Frame::Request {
            id: 11,
            deadline_ms: 5,
            sample_len: 2,
            model: 0,
            data: Payload::F32(vec![1.0, 2.0]),
        });
        // Flip every byte position past the length prefix in turn: all
        // must fail CRC or a structural check, none may panic or
        // silently decode.
        for i in 4..bytes.len() {
            let mut b = bytes.clone();
            b[i] ^= 0xA5;
            assert!(decode(&b).is_err(), "corruption at byte {i} went unnoticed");
        }
    }

    #[test]
    fn crafted_sample_count_overflow_is_error_not_panic() {
        // n_samples * sample_len = 2^62 - 1 passes a naive product
        // check and n_vals * 4 = 2^64 - 4 then wraps `pos + n` in the
        // cursor; the decoder must reject it, never panic.
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC);
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.push(1); // Request
        body.push(0); // f32
        body.extend_from_slice(&7u64.to_le_bytes()); // id
        body.extend_from_slice(&0u32.to_le_bytes()); // deadline
        body.extend_from_slice(&0x7FFF_FFFFu32.to_le_bytes()); // n_samples
        body.extend_from_slice(&0x8000_0001u32.to_le_bytes()); // sample_len
        body.extend_from_slice(&0u32.to_le_bytes()); // model
        let crc = crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        let mut frame = (body.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&body);
        assert!(decode(&frame).is_err());
    }

    #[test]
    fn oversized_length_prefix_rejected() {
        let mut b = vec![0u8; 8];
        b[..4].copy_from_slice(&((MAX_FRAME_LEN as u32) + 1).to_le_bytes());
        assert!(matches!(decode(&b), Err(WireError::BadLength(_))));
        // Undersized, too: smaller than header + crc can ever be.
        let mut b = vec![0u8; 24];
        b[..4].copy_from_slice(&8u32.to_le_bytes());
        assert!(matches!(decode(&b), Err(WireError::BadLength(8))));
    }

    #[test]
    fn reader_reassembles_across_arbitrary_chunking() {
        let frames = vec![
            Frame::Ping { id: 1 },
            Frame::Request {
                id: 2,
                deadline_ms: 9,
                sample_len: 2,
                model: 1,
                data: Payload::I8(vec![1, -2, 3, -4]),
            },
            Frame::Goodbye,
        ];
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend(encode(f));
        }
        for chunk in [1usize, 3, 7, 64] {
            let mut r = FrameReader::new();
            let mut got = Vec::new();
            for piece in stream.chunks(chunk) {
                r.feed(piece);
                while let Some(f) = r.next().unwrap() {
                    got.push(f);
                }
            }
            assert_eq!(got, frames, "chunk size {chunk}");
            assert_eq!(r.pending(), 0);
        }
    }

    #[test]
    fn i8_payload_dequantizes() {
        let p = Payload::I8(vec![127, -127, 0]);
        let f = p.into_f32();
        assert!((f[0] - 1.0).abs() < 1e-6);
        assert!((f[1] + 1.0).abs() < 1e-6);
        assert_eq!(f[2], 0.0);
    }

    /// Wrap a hand-built body (magic/version/type/dtype/id already
    /// inside) with its CRC and length prefix.
    fn seal(mut body: Vec<u8>) -> Vec<u8> {
        let crc = crc32(&body);
        body.extend_from_slice(&crc.to_le_bytes());
        let mut out = (body.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(&body);
        out
    }

    fn header(version: u16, ftype: u8, dtype: u8, id: u64) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&MAGIC);
        b.extend_from_slice(&version.to_le_bytes());
        b.push(ftype);
        b.push(dtype);
        b.extend_from_slice(&id.to_le_bytes());
        b
    }

    #[test]
    fn v3_request_decodes_as_model_zero() {
        // A v3 peer's Request has no model field; it must land on the
        // default model, not error.
        let mut body = header(3, 1, 0, 21);
        body.extend_from_slice(&50u32.to_le_bytes()); // deadline_ms
        body.extend_from_slice(&1u32.to_le_bytes()); // n_samples
        body.extend_from_slice(&2u32.to_le_bytes()); // sample_len
        for v in [0.5f32, -0.5] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        let (frame, _) = decode(&seal(body)).unwrap().unwrap();
        assert_eq!(
            frame,
            Frame::Request {
                id: 21,
                deadline_ms: 50,
                sample_len: 2,
                model: 0,
                data: Payload::F32(vec![0.5, -0.5]),
            }
        );
    }

    #[test]
    fn v3_setbudget_decodes_as_fleet_scope() {
        let mut body = header(3, 7, 0, 4);
        body.extend_from_slice(&2.5f64.to_le_bytes());
        let (frame, _) = decode(&seal(body)).unwrap().unwrap();
        assert_eq!(frame, Frame::SetBudget { id: 4, budget_mj: 2.5, model: FLEET_MODEL });
    }

    #[test]
    fn v3_stats_decodes_with_default_tail() {
        // v3 Stats body: the 16 known fields, no v4 tail.
        let mut body = header(3, 8, 0, 6);
        for v in [712u32, 11, 20] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        for v in [3.25f64, 3.31] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        body.extend_from_slice(&0.41f32.to_le_bytes());
        for v in [190u64, 12, 17, 1, 9, 7, 2, 2, 1, 1] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        let (frame, _) = decode(&seal(body)).unwrap().unwrap();
        match frame {
            Frame::Stats { model, models_loaded, fleet_budget_mj, scale_q8, .. } => {
                assert_eq!(scale_q8, 712);
                assert_eq!(model, 0);
                assert_eq!(models_loaded, 0);
                assert_eq!(fleet_budget_mj, 0.0);
            }
            other => panic!("expected Stats, got {other:?}"),
        }
    }

    #[test]
    fn stats_tolerates_trailing_extension() {
        // Regression: the old decoder rejected any trailing payload
        // bytes, so Stats could never grow compatibly. A hypothetical
        // v4.1 peer appending fields must still parse.
        let full = encode(&Frame::Stats {
            id: 8,
            scale_q8: 300,
            step: 4,
            steps_total: 20,
            budget_mj: 1.5,
            ewma_mj: 1.4,
            keep_ratio: 0.7,
            cache_hits: 5,
            cache_misses: 1,
            swaps: 2,
            bg_pending: 0,
            bg_compiled: 2,
            bg_upgrades: 1,
            worker_panics: 0,
            respawns: 0,
            drift_trips: 0,
            recalibrations: 0,
            model: 1,
            models_loaded: 3,
            fleet_budget_mj: 9.0,
        });
        // Rebuild the body with 12 extra bytes before the CRC.
        let body_len = full.len() - 4;
        let mut body = full[4..4 + body_len - 4].to_vec(); // strip prefix + crc
        body.extend_from_slice(&[0xAB; 12]);
        let (frame, used) = decode(&seal(body)).unwrap().unwrap();
        match frame {
            Frame::Stats { id, scale_q8, model, models_loaded, fleet_budget_mj, .. } => {
                assert_eq!((id, scale_q8, model, models_loaded), (8, 300, 1, 3));
                assert_eq!(fleet_budget_mj, 9.0);
            }
            other => panic!("expected Stats, got {other:?}"),
        }
        assert!(used > 0);
    }

    #[test]
    fn scrape_and_tracedump_tolerate_trailing_extension() {
        // The v5 admin frames opt into the same forward tolerance as
        // Stats: a future revision may append fields after the body
        // without breaking this parser.
        for ftype in [9u8, 10] {
            let mut body = header(VERSION, ftype, 0, 31);
            let text = b"unit_inflight 0\n";
            body.extend_from_slice(&(text.len() as u32).to_le_bytes());
            body.extend_from_slice(text);
            body.extend_from_slice(&[0xCD; 9]); // hypothetical v5.1 tail
            let (frame, _) = decode(&seal(body)).unwrap().unwrap();
            match frame {
                Frame::Scrape { id, body } | Frame::TraceDump { id, body } => {
                    assert_eq!(id, 31);
                    assert_eq!(body, "unit_inflight 0\n");
                }
                other => panic!("expected admin frame, got {other:?}"),
            }
        }
    }

    #[test]
    fn setslo_tolerates_trailing_extension() {
        // The v6 admin frame joins the forward-tolerant set: a future
        // revision may append objectives without breaking this parser.
        let mut body = header(VERSION, 11, 0, 40);
        body.extend_from_slice(&1u32.to_le_bytes()); // model
        body.extend_from_slice(&25.0f64.to_le_bytes()); // p99_ms
        body.extend_from_slice(&0.5f32.to_le_bytes()); // keep_floor
        body.extend_from_slice(&0.02f32.to_le_bytes()); // err_ceiling
        body.extend_from_slice(&[0xEE; 6]); // hypothetical v6.1 tail
        let (frame, _) = decode(&seal(body)).unwrap().unwrap();
        assert_eq!(
            frame,
            Frame::SetSlo { id: 40, model: 1, p99_ms: 25.0, keep_floor: 0.5, err_ceiling: 0.02 }
        );
    }

    #[test]
    fn scrape_body_must_be_utf8() {
        let mut body = header(VERSION, 9, 0, 1);
        body.extend_from_slice(&2u32.to_le_bytes());
        body.extend_from_slice(&[0xFF, 0xFE]); // invalid UTF-8
        assert_eq!(decode(&seal(body)), Err(WireError::Malformed("body not UTF-8")));
    }

    #[test]
    fn unknown_version_is_bad_version_not_generic_error() {
        // Sessions special-case BadVersion into a clean Goodbye, so the
        // decoder must report it precisely — not as Malformed/BadType.
        let mut body = header(99, 4, 0, 1);
        body.extend_from_slice(&[0u8; 0]);
        assert_eq!(decode(&seal(body)), Err(WireError::BadVersion(99)));
        let body = header(2, 4, 0, 1); // pre-MIN_VERSION peer
        assert_eq!(decode(&seal(body)), Err(WireError::BadVersion(2)));
    }
}
